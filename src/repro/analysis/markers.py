"""Hot-path markers the analyzer keys on.

``@hot_path`` declares a function part of the steady-state serving hot
path: the decode tick, wave gather/scatter, spec draft/verify rounds.
Rule R1 (``repro-lint``) then rejects any host-sync construct inside it
— ``.item()``, ``np.asarray`` on device values, ``float()``/``int()``
on device scalars, ``jax.device_get`` — unless the site carries a
``# repro-lint: ok(R1, <reason>)`` marker.  The decorator is a pure
annotation (sets ``__hot_path__`` and returns the function unchanged),
so it composes with ``jax.jit``/``jax.vmap`` and costs nothing at
runtime; it exists so the static pass and human readers agree on where
the hot path IS.

Functions that cannot carry a decorator (e.g. generated code) can be
named in ``HOT_PATH_MODULES`` instead: a mapping of module-path suffix
(POSIX, e.g. ``"core/scheduler.py"``) to the set of function names the
analyzer must treat as hot in that module.
"""
from __future__ import annotations

from typing import Callable, Dict, FrozenSet, TypeVar

F = TypeVar("F", bound=Callable)


def hot_path(fn: F) -> F:
    """Mark ``fn`` as steady-state hot-path code (see module docstring)."""
    fn.__hot_path__ = True
    return fn


# module-path suffix -> function names that are hot even without the
# decorator (reserved for functions the decorator cannot reach)
HOT_PATH_MODULES: Dict[str, FrozenSet[str]] = {}
