"""repro-lint rule R4: protocol conformance + scheduler purity.

Two halves:

* every class that DIRECTLY subclasses one of the serving protocols
  (``SequenceState`` / ``SpecOps`` / ``CollabPolicy``) must define the
  protocol's required-method surface with a compatible arity — the
  methods whose base implementation raises ``NotImplementedError``.
  (Indirect subclasses — e.g. ``RecurrentState(DenseKV)`` — inherit a
  real implementation and are out of static reach; the tier-1 parity
  tests cover them.)
* ``core/scheduler.py`` must contain ZERO knowledge of concrete KV
  layouts or model families: no ``isinstance`` against the concrete
  adapter/pool classes, no comparisons on ``.layout``/``.family``
  attributes, no ``getattr``/``hasattr`` probes for paged-pool
  internals.  This is the PR 3/5 invariant ("adding a layout or family
  never touches the scheduler"), made mechanical.

``PROTOCOL_SURFACES`` is a baked table (method -> exact positional
arity incl. ``self``); ``tests/test_analysis.py`` pins it against the
live protocol classes via ``inspect.signature`` so it cannot rot.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Tuple

from repro.analysis.core import Finding, ModuleContext, rule

# protocol -> {required method -> positional arity including self}
PROTOCOL_SURFACES: Dict[str, Dict[str, int]] = {
    "SequenceState": {"admit": 4, "finalize": 3, "detached_len": 2},
    "CollabPolicy": {"decide": 4},
    "SpecOps": {"step": 4, "extend": 4, "snapshot": 2, "commit": 6},
}

# concrete layout/pool classes the scheduler must never name
CONCRETE_STATE_CLASSES = {"DenseKV", "PagedKV", "RecurrentState",
                          "BlockPool", "ShardedBlockPool"}
# attribute probes that reach into paged-pool internals
LAYOUT_PROBE_ATTRS = {"pool", "table", "blocks", "block_size"}
SCHEDULER_SUFFIX = "core/scheduler.py"


@rule("R4", "protocol conformance: SequenceState/SpecOps/CollabPolicy "
            "subclasses define the required surface with matching arity; "
            "core/scheduler.py never branches on concrete layouts or "
            "families")
def check_protocols(ctx: ModuleContext) -> Iterable[Finding]:
    yield from _check_implementors(ctx)
    if ctx.relpath.endswith(SCHEDULER_SUFFIX):
        yield from _check_scheduler_purity(ctx)


def _check_implementors(ctx: ModuleContext) -> Iterable[Finding]:
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.ClassDef):
            continue
        for base in node.bases:
            name = base.id if isinstance(base, ast.Name) else (
                base.attr if isinstance(base, ast.Attribute) else None)
            surface = PROTOCOL_SURFACES.get(name or "")
            if not surface:
                continue
            methods = {n.name: n for n in node.body
                       if isinstance(n, ast.FunctionDef)}
            for meth, arity in surface.items():
                impl = methods.get(meth)
                if impl is None:
                    yield Finding(
                        ctx.path, node.lineno, node.col_offset, "R4",
                        f"`{node.name}` subclasses `{name}` but does not "
                        f"define required method `{meth}` — the inherited "
                        "base raises NotImplementedError at runtime")
                    continue
                lo, hi = _arity_range(impl)
                if not (lo <= arity <= hi):
                    yield Finding(
                        ctx.path, impl.lineno, impl.col_offset, "R4",
                        f"`{node.name}.{meth}` accepts {lo}..{_fmt(hi)} "
                        f"positional args but the `{name}` protocol calls "
                        f"it with {arity}")


def _arity_range(fn: ast.FunctionDef) -> Tuple[int, float]:
    args = fn.args
    pos: List[ast.arg] = list(args.posonlyargs) + list(args.args)
    hi: float = float("inf") if args.vararg else len(pos)
    lo = len(pos) - len(args.defaults)
    return lo, hi


def _fmt(hi: float) -> str:
    return "*" if hi == float("inf") else str(int(hi))


def _check_scheduler_purity(ctx: ModuleContext) -> Iterable[Finding]:
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call):
            fname = node.func.id if isinstance(node.func, ast.Name) else None
            if fname == "isinstance" and len(node.args) == 2:
                classes = (node.args[1].elts
                           if isinstance(node.args[1], ast.Tuple)
                           else [node.args[1]])
                for c in classes:
                    cname = c.id if isinstance(c, ast.Name) else (
                        c.attr if isinstance(c, ast.Attribute) else None)
                    if cname in CONCRETE_STATE_CLASSES:
                        yield Finding(
                            ctx.path, node.lineno, node.col_offset, "R4",
                            f"scheduler isinstance-checks concrete state "
                            f"class `{cname}` — route through the "
                            "SequenceState protocol instead")
            elif (fname in ("getattr", "hasattr") and len(node.args) >= 2
                    and isinstance(node.args[1], ast.Constant)
                    and node.args[1].value in LAYOUT_PROBE_ATTRS):
                yield Finding(
                    ctx.path, node.lineno, node.col_offset, "R4",
                    f"scheduler probes layout internals via "
                    f"`{fname}(..., {node.args[1].value!r})` — add the "
                    "query to the SequenceState protocol instead")
        elif isinstance(node, ast.Compare):
            for side in [node.left] + node.comparators:
                if (isinstance(side, ast.Attribute)
                        and side.attr in ("layout", "family")):
                    yield Finding(
                        ctx.path, node.lineno, node.col_offset, "R4",
                        f"scheduler compares `.{side.attr}` — layout/"
                        "family dispatch belongs behind SequenceState/"
                        "Lane, not in the scheduler")
