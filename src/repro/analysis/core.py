"""repro-lint core: findings, suppressions, the rule registry, and the
file/tree walkers.

A rule is a callable ``(ModuleContext) -> Iterable[Finding]`` registered
under a stable id (``R1``..``R4``).  Suppression is per-line and
per-rule: a finding at line ``L`` is dropped when line ``L`` or line
``L - 1`` carries ``# repro-lint: ok(<rule>, <reason>)`` with a
non-empty reason.  A marker WITHOUT a reason never suppresses anything
and is itself reported (rule ``R0``), so every shipped suppression
documents why the construct is deliberate.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set

from repro.analysis.markers import HOT_PATH_MODULES

SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*ok\(\s*([A-Za-z0-9_]+)\s*(?:,\s*([^)]*?)\s*)?\)")
# a marker that LOOKS like a suppression but doesn't parse (wrong spelling,
# missing parens) — flagged so typos don't silently stop suppressing
SUPPRESS_LIKE_RE = re.compile(r"#\s*repro-lint\b")

PY_EXTENSIONS = (".py",)
SKIP_DIRS = {"__pycache__", ".git", ".ruff_cache", ".pytest_cache", "build"}


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    col: int
    rule: str
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class ModuleContext:
    """Per-file analysis state shared by every rule: the parsed tree, raw
    lines, hot-path function set, and the jit registry (function ->
    static-arg names) rules R1/R2 consume."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.relpath = Path(path).as_posix()
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self._attach_parents()
        self.suppressions: Dict[int, Set[str]] = {}
        self.bare_markers: List[int] = []
        self._scan_markers()
        self.hot_functions = self._find_hot_functions()
        self.jit_static: Dict[ast.AST, Set[str]] = {}
        self.jit_aliases: Dict[str, Set[str]] = {}
        self._find_jitted()

    # ---------------------------------------------------------- structure
    def _attach_parents(self):
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                child._rl_parent = node

    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return getattr(node, "_rl_parent", None)

    def enclosing_functions(self, node: ast.AST):
        cur = self.parent(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda)):
                yield cur
            cur = self.parent(cur)

    # --------------------------------------------------------- suppression
    def _scan_markers(self):
        # only COMMENT tokens count — docstrings that merely describe the
        # marker syntax are not markers
        try:
            tokens = tokenize.generate_tokens(
                io.StringIO(self.source).readline)
            comments = [(t.start[0], t.string) for t in tokens
                        if t.type == tokenize.COMMENT]
        except (tokenize.TokenError, IndentationError):
            comments = []
        for i, text in comments:
            if not SUPPRESS_LIKE_RE.search(text):
                continue
            matched = False
            for m in SUPPRESS_RE.finditer(text):
                matched = True
                rule, reason = m.group(1), (m.group(2) or "").strip()
                if reason:
                    self.suppressions.setdefault(i, set()).add(rule)
                else:
                    self.bare_markers.append(i)
            if not matched:
                self.bare_markers.append(i)

    def suppressed(self, line: int, rule: str) -> bool:
        for ln in (line, line - 1):
            if rule in self.suppressions.get(ln, ()):
                return True
        return False

    # ----------------------------------------------------------- hot paths
    def _find_hot_functions(self) -> Set[ast.AST]:
        allow: Set[str] = set()
        for suffix, names in HOT_PATH_MODULES.items():
            if self.relpath.endswith(suffix):
                allow |= set(names)
        hot: Set[ast.AST] = set()
        for node in ast.walk(self.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if node.name in allow or any(
                    _name_is(d, "hot_path") for d in node.decorator_list):
                hot.add(node)
        # hot-ness extends into lexically nested functions
        grew = True
        while grew:
            grew = False
            for node in ast.walk(self.tree):
                if (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                        and node not in hot
                        and any(f in hot
                                for f in self.enclosing_functions(node))):
                    hot.add(node)
                    grew = True
        return hot

    def in_hot_function(self, node: ast.AST) -> bool:
        return any(f in self.hot_functions
                   for f in self.enclosing_functions(node))

    # ------------------------------------------------------------ jit info
    def _find_jitted(self):
        """Map jitted functions/lambdas to their static-arg name sets, and
        record the names/attrs jitted callables are bound to so R2 can
        check call sites for unhashable static args.

        Recognized forms: ``@jax.jit`` / ``@jit`` decorators (bare or via
        ``functools.partial``), and ``X = jax.jit(fn_or_lambda, ...)``
        assignments where the target is a plain name or ``self.<attr>``.
        """
        defs_by_name: Dict[str, List[ast.AST]] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs_by_name.setdefault(node.name, []).append(node)

        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    statics = _jit_statics_from(dec, node)
                    if statics is not None:
                        self.jit_static[node] = statics
            elif isinstance(node, ast.Assign):
                call = node.value
                if not (isinstance(call, ast.Call)
                        and _name_is(call.func, "jit") and call.args):
                    continue
                fn_arg = call.args[0]
                target_fn: Optional[ast.AST] = None
                if isinstance(fn_arg, ast.Lambda):
                    target_fn = fn_arg
                elif isinstance(fn_arg, ast.Name):
                    cands = defs_by_name.get(fn_arg.id, [])
                    if len(cands) == 1:
                        target_fn = cands[0]
                if target_fn is None:
                    continue
                statics = _static_names(call, target_fn)
                self.jit_static[target_fn] = statics
                for tgt in node.targets:
                    name = None
                    if isinstance(tgt, ast.Name):
                        name = tgt.id
                    elif isinstance(tgt, ast.Attribute):
                        name = tgt.attr
                    if name:
                        self.jit_aliases.setdefault(name, set()).update(
                            statics)

    def traced_params(self, fn: ast.AST) -> Set[str]:
        """Param names of a registered jitted function that are traced
        (everything positional except ``self`` and the static args)."""
        statics = self.jit_static.get(fn)
        if statics is None:
            return set()
        args = fn.args
        names = [a.arg for a in args.posonlyargs + args.args]
        return {n for n in names if n != "self"} - statics


def _name_is(node: ast.AST, name: str) -> bool:
    """True when ``node`` is ``name``, ``x.name``, or a
    ``functools.partial(x.name, ...)`` wrapper of either."""
    if isinstance(node, ast.Name):
        return node.id == name
    if isinstance(node, ast.Attribute):
        return node.attr == name
    if (isinstance(node, ast.Call) and _name_is(node.func, "partial")
            and node.args):
        return _name_is(node.args[0], name)
    return False


def _jit_statics_from(dec: ast.AST, fn: ast.AST) -> Optional[Set[str]]:
    """Static-arg names when ``dec`` is a jit decorator, else None."""
    if isinstance(dec, (ast.Name, ast.Attribute)) and _name_is(dec, "jit"):
        return set()
    if isinstance(dec, ast.Call):
        if _name_is(dec.func, "jit"):
            return _static_names(dec, fn)
        if (_name_is(dec.func, "partial") and dec.args
                and _name_is(dec.args[0], "jit")):
            return _static_names(dec, fn)
    return None


def _static_names(call: ast.Call, fn: ast.AST) -> Set[str]:
    statics: Set[str] = set()
    pos_names = ([a.arg for a in fn.args.posonlyargs + fn.args.args]
                 if hasattr(fn, "args") else [])
    for kw in call.keywords:
        vals: Sequence[ast.AST]
        if isinstance(kw.value, (ast.Tuple, ast.List)):
            vals = kw.value.elts
        else:
            vals = [kw.value]
        if kw.arg == "static_argnames":
            statics |= {v.value for v in vals
                        if isinstance(v, ast.Constant)
                        and isinstance(v.value, str)}
        elif kw.arg == "static_argnums":
            for v in vals:
                if (isinstance(v, ast.Constant) and isinstance(v.value, int)
                        and 0 <= v.value < len(pos_names)):
                    statics.add(pos_names[v.value])
    return statics


# ---------------------------------------------------------------- registry
Rule = Callable[[ModuleContext], Iterable[Finding]]
RULES: Dict[str, Rule] = {}
RULE_DOCS: Dict[str, str] = {}


def rule(rule_id: str, doc: str):
    def register(fn: Rule) -> Rule:
        RULES[rule_id] = fn
        RULE_DOCS[rule_id] = doc
        return fn
    return register


@rule("R0", "suppression hygiene: every `# repro-lint: ok(...)` marker "
            "must name a rule and carry a non-empty reason")
def check_markers(ctx: ModuleContext) -> Iterable[Finding]:
    for line in ctx.bare_markers:
        yield Finding(ctx.path, line, 0, "R0",
                      "repro-lint marker without `ok(<rule>, <reason>)` — "
                      "a reasonless marker suppresses nothing")


# ---------------------------------------------------------------- analysis
def analyze_source(path: str, source: str,
                   rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run the selected rules (default: all) over one file's source."""
    try:
        ctx = ModuleContext(path, source)
    except SyntaxError as e:
        return [Finding(path, e.lineno or 0, e.offset or 0, "E0",
                        f"syntax error: {e.msg}")]
    selected = list(RULES) if rules is None else list(rules)
    out: List[Finding] = []
    for rid in selected:
        if rid not in RULES:
            raise KeyError(f"unknown rule {rid!r}; known: {sorted(RULES)}")
        for f in RULES[rid](ctx):
            if not ctx.suppressed(f.line, f.rule):
                out.append(f)
    out.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return out


def analyze_file(path, rules: Optional[Sequence[str]] = None) -> List[Finding]:
    p = Path(path)
    return analyze_source(str(p), p.read_text(), rules)


def iter_python_files(paths: Sequence) -> List[Path]:
    files: List[Path] = []
    for raw in paths:
        p = Path(raw)
        if p.is_dir():
            files.extend(
                f for f in sorted(p.rglob("*.py"))
                if not (set(f.parts) & SKIP_DIRS))
        elif p.suffix in PY_EXTENSIONS:
            files.append(p)
    return files


def analyze_paths(paths: Sequence,
                  rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Analyze every ``.py`` under ``paths`` (files or directories)."""
    out: List[Finding] = []
    for f in iter_python_files(paths):
        out.extend(analyze_file(f, rules))
    return out
