"""repro-lint rules R1-R3: hot-path purity, recompile hazards, Pallas
kernel hygiene.  R4 (protocol conformance) lives in ``protocol.py``.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set

from repro.analysis.core import Finding, ModuleContext, _name_is, rule

# attributes of a traced value that are static under trace — branching on
# them never retraces
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding", "nbytes",
                "itemsize", "weak_type"}
# call roots allowed inside a BlockSpec index map: trace-safe arithmetic
INDEX_MAP_ROOTS = {"jnp", "jax", "lax", "pl", "pltpu", "min", "max", "abs",
                   "divmod", "int", "sum", "len", "functools", "partial"}


# ------------------------------------------------------------------- R1
@rule("R1", "no host syncs on the hot path: `.item()`, `np.asarray` on "
            "device values, `float()`/`int()` on device scalars, "
            "`device_get`/`block_until_ready` inside @hot_path functions")
def check_host_sync(ctx: ModuleContext) -> Iterable[Finding]:
    if not ctx.hot_functions:
        return
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call) or not ctx.in_hot_function(node):
            continue
        msg = _host_sync_message(node)
        if msg:
            yield Finding(ctx.path, node.lineno, node.col_offset, "R1", msg)


def _host_sync_message(call: ast.Call) -> Optional[str]:
    fn = call.func
    if isinstance(fn, ast.Attribute):
        if fn.attr == "item" and not call.args:
            return "`.item()` forces a device->host sync"
        if fn.attr == "block_until_ready":
            return "`.block_until_ready()` stalls the dispatch pipeline"
        if (fn.attr == "asarray" and isinstance(fn.value, ast.Name)
                and fn.value.id in ("np", "numpy")):
            return ("`np.asarray(...)` on a device value is an implicit "
                    "device->host sync; batch it into one explicit "
                    "`jax.device_get` per wave (use `np.array` for "
                    "host-list conversions)")
        if (fn.attr in ("device_get", "block_until_ready")
                and isinstance(fn.value, ast.Name)
                and fn.value.id == "jax"):
            return (f"`jax.{fn.attr}` syncs host and device — allowed only "
                    "as the single batched pull per wave (suppress with a "
                    "reason)")
    elif isinstance(fn, ast.Name):
        if fn.id == "device_get":
            return ("`device_get` syncs host and device — allowed only as "
                    "the single batched pull per wave (suppress with a "
                    "reason)")
        if fn.id in ("float", "int", "bool") and len(call.args) == 1:
            arg = call.args[0]
            if isinstance(arg, ast.Call) and _host_sync_message(arg):
                return (f"`{fn.id}(...)` over a syncing call — double "
                        "host pull")
            if isinstance(arg, ast.Call) and isinstance(
                    arg.func, ast.Attribute) and arg.func.attr in (
                    "sum", "mean", "max", "min", "argmax", "argmin"):
                return (f"`{fn.id}(array.{arg.func.attr}())` pulls a "
                        "device scalar to host")
    return None


# ------------------------------------------------------------------- R2
@rule("R2", "no recompile hazards in jitted code: Python branching or "
            "f-strings on traced params, unhashable static args at jit "
            "call sites, shape-dependent Python loops")
def check_recompile_hazards(ctx: ModuleContext) -> Iterable[Finding]:
    for fn, _ in ctx.jit_static.items():
        traced = ctx.traced_params(fn)
        if not traced:
            continue
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        for stmt in body:
            yield from _scan_traced_use(ctx, stmt, traced, fn)
    yield from _check_static_call_sites(ctx)


def _scan_traced_use(ctx: ModuleContext, root: ast.AST, traced: Set[str],
                     fn: ast.AST) -> Iterable[Finding]:
    # nested defs (scan bodies, vmapped closures) are traced too, so the
    # walk descends into them; shadowed names can in principle false-
    # positive, which is what the suppression markers are for
    for node in ast.walk(root):
        if isinstance(node, (ast.If, ast.While)):
            name = _traced_ref(node.test, traced)
            if name:
                kind = "if" if isinstance(node, ast.If) else "while"
                yield Finding(
                    ctx.path, node.lineno, node.col_offset, "R2",
                    f"Python `{kind}` on traced param `{name}` retraces "
                    "per value — use `jnp.where`/`lax.cond` or mark the "
                    "param static")
        elif isinstance(node, ast.IfExp):
            name = _traced_ref(node.test, traced)
            if name:
                yield Finding(
                    ctx.path, node.lineno, node.col_offset, "R2",
                    f"conditional expression on traced param `{name}` "
                    "retraces per value — use `jnp.where`")
        elif isinstance(node, ast.JoinedStr):
            for val in node.values:
                if isinstance(val, ast.FormattedValue):
                    name = _traced_ref(val.value, traced)
                    if name:
                        yield Finding(
                            ctx.path, node.lineno, node.col_offset, "R2",
                            f"f-string formats traced param `{name}` — "
                            "forces a trace-time value read")
        elif isinstance(node, ast.For):
            name = _loop_over_traced(node.iter, traced)
            if name:
                yield Finding(
                    ctx.path, node.lineno, node.col_offset, "R2",
                    f"Python loop over traced param `{name}` unrolls "
                    "per value — use `lax.scan`/`lax.fori_loop`")


def _traced_ref(expr: ast.AST, traced: Set[str]) -> Optional[str]:
    """Name of a traced param whose VALUE the expression depends on, or
    None.  References through static attributes (``x.shape``...), through
    ``len(x)``/``isinstance(x, ...)`` and identity tests (``x is None``)
    are static under trace and excluded."""
    for node in ast.walk(expr):
        if isinstance(node, ast.Compare) and all(
                isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            return None
        if not isinstance(node, ast.Name) or node.id not in traced:
            continue
        parent = getattr(node, "_rl_parent", None)
        if (isinstance(parent, ast.Attribute) and parent.value is node
                and parent.attr in STATIC_ATTRS):
            continue
        if (isinstance(parent, ast.Call) and node in parent.args
                and isinstance(parent.func, ast.Name)
                and parent.func.id in ("len", "isinstance", "type")):
            continue
        return node.id
    return None


def _loop_over_traced(it: ast.AST, traced: Set[str]) -> Optional[str]:
    if isinstance(it, ast.Call) and _name_is(it.func, "range"):
        for arg in it.args:
            name = _traced_ref(arg, traced)
            if name:
                return name
        return None
    if isinstance(it, ast.Name) and it.id in traced:
        return it.id
    return None


_UNHASHABLE = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
               ast.SetComp, ast.GeneratorExp)


def _check_static_call_sites(ctx: ModuleContext) -> Iterable[Finding]:
    if not ctx.jit_aliases:
        return
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = None
        if isinstance(node.func, ast.Name):
            name = node.func.id
        elif isinstance(node.func, ast.Attribute):
            name = node.func.attr
        statics = ctx.jit_aliases.get(name)
        if not statics:
            continue
        for kw in node.keywords:
            if kw.arg in statics and isinstance(kw.value, _UNHASHABLE):
                yield Finding(
                    ctx.path, node.lineno, node.col_offset, "R2",
                    f"unhashable value for static arg `{kw.arg}` of jitted "
                    f"`{name}` — every call raises or retraces; pass a "
                    "tuple/scalar")


# ------------------------------------------------------------------- R3
@rule("R3", "Pallas hygiene: pure BlockSpec index maps, side-effect-free "
            "kernel bodies, and a `ref.py` oracle + interpret-mode "
            "dispatch for every kernel entry point")
def check_pallas(ctx: ModuleContext) -> Iterable[Finding]:
    if "pallas" not in ctx.source:
        return
    defs_by_name: Dict[str, List[ast.FunctionDef]] = {}
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.FunctionDef):
            defs_by_name.setdefault(node.name, []).append(node)

    kernel_names: Set[str] = set()
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        if _name_is(node.func, "BlockSpec"):
            yield from _check_index_map(ctx, node, defs_by_name)
        elif _name_is(node.func, "pallas_call") and node.args:
            kname = _callable_name(node.args[0])
            if kname:
                kernel_names.add(kname)
                for kdef in defs_by_name.get(kname, []):
                    yield from _check_kernel_body(ctx, kdef)
    if kernel_names:
        yield from _check_oracle_and_interpret(ctx)


def _callable_name(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if (isinstance(node, ast.Call) and _name_is(node.func, "partial")
            and node.args and isinstance(node.args[0], ast.Name)):
        return node.args[0].id
    return None


def _check_index_map(ctx: ModuleContext, call: ast.Call,
                     defs_by_name) -> Iterable[Finding]:
    imap: Optional[ast.AST] = None
    if len(call.args) >= 2:
        imap = call.args[1]
    for kw in call.keywords:
        if kw.arg == "index_map":
            imap = kw.value
    if imap is None:
        return
    body: List[ast.AST]
    if isinstance(imap, ast.Lambda):
        body = [imap.body]
    elif isinstance(imap, ast.Name):
        defs = defs_by_name.get(imap.id, [])
        if not defs:
            return
        body = defs[0].body
    else:
        return
    for stmt in body:
        for node in ast.walk(stmt):
            bad = _index_map_impurity(node)
            if bad:
                yield Finding(ctx.path, node.lineno, node.col_offset, "R3",
                              f"BlockSpec index map must be a pure function "
                              f"of grid indices: {bad}")


def _index_map_impurity(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Call):
        root = node.func
        while isinstance(root, ast.Attribute):
            root = root.value
        if isinstance(root, ast.Name) and root.id not in INDEX_MAP_ROOTS:
            return f"calls `{ast.unparse(node.func)}`"
    if isinstance(node, (ast.Global, ast.Nonlocal)):
        return "rebinds an outer name"
    if isinstance(node, (ast.Assign, ast.AugAssign)):
        targets = (node.targets if isinstance(node, ast.Assign)
                   else [node.target])
        for t in targets:
            if isinstance(t, (ast.Attribute, ast.Subscript)):
                return "writes through an attribute/subscript"
    return None


def _check_kernel_body(ctx: ModuleContext,
                       kdef: ast.FunctionDef) -> Iterable[Finding]:
    for node in ast.walk(kdef):
        if isinstance(node, (ast.Global, ast.Nonlocal)):
            yield Finding(ctx.path, node.lineno, node.col_offset, "R3",
                          "kernel body rebinds an outer name — Pallas "
                          "kernels must be side-effect-free")
        elif (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id in ("print", "open", "input")):
            yield Finding(ctx.path, node.lineno, node.col_offset, "R3",
                          f"kernel body calls `{node.func.id}` — Python "
                          "side effects don't exist on device and break "
                          "interpret-mode parity")


def _check_oracle_and_interpret(ctx: ModuleContext) -> Iterable[Finding]:
    """Every public entry point wrapping a `pallas_call` needs an
    `interpret` kwarg (CPU/CI dispatch) and a `<name>_ref` oracle in the
    sibling `ref.py`."""
    ref_names = _ref_oracle_names(Path(ctx.path).parent)
    for node in ctx.tree.body:
        if not isinstance(node, ast.FunctionDef) or node.name.startswith("_"):
            continue
        if not any(isinstance(n, ast.Call)
                   and _name_is(n.func, "pallas_call")
                   for n in ast.walk(node)):
            continue
        params = {a.arg for a in node.args.args + node.args.kwonlyargs}
        if "interpret" not in params:
            yield Finding(ctx.path, node.lineno, node.col_offset, "R3",
                          f"kernel entry `{node.name}` has no `interpret` "
                          "parameter — CPU CI cannot dispatch it")
        if f"{node.name}_ref" not in (ref_names or set()):
            where = ("ref.py" if ref_names is not None
                     else "a sibling ref.py (missing)")
            yield Finding(ctx.path, node.lineno, node.col_offset, "R3",
                          f"kernel entry `{node.name}` has no "
                          f"`{node.name}_ref` oracle in {where}")


_REF_CACHE: Dict[str, Optional[Set[str]]] = {}


def _ref_oracle_names(directory: Path) -> Optional[Set[str]]:
    key = str(directory)
    if key not in _REF_CACHE:
        ref = directory / "ref.py"
        if not ref.is_file():
            _REF_CACHE[key] = None
        else:
            tree = ast.parse(ref.read_text())
            _REF_CACHE[key] = {n.name for n in tree.body
                               if isinstance(n, ast.FunctionDef)}
    return _REF_CACHE[key]
