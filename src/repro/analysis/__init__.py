"""repro-lint: static analysis enforcing the repo's serving invariants.

Rules (see ``scripts/repro_lint.py --help`` and the per-rule docs):

* **R1** — no host syncs inside ``@hot_path`` functions.
* **R2** — no recompile hazards in jitted code.
* **R3** — Pallas kernel hygiene (pure index maps, side-effect-free
  bodies, ref.py oracle + interpret dispatch).
* **R4** — protocol conformance and scheduler layout/family purity.
* **R0** — suppression markers must carry a reason.

This package deliberately avoids importing ``jax`` at top level so that
production modules can import ``hot_path`` for free; the runtime
compile counter lives in ``repro.analysis.compile_guard``.
"""
from repro.analysis.core import (Finding, RULE_DOCS, RULES, analyze_file,
                                 analyze_paths, analyze_source)
from repro.analysis.markers import hot_path

# importing the rule modules populates the registry
from repro.analysis import protocol as _protocol  # noqa: F401
from repro.analysis import rules as _rules  # noqa: F401

__all__ = ["Finding", "RULES", "RULE_DOCS", "analyze_file", "analyze_paths",
           "analyze_source", "hot_path"]
