"""Runtime complement to the static pass: a compile counter built on
``jax.log_compiles``.

``CompileCounter`` is a context manager that turns on JAX's
compile-event logging and counts every "Compiling ..." record emitted
under the ``jax`` logger hierarchy while it is active.  The serving
invariant it enforces: warm-up ticks may compile (``count > 0``), but
the steady-state decode loop must not (``reset()`` then drive identical-
shape ticks and assert ``count == 0``) — one silent retrace inside the
tick loop corrupts every latency number the bench asserts.

Used by the ``compile_counter`` pytest fixture (``tests/conftest.py``)
and by the ``compile_stability`` arm of ``benchmarks/bench_serving.py``
(the ``decode_compiles`` / ``steady_state_recompiles`` fields of
``BENCH_serving.json``).
"""
from __future__ import annotations

import logging
from typing import List


class CompileCounter(logging.Handler):
    """Count XLA compilations while the context is active.

    >>> with CompileCounter() as cc:
    ...     warm_up()          # compiles: cc.count > 0
    ...     cc.reset()
    ...     steady_state()     # must not: cc.count == 0
    """

    _MARKER = "Compiling "

    def __init__(self):
        super().__init__(level=logging.DEBUG)
        self.events: List[str] = []
        self._log_ctx = None

    @property
    def count(self) -> int:
        return len(self.events)

    def reset(self):
        self.events = []

    # ----------------------------------------------------- logging.Handler
    def emit(self, record: logging.LogRecord):
        msg = record.getMessage()
        if msg.startswith(self._MARKER):
            self.events.append(msg.split(" with ")[0])

    # ---------------------------------------------------- context manager
    def __enter__(self) -> "CompileCounter":
        import jax

        self._log_ctx = jax.log_compiles()
        self._log_ctx.__enter__()
        logging.getLogger("jax").addHandler(self)
        return self

    def __exit__(self, *exc):
        logging.getLogger("jax").removeHandler(self)
        ctx, self._log_ctx = self._log_ctx, None
        ctx.__exit__(*exc)
        return False
