"""Data pipeline: synthetic LM corpora + non-IID federated partitioning
(survey §4: LEAF/FedNLP-style heterogeneity without shipping datasets).

The synthetic corpus is a mixture of per-"domain" Markov chains over the
vocabulary — learnable structure (a model CAN reduce loss below uniform) and
controllable inter-client divergence via Dirichlet mixing (FedNLP's split).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    n_domains: int = 4
    order_vocab: int = 256     # active sub-vocabulary per domain
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.domain_vocab = [
            rng.choice(self.vocab_size, size=min(self.order_vocab,
                                                 self.vocab_size),
                       replace=False)
            for _ in range(self.n_domains)]
        # sparse per-domain bigram transition: each symbol -> few successors
        self.trans = []
        for d in range(self.n_domains):
            V = len(self.domain_vocab[d])
            succ = rng.integers(0, V, size=(V, 4))
            probs = rng.dirichlet(np.ones(4) * 0.5, size=V)
            self.trans.append((succ, probs))

    def sample(self, rng: np.random.Generator, domain: int, length: int
               ) -> np.ndarray:
        succ, probs = self.trans[domain]
        vocab = self.domain_vocab[domain]
        V = len(vocab)
        s = rng.integers(0, V)
        out = np.empty(length, np.int64)
        for i in range(length):
            out[i] = s
            s = succ[s, rng.choice(4, p=probs[s])]
        return vocab[out]


def batches(cfg, batch: int, seq: int, *, domain_weights=None, seed: int = 0,
            model_cfg=None, synth: Optional[SyntheticLM] = None
            ) -> Iterator[Dict]:
    """Infinite iterator of {"tokens", "labels"} (+ stub inputs per family)."""
    import jax.numpy as jnp
    synth = synth or SyntheticLM(cfg.vocab_size)
    rng = np.random.default_rng(seed)
    w = np.asarray(domain_weights if domain_weights is not None
                   else np.ones(synth.n_domains) / synth.n_domains)
    w = w / w.sum()
    s_text = seq
    if cfg.family == "vlm":
        s_text = max(seq - cfg.num_image_tokens, 8)
    while True:
        toks = np.stack([synth.sample(rng, rng.choice(len(w), p=w), s_text)
                         for _ in range(batch)])
        out = {"tokens": jnp.asarray(toks, jnp.int32),
               "labels": jnp.asarray(toks, jnp.int32)}
        if cfg.family == "vlm":
            out["embeds"] = jnp.asarray(
                rng.standard_normal((batch, cfg.num_image_tokens, cfg.d_model)),
                jnp.float32)
        if cfg.family == "encdec":
            out["frames"] = jnp.asarray(
                rng.standard_normal((batch, cfg.encoder_seq, cfg.d_model)),
                jnp.float32)
        yield out


def dirichlet_clients(n_clients: int, n_domains: int, alpha: float = 0.3,
                      seed: int = 0) -> List[np.ndarray]:
    """FedNLP-style non-IID client mixtures: each client's domain weights
    ~ Dirichlet(alpha). Small alpha = more skew."""
    rng = np.random.default_rng(seed)
    return [rng.dirichlet(np.ones(n_domains) * alpha) for _ in range(n_clients)]


def client_divergence(weights: List[np.ndarray]) -> float:
    """Mean pairwise total-variation distance between client mixtures."""
    n = len(weights)
    tv = [0.5 * np.abs(weights[i] - weights[j]).sum()
          for i in range(n) for j in range(i + 1, n)]
    return float(np.mean(tv)) if tv else 0.0
