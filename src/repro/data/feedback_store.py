"""Serve-time feedback capture: the training side of the serving loop.

Every completed request that involved the cloud already produced a
supervision triple — the prompt, the edge draft the policy rejected (or
accepted), and the cloud-corrected continuation — and the cloud-regen
paths even paid for full teacher logits along the way.  ``FeedbackStore``
is the bounded ring buffer those triples retire into: the scheduler's
``_finish`` path appends ONE host-resident record per completion (all
fields come off the wave's single designated ``jax.device_get`` — capture
never adds a sync), and ``core/adaptation.py`` periodically assembles
padded ``{"tokens", "labels"}`` batches from it, following the
``data/pipeline.py::batches`` conventions, to take background
distillation / LoRA steps.

Records carry a ``domain`` tag (caller-assigned workload domain, e.g. the
``SyntheticLM`` chain a prompt was sampled from) and an ``sla`` tag
(realized deadline outcome: ``"met"`` / ``"missed"`` / ``"none"`` when no
SLO is configured), so adaptation can be sliced per domain or per SLA
class.  The buffer is bounded: once ``capacity`` records are held, each
append evicts the oldest (``evicted`` counts them).

Teacher supervision is stored SPARSE — per generated position, the
top-k logit values and their vocab indices, exactly what the cloud
decode's scan emitted — and scattered to a dense ``(B, S, V)`` tensor
plus a position mask only at batch-assembly time (``kd_mask`` feeds
``training/distillation.kd_loss``; positions without teacher data carry
zero KL weight).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

#: logit fill for vocab entries outside the stored top-k: small enough to
#: carry ~zero probability mass after the KD temperature softmax, large
#: enough to keep `exp` finite (no -inf -> nan under log_softmax)
TOPK_FILL = -30.0


@dataclasses.dataclass
class FeedbackTriple:
    """One completion's supervision record (all host-resident numpy)."""
    prompt: np.ndarray                      # (P,) int32 prompt tokens
    tokens: np.ndarray                      # (C,) int32 corrected continuation
    draft: Optional[np.ndarray] = None      # (D,) int32 edge draft (may = tokens)
    teacher_values: Optional[np.ndarray] = None   # (C', k) f32 top-k logits
    teacher_indices: Optional[np.ndarray] = None  # (C', k) int32 vocab ids
    domain: Optional[int] = None            # workload domain tag
    sla: str = "none"                       # met | missed | none
    path: str = "edge"                      # serving path that produced it


class FeedbackStore:
    """Bounded ring buffer of ``FeedbackTriple`` records with padded-batch
    assembly (see the module docstring)."""

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._buf: collections.deque = collections.deque(maxlen=capacity)
        self.added = 0
        self.evicted = 0
        self._domain_counts: Dict[str, int] = {}
        self._sla_counts: Dict[str, int] = {}
        self._path_counts: Dict[str, int] = {}

    # ------------------------------------------------------------ capture
    def add(self, prompt, tokens, *, draft=None, teacher_topk=None,
            domain: Optional[int] = None, sla: str = "none",
            path: str = "edge") -> None:
        """Append one completion.  ``teacher_topk`` is an optional
        ``(values, indices)`` pair of per-generated-position top-k arrays
        (shape ``(C', k)``) as emitted by the cloud decode scan."""
        tv = ti = None
        if teacher_topk is not None:
            tv = np.asarray(teacher_topk[0], np.float32)
            ti = np.asarray(teacher_topk[1], np.int32)
        if len(self._buf) == self.capacity:
            self.evicted += 1
        self._buf.append(FeedbackTriple(
            prompt=np.asarray(prompt, np.int32).reshape(-1),
            tokens=np.asarray(tokens, np.int32).reshape(-1),
            draft=None if draft is None
            else np.asarray(draft, np.int32).reshape(-1),
            teacher_values=tv, teacher_indices=ti,
            domain=domain, sla=sla, path=path))
        self.added += 1
        key = "untagged" if domain is None else str(domain)
        self._domain_counts[key] = self._domain_counts.get(key, 0) + 1
        self._sla_counts[sla] = self._sla_counts.get(sla, 0) + 1
        self._path_counts[path] = self._path_counts.get(path, 0) + 1

    def __len__(self) -> int:
        return len(self._buf)

    def records(self) -> List[FeedbackTriple]:
        """Current ring contents, oldest first."""
        return list(self._buf)

    def stats(self) -> Dict[str, object]:
        return {"size": len(self._buf), "capacity": self.capacity,
                "added": self.added, "evicted": self.evicted,
                "by_domain": dict(self._domain_counts),
                "by_sla": dict(self._sla_counts),
                "by_path": dict(self._path_counts)}

    # ------------------------------------------------------------ batches
    def sample_batch(self, rng: np.random.Generator, batch: int, seq: int,
                     vocab_size: int, *, topk: int = 0,
                     domains: Optional[Sequence[int]] = None) -> Dict:
        """Assemble a padded training batch (``data/pipeline.py`` shapes):
        ``tokens``/``labels`` are ``(batch, seq)`` int32 with labels -1 on
        prompt and pad positions (only the corrected continuation is
        supervised — ``models.model.cross_entropy`` ignores -1).  With
        ``topk > 0`` the batch also carries ``teacher_logits`` (``(batch,
        seq, vocab)`` f32, stored top-k scattered, ``TOPK_FILL``
        elsewhere) and ``kd_mask`` (``(batch, seq)`` bool, True exactly
        where teacher data exists) for ``kd_loss``.  Sampling is uniform
        WITH replacement so the batch shape is fixed regardless of ring
        occupancy — the jitted train step compiles once.  ``domains``
        optionally restricts sampling to the tagged subset (falls back to
        the whole ring when the subset is empty)."""
        if not self._buf:
            raise ValueError("feedback store is empty")
        pool = list(self._buf)
        if domains is not None:
            sub = [r for r in pool if r.domain in set(domains)]
            pool = sub or pool
        picks = [pool[i] for i in rng.integers(0, len(pool), size=batch)]
        import jax.numpy as jnp
        toks = np.zeros((batch, seq), np.int32)
        labels = np.full((batch, seq), -1, np.int32)
        out: Dict = {}
        if topk:
            teacher = np.full((batch, seq, vocab_size), TOPK_FILL,
                              np.float32)
            kd_mask = np.zeros((batch, seq), bool)
        for b, r in enumerate(picks):
            full = np.concatenate([r.prompt, r.tokens])[:seq]
            toks[b, :full.size] = full
            P = min(r.prompt.size, seq)
            labels[b, P:full.size] = full[P:]
            if topk and r.teacher_values is not None:
                # generated token j was scored at teacher-forced position
                # P-1+j (the prefix up to and including position P-2+j)
                k = min(topk, r.teacher_values.shape[1])
                for j in range(min(r.teacher_values.shape[0],
                                   r.tokens.size)):
                    pos = r.prompt.size - 1 + j
                    if pos >= seq:
                        break
                    teacher[b, pos, r.teacher_indices[j, :k]] = \
                        r.teacher_values[j, :k]
                    kd_mask[b, pos] = True
        out["tokens"] = jnp.asarray(toks)
        out["labels"] = jnp.asarray(labels)
        if topk:
            out["teacher_logits"] = jnp.asarray(teacher)
            out["kd_mask"] = jnp.asarray(kd_mask)
        return out
