from repro.data.feedback_store import FeedbackStore, FeedbackTriple  # noqa: F401
from repro.data.pipeline import SyntheticLM, batches, dirichlet_clients  # noqa: F401
