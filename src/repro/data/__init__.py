from repro.data.pipeline import SyntheticLM, batches, dirichlet_clients  # noqa: F401
