from repro.training.optimizer import AdamW, cosine_schedule  # noqa: F401
from repro.training.trainer import make_train_step, train  # noqa: F401
