"""Adapter-based modular training (survey §3.4).

LoRA adapters injected on selected dense matrices of any repro model;
federated aggregation including HETLoRA's rank-aware scheme (clients train
heterogeneous ranks; the server zero-pads + sparsity-weights).

Params layout: adapters live in a separate pytree {path: {"A": (r, in),
"B": (out, r)}} keyed by "/"-joined param paths, so the frozen base model
is untouched (communication = adapters only, the survey's §3.4 point).
"""
from __future__ import annotations

import re
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_TARGETS = (r".*attn/wq$", r".*attn/wk$", r".*attn/wv$", r".*attn/wo$")


def _flatten(params, prefix=""):
    out = {}
    for k, v in params.items():
        path = f"{prefix}/{k}" if prefix else k
        if isinstance(v, dict):
            out.update(_flatten(v, path))
        else:
            out[path] = v
    return out


def _set_path(tree, path: str, value):
    keys = path.split("/")
    node = tree
    for k in keys[:-1]:
        node = node[k]
    node[keys[-1]] = value


def target_paths(params, patterns: Sequence[str] = DEFAULT_TARGETS) -> List[str]:
    flat = _flatten(params)
    pats = [re.compile(p) for p in patterns]
    return [p for p, v in flat.items()
            if hasattr(v, "ndim") and v.ndim >= 2 and any(r.match(p) for r in pats)]


def init_lora(rng, params, rank: int = 8,
              patterns: Sequence[str] = DEFAULT_TARGETS,
              alpha: float = 16.0) -> Dict:
    """Adapters for every matching matrix.  Stacked layer dims (L, in, out)
    get stacked adapters (L, r, in)/(L, out, r)."""
    flat = _flatten(params)
    adapters = {}
    for i, path in enumerate(target_paths(params, patterns)):
        w = flat[path]
        r1, r2 = jax.random.split(jax.random.fold_in(rng, i))
        if w.ndim == 2:
            din, dout = w.shape
            A = jax.random.normal(r1, (rank, din)) * (1.0 / np.sqrt(din))
            B = jnp.zeros((dout, rank))
        else:          # stacked (L, din, dout)
            L, din, dout = w.shape
            A = jax.random.normal(r1, (L, rank, din)) * (1.0 / np.sqrt(din))
            B = jnp.zeros((L, dout, rank))
        adapters[path] = {"A": A.astype(jnp.float32),
                          "B": B.astype(jnp.float32),
                          "alpha": jnp.asarray(alpha, jnp.float32)}
    return adapters


def merge_lora(params, adapters: Dict):
    """Return a params copy with W + (alpha/r)·BᵀAᵀ... i.e. delta = (B@A)ᵀ
    folded in (one-time merge for deployment)."""
    import copy
    new = jax.tree.map(lambda x: x, params)   # structural copy

    for path, ad in adapters.items():
        flat = _flatten(new)
        w = flat[path]
        r = ad["A"].shape[-2]
        scale = ad["alpha"] / r
        if w.ndim == 2:
            delta = (ad["B"] @ ad["A"]).T          # (din, dout)
        else:
            delta = jnp.einsum("lor,lri->lio", ad["B"], ad["A"])
        _set_path(new, path, (w.astype(jnp.float32) + scale * delta)
                  .astype(w.dtype))
    return new


def lora_loss_fn(model, base_params, *, patterns=DEFAULT_TARGETS):
    """loss(adapters, batch): merge-free adapter forward would need model
    surgery; for clarity we merge functionally per step (the matmul cost is
    fine at framework-test scale, and XLA fuses the add)."""
    def loss(adapters, batch):
        merged = merge_lora(base_params, adapters)
        return model.loss(merged, batch)
    return loss


# ---------------------------------------------------------------- federated
def fedavg_adapters(client_adapters: List[Dict], weights=None) -> Dict:
    """Plain FedAvg over homogeneous-rank adapters."""
    n = len(client_adapters)
    w = np.asarray(weights if weights is not None else [1 / n] * n, np.float32)
    w = w / w.sum()
    return jax.tree.map(lambda *xs: sum(wi * x for wi, x in zip(w, xs)),
                        *client_adapters)


def hetlora_aggregate(client_adapters: List[Dict], max_rank: int) -> Dict:
    """HETLoRA (survey §3.4): clients hold heterogeneous ranks r_c ≤ R.
    Zero-pad every adapter to rank R, then weight each client by the
    Frobenius mass of its delta (sparsity-weighted aggregation)."""
    def pad(ad):
        out = {}
        for path, a in ad.items():
            A, B = a["A"], a["B"]
            r = A.shape[-2]
            pad_r = max_rank - r
            if pad_r:
                pa = [(0, 0)] * A.ndim
                pa[-2] = (0, pad_r)
                pb = [(0, 0)] * B.ndim
                pb[-1] = (0, pad_r)
                A, B = jnp.pad(A, pa), jnp.pad(B, pb)
            out[path] = {"A": A, "B": B, "alpha": a["alpha"]}
        return out

    padded = [pad(c) for c in client_adapters]
    mass = []
    for c in padded:
        m = sum(float(jnp.sum(jnp.square(a["B"] @ a["A"] if a["A"].ndim == 2
                                         else jnp.einsum("lor,lri->loi",
                                                         a["B"], a["A"]))))
                for a in c.values())
        mass.append(m + 1e-8)
    w = np.asarray(mass, np.float32)
    w = w / w.sum()
    agg = {}
    for path in padded[0]:
        agg[path] = {
            "A": sum(wi * c[path]["A"] for wi, c in zip(w, padded)),
            "B": sum(wi * c[path]["B"] for wi, c in zip(w, padded)),
            "alpha": padded[0][path]["alpha"],
        }
    return agg


def lora_param_count(adapters: Dict) -> int:
    return int(sum(np.prod(a["A"].shape) + np.prod(a["B"].shape)
                   for a in adapters.values()))
