"""Collaborative training: distillation family (survey §3.2, §3.5).

* ``kd_loss`` — forward KD (cloud LLM teaches edge SLM): CE + T^2·KL(p_t‖p_s).
* ``reverse_kd_loss`` — mode-seeking KL(p_s‖p_t) (MiniLLM-style).
* ``distillspec_data`` — DistillSpec: self-sampled target sequences as the
  distillation corpus, which provably raises speculative acceptance
  (acceptance = 1 - TV(p, q), and KD on on-policy data minimizes it).
* ``logit_delta`` — SLM-guided LLM adaptation (Mitchell et al. emulator,
  survey §3.5.2): apply (logits_slm_ft - logits_slm_base) to the LLM.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.model import cross_entropy


def _ce_mask(labels, ignore=-1):
    return labels != ignore


def kl_divergence(teacher_logits, student_logits, temperature: float = 1.0,
                  mask=None):
    """KL(teacher || student), mean over positions. Inputs (..., V).
    ``mask`` (broadcastable to the position dims) restricts the mean to
    positions that actually carry teacher supervision — serve-time
    capture stores teacher logits only at generated positions, so the
    rest must contribute zero KL, not garbage."""
    t = jax.nn.log_softmax(teacher_logits.astype(jnp.float32) / temperature, -1)
    s = jax.nn.log_softmax(student_logits.astype(jnp.float32) / temperature, -1)
    kl = jnp.sum(jnp.exp(t) * (t - s), axis=-1)
    if mask is None:
        return jnp.mean(kl)
    m = mask.astype(kl.dtype)
    return jnp.sum(kl * m) / jnp.maximum(jnp.sum(m), 1.0)


def kd_loss(student_model, student_params, batch, teacher_logits, *,
            alpha: float = 0.5, temperature: float = 2.0, kd_mask=None):
    """alpha·CE(labels) + (1-alpha)·T²·KL(teacher‖student).  ``kd_mask``
    ((B, S) bool) marks the positions with real teacher logits (sparse
    serve-time capture); None keeps the historical all-position mean."""
    logits, aux = student_model.forward(student_params, batch)[:2]
    if student_model.cfg.family == "vlm":
        logits = logits[:, batch["embeds"].shape[1]:, :]
    ce = cross_entropy(logits[:, :-1], batch["labels"][:, 1:])
    kl = kl_divergence(teacher_logits[:, :-1], logits[:, :-1], temperature,
                       mask=None if kd_mask is None else kd_mask[:, :-1])
    return alpha * ce + (1 - alpha) * (temperature ** 2) * kl + aux


def reverse_kd_loss(student_model, student_params, batch, teacher_logits, *,
                    temperature: float = 1.0):
    """KL(student || teacher): mode-seeking; better for generative students
    (MiniLLM).  Gradient flows through the student distribution."""
    logits, aux = student_model.forward(student_params, batch)[:2]
    if student_model.cfg.family == "vlm":
        logits = logits[:, batch["embeds"].shape[1]:, :]
    s = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32) / temperature, -1)
    t = jax.nn.log_softmax(teacher_logits[:, :-1].astype(jnp.float32) / temperature, -1)
    return jnp.mean(jnp.sum(jnp.exp(s) * (s - t), axis=-1)) + aux


def distillspec_data(target_model, target_params, prompts, max_new: int,
                     rng, temperature: float = 1.0):
    """Sample on-policy sequences from the TARGET (the DistillSpec corpus).
    prompts: (B, S) int32. Returns (B, S+max_new) token arrays."""
    tokens = jnp.asarray(prompts, jnp.int32)
    _, cache = target_model.prefill(target_params, {"tokens": tokens[:, :-1]},
                                    max_seq=tokens.shape[1] + max_new + 2)
    step = jax.jit(lambda p, t, c: target_model.decode_step(p, t, c))
    tok = tokens[:, -1:]
    outs = [tokens]
    for _ in range(max_new):
        lg, cache = step(target_params, tok, cache)
        rng, rr = jax.random.split(rng)
        if temperature == 0.0:
            nxt = jnp.argmax(lg, -1).astype(jnp.int32)
        else:
            nxt = jax.random.categorical(rr, lg / temperature, -1).astype(jnp.int32)
        tok = nxt[:, None]
        outs.append(tok)
    return jnp.concatenate(outs, axis=1)


def teacher_logits_fn(teacher_model, teacher_params):
    """Jitted teacher forward for KD (teacher is frozen — lax.stop_gradient)."""
    @jax.jit
    def fn(batch):
        logits, _ = teacher_model.forward(teacher_params, batch)[:2]
        if teacher_model.cfg.family == "vlm":
            logits = logits[:, batch["embeds"].shape[1]:, :]
        return jax.lax.stop_gradient(logits)
    return fn


def logit_delta_guidance(llm_logits, slm_ft_logits, slm_base_logits,
                         beta: float = 1.0):
    """Emulated fine-tuning (survey §3.5.2): LLM + beta·(SLM_ft - SLM_base).
    The tiny models carry the domain adaptation; the big model supplies
    capability.  All inputs (..., V) over a shared vocab."""
    return llm_logits.astype(jnp.float32) + beta * (
        slm_ft_logits.astype(jnp.float32) - slm_base_logits.astype(jnp.float32))


def acceptance_estimate(draft_logits, target_logits, temperature: float = 1.0):
    """Expected speculative acceptance 1 - TV(p,q) per position — the metric
    DistillSpec optimizes. Inputs (..., V)."""
    p = jax.nn.softmax(target_logits.astype(jnp.float32) / temperature, -1)
    q = jax.nn.softmax(draft_logits.astype(jnp.float32) / temperature, -1)
    return jnp.mean(jnp.sum(jnp.minimum(p, q), axis=-1))
