"""Training loop substrate: jitted train_step builder + host loop."""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import jax

from repro.training.optimizer import AdamW, AdamWState


def make_train_step(model, opt: AdamW, *, loss_fn: Optional[Callable] = None,
                    remat: bool = False, donate: bool = True):
    """Returns jitted step(params, opt_state, batch) -> (params, state, metrics).

    loss_fn(params, batch) overrides the model's default CE loss (used for
    distillation / LayerSkip objectives).
    """
    _loss = loss_fn or (lambda p, b: model.loss(p, b, remat=remat))

    def step(params, opt_state: AdamWState, batch):
        loss, grads = jax.value_and_grad(_loss)(params, batch)
        params, opt_state, gnorm = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    # assignment form so the repro-lint R2 registry picks the jit up
    # (serve-time adaptation runs this step between scheduler ticks —
    # fixed batch shapes mean it compiles exactly once)
    step_fn = jax.jit(step, donate_argnums=(0, 1) if donate else ())
    return step_fn


def train(model, params, data_iter, *, steps: int, opt: Optional[AdamW] = None,
          loss_fn=None, remat: bool = False, log_every: int = 10,
          donate: bool = False, log: Callable = print) -> Dict:
    """Host training loop.  ``donate=True`` donates param/opt buffers for
    memory efficiency (the caller's params become invalid)."""
    opt = opt or AdamW()
    opt_state = opt.init(params)
    step_fn = make_train_step(model, opt, loss_fn=loss_fn, remat=remat,
                              donate=donate)
    history = []
    t0 = time.time()
    for i in range(steps):
        batch = next(data_iter)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % log_every == 0 or i == steps - 1:
            loss = float(metrics["loss"])
            history.append((i, loss))
            log(f"step {i:5d}  loss {loss:.4f}  "
                f"gnorm {float(metrics['grad_norm']):.3f}  "
                f"{(time.time()-t0):.1f}s")
    return {"params": params, "opt_state": opt_state, "history": history}
