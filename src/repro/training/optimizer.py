"""AdamW + schedules in pure JAX (no optax in this container).

State is a pytree mirroring params: {"m": ..., "v": ..., "step": ()}.
Moments are f32 regardless of param dtype (bf16-safe).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    m: dict
    v: dict
    step: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: Optional[Callable] = None     # step -> lr multiplier

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(jax.tree.map(zeros, params),
                          jax.tree.map(zeros, params),
                          jnp.zeros((), jnp.int32))

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        # global-norm clip
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-9)) \
            if self.grad_clip else 1.0
        lr = self.lr * (self.schedule(step) if self.schedule else 1.0)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * g * g
            mh = m / (1 - self.b1 ** step)
            vh = v / (1 - self.b2 ** step)
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if self.weight_decay and p.ndim >= 2:   # decay matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(new_m, new_v, step), gnorm


def cosine_schedule(warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return warm * cos
    return fn
