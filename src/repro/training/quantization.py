"""Quantization for efficient edge deployment (survey §3.1, Fig. 8a).

* ``quantize_params`` / ``dequantize_params`` — per-channel symmetric int8
  PTQ of all >=2D weights (embeddings included), with size accounting.
* ``fake_quant`` — straight-through-estimator QAT hook (LLM-QAT style).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


def _quant_leaf(w, bits: int = 8):
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-1, keepdims=True) / qmax
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(w / scale), -qmax, qmax).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def _dequant_leaf(d, dtype):
    return (d["q"].astype(jnp.float32) * d["scale"]).astype(dtype)


def quantize_params(params, bits: int = 8):
    """Returns (qtree, meta) where matrices are {"q", "scale"} dicts and
    small vectors stay fp."""
    def q(w):
        if hasattr(w, "ndim") and w.ndim >= 2 and jnp.issubdtype(w.dtype, jnp.floating):
            return _quant_leaf(w, bits)
        return w
    return jax.tree.map(q, params)


def dequantize_params(qparams, dtype=jnp.float32):
    def dq(node):
        if isinstance(node, dict) and set(node) == {"q", "scale"}:
            return _dequant_leaf(node, dtype)
        return node
    return jax.tree.map(dq, qparams,
                        is_leaf=lambda n: isinstance(n, dict) and set(n) == {"q", "scale"})


def quantized_bytes(qparams) -> int:
    total = 0
    for leaf in jax.tree.leaves(qparams):
        total += np.asarray(leaf).nbytes
    return int(total)


def fake_quant(w, bits: int = 8):
    """Straight-through fake quantization (QAT): forward = quantized,
    gradient = identity."""
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(w), axis=-1, keepdims=True) / qmax
    scale = jnp.maximum(scale, 1e-12)
    wq = jnp.round(w / scale) * scale
    return w + jax.lax.stop_gradient(wq - w)


def quantization_error(params, qparams) -> Dict[str, float]:
    deq = dequantize_params(qparams)
    errs = []
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(deq)):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        errs.append(np.linalg.norm(a - b) / (np.linalg.norm(a) + 1e-12))
    return {"mean_rel_err": float(np.mean(errs)), "max_rel_err": float(np.max(errs))}
