"""Checkpointing: flat-key npz save/restore for arbitrary param pytrees."""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _esc(key: str) -> str:
    """Escape "/" (and the escape char itself) WITHIN a single pytree key.
    Flat npz keys are "/"-joined paths, so a dict key that itself contains
    "/" — LoRA adapters are keyed by joined param paths like
    ``blocks/0/attn/wq`` — would otherwise produce the SAME flat key as a
    nested spelling of that path and silently collide (last writer wins on
    save, and restore reads one leaf into both slots)."""
    return key.replace("%", "%25").replace("/", "%2F")


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        items = tree.items()
    elif isinstance(tree, (list, tuple)):
        items = ((str(i), v) for i, v in enumerate(tree))
    elif hasattr(tree, "_fields"):          # NamedTuple
        items = zip(tree._fields, tree)
    else:
        return {prefix: tree}
    for k, v in items:
        k = _esc(str(k))
        path = f"{prefix}/{k}" if prefix else k
        out.update(_flatten(v, path))
    return out


def save(path: str, params: Any, step: int = 0):
    flat = _flatten(params)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    arrays["__step__"] = np.asarray(step)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **arrays)


def _jax_paths(like):
    """Keys in jax's own flatten order, named consistently with _flatten."""
    flat, _ = jax.tree_util.tree_flatten_with_path(like)
    keys = []
    for path, _leaf in flat:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            elif hasattr(p, "name"):
                parts.append(str(p.name))
            else:
                parts.append(str(p))
        keys.append("/".join(_esc(part) for part in parts))
    return keys


def restore(path: str, like: Any):
    """Restore into the structure of ``like`` (same treedef)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    _, treedef = jax.tree.flatten(like)
    ordered = [jnp.asarray(data[k]) for k in _jax_paths(like)]
    return jax.tree.unflatten(treedef, ordered), int(data["__step__"])
