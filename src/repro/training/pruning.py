"""Pruning (survey §3.1, Fig. 8b): magnitude pruning with soft-mask
reactivation (Li et al. [120]) and structured d_ff channel pruning
(EfficientLLM-style)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


def magnitude_masks(params, sparsity: float):
    """Unstructured per-matrix magnitude masks (1 = keep)."""
    def mask(w):
        if not (hasattr(w, "ndim") and w.ndim >= 2):
            return jnp.ones_like(w, dtype=bool)
        k = int(w.size * sparsity)
        if k == 0:
            return jnp.ones(w.shape, bool)
        thresh = jnp.sort(jnp.abs(w).reshape(-1))[k - 1]
        return jnp.abs(w) > thresh
    return jax.tree.map(mask, params)


def apply_masks(params, masks):
    return jax.tree.map(lambda w, m: w * m.astype(w.dtype), params, masks)


def soft_mask_update(params, masks, reactivate_frac: float = 0.01, rng=None):
    """Soft-mask mechanism: reactivate the largest masked-out weights
    (they may have regrown during masked training)."""
    def upd(w, m):
        if not (hasattr(w, "ndim") and w.ndim >= 2):
            return m
        masked_vals = jnp.where(m, -jnp.inf, jnp.abs(w)).reshape(-1)
        k = max(1, int(w.size * reactivate_frac))
        thresh = jax.lax.top_k(masked_vals, k)[0][-1]
        return m | (jnp.abs(w) >= jnp.maximum(thresh, 1e-12))
    return jax.tree.map(upd, params, masks)


def structured_ffn_prune(params, cfg, keep_frac: float):
    """Structured pruning of d_ff channels by combined gate+up+down column
    importance.  Returns a new params tree with physically smaller MLPs —
    the edge-deployable artifact (dense/vlm families)."""
    blocks = params["blocks"]
    w_up = blocks["mlp"]["w_up"]                 # (L, d, f)
    score = jnp.sum(jnp.abs(w_up), axis=1)       # (L, f)
    if "w_gate" in blocks["mlp"]:
        score = score + jnp.sum(jnp.abs(blocks["mlp"]["w_gate"]), axis=1)
    score = score + jnp.sum(jnp.abs(blocks["mlp"]["w_down"]), axis=2)
    keep = max(8, int(w_up.shape[-1] * keep_frac) // 8 * 8)
    idx = jax.lax.top_k(score, keep)[1]          # (L, keep)
    idx = jnp.sort(idx, axis=-1)

    def take_cols(w):   # (L, d, f) -> (L, d, keep)
        return jax.vmap(lambda wl, il: wl[:, il])(w, idx)

    def take_rows(w):   # (L, f, d) -> (L, keep, d)
        return jax.vmap(lambda wl, il: wl[il, :])(w, idx)

    new_mlp = {"w_up": take_cols(blocks["mlp"]["w_up"]),
               "w_down": take_rows(blocks["mlp"]["w_down"])}
    if "w_gate" in blocks["mlp"]:
        new_mlp["w_gate"] = take_cols(blocks["mlp"]["w_gate"])
    new_blocks = dict(blocks, mlp=new_mlp)
    return dict(params, blocks=new_blocks), keep


def sparsity_report(masks) -> Dict[str, float]:
    kept = sum(int(jnp.sum(m)) for m in jax.tree.leaves(masks))
    total = sum(int(np.prod(m.shape)) for m in jax.tree.leaves(masks))
    return {"kept_frac": kept / total, "pruned_frac": 1 - kept / total}
