"""Unified model API over all architecture families.

    m = Model(cfg)
    params = m.init(rng)
    logits, aux = m.forward(params, batch)          # teacher-forced
    loss = m.loss(params, batch)
    logits, cache = m.prefill(params, batch)
    logits, cache = m.decode_step(params, token, cache)

``batch`` is a dict: {"tokens", "labels"?, "frames"? (encdec stub),
"embeds"? (vlm stub)}.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, hybrid, ssm, transformer, xlstm


def cross_entropy(logits, labels, ignore: int = -1):
    """logits (B,S,V) f32; labels (B,S) int32. Mean over non-ignored."""
    mask = (labels != ignore)
    lab = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ---------------------------------------------------------------- init
    def init(self, rng):
        cfg = self.cfg
        if cfg.family in ("dense", "moe", "vlm"):
            return transformer.init_params(rng, cfg)
        if cfg.family == "ssm":
            return ssm.init_params(rng, cfg)
        if cfg.family == "xlstm":
            return xlstm.init_params(rng, cfg)
        if cfg.family == "hybrid":
            return hybrid.init_params(rng, cfg)
        if cfg.family == "encdec":
            return encdec.init_params(rng, cfg)
        raise ValueError(cfg.family)

    # ---------------------------------------------------------------- fwd
    def forward(self, params, batch: Dict, *, window: int = 0,
                remat: bool = False, collect_hidden: bool = False):
        cfg = self.cfg
        tokens = batch["tokens"]
        if cfg.family in ("dense", "moe"):
            return transformer.forward(params, tokens, cfg, window=window,
                                       remat=remat, collect_hidden=collect_hidden)
        if cfg.family == "vlm":
            return transformer.forward(params, tokens, cfg, embeds=batch["embeds"],
                                       window=window, remat=remat,
                                       collect_hidden=collect_hidden)
        if cfg.family == "ssm":
            return ssm.forward(params, tokens, cfg, remat=remat,
                               collect_hidden=collect_hidden)
        if cfg.family == "xlstm":
            return xlstm.forward(params, tokens, cfg, remat=remat,
                                 collect_hidden=collect_hidden)
        if cfg.family == "hybrid":
            return hybrid.forward(params, tokens, cfg, window=window, remat=remat,
                                  collect_hidden=collect_hidden)
        if cfg.family == "encdec":
            return encdec.forward(params, tokens, cfg, frames=batch["frames"],
                                  remat=remat, collect_hidden=collect_hidden)
        raise ValueError(cfg.family)

    def loss(self, params, batch: Dict, *, window: int = 0, remat: bool = False):
        out = self.forward(params, batch, window=window, remat=remat)
        logits, aux = out[0], out[1]
        labels = batch["labels"]
        if self.cfg.family == "vlm":
            # image-prefix positions carry no next-token loss
            P = batch["embeds"].shape[1]
            logits = logits[:, P:, :]
        return cross_entropy(logits[:, :-1, :], labels[:, 1:]) + aux

    # ---------------------------------------------------------------- cache
    def init_cache(self, batch_size: int, max_seq: int):
        cfg = self.cfg
        if cfg.family in ("dense", "moe", "vlm"):
            return transformer.init_cache(cfg, batch_size, max_seq)
        if cfg.family == "ssm":
            return ssm.init_cache(cfg, batch_size)
        if cfg.family == "xlstm":
            return xlstm.init_cache(cfg, batch_size)
        if cfg.family == "hybrid":
            return hybrid.init_cache(cfg, batch_size, max_seq)
        if cfg.family == "encdec":
            return encdec.init_cache(cfg, batch_size, max_seq)
        raise ValueError(cfg.family)

    def prefill(self, params, batch: Dict, *, max_seq: Optional[int] = None,
                window: int = 0):
        cfg = self.cfg
        tokens = batch["tokens"]
        if cfg.family in ("dense", "moe"):
            return transformer.prefill(params, tokens, cfg, max_seq=max_seq,
                                       window=window)
        if cfg.family == "vlm":
            return transformer.prefill(params, tokens, cfg, max_seq=max_seq,
                                       embeds=batch["embeds"], window=window)
        if cfg.family == "ssm":
            return ssm.prefill(params, tokens, cfg)
        if cfg.family == "xlstm":
            return xlstm.prefill(params, tokens, cfg)
        if cfg.family == "hybrid":
            return hybrid.prefill(params, tokens, cfg, max_seq=max_seq,
                                  window=window)
        if cfg.family == "encdec":
            return encdec.prefill(params, tokens, cfg, frames=batch["frames"],
                                  max_seq=max_seq)
        raise ValueError(cfg.family)

    def decode_step(self, params, token, cache, *, window: int = 0):
        cfg = self.cfg
        if cfg.family in ("dense", "moe", "vlm"):
            return transformer.decode_step(params, token, cache, cfg, window=window)
        if cfg.family == "ssm":
            return ssm.decode_step(params, token, cache, cfg)
        if cfg.family == "xlstm":
            return xlstm.decode_step(params, token, cache, cfg)
        if cfg.family == "hybrid":
            return hybrid.decode_step(params, token, cache, cfg, window=window)
        if cfg.family == "encdec":
            return encdec.decode_step(params, token, cache, cfg)
        raise ValueError(cfg.family)

    def extend_step(self, params, tokens, cache, *, window: int = 0,
                    block_mask=None, q_positions=None):
        """Multi-token cached decode (chunked prefill, speculative verify).
        tokens (B,T) -> (logits (B,T,V), cache).  ``block_mask`` is only
        supported for attention-based decoders (token trees); SSM/hybrid
        recurrences are inherently linear-order (see DESIGN.md)."""
        cfg = self.cfg
        if cfg.family in ("dense", "moe", "vlm"):
            return transformer.extend_step(params, tokens, cache, cfg,
                                           window=window, block_mask=block_mask,
                                           q_positions=q_positions)
        if block_mask is not None or q_positions is not None:
            raise ValueError(f"block_mask unsupported for family {cfg.family}")
        if cfg.family == "ssm":
            return ssm.extend_step(params, tokens, cache, cfg)
        if cfg.family == "xlstm":
            return xlstm.extend_step(params, tokens, cache, cfg)
        if cfg.family == "hybrid":
            return hybrid.extend_step(params, tokens, cache, cfg, window=window)
        if cfg.family == "encdec":
            return encdec.extend_step(params, tokens, cache, cfg)
        raise ValueError(cfg.family)

    # ------------------------------------------------------------ paged kv
    @property
    def paged_kv(self) -> bool:
        """True if the family's cache is a pure self-attention KV cache that
        the serving scheduler can lay out as a shared block pool + block
        tables (see ``core/paged_cache.py``).  Recurrent state (ssm/hybrid)
        has no sequence axis to page; encdec carries cross-attention K/V
        pinned to the encoder length."""
        return self.cfg.family in ("dense", "moe", "vlm")

    def _require_paged(self):
        if not self.paged_kv:
            raise ValueError(f"paged KV cache unsupported for family "
                             f"{self.cfg.family!r} (KV-cache transformer "
                             "families only)")

    def init_paged_cache(self, num_blocks: int, block_size: int, batch: int,
                         max_blocks: int):
        self._require_paged()
        return transformer.init_paged_cache(self.cfg, num_blocks, block_size,
                                            batch, max_blocks)

    def paged_decode_step(self, params, token, cache, *,
                          attn_backend: str = "auto"):
        """One decode step over a paged cache. token (B,1) -> (logits (B,V),
        cache).  ``attn_backend``: "auto" (TPU: Pallas paged kernel —
        windowed variant under ``cfg.sliding_window``; CPU: jnp oracle),
        "kernel", "ref", or "gather" (the full-width block-table gather,
        kept only as a test oracle — it is off every decode hot path)."""
        self._require_paged()
        return transformer.paged_decode_step(params, token, cache, self.cfg,
                                             attn_backend=attn_backend)

    def paged_extend_step(self, params, tokens, cache):
        """Multi-token cached decode over a paged cache. tokens (B,T) ->
        (logits (B,T,V), cache)."""
        self._require_paged()
        return transformer.paged_extend_step(params, tokens, cache, self.cfg)

    @property
    def rewindable_cache(self) -> bool:
        """True if the cache can be rolled back by resetting ``pos`` (KV
        caches); False for recurrent state (ssm/xlstm/hybrid), which rewinds
        by replaying the accepted prefix (``replay_step``)."""
        return self.cfg.family in ("dense", "moe", "vlm", "encdec")

    def rewind(self, cache, new_pos):
        assert self.rewindable_cache
        return {**cache, "pos": jnp.asarray(new_pos, jnp.int32)}

    def replay_step(self, params, tokens, cache, count):
        """Recurrent-state rewind primitive: re-advance ``cache`` through
        ``tokens[:, :count]`` of a padded draft tape (``count`` () int32;
        ``count == 0`` keeps the cache).  vmapped over slots by the serving
        scheduler, this rewinds every slot to its own accepted count in one
        fused scan — the batched replacement for per-request
        snapshot+replay.  KV-cache families rewind via ``rewind`` instead."""
        cfg = self.cfg
        if cfg.family == "ssm":
            return ssm.replay_step(params, tokens, cache, count, cfg)
        if cfg.family == "xlstm":
            return xlstm.replay_step(params, tokens, cache, count, cfg)
        if cfg.family == "hybrid":
            return hybrid.replay_step(params, tokens, cache, count, cfg)
        raise ValueError(f"replay_step is for recurrent-state families; "
                         f"{cfg.family!r} caches rewind via pos")


# ---------------------------------------------------------------- batches
def example_batch(cfg: ModelConfig, batch: int, seq: int, rng=None,
                  with_labels: bool = True) -> Dict:
    """Concrete random batch matching input_specs layout (smoke tests)."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    r1, r2, r3 = jax.random.split(rng, 3)
    out: Dict = {}
    s_text = seq
    if cfg.family == "vlm":
        s_text = max(seq - cfg.num_image_tokens, 8)
        out["embeds"] = jax.random.normal(
            r2, (batch, cfg.num_image_tokens, cfg.d_model),
            dtype=jnp.dtype(cfg.activ_dtype))
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(
            r2, (batch, cfg.encoder_seq, cfg.d_model),
            dtype=jnp.dtype(cfg.activ_dtype))
    out["tokens"] = jax.random.randint(r1, (batch, s_text), 0, cfg.vocab_size,
                                       dtype=jnp.int32)
    if with_labels:
        out["labels"] = jax.random.randint(r3, (batch, s_text), 0, cfg.vocab_size,
                                           dtype=jnp.int32)
    return out
