"""State-space sequence mixing: a generic *chunked gated linear attention*
(GLA) engine shared by Mamba2 (SSD) and xLSTM's mLSTM, the Mamba2 block, and
the standalone pure-Mamba2 model (family "ssm", e.g. ``mamba2-370m``).

Recurrence (per batch b, head h):
    S_t = a_t * S_{t-1} + i_t * k_t v_t^T          (N x P matrix state)
    n_t = a_t * n_{t-1} + i_t * k_t                (N normalizer, mLSTM only)
    y_t = q_t^T S_t        [mamba]      or     q_t^T S_t / max(|q_t^T n_t|, e^{-m_t})  [mlstm]

All math is done in log space with a running max stabilizer m_t so that
exp-input-gated mLSTM is stable; the carried state is S~ = S * e^{-M}.
The chunked form (chunk Q) computes intra-chunk terms with an O(Q^2)
masked matmul and carries (S~, n~, M) across chunks with lax.scan — this is
the structure the `ssd_chunk_scan` Pallas kernel mirrors.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense_init, split

_NEG = -1e30


class GLAState(NamedTuple):
    S: jnp.ndarray     # (B, H, N, P)  stabilized matrix state
    n: jnp.ndarray     # (B, H, N)     stabilized normalizer
    m: jnp.ndarray     # (B, H)        running log-max


def init_gla_state(B: int, H: int, N: int, P: int, dtype=jnp.float32) -> GLAState:
    return GLAState(
        S=jnp.zeros((B, H, N, P), dtype),
        n=jnp.zeros((B, H, N), dtype),
        m=jnp.full((B, H), _NEG, dtype),
    )


def gla_chunked(q, k, v, log_a, log_i, *, chunk: int,
                state: Optional[GLAState] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, GLAState]:
    """q,k: (B,S,H,N); v: (B,S,H,P); log_a/log_i: (B,S,H).

    Returns (y_num (B,S,H,P), den (B,S,H), m (B,S,H), final_state), all f32.
    ``y_num``/``den`` are stabilized by e^{-m}.
    """
    B, S, H, N = q.shape
    P = v.shape[-1]
    Q = min(chunk, S)
    f32 = jnp.float32
    q, k, v = q.astype(f32), k.astype(f32), v.astype(f32)
    log_a, log_i = log_a.astype(f32), log_i.astype(f32)
    # Front-pad to a chunk multiple. Pad steps contribute nothing: k=v=0 and
    # log_i=-1e30 kill their state/normalizer contributions; their (garbage
    # but finite) outputs are sliced off below.
    pad = (-S) % Q
    if pad:
        def pf(x, fill=0.0):
            w = [(0, 0)] * x.ndim
            w[1] = (pad, 0)
            return jnp.pad(x, w, constant_values=fill)
        q, k, v = pf(q), pf(k), pf(v)
        log_a, log_i = pf(log_a), pf(log_i, fill=_NEG)
    S_p = S + pad
    nc = S_p // Q

    def to_chunks(x):
        return x.reshape((B, nc, Q) + x.shape[2:]).swapaxes(0, 1)  # (nc,B,Q,...)

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    lac, lic = to_chunks(log_a), to_chunks(log_i)
    if state is None:
        state = init_gla_state(B, H, N, P)

    tri = np.tril(np.ones((Q, Q), np.bool_))  # s <= j

    def body(carry: GLAState, xs):
        q_c, k_c, v_c, la_c, li_c = xs             # (B,Q,H,*)
        St, nt, M = carry
        La = jnp.cumsum(la_c, axis=1)              # (B,Q,H) inclusive
        w = jax.lax.cummax(li_c - La, axis=1)      # (B,Q,H)
        m = La + jnp.maximum(M[:, None, :], w)     # (B,Q,H) per-row log max
        # ---- intra-chunk
        c_log = (La[:, :, None, :] - La[:, None, :, :]
                 + li_c[:, None, :, :] - m[:, :, None, :])     # (B,j,s,H)
        cmat = jnp.where(tri[None, :, :, None], jnp.exp(c_log), 0.0)
        scores = jnp.einsum("bjhn,bshn->bjsh", q_c, k_c)
        y = jnp.einsum("bjsh,bshp->bjhp", scores * cmat, v_c)
        den = jnp.einsum("bjsh->bjh", scores * cmat)
        # ---- inter-chunk (carry-in state)
        coef = jnp.exp(La + M[:, None, :] - m)                 # (B,Q,H)
        y = y + jnp.einsum("bjhn,bhnp->bjhp", q_c, St) * coef[..., None]
        den = den + jnp.einsum("bjhn,bhn->bjh", q_c, nt) * coef
        # ---- carry update
        la_sum = La[:, -1, :]                                   # (B,H)
        m_new = la_sum + jnp.maximum(M, w[:, -1, :])
        z = jnp.exp(la_sum[:, None, :] - La + li_c - m_new[:, None, :])  # (B,Q,H)
        s_scale = jnp.exp(jnp.clip(la_sum + M - m_new, None, 0.0))
        S_new = s_scale[..., None, None] * St + jnp.einsum(
            "bshn,bshp,bsh->bhnp", k_c, v_c, z)
        n_new = s_scale[..., None] * nt + jnp.einsum("bshn,bsh->bhn", k_c, z)
        return GLAState(S_new, n_new, m_new), (y, den, m)

    final, (ys, dens, ms) = jax.lax.scan(body, state, (qc, kc, vc, lac, lic))

    def from_chunks(x):
        y = x.swapaxes(0, 1).reshape((B, S_p) + x.shape[3:])
        return y[:, pad:] if pad else y

    return from_chunks(ys), from_chunks(dens), from_chunks(ms), final


def gla_step(q, k, v, log_a, log_i, state: GLAState
             ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, GLAState]:
    """Single decode step. q,k: (B,H,N); v: (B,H,P); log_a/log_i: (B,H)."""
    f32 = jnp.float32
    q, k, v = q.astype(f32), k.astype(f32), v.astype(f32)
    log_a, log_i = log_a.astype(f32), log_i.astype(f32)
    St, nt, M = state
    m_new = jnp.maximum(M + log_a, log_i)
    sc = jnp.exp(jnp.clip(M + log_a - m_new, None, 0.0))
    ic = jnp.exp(log_i - m_new)
    S_new = sc[..., None, None] * St + ic[..., None, None] * (k[..., :, None] * v[..., None, :])
    n_new = sc[..., None] * nt + ic[..., None] * k
    y = jnp.einsum("bhn,bhnp->bhp", q, S_new)
    den = jnp.einsum("bhn,bhn->bh", q, n_new)
    return y, den, m_new, GLAState(S_new, n_new, m_new)


# ------------------------------------------------------------- causal conv1d
def init_conv(rng, channels: int, width: int, dtype):
    return {
        "w": dense_init(rng, (width, channels), scale=1.0, dtype=dtype),
        "b": jnp.zeros((channels,), dtype),
    }


def causal_conv(p, x, state=None):
    """Depthwise causal conv. x: (B,S,C) -> (B,S,C); returns (y, new_state).
    ``state``: (B, W-1, C) trailing inputs from the previous segment (zeros
    at sequence start)."""
    w = p["w"]                       # (W, C)
    W = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(W))
    y = y + p["b"]
    if W > 1:
        state = xp[:, -(W - 1):, :]   # last W-1 raw inputs
    else:
        state = jnp.zeros((x.shape[0], 0, x.shape[2]), x.dtype)
    return y, state


def causal_conv_step(p, x, state):
    """x: (B,1,C); state: (B,W-1,C). Returns (y (B,1,C), new_state)."""
    w, b = p["w"], p["b"]
    window = jnp.concatenate([state, x], axis=1)      # (B, W, C)
    y = jnp.einsum("bwc,wc->bc", window, w) + b
    return y[:, None, :], window[:, 1:, :]


# ----------------------------------------------------------------- Mamba2
def init_mamba2(rng, cfg, dtype):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    N, P = cfg.ssm_state, cfg.ssm_head_dim
    H = di // P
    r = split(rng, 6)
    return {
        "in_proj": dense_init(r[0], (d, 2 * di + 2 * N + H), dtype=dtype),
        "conv": init_conv(r[1], di + 2 * N, cfg.conv_kernel, dtype),
        "A_log": jnp.zeros((H,), jnp.float32),          # A = -exp(A_log) = -1
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm": jnp.zeros((di,), dtype),
        "out_proj": dense_init(r[2], (di, d), dtype=dtype),
    }


def _mamba_dims(cfg):
    di = cfg.ssm_expand * cfg.d_model
    N, P = cfg.ssm_state, cfg.ssm_head_dim
    return di, N, P, di // P


def _mamba_split(p, x, cfg):
    di, N, P, H = _mamba_dims(cfg)
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * N], axis=-1)
    return z, xbc, dt


def mamba2_forward(p, x, cfg, cache=None):
    """x: (B,S,d) -> (y (B,S,d), final GLA state + conv state).
    ``cache``: optional {"gla": GLAState, "conv": (B,W-1,C)} to continue
    from a previous segment (chunked prefill / speculative extension)."""
    from repro.models.layers import rmsnorm
    B, S, d = x.shape
    di, N, P, H = _mamba_dims(cfg)
    z, xbc, dt = _mamba_split(p, x, cfg)
    xbc, conv_state = causal_conv(p["conv"], xbc,
                                  state=None if cache is None else cache["conv"])
    xbc = jax.nn.silu(xbc)
    xs, Bm, Cm = jnp.split(xbc, [di, di + N], axis=-1)
    delta = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    log_a = -jnp.exp(p["A_log"]) * delta
    log_i = jnp.log(delta + 1e-9)
    v = xs.reshape(B, S, H, P)
    k = jnp.broadcast_to(Bm[:, :, None, :], (B, S, H, N))
    q = jnp.broadcast_to(Cm[:, :, None, :], (B, S, H, N))
    y, _den, m, st = gla_chunked(q, k, v, log_a, log_i, chunk=cfg.ssm_chunk,
                                 state=None if cache is None else cache["gla"])
    y = y * jnp.exp(m)[..., None]                                    # un-stabilize
    y = y + p["D"][None, None, :, None] * v.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    return y @ p["out_proj"], {"gla": st, "conv": conv_state}


def mamba2_init_cache(cfg, batch: int, dtype=jnp.float32):
    di, N, P, H = _mamba_dims(cfg)
    return {
        "gla": init_gla_state(batch, H, N, P),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, di + 2 * N), dtype),
    }


def mamba2_step(p, x, cache, cfg):
    """x: (B,1,d). Returns (y (B,1,d), new_cache)."""
    from repro.models.layers import rmsnorm
    B = x.shape[0]
    di, N, P, H = _mamba_dims(cfg)
    z, xbc, dt = _mamba_split(p, x, cfg)
    xbc, conv_state = causal_conv_step(p["conv"], xbc, cache["conv"])
    xbc = jax.nn.silu(xbc)
    xs, Bm, Cm = jnp.split(xbc, [di, di + N], axis=-1)
    delta = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    log_a = -jnp.exp(p["A_log"]) * delta
    log_i = jnp.log(delta + 1e-9)
    v = xs[:, 0].reshape(B, H, P)
    k = jnp.broadcast_to(Bm[:, 0, None, :], (B, H, N))
    q = jnp.broadcast_to(Cm[:, 0, None, :], (B, H, N))
    y, _den, m, st = gla_step(q, k, v, log_a, log_i, cache["gla"])
    y = y * jnp.exp(m)[..., None] + p["D"][None, :, None] * v.astype(jnp.float32)
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    return y @ p["out_proj"], {"gla": st, "conv": conv_state}


# ------------------------------------------------------- standalone model
# Pure-Mamba2 decoder (family "ssm"): embed + L stacked mamba2 blocks
# consumed with lax.scan (one-block-sized HLO, like hybrid.py minus its
# shared attention) + final norm.  The cache is pure recurrent state —
# no sequence axis at all, so decode cost is O(1) in context length.
def init_params(rng, cfg):
    from repro.models import layers as L
    dtype = jnp.dtype(cfg.param_dtype)
    r = L.split(rng, cfg.num_layers + 2)
    blocks = jax.tree.map(lambda *xs: jnp.stack(xs),
                          *[init_mamba2(r[i], cfg, dtype)
                            for i in range(cfg.num_layers)])
    return {
        "embed": L.init_embedding(r[-2], cfg.vocab_size, cfg.d_model, dtype),
        "blocks": blocks,
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }


def _stacked_cache(cfg, batch: int):
    base = mamba2_init_cache(cfg, batch)
    cache = jax.tree.map(
        lambda x: jnp.zeros((cfg.num_layers,) + x.shape, x.dtype), base)
    # running-max needs -inf init, not zeros:
    cache["gla"] = GLAState(cache["gla"].S, cache["gla"].n,
                            jnp.full(cache["gla"].m.shape, _NEG, jnp.float32))
    return cache


def init_cache(cfg, batch: int):
    return {"layers": _stacked_cache(cfg, batch),
            "pos": jnp.zeros((), jnp.int32)}


def forward(params, tokens, cfg, *, remat: bool = False,
            collect_hidden: bool = False):
    from repro import runtime
    from repro.models.layers import embed, rmsnorm, unembed
    h = embed(params["embed"], tokens).astype(jnp.dtype(cfg.activ_dtype))

    def body(hh, p):
        hh = runtime.shard_activation(hh)
        out, _st = mamba2_forward(p, hh, cfg)
        hh = hh + out
        return hh, (hh if collect_hidden else jnp.zeros((), hh.dtype))

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    h, hs = jax.lax.scan(body, h, params["blocks"])
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], h)
    if collect_hidden:
        return logits, jnp.float32(0.0), hs
    return logits, jnp.float32(0.0)


def _run_cached(params, tokens_or_token, cache, cfg, block_fn):
    """Shared scan-over-layers driver for prefill/extend/decode: ``block_fn``
    maps (p, h, layer_state) -> (h, new_state)."""
    from repro import runtime
    from repro.models.layers import embed, rmsnorm, unembed
    h = embed(params["embed"], tokens_or_token).astype(
        jnp.dtype(cfg.activ_dtype))

    def body(hh, xs):
        p, st = xs
        hh = runtime.shard_activation(hh)
        out, st = block_fn(p, hh, st)
        return hh + out, st

    h, states = jax.lax.scan(body, h, (params["blocks"], cache))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return unembed(params["embed"], h), states


def prefill(params, tokens, cfg):
    """Returns (last-token logits (B,V), cache with final recurrent state)."""
    cache = _stacked_cache(cfg, tokens.shape[0])
    logits, states = _run_cached(
        params, tokens, cache, cfg,
        lambda p, hh, st: mamba2_forward(p, hh, cfg, cache=st))
    return logits[:, -1, :], {"layers": states,
                              "pos": jnp.asarray(tokens.shape[1], jnp.int32)}


def extend_step(params, tokens, cache, cfg):
    """Multi-token cached decode. tokens (B,T) -> (logits (B,T,V), cache)."""
    logits, states = _run_cached(
        params, tokens, cache["layers"], cfg,
        lambda p, hh, st: mamba2_forward(p, hh, cfg, cache=st))
    return logits, {"layers": states,
                    "pos": cache["pos"] + jnp.asarray(tokens.shape[1],
                                                      jnp.int32)}


def decode_step(params, token, cache, cfg):
    """One decode step. token (B,1) -> (logits (B,V), cache)."""
    logits, states = _run_cached(
        params, token, cache["layers"], cfg,
        lambda p, hh, st: mamba2_step(p, hh, st, cfg))
    return logits[:, 0, :], {"layers": states, "pos": cache["pos"] + 1}


# ------------------------------------------------------- batched replay
def tree_where(pred, new, old):
    """Per-leaf ``jnp.where`` over two identically-shaped pytrees: ``pred``
    is a scalar (or broadcastable) bool.  The recurrent families' rewind
    primitive — under ``vmap`` the predicate becomes per-slot, so one call
    selects each slot's state at its own accepted count."""
    return jax.tree.map(lambda a, b: jnp.where(pred, a, b), new, old)


def replay_step(params, tokens, cache, count, cfg):
    """Batched accepted-prefix replay for speculative rewind (family "ssm").

    Recurrent state cannot be rolled back by a ``pos`` write, so rewinding
    to an accepted draft prefix means re-advancing from the pre-round state.
    ``tokens`` (B, T) is the PADDED tape [pending token, draft_0 ..
    draft_{T-2}]; ``count`` () int32 in [0, T] is how many of those tokens
    are actually committed.  The scan advances the state only while
    ``t < count`` (a ``tree_where`` select), so vmapping over slots replays
    every slot's own accepted prefix in ONE fused scan — no host-side
    per-request snapshot+replay.  ``count == 0`` returns ``cache``
    unchanged (frozen slots keep their snapshot)."""
    def body(carry, xs):
        t, tok = xs
        _, nxt = decode_step(params, tok[:, None], carry, cfg)
        return tree_where(t < count, nxt, carry), None

    T = tokens.shape[1]
    cache, _ = jax.lax.scan(body, cache,
                            (jnp.arange(T, dtype=jnp.int32), tokens.T))
    return cache
