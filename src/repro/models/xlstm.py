"""xLSTM (arXiv:2405.04517): alternating mLSTM and sLSTM blocks.

* mLSTM: matrix-memory LSTM with exponential input gating — mathematically a
  gated linear attention; we reuse the stabilized chunked GLA engine from
  ``ssm.py`` (parallel/chunked form for train+prefill, O(1)-state recurrent
  form for decode).
* sLSTM: scalar-memory LSTM with memory mixing (recurrent matrices) —
  inherently sequential; implemented with ``lax.scan`` over time.

d_ff = 0 in the assigned config: blocks carry their own up/down projections
(mLSTM proj factor 2, sLSTM GLU factor 4/3), so there is no separate MLP.
The model has only 12 layers, so layers are a Python loop (no param
stacking needed; HLO stays small because each block is compact).
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import (dense_init, embed, groupnorm_heads, rmsnorm,
                                 split, unembed)
from repro.models.ssm import (GLAState, gla_chunked, gla_step, init_gla_state)
from repro import runtime


def _dt(cfg):
    return jnp.dtype(cfg.param_dtype)


def is_slstm(cfg, layer: int) -> bool:
    k = cfg.xlstm_slstm_every
    return bool(k) and (layer % k == k - 1)


# ----------------------------------------------------------------- mLSTM
def _mlstm_dims(cfg):
    di = 2 * cfg.d_model
    H = cfg.num_heads
    hd = di // H
    return di, H, hd


def init_mlstm(rng, cfg, dtype):
    d = cfg.d_model
    di, H, hd = _mlstm_dims(cfg)
    r = split(rng, 8)
    return {
        "norm": jnp.zeros((d,), dtype),
        "w_up": dense_init(r[0], (d, 2 * di), dtype=dtype),
        "w_q": dense_init(r[1], (di, di), dtype=dtype),
        "w_k": dense_init(r[2], (di, di), dtype=dtype),
        "w_v": dense_init(r[3], (di, di), dtype=dtype),
        "w_i": dense_init(r[4], (di, H), dtype=jnp.float32),
        "w_f": dense_init(r[5], (di, H), dtype=jnp.float32),
        "f_bias": jnp.full((H,), 3.0, jnp.float32),   # open forget gates at init
        "out_norm": jnp.ones((H, hd), jnp.float32),
        "w_down": dense_init(r[6], (di, d), dtype=dtype),
    }


def _mlstm_qkvif(p, xi, cfg):
    B, S, di = xi.shape
    _, H, hd = _mlstm_dims(cfg)
    q = (xi @ p["w_q"]).reshape(B, S, H, hd) / np.sqrt(hd)
    k = (xi @ p["w_k"]).reshape(B, S, H, hd)
    v = (xi @ p["w_v"]).reshape(B, S, H, hd)
    log_i = xi.astype(jnp.float32) @ p["w_i"]                        # exp gate
    log_f = jax.nn.log_sigmoid(xi.astype(jnp.float32) @ p["w_f"] + p["f_bias"])
    return q, k, v, log_i, log_f


def mlstm_forward(p, x, cfg, *, chunk: int = 0, state: GLAState = None):
    """x: (B,S,d) -> (y, final GLAState)."""
    B, S, d = x.shape
    di, H, hd = _mlstm_dims(cfg)
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    up = xn @ p["w_up"]
    xi, z = jnp.split(up, 2, axis=-1)
    q, k, v, log_i, log_f = _mlstm_qkvif(p, xi, cfg)
    ck = chunk or cfg.ssm_chunk
    y, den, m, st = gla_chunked(q, k, v, log_f, log_i, chunk=ck, state=state)
    y = y / jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None]        # mLSTM denom
    y = groupnorm_heads(y, p["out_norm"], cfg.norm_eps)
    y = y.reshape(B, S, di).astype(x.dtype) * jax.nn.silu(z)
    return x + y @ p["w_down"], st


def mlstm_init_cache(cfg, batch: int):
    di, H, hd = _mlstm_dims(cfg)
    return init_gla_state(batch, H, hd, hd)


def mlstm_step(p, x, state: GLAState, cfg):
    """x: (B,1,d)."""
    B, _, d = x.shape
    di, H, hd = _mlstm_dims(cfg)
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    up = xn @ p["w_up"]
    xi, z = jnp.split(up, 2, axis=-1)
    q, k, v, log_i, log_f = _mlstm_qkvif(p, xi, cfg)
    y, den, m, st = gla_step(q[:, 0], k[:, 0], v[:, 0], log_f[:, 0], log_i[:, 0], state)
    y = y / jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None]
    y = groupnorm_heads(y, p["out_norm"], cfg.norm_eps)
    y = y.reshape(B, 1, di).astype(x.dtype) * jax.nn.silu(z)
    return x + y @ p["w_down"], st


# ----------------------------------------------------------------- sLSTM
def init_slstm(rng, cfg, dtype):
    d = cfg.d_model
    H = cfg.num_heads
    hd = d // H
    f = int(d * 4 / 3) // 8 * 8
    r = split(rng, 5)
    return {
        "norm": jnp.zeros((d,), dtype),
        "w_gates": dense_init(r[0], (d, 4 * d), dtype=jnp.float32),
        "r_gates": dense_init(r[1], (H, hd, 4 * hd), scale=1.0, dtype=jnp.float32),
        "g_bias": jnp.concatenate([
            jnp.zeros((d,)), jnp.full((d,), 3.0), jnp.zeros((2 * d,))]).astype(jnp.float32),
        "out_norm": jnp.ones((H, hd), jnp.float32),
        "w_up": dense_init(r[2], (d, 2 * f), dtype=dtype),
        "w_down": dense_init(r[3], (f, d), dtype=dtype),
    }


def slstm_init_cache(cfg, batch: int):
    H = cfg.num_heads
    hd = cfg.d_model // H
    z = jnp.zeros((batch, H, hd), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, H, hd), -1e30, jnp.float32)}


def _slstm_cell(p, xg, st, cfg):
    """One time step. xg: (B, 4d) pre-computed input gates; st: state dict."""
    B = xg.shape[0]
    H = cfg.num_heads
    hd = cfg.d_model // H
    rec = jnp.einsum("bhi,hij->bhj", st["h"], p["r_gates"])          # (B,H,4hd)
    g = xg.reshape(B, H, 4 * hd) + rec + p["g_bias"].reshape(H, 4 * hd)
    zt, ft, it, ot = jnp.split(g, 4, axis=-1)                        # (B,H,hd)
    zt = jnp.tanh(zt)
    ot = jax.nn.sigmoid(ot)
    log_f = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(log_f + st["m"], it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(log_f + st["m"] - m_new)
    c = f_p * st["c"] + i_p * zt
    n = f_p * st["n"] + i_p
    h = ot * c / jnp.maximum(jnp.abs(n), 1.0)
    return {"c": c, "n": n, "h": h, "m": m_new}


def slstm_forward(p, x, cfg, state=None):
    """x: (B,S,d) -> (y, final_state). Sequential scan over time."""
    B, S, d = x.shape
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    xg = xn.astype(jnp.float32) @ p["w_gates"]                        # (B,S,4d)
    st = state or slstm_init_cache(cfg, B)

    def body(st, xg_t):
        st = _slstm_cell(p, xg_t, st, cfg)
        return st, st["h"]

    st, hs = jax.lax.scan(body, st, xg.swapaxes(0, 1))                # scan time
    hs = hs.swapaxes(0, 1)                                            # (B,S,H,hd)
    y = groupnorm_heads(hs, p["out_norm"], cfg.norm_eps).reshape(B, S, d)
    y = y.astype(x.dtype)
    g, u = jnp.split(y @ p["w_up"], 2, axis=-1)
    y = (jax.nn.gelu(g) * u) @ p["w_down"]
    return x + y, st


def slstm_step(p, x, state, cfg):
    B, _, d = x.shape
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    xg = (xn.astype(jnp.float32) @ p["w_gates"])[:, 0]
    st = _slstm_cell(p, xg, state, cfg)
    y = groupnorm_heads(st["h"], p["out_norm"], cfg.norm_eps).reshape(B, 1, d)
    y = y.astype(x.dtype)
    g, u = jnp.split(y @ p["w_up"], 2, axis=-1)
    y = (jax.nn.gelu(g) * u) @ p["w_down"]
    return x + y, st


# ----------------------------------------------------------------- model
def init_params(rng, cfg):
    dtype = _dt(cfg)
    r = split(rng, cfg.num_layers + 2)
    blocks: List[dict] = []
    for l in range(cfg.num_layers):
        if is_slstm(cfg, l):
            blocks.append(init_slstm(r[l], cfg, dtype))
        else:
            blocks.append(init_mlstm(r[l], cfg, dtype))
    from repro.models.layers import init_embedding
    return {
        "embed": init_embedding(r[-2], cfg.vocab_size, cfg.d_model, dtype),
        "blocks": blocks,
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }


def forward(params, tokens, cfg, *, remat: bool = False,
            collect_hidden: bool = False):
    h = embed(params["embed"], tokens).astype(jnp.dtype(cfg.activ_dtype))
    hiddens = []
    for l, p in enumerate(params["blocks"]):
        h = runtime.shard_activation(h)
        if is_slstm(cfg, l):
            fn = lambda pp, hh: slstm_forward(pp, hh, cfg)
        else:
            fn = lambda pp, hh: mlstm_forward(pp, hh, cfg)
        if remat:
            fn = jax.checkpoint(fn,
                                policy=jax.checkpoint_policies.nothing_saveable)
        h, _ = fn(p, h)
        if collect_hidden:
            hiddens.append(h)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], h)
    if collect_hidden:
        return logits, jnp.float32(0.0), jnp.stack(hiddens)
    return logits, jnp.float32(0.0)


def init_cache(cfg, batch: int):
    cache = []
    for l in range(cfg.num_layers):
        if is_slstm(cfg, l):
            cache.append(slstm_init_cache(cfg, batch))
        else:
            cache.append(mlstm_init_cache(cfg, batch))
    return {"layers": cache, "pos": jnp.zeros((), jnp.int32)}


def prefill(params, tokens, cfg):
    """Returns (last-token logits (B,V), cache with final recurrent states)."""
    h = embed(params["embed"], tokens).astype(jnp.dtype(cfg.activ_dtype))
    states = []
    for l, p in enumerate(params["blocks"]):
        h = runtime.shard_activation(h)
        if is_slstm(cfg, l):
            h, st = slstm_forward(p, h, cfg)
        else:
            h, st = mlstm_forward(p, h, cfg)
        states.append(st)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], h[:, -1, :])
    return logits, {"layers": states, "pos": jnp.asarray(tokens.shape[1], jnp.int32)}


def extend_step(params, tokens, cache, cfg):
    """Multi-token cached decode: tokens (B,T). Returns (logits (B,T,V), cache)."""
    h = embed(params["embed"], tokens).astype(jnp.dtype(cfg.activ_dtype))
    states = []
    for l, (p, st) in enumerate(zip(params["blocks"], cache["layers"])):
        if is_slstm(cfg, l):
            h, st = slstm_forward(p, h, cfg, state=st)
        else:
            h, st = mlstm_forward(p, h, cfg, state=st)
        states.append(st)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], h)
    return logits, {"layers": states,
                    "pos": cache["pos"] + jnp.asarray(tokens.shape[1], jnp.int32)}


def decode_step(params, token, cache, cfg):
    h = embed(params["embed"], token).astype(jnp.dtype(cfg.activ_dtype))
    new_states = []
    for l, (p, st) in enumerate(zip(params["blocks"], cache["layers"])):
        if is_slstm(cfg, l):
            h, st = slstm_step(p, h, st, cfg)
        else:
            h, st = mlstm_step(p, h, st, cfg)
        new_states.append(st)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], h[:, 0, :])
    return logits, {"layers": new_states, "pos": cache["pos"] + 1}


def replay_step(params, tokens, cache, count, cfg):
    """Batched accepted-prefix replay for speculative rewind (see
    ``models.ssm.replay_step`` — same contract: advance the mLSTM/sLSTM
    states through ``tokens[:, :count]`` of the padded draft tape, one
    ``tree_where``-gated scan step per token, so vmapping over slots rewinds
    each slot to its own accepted count without host-side snapshot+replay."""
    from repro.models.ssm import tree_where

    def body(carry, xs):
        t, tok = xs
        _, nxt = decode_step(params, tok[:, None], carry, cfg)
        return tree_where(t < count, nxt, carry), None

    T = tokens.shape[1]
    cache, _ = jax.lax.scan(body, cache,
                            (jnp.arange(T, dtype=jnp.int32), tokens.T))
    return cache
