"""Decoder-only transformer (dense / moe / vlm families).

Scan-over-layers with stacked params keeps the HLO one-layer-sized, which
matters both for the 80 dry-run compiles in this container and for real
compile times on pods.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import runtime
from repro.models import layers as L
from repro.models import moe as MOE


def _dt(cfg):
    return jnp.dtype(cfg.param_dtype)


def _adt(cfg):
    return jnp.dtype(cfg.activ_dtype)


# ----------------------------------------------------------------- init
def init_params(rng, cfg):
    dtype = _dt(cfg)
    r = L.split(rng, cfg.num_layers + 3)

    def one_block(rng_l):
        rr = L.split(rng_l, 2)
        blk = {
            "attn_norm": jnp.zeros((cfg.d_model,), dtype),
            "attn": L.init_attention(rr[0], cfg, dtype),
            "mlp_norm": jnp.zeros((cfg.d_model,), dtype),
        }
        if cfg.family == "moe":
            blk["moe"] = MOE.init_moe(rr[1], cfg, dtype)
        else:
            blk["mlp"] = L.init_mlp(rr[1], cfg, dtype)
        return blk

    blocks = jax.tree.map(lambda *xs: jnp.stack(xs),
                          *[one_block(r[i]) for i in range(cfg.num_layers)])
    params = {
        "embed": L.init_embedding(r[-3], cfg.vocab_size, cfg.d_model, dtype),
        "blocks": blocks,
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_embedding(r[-2], cfg.vocab_size, cfg.d_model, dtype)
    return params


def _head(params):
    return params.get("lm_head", params["embed"])


# ----------------------------------------------------------------- blocks
def _block(p, h, positions, cfg, mask):
    window, prefix_len = mask   # (window, prefix_len); causal always True here
    h = runtime.shard_activation(h)
    a, _kv = L.attention_block(p["attn"], L.rmsnorm(h, p["attn_norm"], cfg.norm_eps),
                               positions, cfg, window=window,
                               prefix_len=prefix_len)
    h = h + a
    hn = L.rmsnorm(h, p["mlp_norm"], cfg.norm_eps)
    if cfg.family == "moe":
        m, aux = MOE.moe_apply(p["moe"], hn, cfg)
    else:
        m, aux = L.mlp_block(p["mlp"], hn, cfg.mlp_activation), jnp.float32(0.0)
    return h + m, aux, _kv


# ----------------------------------------------------------------- forward
def forward(params, tokens, cfg, *, embeds=None, window: int = 0,
            remat: bool = False, collect_hidden: bool = False):
    """Training / scoring forward pass.

    tokens: (B, S_text) int32.  For vlm, ``embeds`` (B, P, d) is prepended
    (prefix-LM bidirectional attention over the prefix).
    Returns (logits (B, S_total, V) f32, aux_loss, hidden?) .
    """
    h = L.embed(params["embed"], tokens).astype(_adt(cfg))
    prefix_len = 0
    if embeds is not None:
        h = jnp.concatenate([embeds.astype(h.dtype), h], axis=1)
        prefix_len = embeds.shape[1]
    S = h.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    mask = (window or cfg.sliding_window, prefix_len)

    def body(carry, p):
        hh, aux = carry
        hh, a, _ = _block(p, hh, positions, cfg, mask)
        y = hh if collect_hidden else jnp.zeros((), hh.dtype)
        return (hh, aux + a), y

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (h, aux), hs = jax.lax.scan(body, (h, jnp.float32(0.0)), params["blocks"])
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(_head(params), h)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    if collect_hidden:
        return logits, aux, hs
    return logits, aux


# ----------------------------------------------------------------- cache
def init_cache(cfg, batch: int, max_seq: int, dtype=None):
    dtype = dtype or _dt(cfg)
    shape = (cfg.num_layers, batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def init_paged_cache(cfg, num_blocks: int, block_size: int, batch: int,
                     max_blocks: int, dtype=None):
    """Paged twin of ``init_cache``: ONE (num_blocks, block_size) K/V pool
    per layer shared by all ``batch`` sequences, a per-sequence block table
    (padded with the trap block 0) and per-sequence write positions.  See
    ``core/paged_cache.py`` for the allocation protocol."""
    dtype = dtype or _dt(cfg)
    shape = (cfg.num_layers, num_blocks, block_size, cfg.num_kv_heads,
             cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "table": jnp.zeros((batch, max_blocks), jnp.int32),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def paged_decode_step(params, token, cache, cfg, *, attn_backend: str = "auto"):
    """One decode step over a paged cache. token: (B, 1) int32; cache as
    built by ``init_paged_cache``.  Returns (logits (B, V), cache).

    The batched counterpart of vmapping ``decode_step`` over stacked dense
    slots: same math, but K/V are read and written through the block table
    so per-sequence capacity is whatever the scheduler allocated.  The
    attention read dispatches per backend (TPU: the Pallas flash-decoding
    paged kernel — including its windowed variant for
    ``cfg.sliding_window`` configs; CPU: the pure-jnp oracle) instead of
    gathering the full block-table width every step.  Only
    ``attn_backend="gather"`` keeps the general T=1 ``paged_extend_step``
    path (the parity oracle for tests) — no config falls off the kernel
    fast path."""
    if attn_backend != "gather":
        return _paged_decode_step_kernel(params, token, cache, cfg,
                                         attn_backend)
    logits, cache = paged_extend_step(params, token, cache, cfg)
    return logits[:, 0], cache


def _paged_decode_step_kernel(params, token, cache, cfg, backend: str):
    """T=1 paged decode with the dispatched attention read
    (``layers.paged_decode_attention_block``)."""
    h = L.embed(params["embed"], token).astype(_adt(cfg))
    pos, table = cache["pos"], cache["table"]

    def body(hh, xs):
        p, ck, cv = xs
        hh = runtime.shard_activation(hh)
        hn = L.rmsnorm(hh, p["attn_norm"], cfg.norm_eps)
        a, ck, cv = L.paged_decode_attention_block(p["attn"], hn, ck, cv,
                                                   table, pos, cfg,
                                                   backend=backend)
        hh = hh + a
        hn = L.rmsnorm(hh, p["mlp_norm"], cfg.norm_eps)
        if cfg.family == "moe":
            m, _ = MOE.moe_apply(p["moe"], hn, cfg)
        else:
            m = L.mlp_block(p["mlp"], hn, cfg.mlp_activation)
        return hh + m, (ck, cv)

    h, (ks, vs) = jax.lax.scan(body, h, (params["blocks"], cache["k"],
                                         cache["v"]))
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    # pin the paged serving path's logits to batch sharding: the lm_head
    # contraction is vocab-sharded over 'model', and without this XLA defers
    # a vocab-sharded (B, V) tensor to the sampler's argmax/categorical
    logits = runtime.shard_activation(L.unembed(_head(params), h[:, 0, :]))
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits, {**cache, "k": ks, "v": vs, "pos": pos + 1}


def paged_extend_step(params, tokens, cache, cfg):
    """Multi-token cached decode over a paged cache (speculative verify).
    tokens (B, T) -> (logits (B, T, V), cache)."""
    h = L.embed(params["embed"], tokens).astype(_adt(cfg))
    pos, table = cache["pos"], cache["table"]
    T = tokens.shape[1]

    def body(hh, xs):
        p, ck, cv = xs
        hh = runtime.shard_activation(hh)
        hn = L.rmsnorm(hh, p["attn_norm"], cfg.norm_eps)
        a, ck, cv = L.paged_extend_attention(p["attn"], hn, ck, cv, table,
                                             pos, cfg)
        hh = hh + a
        hn = L.rmsnorm(hh, p["mlp_norm"], cfg.norm_eps)
        if cfg.family == "moe":
            m, _ = MOE.moe_apply(p["moe"], hn, cfg)
        else:
            m = L.mlp_block(p["mlp"], hn, cfg.mlp_activation)
        return hh + m, (ck, cv)

    h, (ks, vs) = jax.lax.scan(body, h, (params["blocks"], cache["k"],
                                         cache["v"]))
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    # batch-shard the verify logits for the same reason as the decode step
    logits = runtime.shard_activation(L.unembed(_head(params), h))
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits, {**cache, "k": ks, "v": vs,
                    "pos": pos + jnp.asarray(T, jnp.int32)}


def prefill(params, tokens, cfg, *, max_seq: Optional[int] = None,
            embeds=None, window: int = 0):
    """Run the prompt, build the KV cache. Returns (last-token logits, cache)."""
    h = L.embed(params["embed"], tokens).astype(_adt(cfg))
    prefix_len = 0
    if embeds is not None:
        h = jnp.concatenate([embeds.astype(h.dtype), h], axis=1)
        prefix_len = embeds.shape[1]
    S = h.shape[1]
    max_seq = max_seq or S
    positions = jnp.arange(S, dtype=jnp.int32)
    mask = (window or cfg.sliding_window, prefix_len)

    def body(carry, p):
        hh = carry
        hh, _aux, (k, v) = _block(p, hh, positions, cfg, mask)
        return hh, (k, v)

    h, (ks, vs) = jax.lax.scan(body, h, params["blocks"])
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(_head(params), h[:, -1:, :])[:, 0]
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    pad = max_seq - S
    if pad > 0:
        zpad = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
        ks = jnp.pad(ks, zpad)
        vs = jnp.pad(vs, zpad)
    cache = {"k": ks.astype(_dt(cfg)), "v": vs.astype(_dt(cfg)),
             "pos": jnp.asarray(S, jnp.int32)}
    return logits, cache


def extend_step(params, tokens, cache, cfg, *, window: int = 0, block_mask=None,
                q_positions=None):
    """Multi-token cached decode. tokens (B,T) -> (logits (B,T,V), cache).
    ``block_mask`` (T,C), C >= T, customizes intra-block attention (its
    last T columns are the new tokens, earlier columns cover tree nodes
    already in the cache — see layers.extend_attention); ``q_positions``
    overrides RoPE positions (token trees)."""
    h = L.embed(params["embed"], tokens).astype(_adt(cfg))
    pos = cache["pos"]
    T = tokens.shape[1]

    def body(hh, xs):
        p, ck, cv = xs
        hh = runtime.shard_activation(hh)
        hn = L.rmsnorm(hh, p["attn_norm"], cfg.norm_eps)
        a, ck, cv = L.extend_attention(p["attn"], hn, ck, cv, pos, cfg,
                                       window=window or cfg.sliding_window,
                                       block_mask=block_mask,
                                       q_positions=q_positions)
        hh = hh + a
        hn = L.rmsnorm(hh, p["mlp_norm"], cfg.norm_eps)
        if cfg.family == "moe":
            m, _ = MOE.moe_apply(p["moe"], hn, cfg)
        else:
            m = L.mlp_block(p["mlp"], hn, cfg.mlp_activation)
        return hh + m, (ck, cv)

    h, (ks, vs) = jax.lax.scan(body, h, (params["blocks"], cache["k"], cache["v"]))
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(_head(params), h)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits, {"k": ks, "v": vs, "pos": pos + jnp.asarray(T, jnp.int32)}


def decode_step(params, token, cache, cfg, *, window: int = 0):
    """One decode step. token: (B, 1) int32. Returns (logits (B,V), cache)."""
    h = L.embed(params["embed"], token).astype(_adt(cfg))
    pos = cache["pos"]

    def body(hh, xs):
        p, ck, cv = xs
        hh = runtime.shard_activation(hh)
        hn = L.rmsnorm(hh, p["attn_norm"], cfg.norm_eps)
        a, ck, cv = L.decode_attention(p["attn"], hn, ck, cv, pos, cfg,
                                       window=window or cfg.sliding_window)
        hh = hh + a
        hn = L.rmsnorm(hh, p["mlp_norm"], cfg.norm_eps)
        if cfg.family == "moe":
            m, _ = MOE.moe_apply(p["moe"], hn, cfg)
        else:
            m = L.mlp_block(p["mlp"], hn, cfg.mlp_activation)
        return hh + m, (ck, cv)

    h, (ks, vs) = jax.lax.scan(body, h, (params["blocks"], cache["k"], cache["v"]))
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(_head(params), h[:, 0, :])
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits, {"k": ks, "v": vs, "pos": pos + 1}
