"""Whisper-style encoder-decoder (arXiv:2212.04356).

The mel-spectrogram + conv feature extractor is a STUB per the assignment
carve-out: the encoder consumes precomputed frame embeddings
(B, encoder_seq, d_model).  Learned positional embeddings, GELU MLPs,
pre-LayerNorm blocks — faithful to Whisper's transformer backbone.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import runtime
from repro.models import layers as L


def _dt(cfg):
    return jnp.dtype(cfg.param_dtype)


def init_params(rng, cfg):
    dtype = _dt(cfg)
    r = L.split(rng, 8)

    def enc_block(rng_l):
        rr = L.split(rng_l, 2)
        return {
            "attn_norm_w": jnp.ones((cfg.d_model,), dtype),
            "attn_norm_b": jnp.zeros((cfg.d_model,), dtype),
            "attn": L.init_attention(rr[0], cfg, dtype),
            "mlp_norm_w": jnp.ones((cfg.d_model,), dtype),
            "mlp_norm_b": jnp.zeros((cfg.d_model,), dtype),
            "mlp": L.init_mlp(rr[1], cfg, dtype),
        }

    def dec_block(rng_l):
        rr = L.split(rng_l, 3)
        blk = enc_block(rng_l)
        blk.update({
            "cross_norm_w": jnp.ones((cfg.d_model,), dtype),
            "cross_norm_b": jnp.zeros((cfg.d_model,), dtype),
            "cross": L.init_attention(rr[2], cfg, dtype),
        })
        return blk

    enc_rngs = L.split(r[0], cfg.encoder_layers)
    dec_rngs = L.split(r[1], cfg.num_layers)
    enc = jax.tree.map(lambda *xs: jnp.stack(xs), *[enc_block(x) for x in enc_rngs])
    dec = jax.tree.map(lambda *xs: jnp.stack(xs), *[dec_block(x) for x in dec_rngs])
    return {
        "enc_pos": L.dense_init(r[2], (cfg.encoder_seq, cfg.d_model), dtype=dtype),
        "dec_pos": L.dense_init(r[3], (cfg.max_position_embeddings, cfg.d_model),
                                dtype=dtype),
        "embed": L.init_embedding(r[4], cfg.vocab_size, cfg.d_model, dtype),
        "encoder": enc,
        "decoder": dec,
        "enc_norm_w": jnp.ones((cfg.d_model,), dtype),
        "enc_norm_b": jnp.zeros((cfg.d_model,), dtype),
        "final_norm_w": jnp.ones((cfg.d_model,), dtype),
        "final_norm_b": jnp.zeros((cfg.d_model,), dtype),
    }


def encode(params, frames, cfg):
    """frames: (B, Se, d) precomputed embeddings -> (B, Se, d)."""
    Se = frames.shape[1]
    h = frames.astype(jnp.dtype(cfg.activ_dtype)) + params["enc_pos"][None, :Se]
    positions = jnp.arange(Se, dtype=jnp.int32)

    def body(hh, p):
        hh = runtime.shard_activation(hh)
        a, _ = L.attention_block(
            p["attn"], L.layernorm(hh, p["attn_norm_w"], p["attn_norm_b"]),
            positions, cfg, causal=False)
        hh = hh + a
        m = L.mlp_block(p["mlp"], L.layernorm(hh, p["mlp_norm_w"], p["mlp_norm_b"]),
                        cfg.mlp_activation)
        return hh + m, jnp.zeros((), hh.dtype)

    h, _ = jax.lax.scan(body, h, params["encoder"])
    return L.layernorm(h, params["enc_norm_w"], params["enc_norm_b"])


def _dec_block(p, h, positions, cfg, mask, ck, cv):
    a, kv = L.attention_block(
        p["attn"], L.layernorm(h, p["attn_norm_w"], p["attn_norm_b"]),
        positions, cfg)
    h = h + a
    c = L.cross_attention(p["cross"],
                          L.layernorm(h, p["cross_norm_w"], p["cross_norm_b"]),
                          ck, cv, cfg)
    h = h + c
    m = L.mlp_block(p["mlp"], L.layernorm(h, p["mlp_norm_w"], p["mlp_norm_b"]),
                    cfg.mlp_activation)
    return h + m, kv


def forward(params, tokens, cfg, *, frames=None, remat: bool = False,
            collect_hidden: bool = False):
    """Teacher-forced decoder logits. frames: (B, Se, d) stub embeddings."""
    enc = encode(params, frames, cfg)
    B, Sd = tokens.shape
    h = L.embed(params["embed"], tokens).astype(jnp.dtype(cfg.activ_dtype))
    h = h + params["dec_pos"][None, :Sd]
    positions = jnp.arange(Sd, dtype=jnp.int32)

    def body(hh, p):
        hh = runtime.shard_activation(hh)
        ck, cv = L.cross_attention_kv(p["cross"], enc, cfg)
        hh, _ = _dec_block(p, hh, positions, cfg, None, ck, cv)
        y = hh if collect_hidden else jnp.zeros((), hh.dtype)
        return hh, y

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    h, hs = jax.lax.scan(body, h, params["decoder"])
    h = L.layernorm(h, params["final_norm_w"], params["final_norm_b"])
    logits = L.unembed(params["embed"], h)
    if collect_hidden:
        return logits, jnp.float32(0.0), hs
    return logits, jnp.float32(0.0)


# ----------------------------------------------------------------- cache
def init_cache(cfg, batch: int, max_seq: int, dtype=None):
    dtype = dtype or _dt(cfg)
    Ld = cfg.num_layers
    self_shape = (Ld, batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
    cross_shape = (Ld, batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(self_shape, dtype),
        "v": jnp.zeros(self_shape, dtype),
        "ck": jnp.zeros(cross_shape, dtype),
        "cv": jnp.zeros(cross_shape, dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(params, tokens, cfg, *, frames=None, max_seq=None):
    """Encode + run decoder prompt; build self- and cross-attention caches."""
    enc = encode(params, frames, cfg)
    B, Sd = tokens.shape
    max_seq = max_seq or Sd
    h = L.embed(params["embed"], tokens).astype(jnp.dtype(cfg.activ_dtype))
    h = h + params["dec_pos"][None, :Sd]
    positions = jnp.arange(Sd, dtype=jnp.int32)

    def body(hh, p):
        hh = runtime.shard_activation(hh)
        ck, cv = L.cross_attention_kv(p["cross"], enc, cfg)
        hh, (k, v) = _dec_block(p, hh, positions, cfg, None, ck, cv)
        return hh, (k, v, ck, cv)

    h, (ks, vs, cks, cvs) = jax.lax.scan(body, h, params["decoder"])
    h = L.layernorm(h, params["final_norm_w"], params["final_norm_b"])
    logits = L.unembed(params["embed"], h[:, -1, :])
    pad = max_seq - Sd
    if pad > 0:
        zp = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
        ks, vs = jnp.pad(ks, zp), jnp.pad(vs, zp)
    dt = _dt(cfg)
    return logits, {"k": ks.astype(dt), "v": vs.astype(dt),
                    "ck": cks.astype(dt), "cv": cvs.astype(dt),
                    "pos": jnp.asarray(Sd, jnp.int32)}


def extend_step(params, tokens, cache, cfg):
    """Multi-token cached decode on the decoder side. tokens (B,T)."""
    B, T = tokens.shape
    pos = cache["pos"]
    h = L.embed(params["embed"], tokens).astype(jnp.dtype(cfg.activ_dtype))
    h = h + jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos, T, axis=0)[None]

    def body(hh, xs):
        p, ck_, cv_, xk, xv = xs
        hn = L.layernorm(hh, p["attn_norm_w"], p["attn_norm_b"])
        a, ck_, cv_ = L.extend_attention(p["attn"], hn, ck_, cv_, pos, cfg)
        hh = hh + a
        c = L.cross_attention(p["cross"],
                              L.layernorm(hh, p["cross_norm_w"], p["cross_norm_b"]),
                              xk, xv, cfg)
        hh = hh + c
        m = L.mlp_block(p["mlp"], L.layernorm(hh, p["mlp_norm_w"], p["mlp_norm_b"]),
                        cfg.mlp_activation)
        return hh + m, (ck_, cv_)

    h, (ks, vs) = jax.lax.scan(
        body, h, (params["decoder"], cache["k"], cache["v"],
                  cache["ck"], cache["cv"]))
    h = L.layernorm(h, params["final_norm_w"], params["final_norm_b"])
    logits = L.unembed(params["embed"], h)
    return logits, {"k": ks, "v": vs, "ck": cache["ck"], "cv": cache["cv"],
                    "pos": pos + jnp.asarray(T, jnp.int32)}


def decode_step(params, token, cache, cfg):
    pos = cache["pos"]
    h = L.embed(params["embed"], token).astype(jnp.dtype(cfg.activ_dtype))
    h = h + jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1, axis=0)[None]

    def body(hh, xs):
        p, ck_, cv_, xk, xv = xs
        hn = L.layernorm(hh, p["attn_norm_w"], p["attn_norm_b"])
        a, ck_, cv_ = L.decode_attention(p["attn"], hn, ck_, cv_, pos, cfg)
        hh = hh + a
        c = L.cross_attention(p["cross"],
                              L.layernorm(hh, p["cross_norm_w"], p["cross_norm_b"]),
                              xk, xv, cfg)
        hh = hh + c
        m = L.mlp_block(p["mlp"], L.layernorm(hh, p["mlp_norm_w"], p["mlp_norm_b"]),
                        cfg.mlp_activation)
        return hh + m, (ck_, cv_)

    h, (ks, vs) = jax.lax.scan(
        body, h, (params["decoder"], cache["k"], cache["v"],
                  cache["ck"], cache["cv"]))
    h = L.layernorm(h, params["final_norm_w"], params["final_norm_b"])
    logits = L.unembed(params["embed"], h[:, 0, :])
    return logits, {"k": ks, "v": vs, "ck": cache["ck"], "cv": cache["cv"],
                    "pos": pos + 1}
