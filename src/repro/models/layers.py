"""Shared neural-net layers (pure functions over param pytrees).

Conventions:
  * params are nested dicts of jnp arrays; layer-stacked params have a
    leading ``L`` axis and are consumed via ``lax.scan``.
  * activations run in ``cfg.activ_dtype``; softmax/normalization in f32.
  * attention layout: q (B, S, H, hd); kv (B, S, Kv, hd); GQA groups G=H/Kv.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np


# ----------------------------------------------------------------- init utils
def dense_init(rng, shape, scale: float = 1.0, dtype=jnp.float32):
    # fan_in is the next-to-last dim for matrices / batched matrices (E,d,f).
    fan_in = shape[-2] if len(shape) >= 2 else shape[0]
    std = scale / np.sqrt(fan_in)
    return (jax.random.normal(rng, shape) * std).astype(dtype)


def split(rng, n):
    return list(jax.random.split(rng, n))


# ----------------------------------------------------------------- norms
def rmsnorm(x, weight, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def layernorm(x, weight, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def groupnorm_heads(x, weight, eps: float = 1e-5):
    """Per-head group norm used by xLSTM cell outputs. x: (..., H, hd)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(dt)


# ----------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float = 10_000.0):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (B, S, H, hd); positions: (S,) or (B, S) int32."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), dtype=jnp.float32)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]   # (S, hd/2)
        ang = ang[None, :, None, :]                                      # (1,S,1,hd/2)
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs           # (B,S,hd/2)
        ang = ang[:, :, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- attention
def init_attention(rng, cfg, dtype):
    d, hd, H, Kv = cfg.d_model, cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    r = split(rng, 4)
    return {
        "wq": dense_init(r[0], (d, H * hd), dtype=dtype),
        "wk": dense_init(r[1], (d, Kv * hd), dtype=dtype),
        "wv": dense_init(r[2], (d, Kv * hd), dtype=dtype),
        "wo": dense_init(r[3], (H * hd, d), dtype=dtype),
    }


def _attn_mask(q_pos, k_pos, *, causal: bool, window: int, prefix_len: int):
    """Boolean mask (..., Sq, Sk): True = attend."""
    m = jnp.ones(q_pos.shape[-1:] + k_pos.shape[-1:], dtype=bool)
    if causal:
        m = k_pos[None, :] <= q_pos[:, None]
        if prefix_len:
            # prefix-LM: bidirectional over the first `prefix_len` positions
            m = m | (k_pos[None, :] < prefix_len)
    if window:
        m = m & (k_pos[None, :] > q_pos[:, None] - window)
    return m


def mha(q, k, v, mask=None, softcap: float = 0.0):
    """q: (B,Sq,H,hd), k/v: (B,Sk,Kv,hd). Returns (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    Kv = k.shape[2]
    G = H // Kv
    qf = q.reshape(B, Sq, Kv, G, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qf, kf) / np.sqrt(hd)
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def mha_chunked(q, k, v, *, causal: bool = True, window: int = 0,
                prefix_len: int = 0, bq: int = 512, bk: int = 512):
    """Flash-style chunked attention in pure jnp (double lax.scan with online
    softmax).  Memory O(BQ*BK) per step instead of O(Sq*Sk) — the XLA
    equivalent of the Pallas flash kernel, used for long prefills where the
    full score matrix cannot be materialized.

    q: (B,Sq,H,hd); k/v: (B,Sk,Kv,hd). Returns (B,Sq,H,hd).
    NOTE: computes all (Sq/bq)x(Sk/bk) blocks including fully-masked ones
    (baseline; block-skipping is a recorded perf iteration).
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    Kv = k.shape[2]
    G = H // Kv
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0
    nq, nk = Sq // bq, Sk // bk
    scale = 1.0 / np.sqrt(hd)

    qb = q.reshape(B, nq, bq, Kv, G, hd).astype(jnp.float32)
    kb = k.reshape(B, nk, bk, Kv, hd).astype(jnp.float32)
    vb = v.reshape(B, nk, bk, Kv, hd).astype(jnp.float32)

    def q_block(_, iq):
        qq = qb[:, iq]                                     # (B,bq,Kv,G,hd)
        q_pos = iq * bq + jnp.arange(bq)

        def kv_block(carry, ik):
            m_run, l_run, acc = carry
            kk = kb[:, ik]                                 # (B,bk,Kv,hd)
            vv = vb[:, ik]
            k_pos = ik * bk + jnp.arange(bk)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qq, kk) * scale
            msk = jnp.ones((bq, bk), bool)
            if causal:
                msk = k_pos[None, :] <= q_pos[:, None]
                if prefix_len:
                    msk = msk | (k_pos[None, :] < prefix_len)
            if window:
                msk = msk & (k_pos[None, :] > q_pos[:, None] - window)
            s = jnp.where(msk, s, -1e30)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m_run - m_new)
            l_new = l_run * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum("bkgqs,bskh->bkgqh", p, vv)
            return (m_new, l_new, acc), None

        init = (jnp.full((B, Kv, G, bq), -1e30, jnp.float32),
                jnp.zeros((B, Kv, G, bq), jnp.float32),
                jnp.zeros((B, Kv, G, bq, hd), jnp.float32))
        (m_f, l_f, acc), _ = jax.lax.scan(kv_block, init, jnp.arange(nk))
        out = acc / jnp.maximum(l_f, 1e-20)[..., None]     # (B,Kv,G,bq,hd)
        return None, out

    _, outs = jax.lax.scan(q_block, None, jnp.arange(nq))  # (nq,B,Kv,G,bq,hd)
    out = jnp.moveaxis(outs, 0, 1)                          # (B,nq,Kv,G,bq,hd)
    out = jnp.moveaxis(out, -2, 2)                          # (B,nq,bq,Kv,G,hd)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


# Sequence length above which prefill/train attention switches to the
# chunked (flash-equivalent) path.  Perf iteration #5 tried 4096 and was
# REFUTED: at train_4k the chunked double-scan's per-block dynamic slices
# sit at fusion boundaries, where both our analyzer and XLA's cost model
# charge full-operand traffic — measured memory term rose 5x
# (EXPERIMENTS.md §Perf).  8192 keeps chunking where it is essential
# (32k prefill) and the dense mha path where the (S,S) scores still fit.
CHUNKED_ATTN_THRESHOLD = 8192


def attention_block(p, x, positions, cfg, *, causal: bool = True,
                    window: int = 0, prefix_len: int = 0, rope_theta=None):
    """Full (prefill / train) attention. x: (B,S,d) -> (B,S,d), plus (k,v).
    Long sequences (>= CHUNKED_ATTN_THRESHOLD) take the flash-equivalent
    chunked path; short ones materialize the (S,S) mask directly."""
    B, S, d = x.shape
    H, Kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, Kv, hd)
    v = (x @ p["wv"]).reshape(B, S, Kv, hd)
    if cfg.use_rope:
        theta = rope_theta if rope_theta is not None else cfg.rope_theta
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    if S >= CHUNKED_ATTN_THRESHOLD:
        out = mha_chunked(q, k, v, causal=causal, window=window,
                          prefix_len=prefix_len)
    else:
        mask = _attn_mask(positions, positions, causal=causal, window=window,
                          prefix_len=prefix_len) if causal or window else None
        out = mha(q, k, v, mask=mask)
    return out.reshape(B, S, H * hd) @ p["wo"], (k, v)


def decode_attention(p, x, cache_k, cache_v, pos, cfg, *, window: int = 0):
    """Single-token decode. x: (B,1,d); cache_k/v: (B,Smax,Kv,hd); pos ().

    Returns (out (B,1,d), new_k, new_v). With ``window`` > 0, only the last
    ``window`` cache entries are read (sliding-window decode for long ctx).
    """
    B, _, d = x.shape
    H, Kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, 1, H, hd)
    k = (x @ p["wk"]).reshape(B, 1, Kv, hd)
    v = (x @ p["wv"]).reshape(B, 1, Kv, hd)
    if cfg.use_rope:
        pp = jnp.full((1,), pos, dtype=jnp.int32)
        q = apply_rope(q, pp, cfg.rope_theta)
        k = apply_rope(k, pp, cfg.rope_theta)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), pos, axis=1)
    if window:
        start = jnp.maximum(pos - (window - 1), 0)
        kk = jax.lax.dynamic_slice_in_dim(cache_k, start, window, axis=1)
        vv = jax.lax.dynamic_slice_in_dim(cache_v, start, window, axis=1)
        k_pos = start + jnp.arange(window)
    else:
        kk, vv = cache_k, cache_v
        k_pos = jnp.arange(cache_k.shape[1])
    mask = (k_pos <= pos)[None, None, None, None, :]   # (1,1,1,1,Sk) over bkgqs
    out = mha(q, kk, vv, mask=mask)
    return out.reshape(B, 1, H * hd) @ p["wo"], cache_k, cache_v


def paged_extend_attention(p, x, k_pool, v_pool, table, pos, cfg):
    """Cached decode through a paged KV pool (whole batch at once; T=1 is
    the single-token decode step, T>1 the speculative verify).

    x: (B,T,d); k_pool/v_pool: (NB, bs, Kv, hd) — ONE block pool shared by
    all sequences; table: (B, MB) int32 block table (logical position ``t``
    of sequence ``b`` lives in block ``table[b, t // bs]`` at offset
    ``t % bs``); pos: (B,) per-sequence write position.

    Unlike the dense paths (scalar ``pos``, vmapped per slot), this is
    inherently batched: the pool has no leading batch axis, so the new K/V
    land via one advanced-indexing scatter and the read is a (B, MB)
    block-table gather.  Out-of-range positions (a retired slot
    garbage-decoding past its table) clamp to the last table entry, which
    the scheduler keeps pointed at the trap block.  Intra-block attention
    is causal, windowed by ``cfg.sliding_window`` exactly like the dense
    decode (block masks / token trees stay on the dense layout).
    Returns (out (B,T,d), new_k_pool, new_v_pool).
    """
    B, T, d = x.shape
    _, bs, Kv, hd = k_pool.shape
    H = cfg.num_heads
    q = (x @ p["wq"]).reshape(B, T, H, hd)
    k = (x @ p["wk"]).reshape(B, T, Kv, hd)
    v = (x @ p["wv"]).reshape(B, T, Kv, hd)
    q_pos = pos[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]   # (B, T)
    if cfg.use_rope:
        q = apply_rope(q, q_pos, cfg.rope_theta)
        k = apply_rope(k, q_pos, cfg.rope_theta)
    blk = jnp.take_along_axis(table, q_pos // bs, axis=1)            # (B, T)
    off = q_pos % bs
    k_pool = k_pool.at[blk, off].set(k.astype(k_pool.dtype))
    v_pool = v_pool.at[blk, off].set(v.astype(v_pool.dtype))
    kk = k_pool[table].reshape(B, -1, Kv, hd)
    vv = v_pool[table].reshape(B, -1, Kv, hd)
    k_pos = jnp.arange(kk.shape[1], dtype=jnp.int32)
    mask = k_pos[None, None, :] <= q_pos[:, :, None]                 # (B,T,S)
    if cfg.sliding_window:
        mask = mask & (k_pos[None, None, :] >
                       q_pos[:, :, None] - cfg.sliding_window)
    out = mha(q, kk, vv, mask=mask[:, None, None, :, :])
    return out.reshape(B, T, H * hd) @ p["wo"], k_pool, v_pool


def paged_decode_attention_block(p, x, k_pool, v_pool, table, pos, cfg, *,
                                 backend: str = "auto"):
    """Single-token decode through a paged KV pool WITHOUT materializing the
    block-table gather.

    Same write path as ``paged_extend_attention`` (the new K/V land at
    ``table[b, pos // bs]``, offset ``pos % bs``), but the read dispatches
    on backend: TPU runs the flash-decoding Pallas kernel
    (``kernels.ops.paged_decode_attention`` — scalar-prefetched block-table
    index maps, each grid step DMAs exactly one block), CPU runs its
    pure-jnp oracle ``kernels.ref.paged_decode_attention_ref``.  ``backend``
    "kernel" / "ref" force a side (tests); "auto" picks by device.
    ``cfg.sliding_window`` configs run the kernel's windowed variant
    (trailing-window blocks only) — the masked full-width gather is no
    longer on any T=1 decode path.

    x: (B, 1, d); k_pool/v_pool: (NB, bs, Kv, hd); table: (B, MB) int32;
    pos: (B,).  Returns (out (B, 1, d), new_k_pool, new_v_pool).
    """
    from repro.kernels import ops, ref
    B, T, d = x.shape
    assert T == 1, "paged_decode_attention_block is the T=1 fast path"
    _, bs, Kv, hd = k_pool.shape
    H = cfg.num_heads
    G = H // Kv
    q = (x @ p["wq"]).reshape(B, 1, H, hd)
    k = (x @ p["wk"]).reshape(B, 1, Kv, hd)
    v = (x @ p["wv"]).reshape(B, 1, Kv, hd)
    q_pos = pos[:, None]                                             # (B, 1)
    if cfg.use_rope:
        q = apply_rope(q, q_pos, cfg.rope_theta)
        k = apply_rope(k, q_pos, cfg.rope_theta)
    blk = jnp.take_along_axis(table, q_pos // bs, axis=1)[:, 0]      # (B,)
    off = (pos % bs)
    k_pool = k_pool.at[blk, off].set(k[:, 0].astype(k_pool.dtype))
    v_pool = v_pool.at[blk, off].set(v[:, 0].astype(v_pool.dtype))
    qh = q[:, 0].reshape(B, Kv, G, hd)          # head h = kv*G + g, as mha
    length = pos + 1
    win = cfg.sliding_window
    if backend == "kernel" or (backend == "auto" and not ops.on_cpu()):
        out = ops.paged_decode_attention(qh, k_pool, v_pool, table, length,
                                         window=win)
    else:
        out = ref.paged_decode_attention_ref(qh, k_pool, v_pool, table,
                                             length, window=win)
    out = out.reshape(B, 1, H * hd).astype(x.dtype)
    return out @ p["wo"], k_pool, v_pool


def extend_attention(p, x, cache_k, cache_v, pos, cfg, *, window: int = 0,
                     block_mask=None, q_positions=None):
    """Multi-token cached decode (chunked prefill / speculative verify).

    x: (B,T,d); new k/v written into the cache at [pos, pos+T).  By default
    intra-block attention is causal; ``block_mask`` (T,C) with C >= T
    overrides it — its LAST T columns align with the new tokens, earlier
    columns cover tokens already in the cache at [pos-(C-T), pos) (token
    trees drafted level by level; one-shot verification passes C == T) —
    and ``q_positions`` (T,) overrides the RoPE positions (token-tree
    nodes use tree base + node depth).
    Returns (out (B,T,d), new_k, new_v).
    """
    B, T, d = x.shape
    H, Kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    Smax = cache_k.shape[1]
    q = (x @ p["wq"]).reshape(B, T, H, hd)
    k = (x @ p["wk"]).reshape(B, T, Kv, hd)
    v = (x @ p["wv"]).reshape(B, T, Kv, hd)
    q_pos = (pos + jnp.arange(T, dtype=jnp.int32)) if q_positions is None \
        else jnp.asarray(q_positions, jnp.int32)
    if cfg.use_rope:
        q = apply_rope(q, q_pos, cfg.rope_theta)
        k = apply_rope(k, q_pos, cfg.rope_theta)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), pos, axis=1)
    k_pos = jnp.arange(Smax, dtype=jnp.int32)
    if block_mask is None:
        mask = k_pos[None, :] <= q_pos[:, None]                     # (T, Smax)
    else:
        from repro.kernels import ops
        if not ops.on_cpu():
            # token-tree verify on TPU: the flash-decoding tree kernel
            # streams the cache once instead of materializing the
            # (T, Smax) mask; CPU keeps the jnp masked-mha path below
            G = H // Kv
            qh = jnp.transpose(q.reshape(B, T, Kv, G, hd), (0, 2, 3, 1, 4))
            out = ops.tree_verify_attention(
                qh, jnp.moveaxis(cache_k, 2, 1), jnp.moveaxis(cache_v, 2, 1),
                jnp.broadcast_to(pos, (B,)), block_mask,
                jnp.broadcast_to(q_pos, (B, T)), window=window)
            out = jnp.transpose(out, (0, 3, 1, 2, 4)).astype(x.dtype)
            return out.reshape(B, T, H * hd) @ p["wo"], cache_k, cache_v
        off = block_mask.shape[1] - T            # tree nodes already cached
        base = k_pos[None, :] < pos - off                            # cached part
        placed = jax.lax.dynamic_update_slice(
            jnp.zeros((T, Smax), bool), block_mask.astype(bool),
            (0, pos - off))
        mask = base | placed
    if window:
        mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
    out = mha(q, cache_k, cache_v, mask=mask)
    return out.reshape(B, T, H * hd) @ p["wo"], cache_k, cache_v


def cross_attention_kv(p, enc, cfg):
    """Precompute cross-attention k/v from encoder output. enc: (B,Se,d)."""
    B, Se, _ = enc.shape
    Kv, hd = cfg.num_kv_heads, cfg.head_dim
    k = (enc @ p["wk"]).reshape(B, Se, Kv, hd)
    v = (enc @ p["wv"]).reshape(B, Se, Kv, hd)
    return k, v


def cross_attention(p, x, k, v, cfg):
    """x: (B,Sq,d) attends over fixed (k, v). No mask (encoder fully visible)."""
    B, Sq, d = x.shape
    H, hd = cfg.num_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, Sq, H, hd)
    out = mha(q, k, v, mask=None)
    return out.reshape(B, Sq, H * hd) @ p["wo"]


# ----------------------------------------------------------------- mlp
def init_mlp(rng, cfg, dtype):
    d, f = cfg.d_model, cfg.d_ff
    r = split(rng, 3)
    if cfg.mlp_activation in ("silu", "geglu"):
        return {
            "w_gate": dense_init(r[0], (d, f), dtype=dtype),
            "w_up": dense_init(r[1], (d, f), dtype=dtype),
            "w_down": dense_init(r[2], (f, d), dtype=dtype),
        }
    return {   # relu2 / gelu: single up projection
        "w_up": dense_init(r[0], (d, f), dtype=dtype),
        "w_down": dense_init(r[1], (f, d), dtype=dtype),
    }


def mlp_block(p, x, activation: str):
    if activation == "silu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif activation == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif activation == "relu2":
        h = jnp.square(jax.nn.relu(x @ p["w_up"]))
    elif activation == "gelu":
        h = jax.nn.gelu(x @ p["w_up"])
    else:
        raise ValueError(activation)
    return h @ p["w_down"]


# ----------------------------------------------------------------- embeddings
def init_embedding(rng, vocab: int, d: int, dtype):
    # std 0.02, GPT-style; keeps tied-head logits O(1) at init for any vocab.
    return (jax.random.normal(rng, (vocab, d)) * 0.02).astype(dtype)


def embed(table, tokens):
    return jnp.take(table, tokens, axis=0)


def unembed(table_or_head, h):
    """h: (..., d) -> logits (..., V) in f32."""
    return jnp.einsum("...d,vd->...v", h.astype(jnp.float32),
                      table_or_head.astype(jnp.float32))
