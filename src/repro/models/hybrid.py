"""Zamba2-style hybrid (arXiv:2411.15242): Mamba2 backbone + one *shared*
attention+MLP block applied after every ``shared_attn_every`` mamba layers.
The shared block's weights are reused at each application (true to Zamba2),
but each application keeps its own KV cache slot.

Layer stacking: the 54 mamba layers are stacked (G groups x K layers) and
consumed with a nested lax.scan so the HLO stays one-mamba-layer sized.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import runtime
from repro.models import layers as L
from repro.models import ssm as S


def _dims(cfg):
    K = cfg.shared_attn_every
    G = cfg.num_layers // K
    assert G * K == cfg.num_layers
    return G, K


def init_params(rng, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    r = L.split(rng, cfg.num_layers + 4)
    mamba = jax.tree.map(lambda *xs: jnp.stack(xs),
                         *[S.init_mamba2(r[i], cfg, dtype)
                           for i in range(cfg.num_layers)])
    G, K = _dims(cfg)
    mamba = jax.tree.map(lambda x: x.reshape((G, K) + x.shape[1:]), mamba)
    rs = L.split(r[-4], 3)
    shared = {
        "attn_norm": jnp.zeros((cfg.d_model,), dtype),
        "attn": L.init_attention(rs[0], cfg, dtype),
        "mlp_norm": jnp.zeros((cfg.d_model,), dtype),
        "mlp": L.init_mlp(rs[1], cfg, dtype),
    }
    return {
        "embed": L.init_embedding(r[-3], cfg.vocab_size, cfg.d_model, dtype),
        "mamba": mamba,
        "shared": shared,
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }


def forward(params, tokens, cfg, *, window: int = 0, remat: bool = False,
            collect_hidden: bool = False):
    h = L.embed(params["embed"], tokens).astype(jnp.dtype(cfg.activ_dtype))
    B, Sq, d = h.shape
    positions = jnp.arange(Sq, dtype=jnp.int32)
    win = window or cfg.sliding_window
    shared = params["shared"]

    def group(h, mamba_group):
        h = runtime.shard_activation(h)

        def one_mamba(hh, p):
            out, _st = S.mamba2_forward(p, hh, cfg)
            return hh + out, jnp.zeros((), hh.dtype)
        h, _ = jax.lax.scan(one_mamba, h, mamba_group)
        a, _kv = L.attention_block(
            shared["attn"], L.rmsnorm(h, shared["attn_norm"], cfg.norm_eps),
            positions, cfg, window=win)
        h = h + a
        m = L.mlp_block(shared["mlp"], L.rmsnorm(h, shared["mlp_norm"], cfg.norm_eps),
                        cfg.mlp_activation)
        h = h + m
        return h, (h if collect_hidden else jnp.zeros((), h.dtype))

    if remat:
        group = jax.checkpoint(group,
                               policy=jax.checkpoint_policies.nothing_saveable)
    h, hs = jax.lax.scan(group, h, params["mamba"])
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], h)
    if collect_hidden:
        return logits, jnp.float32(0.0), hs
    return logits, jnp.float32(0.0)


# ----------------------------------------------------------------- cache
def init_cache(cfg, batch: int, max_seq: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    G, K = _dims(cfg)
    base = S.mamba2_init_cache(cfg, batch)
    mamba = jax.tree.map(lambda x: jnp.zeros((G, K) + x.shape, x.dtype), base)
    # running-max needs -inf init, not zeros:
    mamba["gla"] = S.GLAState(mamba["gla"].S, mamba["gla"].n,
                              jnp.full(mamba["gla"].m.shape, -1e30, jnp.float32))
    kv_shape = (G, batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
    return {
        "mamba": mamba,
        "k": jnp.zeros(kv_shape, dtype),
        "v": jnp.zeros(kv_shape, dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(params, tokens, cfg, *, max_seq=None, window: int = 0):
    h = L.embed(params["embed"], tokens).astype(jnp.dtype(cfg.activ_dtype))
    B, Sq, d = h.shape
    max_seq = max_seq or Sq
    positions = jnp.arange(Sq, dtype=jnp.int32)
    win = window or cfg.sliding_window
    shared = params["shared"]

    def group(h, mamba_group):
        h = runtime.shard_activation(h)

        def one_mamba(hh, p):
            out, st = S.mamba2_forward(p, hh, cfg)
            return hh + out, st
        h, sts = jax.lax.scan(one_mamba, h, mamba_group)
        a, (k, v) = L.attention_block(
            shared["attn"], L.rmsnorm(h, shared["attn_norm"], cfg.norm_eps),
            positions, cfg, window=win)
        h = h + a
        h = h + L.mlp_block(shared["mlp"], L.rmsnorm(h, shared["mlp_norm"], cfg.norm_eps),
                            cfg.mlp_activation)
        return h, (sts, k, v)

    h, (mamba_states, ks, vs) = jax.lax.scan(group, h, params["mamba"])
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], h[:, -1, :])
    pad = max_seq - Sq
    if pad > 0:
        zp = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
        ks, vs = jnp.pad(ks, zp), jnp.pad(vs, zp)
    dtype = jnp.dtype(cfg.param_dtype)
    cache = {"mamba": mamba_states, "k": ks.astype(dtype), "v": vs.astype(dtype),
             "pos": jnp.asarray(Sq, jnp.int32)}
    return logits, cache


def extend_step(params, tokens, cache, cfg, *, window: int = 0):
    """Multi-token cached decode. tokens (B,T) -> (logits (B,T,V), cache)."""
    h = L.embed(params["embed"], tokens).astype(jnp.dtype(cfg.activ_dtype))
    pos = cache["pos"]
    T = tokens.shape[1]
    shared = params["shared"]

    def group(h, xs):
        mamba_group, mstate, ck, cv = xs
        h = runtime.shard_activation(h)

        def one_mamba(hh, xs2):
            p, st = xs2
            out, st = S.mamba2_forward(p, hh, cfg, cache=st)
            return hh + out, st
        h, msts = jax.lax.scan(one_mamba, h, (mamba_group, mstate))
        hn = L.rmsnorm(h, shared["attn_norm"], cfg.norm_eps)
        a, ck, cv = L.extend_attention(shared["attn"], hn, ck, cv, pos, cfg,
                                       window=window or cfg.sliding_window)
        h = h + a
        h = h + L.mlp_block(shared["mlp"], L.rmsnorm(h, shared["mlp_norm"], cfg.norm_eps),
                            cfg.mlp_activation)
        return h, (msts, ck, cv)

    h, (msts, ks, vs) = jax.lax.scan(
        group, h, (params["mamba"], cache["mamba"], cache["k"], cache["v"]))
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], h)
    return logits, {"mamba": msts, "k": ks, "v": vs,
                    "pos": pos + jnp.asarray(T, jnp.int32)}


def decode_step(params, token, cache, cfg, *, window: int = 0):
    h = L.embed(params["embed"], token).astype(jnp.dtype(cfg.activ_dtype))
    pos = cache["pos"]
    shared = params["shared"]

    def group(h, xs):
        mamba_group, mstate, ck, cv = xs
        h = runtime.shard_activation(h)

        def one_mamba(carry, xs2):
            hh = carry
            p, st = xs2
            out, st = S.mamba2_step(p, hh, st, cfg)
            return hh + out, st
        h, msts = jax.lax.scan(one_mamba, h, (mamba_group, mstate))
        hn = L.rmsnorm(h, shared["attn_norm"], cfg.norm_eps)
        a, ck, cv = L.decode_attention(shared["attn"], hn, ck, cv, pos, cfg,
                                       window=window or cfg.sliding_window)
        h = h + a
        h = h + L.mlp_block(shared["mlp"], L.rmsnorm(h, shared["mlp_norm"], cfg.norm_eps),
                            cfg.mlp_activation)
        return h, (msts, ck, cv)

    h, (msts, ks, vs) = jax.lax.scan(
        group, h, (params["mamba"], cache["mamba"], cache["k"], cache["v"]))
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], h[:, 0, :])
    return logits, {"mamba": msts, "k": ks, "v": vs, "pos": pos + 1}


def replay_step(params, tokens, cache, count, cfg):
    """Batched accepted-prefix replay for speculative rewind (see
    ``models.ssm.replay_step``): advance through ``tokens[:, :count]`` of
    the padded draft tape with a ``tree_where``-gated scan.

    Only the mamba states and ``pos`` are gated.  The shared-attention K/V
    slabs always take the step's write: entries land at monotonically
    increasing positions while the slot is alive, and once ``t >= count``
    the frozen ``pos`` makes dead steps overwrite the single entry AT
    ``pos`` — which is past the committed prefix, masked out of every read
    (``k_pos <= pos``), and rewritten by the next real decode.  That keeps
    the replay from copying the full K/V slabs once per scan step."""
    def body(carry, xs):
        t, tok = xs
        _, nxt = decode_step(params, tok[:, None], carry, cfg)
        take = t < count
        return {"mamba": S.tree_where(take, nxt["mamba"], carry["mamba"]),
                "k": nxt["k"], "v": nxt["v"],
                "pos": jnp.where(take, nxt["pos"], carry["pos"])}, None

    T = tokens.shape[1]
    cache, _ = jax.lax.scan(body, cache,
                            (jnp.arange(T, dtype=jnp.int32), tokens.T))
    return cache
