"""Mixture-of-Experts sublayer (olmoe / granite-moe).

Sort-based capacity dispatch (megablox-style, memory O(T*k + E*C*d)) rather
than the one-hot einsum dispatch (O(T*E*C)) — the latter is intractable at
1M tokens x 64 experts.  Under pjit the (E, C, d) buffers are sharded over
the ``model`` axis (expert parallelism); the scatter/gather to/from the
token-sharded layout lowers to all-to-all style collectives.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, split


def init_moe(rng, cfg, dtype):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    r = split(rng, 4)
    return {
        "router": dense_init(r[0], (d, E), dtype=jnp.float32),
        "w_gate": dense_init(r[1], (E, d, f), dtype=dtype),
        "w_up": dense_init(r[2], (E, d, f), dtype=dtype),
        "w_down": dense_init(r[3], (E, f, d), dtype=dtype),
    }


def capacity(tokens: int, cfg) -> int:
    """Per-expert capacity.  Decode/small batches (T <= 4096) get the
    worst-case dropless capacity so serving is exactly consistent with
    per-token routing; large training batches use the Switch-style
    capacity factor (token dropping is part of the training semantics).

    Dropless bound: top-k indices are DISTINCT per token, so one expert can
    receive at most T assignments — C = T, not T*k (perf iteration #6,
    EXPERIMENTS.md §Perf: 8x less padded expert compute at decode)."""
    if tokens <= 4096:
        return tokens
    c = int(tokens * cfg.top_k / cfg.num_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)   # round up to 8


def moe_block(p, x, cfg):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar f32)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- load-balance auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)                              # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0) / (T * k)
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    # ---- dispatch: sort token-expert assignments by expert id
    C = capacity(T, cfg)
    e_flat = expert_idx.reshape(-1)                           # (T*k,)
    order = jnp.argsort(e_flat)                               # stable
    sorted_e = e_flat[order]
    counts = jnp.zeros((E,), jnp.int32).at[e_flat].add(1)
    starts = jnp.cumsum(counts) - counts                      # (E,)
    pos_in_e = jnp.arange(T * k, dtype=jnp.int32) - starts[sorted_e]
    keep = pos_in_e < C
    dest = jnp.where(keep, sorted_e * C + pos_in_e, E * C)    # E*C = drop slot
    src_tok = order // k                                      # token per slot

    buf = jnp.zeros((E * C, d), x.dtype).at[dest].set(
        xt[src_tok], mode="drop")
    h = buf.reshape(E, C, d)

    # ---- expert computation (batched over experts)
    act = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, p["w_gate"]))
    act = act * jnp.einsum("ecd,edf->ecf", h, p["w_up"])
    out_e = jnp.einsum("ecf,efd->ecd", act, p["w_down"])      # (E, C, d)

    # ---- combine: gather back and weight by (renormalized) gates
    flat = out_e.reshape(E * C, d)
    gathered = jnp.where(keep[:, None], flat.at[dest].get(mode="fill", fill_value=0.0), 0.0)
    w = gate_vals.reshape(-1)[order]                          # (T*k,)
    combined = jnp.zeros((T, d), jnp.float32).at[src_tok].add(
        gathered.astype(jnp.float32) * w[:, None])
    return combined.reshape(B, S, d).astype(x.dtype), aux


def moe_block_sharded(p, x, cfg, mesh, dp_axes, ep_axis: str):
    """Expert-parallel MoE via shard_map (the survey's MoE-based modular
    collaboration, §2.1.2, mapped to a TPU mesh).

    Layout: tokens sharded over ``dp_axes`` (replicated over ``ep_axis``);
    experts sharded over ``ep_axis``; router replicated.  Each device routes
    its LOCAL tokens to its LOCAL experts and the partial outputs are
    ``psum``-ed over the expert axis — the dispatch/combine collective the
    survey's edge<->cloud MoE transfers correspond to.
    """
    from jax.sharding import PartitionSpec as P

    from repro.runtime import shard_map

    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    n_ep = mesh.shape[ep_axis]
    n_dp = 1
    for a in dp_axes:
        n_dp *= mesh.shape[a]
    E_local = E // n_ep
    T_local = (B // n_dp) * S
    C = capacity(T_local, cfg)

    def local_fn(router, wg, wu, wd, xl):
        Bl, Sl, _ = xl.shape
        T = Bl * Sl
        xt = xl.reshape(T, d)
        logits = xt.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

        me = jnp.mean(probs, axis=0)
        ce = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0) / (T * k)
        aux_local = cfg.router_aux_coef * E * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux_local, tuple(dp_axes) + (ep_axis,))

        lo = jax.lax.axis_index(ep_axis) * E_local
        e_flat = expert_idx.reshape(-1)
        order = jnp.argsort(e_flat)
        sorted_e = e_flat[order]
        counts = jnp.zeros((E,), jnp.int32).at[e_flat].add(1)
        starts = jnp.cumsum(counts) - counts
        pos_in_e = jnp.arange(T * k, dtype=jnp.int32) - starts[sorted_e]
        mine = (sorted_e >= lo) & (sorted_e < lo + E_local) & (pos_in_e < C)
        dest = jnp.where(mine, (sorted_e - lo) * C + pos_in_e, E_local * C)
        src_tok = order // k

        buf = jnp.zeros((E_local * C, d), xl.dtype).at[dest].set(
            xt[src_tok], mode="drop")
        h = buf.reshape(E_local, C, d)
        act = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, wg))
        act = act * jnp.einsum("ecd,edf->ecf", h, wu)
        out_e = jnp.einsum("ecf,efd->ecd", act, wd).reshape(E_local * C, d)

        gathered = jnp.where(mine[:, None],
                             out_e.at[dest].get(mode="fill", fill_value=0.0), 0.0)
        w = gate_vals.reshape(-1)[order]
        combined = jnp.zeros((T, d), jnp.float32).at[src_tok].add(
            gathered.astype(jnp.float32) * w[:, None])
        combined = jax.lax.psum(combined, ep_axis)
        return combined.reshape(Bl, Sl, d).astype(xl.dtype), aux

    dp = tuple(dp_axes)
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(), P(ep_axis, None, None), P(ep_axis, None, None),
                  P(ep_axis, None, None), P(dp, None, None)),
        out_specs=(P(dp, None, None), P()),
        check_vma=False)
    return fn(p["router"], p["w_gate"], p["w_up"], p["w_down"], x)


def moe_apply(p, x, cfg):
    """Dispatch: shard_map expert parallelism when a mesh context is active
    and the token count is large (train/prefill); plain dispatch otherwise."""
    from repro import runtime
    mesh = runtime.current_mesh()
    if mesh is not None and x.shape[0] * x.shape[1] >= 4096 \
            and cfg.num_experts % mesh.shape[runtime.model_axis()] == 0:
        return moe_block_sharded(p, x, cfg, mesh, runtime.data_axes(),
                                 runtime.model_axis())
    return moe_block(p, x, cfg)


def moe_block_dense_fallback(p, x, cfg):
    """Reference: every token through every expert (O(E) FLOPs). Used as the
    numerical oracle in tests for the sparse dispatch above."""
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = (xt.astype(jnp.float32) @ p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, cfg.top_k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    act = jax.nn.silu(jnp.einsum("td,edf->tef", xt, p["w_gate"]))
    act = act * jnp.einsum("td,edf->tef", xt, p["w_up"])
    out_e = jnp.einsum("tef,efd->ted", act, p["w_down"])      # (T, E, d)
    w = jnp.zeros(probs.shape, jnp.float32)
    w = jax.vmap(lambda wi, ii, gi: wi.at[ii].set(gi))(w, expert_idx, gate_vals)
    out = jnp.einsum("ted,te->td", out_e.astype(jnp.float32), w)
    return out.reshape(B, S, d).astype(x.dtype), jnp.float32(0.0)
