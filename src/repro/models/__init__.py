from repro.models.model import Model, cross_entropy, example_batch  # noqa: F401
