"""Pallas-TPU API compatibility shims.

JAX has renamed the TPU compiler-params dataclass across releases
(``pltpu.CompilerParams`` <-> ``pltpu.TPUCompilerParams``).  Kernels import
the resolved name from here so they run against whichever the installed
JAX provides.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

TPUCompilerParams = getattr(pltpu, "TPUCompilerParams", None) \
    or getattr(pltpu, "CompilerParams")
