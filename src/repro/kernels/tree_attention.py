"""Tree-verification attention Pallas TPU kernel.

Speculative decoding with token trees verifies N candidate tokens per
sequence in ONE target forward: the tree's K/V are appended to the cache at
positions [length, length+N) and every node-query attends (a) the whole
committed cache prefix and (b) its own ancestor chain inside the tree —
the packed ancestor mask from ``TokenTree.attention_mask``.  The mask may
be rectangular (N, C) with C >= N: incremental level drafting extends only
a level's N new nodes while masking against the C-N tree nodes earlier
levels already wrote to the cache.

Same flash-decoding skeleton as ``decode_attention``: grid (B, Kv, S//BS),
sequence axis walked with a running max/denominator in VMEM scratch.  The
per-block novelty is the mask: cache positions use the usual
``k_pos < length`` prefix test, while positions that fall inside the tree
region look up their ancestor-mask column.  The column gather has a
data-dependent start (``length`` differs per sequence), so it is phrased
as a one-hot matmul — ``tree_mask @ onehot(k_pos - length)`` — which the
MXU eats for free at tree widths (N <= 64) instead of a serialized VMEM
gather.

``q_pos`` carries the per-node RoPE positions (length + node depth) so
sliding-window masking stays depth-correct: a node at depth d sees exactly
the window a linear decode at position length+d would.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import TPUCompilerParams

NEG = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, tm_ref, qp_ref, o_ref,
            acc_ref, m_ref, l_ref, *, bs: int, ns: int, N: int, C: int,
            G: int, hd: int, window: int, scale: float):
    isb = pl.program_id(2)

    @pl.when(isb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32).reshape(G * N, hd)
    k = k_ref[0, 0].astype(jnp.float32)              # (BS, hd)
    v = v_ref[0, 0].astype(jnp.float32)              # (BS, hd)
    s = (q @ k.T) * scale                            # (G*N, BS)

    base = len_ref[0] - (C - N)                      # tree start in the cache
    k_pos = isb * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)[0]
    in_cache = k_pos < base                          # (BS,)
    # tree region [base, base+C): column j of the ancestor mask governs
    # the key at cache position base+j.  One-hot matmul in place of the
    # per-sequence dynamic gather.
    t = k_pos - base                                 # (BS,)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (C, bs), 0)
              == t[None, :]).astype(jnp.float32)     # (C, BS); off-range -> 0
    tree_cols = (tm_ref[...].astype(jnp.float32) @ onehot) > 0.5   # (N, BS)
    mask = in_cache[None, :] | tree_cols             # (N, BS)
    if window:
        qp = qp_ref[0]                               # (N,)
        mask = mask & (k_pos[None, :] > qp[:, None] - window)
    mask = jnp.broadcast_to(mask[None], (G, N, bs)).reshape(G * N, bs)
    s = jnp.where(mask, s, NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(isb == ns - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-20)[:, None]
                       ).reshape(G, N, hd).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "bs", "interpret"))
def tree_verify_attention(q, k, v, length, tree_mask, q_pos, *,
                          window: int = 0, bs: int = 512,
                          interpret: bool = False):
    """q: (B, Kv, G, N, hd) — N tree-node queries per kv-head group;
    k, v: (B, Kv, S, hd) — the cache AFTER this call's N tree K/V were
    written at [length, length+N); length: (B,) int32 valid entries BEFORE
    those tokens; tree_mask: (N, C) bool, C >= N — the LAST N columns align
    with the new tokens; earlier columns cover tree nodes already in the
    cache at [length-(C-N), length) (one-shot verify passes C == N);
    q_pos: (B, N) int32 per-node positions (tree base + depth) for
    windowing.  Cache positions >= length+N are masked garbage.  Returns
    (B, Kv, G, N, hd)."""
    B, Kv, G, N, hd = q.shape
    C = tree_mask.shape[1]
    assert C >= N, (N, C)
    S = k.shape[2]
    bs = min(bs, S)
    if S % bs:                                       # pad: tail is masked off
        pad = bs - S % bs
        zp = ((0, 0), (0, 0), (0, pad), (0, 0))
        k = jnp.pad(k, zp)
        v = jnp.pad(v, zp)
        S += pad
    ns = S // bs
    scale = 1.0 / np.sqrt(hd)

    kern = functools.partial(_kernel, bs=bs, ns=ns, N=N, C=C, G=G, hd=hd,
                             window=window, scale=scale)
    return pl.pallas_call(
        kern,
        grid=(B, Kv, ns),
        in_specs=[
            pl.BlockSpec((1,), lambda b, g, i: (b,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, N, hd), lambda b, g, i: (b, g, 0, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, g, i: (b, g, i, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, g, i: (b, g, i, 0)),
            pl.BlockSpec((N, C), lambda b, g, i: (0, 0)),
            pl.BlockSpec((1, N), lambda b, g, i: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, N, hd), lambda b, g, i: (b, g, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Kv, G, N, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G * N, hd), jnp.float32),
            pltpu.VMEM((G * N,), jnp.float32),
            pltpu.VMEM((G * N,), jnp.float32),
        ],
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(length, q, k, v, tree_mask.astype(jnp.int32), q_pos.astype(jnp.int32))
