"""GQA decode attention Pallas TPU kernel (flash-decoding style).

The serving hot loop: ONE query token per sequence against a long KV cache
(32k / 500k).  Memory-bound — the kernel's job is to stream the cache
through VMEM exactly once at full HBM bandwidth.

Layout: q (B, Kv, G, hd) — the G = H/Kv query heads of one kv head are a
(G, hd) tile that rides the MXU against each (BS, hd) kv block.
``length`` (B,) masks the valid cache prefix (cache positions >= length are
garbage/unwritten); window w restricts to the trailing w entries.

Grid: (B, Kv, S//BS) — last axis sequential with running max/denominator in
VMEM scratch.

``paged_decode_attention`` is the paged-KV twin (vLLM-style): K/V live in
ONE (NB, bs, Kv, hd) block pool shared by all sequences, and each
sequence's logical block ``i`` is found through a scalar-prefetched block
table — the BlockSpec index map reads ``table[b, i]`` to aim the next DMA,
so the gather never materializes.  Same online-softmax accumulation; cache
position ``i*bs + off`` masking is identical because block ``i`` holds
logical positions [i*bs, (i+1)*bs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import TPUCompilerParams

NEG = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            bs: int, ns: int, window: int, scale: float):
    isb = pl.program_id(2)

    @pl.when(isb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)             # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)             # (BS, hd)
    v = v_ref[0, 0].astype(jnp.float32)             # (BS, hd)
    s = (q @ k.T) * scale                            # (G, BS)

    length = len_ref[0]
    k_pos = isb * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)[0]
    mask = k_pos < length
    if window:
        mask = mask & (k_pos >= length - window)
    s = jnp.where(mask[None, :], s, NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(isb == ns - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-20)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "bs", "interpret"))
def decode_attention(q, k, v, length, *, window: int = 0, bs: int = 512,
                     interpret: bool = False):
    """q: (B, Kv, G, hd); k,v: (B, Kv, S, hd); length: (B,) int32 — number of
    valid cache entries (the query attends to positions < length).
    Returns (B, Kv, G, hd)."""
    B, Kv, G, hd = q.shape
    S = k.shape[2]
    bs = min(bs, S)
    assert S % bs == 0
    ns = S // bs
    scale = 1.0 / np.sqrt(hd)

    kern = functools.partial(_kernel, bs=bs, ns=ns, window=window, scale=scale)
    return pl.pallas_call(
        kern,
        grid=(B, Kv, ns),
        in_specs=[
            pl.BlockSpec((1,), lambda b, g, i: (b,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, hd), lambda b, g, i: (b, g, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, g, i: (b, g, i, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, g, i: (b, g, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, g, i: (b, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Kv, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(length, q, k, v)


# ---------------------------------------------------------------- paged
def _paged_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, bs: int, ns: int, window: int,
                  scale: float):
    b = pl.program_id(0)
    isb = pl.program_id(2)

    @pl.when(isb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)             # (G, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)          # (bs, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)          # (bs, hd)
    s = (q @ k.T) * scale                            # (G, bs)

    length = len_ref[b]
    # windowed variant: the grid only walks the trailing-window blocks,
    # starting at logical block sb = max(length - window, 0) // bs
    sb = jnp.maximum(length - window, 0) // bs if window else 0
    k_pos = (sb + isb) * bs + \
        jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)[0]
    mask = k_pos < length
    if window:
        mask = mask & (k_pos >= length - window)
    s = jnp.where(mask, s, NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(isb == ns - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-20)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_attention(q, k_pool, v_pool, table, length, *,
                           window: int = 0, interpret: bool = False):
    """Decode attention through a paged KV pool.

    q: (B, Kv, G, hd); k_pool/v_pool: (NB, bs, Kv, hd) — the shared block
    pool; table: (B, MB) int32 block table (entry i holds the pool block
    backing logical positions [i*bs, (i+1)*bs) of that sequence; unused
    entries may point anywhere allocated-or-trap, their positions being
    masked); length: (B,) int32 valid cache entries.  Returns
    (B, Kv, G, hd).

    The block table and lengths ride scalar prefetch: the k/v index maps
    dereference ``table[b, i]`` so each grid step DMAs exactly the one
    block it needs — the paged gather costs no extra HBM traffic over the
    dense kernel.

    With ``window`` > 0 only the trailing ``window`` cache positions are
    attended (sliding-window decode): the grid's sequence axis shrinks to
    the few blocks that can overlap the window, and the index maps offset
    the block-table lookup by the per-sequence start block
    ``max(length - window, 0) // bs`` — long-context sliding-window
    serving reads O(window) bytes per step, not O(length).  Blocks the
    clamp pushes past the table edge read a masked (all-NEG) garbage
    block, contributing exact zeros to the online softmax.
    """
    B, Kv, G, hd = q.shape
    NB, bs, Kv2, hd2 = k_pool.shape
    assert (Kv2, hd2) == (Kv, hd), (k_pool.shape, q.shape)
    MB = table.shape[1]
    scale = 1.0 / np.sqrt(hd)

    # sequence-axis grid: every block (full attention) or just the blocks
    # a trailing window can straddle
    ns = MB if not window else min(MB, (window + bs - 2) // bs + 1)

    def blk(b, g, i, tbl, ln):
        if window:
            i = jnp.minimum(jnp.maximum(ln[b] - window, 0) // bs + i, MB - 1)
        return (tbl[b, i], 0, g, 0)

    kern = functools.partial(_paged_kernel, bs=bs, ns=ns, window=window,
                             scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Kv, ns),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd),
                         lambda b, g, i, tbl, ln: (b, g, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd), blk),
            pl.BlockSpec((1, bs, 1, hd), blk),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, g, i, tbl, ln: (b, g, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Kv, G, hd), q.dtype),
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(table, length, q, k_pool, v_pool)
