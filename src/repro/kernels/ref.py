"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q,k,v: (B,H,S,hd)."""
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(hd)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = k_pos <= q_pos
    if window:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k, v, length, *, window: int = 0):
    """q: (B,Kv,G,hd); k,v: (B,Kv,S,hd); length: (B,)."""
    B, Kv, G, hd = q.shape
    S = k.shape[2]
    s = jnp.einsum("bkgd,bksd->bkgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(hd)
    k_pos = jnp.arange(S)[None, :]
    mask = k_pos < length[:, None]
    if window:
        mask = mask & (k_pos >= length[:, None] - window)
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgs,bksd->bkgd", p, v.astype(jnp.float32)).astype(q.dtype)


def paged_decode_attention_ref(q, k_pool, v_pool, table, length, *,
                               window: int = 0):
    """Pure-jnp oracle for the paged decode kernel, and the CPU-CI
    fallback: gather the block table into a contiguous (B, Kv, S, hd)
    cache, then run dense decode attention.  q: (B,Kv,G,hd);
    k_pool/v_pool: (NB, bs, Kv, hd); table: (B,MB) int32; length: (B,).
    ``window`` > 0 restricts attention to the trailing ``window`` valid
    positions (sliding-window decode), mirroring the kernel's mask."""
    B = q.shape[0]
    Kv, hd = k_pool.shape[2], k_pool.shape[3]
    kk = jnp.moveaxis(k_pool[table].reshape(B, -1, Kv, hd), 2, 1)
    vv = jnp.moveaxis(v_pool[table].reshape(B, -1, Kv, hd), 2, 1)
    return decode_attention_ref(q, kk, vv, length, window=window)


def tree_verify_attention_ref(q, k, v, length, tree_mask, q_pos, *,
                              window: int = 0):
    """Oracle for the tree-verification kernel, and the CPU-CI fallback
    behind ``layers.extend_attention``'s block-mask path.  q:
    (B,Kv,G,N,hd); k,v: (B,Kv,S,hd); length: (B,) valid cache entries
    BEFORE this call's N new tokens at [length, length+N); tree_mask:
    (N,C) bool with C >= N — the mask's LAST N columns align with the new
    tokens, earlier columns cover tree nodes already written at
    [length-(C-N), length) by previous level extends (C == N is the
    one-shot verify case where the whole tree arrives at once); q_pos:
    (B,N) per-node positions (tree base + depth)."""
    B, Kv, G, N, hd = q.shape
    C = tree_mask.shape[1]
    S = k.shape[2]
    s = jnp.einsum("bkgnd,bksd->bkgns", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(hd)
    base = length - (C - N)                                       # tree start
    k_pos = jnp.arange(S, dtype=jnp.int32)
    in_cache = k_pos[None, :] < base[:, None]                     # (B,S)
    t = k_pos[None, :] - base[:, None]                            # (B,S)
    in_tree = (t >= 0) & (t < C)
    cols = jnp.moveaxis(tree_mask[:, jnp.clip(t, 0, C - 1)], 1, 0)  # (B,N,S)
    mask = in_cache[:, None, :] | (in_tree[:, None, :] & cols)
    if window:
        mask = mask & (k_pos[None, None, :] > q_pos[:, :, None] - window)
    s = jnp.where(mask[:, None, None, :, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgns,bksd->bkgnd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def spec_verify_ref(rng, target_logits, draft_logits, draft_tokens, *,
                    temperature: float = 1.0):
    """Mirrors kernels.spec_verify exactly (same rng stream / tie-breaks)."""
    gamma, V = draft_logits.shape
    r_acc, r_res = jax.random.split(rng)
    u_acc = jax.random.uniform(r_acc, (gamma + 1,))
    u_res = jax.random.uniform(r_res, (gamma + 1,))

    tl = target_logits.astype(jnp.float32)
    ql = jnp.concatenate([draft_logits.astype(jnp.float32),
                          jnp.zeros((1, V), jnp.float32)], axis=0)
    if temperature == 0.0:
        p = (tl >= jnp.max(tl, -1, keepdims=True)).astype(jnp.float32)
        p = p / jnp.sum(p, -1, keepdims=True)
        q = (ql >= jnp.max(ql, -1, keepdims=True)).astype(jnp.float32)
        q = q / jnp.sum(q, -1, keepdims=True)
    else:
        p = jax.nn.softmax(tl / temperature, -1)
        q = jax.nn.softmax(ql / temperature, -1)

    toks = jnp.concatenate([jnp.asarray(draft_tokens, jnp.int32),
                            jnp.zeros((1,), jnp.int32)])
    p_tok = jnp.take_along_axis(p, toks[:, None], 1)[:, 0]
    q_tok = jnp.take_along_axis(q, toks[:, None], 1)[:, 0]
    accept = u_acc < jnp.minimum(p_tok / jnp.maximum(q_tok, 1e-20), 1.0)
    n_acc = jnp.sum(jnp.cumprod(accept[:gamma].astype(jnp.int32)))

    is_bonus = (jnp.arange(gamma + 1) == gamma)[:, None]
    resid = jnp.clip(p - jnp.where(is_bonus, 0.0, 1.0) * q, 0.0, None)
    tot = jnp.sum(resid, -1, keepdims=True)
    resid = jnp.where(tot > 0, resid / jnp.maximum(tot, 1e-20), p)
    cdf = jnp.cumsum(resid, axis=-1)
    sel = jnp.sum((cdf < u_res[:, None]).astype(jnp.int32), axis=-1)
    sel = jnp.minimum(sel, V - 1)
    return n_acc, sel[n_acc]


def ssd_chunk_scan_ref(q, k, v, log_a, log_i, *, chunk: int = 128):
    """Delegates to the model-side oracle (zero initial state)."""
    from repro.models.ssm import gla_chunked
    y, den, m, _ = gla_chunked(q, k, v, log_a, log_i, chunk=chunk)
    return y, den, m
