"""Chunked gated-linear-attention / SSD scan Pallas TPU kernel.

The compute core of Mamba2 (zamba2) and mLSTM (xlstm): per chunk of length
Q, an O(Q^2) masked matmul (intra-chunk) plus a rank-N state carry across
chunks.  Chunks ride the sequential grid axis; the (N, P) state, (N,)
normalizer and log-max stabilizer live in VMEM scratch — exactly the
structure of ``repro.models.ssm.gla_chunked`` (the oracle).

Tiling: Q=128 keeps the (Q,Q) decay matrix + (Q,N)+(Q,P) operand tiles in
VMEM; N=P=64..128 aligns the state matmuls to the MXU.

Grid: (B, H, S//Q) with the chunk axis sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import TPUCompilerParams

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, la_ref, li_ref,
            y_ref, den_ref, m_ref,
            S_scr, n_scr, M_scr, *, Q: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        S_scr[...] = jnp.zeros_like(S_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        M_scr[...] = jnp.full_like(M_scr, _NEG)

    q = q_ref[0, 0].astype(jnp.float32)          # (Q, N)
    k = k_ref[0, 0].astype(jnp.float32)          # (Q, N)
    v = v_ref[0, 0].astype(jnp.float32)          # (Q, P)
    la = la_ref[0, 0].astype(jnp.float32)        # (Q,)
    li = li_ref[0, 0].astype(jnp.float32)        # (Q,)

    La = jnp.cumsum(la)                           # (Q,) inclusive
    w = jax.lax.cummax(li - La, axis=0)
    M = M_scr[0, 0]
    m = La + jnp.maximum(M, w)                    # (Q,)

    c_log = La[:, None] - La[None, :] + li[None, :] - m[:, None]
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1) <= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    cmat = jnp.where(tri, jnp.exp(c_log), 0.0)

    scores = q @ k.T                              # (Q, Q)
    sc = scores * cmat
    y = sc @ v                                    # (Q, P)
    den = jnp.sum(sc, axis=1)                     # (Q,)

    coef = jnp.exp(La + M - m)                    # (Q,)
    y = y + (q @ S_scr[...]) * coef[:, None]
    den = den + (q @ n_scr[0]) * coef

    la_sum = La[Q - 1]
    m_new = la_sum + jnp.maximum(M, w[Q - 1])
    z = jnp.exp(la_sum - La + li - m_new)         # (Q,)
    s_scale = jnp.exp(jnp.minimum(la_sum + M - m_new, 0.0))
    S_scr[...] = s_scale * S_scr[...] + k.T @ (v * z[:, None])
    n_scr[0] = s_scale * n_scr[0] + k.T @ z
    M_scr[0, 0] = m_new

    y_ref[0, 0] = y.astype(y_ref.dtype)
    den_ref[0, 0] = den.astype(den_ref.dtype)
    m_ref[0, 0] = m.astype(m_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk_scan(q, k, v, log_a, log_i, *, chunk: int = 128,
                   interpret: bool = False):
    """q,k: (B,S,H,N); v: (B,S,H,P); log_a/log_i: (B,S,H).  S % chunk == 0.
    Returns (y_num (B,S,H,P), den (B,S,H), m (B,S,H)) — stabilized, same
    contract as models.ssm.gla_chunked (zero initial state)."""
    B, S, H, N = q.shape
    P = v.shape[-1]
    Q = chunk
    assert S % Q == 0, (S, Q)
    nc = S // Q

    def to_bh(x):      # (B,S,H,*) -> (B,H,S,*)
        return jnp.moveaxis(x, 2, 1)

    qb, kb, vb = to_bh(q), to_bh(k), to_bh(v)
    lab, lib = jnp.moveaxis(log_a, 2, 1), jnp.moveaxis(log_i, 2, 1)

    kern = functools.partial(_kernel, Q=Q)
    y, den, m = pl.pallas_call(
        kern,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1, Q), lambda b, h, c: (b, h, c)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1, Q), lambda b, h, c: (b, h, c)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, S), jnp.float32),
            jax.ShapeDtypeStruct((B, H, S), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((N, P), jnp.float32),
            pltpu.VMEM((1, N), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qb, kb, vb, lab, lib)

    back = lambda x: jnp.moveaxis(x, 1, 2)        # (B,H,S,*) -> (B,S,H,*)
    return back(y), back(den), back(m)
