"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels run in interpret mode — the kernel body
executes in Python for correctness validation; on TPU they compile to
Mosaic.  ``use_pallas()`` is the global switch the model code consults.
"""
from __future__ import annotations


import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.decode_attention import paged_decode_attention as _paged
from repro.kernels.spec_verify import spec_verify as _verify
from repro.kernels.spec_verify import spec_verify_batched as _verify_batched
from repro.kernels.ssd_scan import ssd_chunk_scan as _ssd
from repro.kernels.tree_attention import tree_verify_attention as _tree


def on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def flash_attention(q, k, v, *, causal=True, window=0, bq=128, bk=128):
    return _flash(q, k, v, causal=causal, window=window, bq=bq, bk=bk,
                  interpret=on_cpu())


def decode_attention(q, k, v, length, *, window=0, bs=512):
    return _decode(q, k, v, length, window=window, bs=bs, interpret=on_cpu())


def paged_decode_attention(q, k_pool, v_pool, table, length, *, window=0):
    """Decode attention through a paged KV pool + block table (the serving
    scheduler's --kv-layout=paged hot loop on TPU).  ``window`` > 0 runs
    the sliding-window variant (trailing-window blocks only)."""
    return _paged(q, k_pool, v_pool, table, length, window=window,
                  interpret=on_cpu())


def tree_verify_attention(q, k, v, length, tree_mask, q_pos, *, window=0,
                          bs=512):
    """Tree-speculation verify attention: N node-queries per sequence over
    cache prefix + packed ancestor mask (the tree K/V sit at
    [length, length+N)).  The TPU half of ``extend_attention``'s
    block-mask path."""
    return _tree(q, k, v, length, tree_mask, q_pos, window=window, bs=bs,
                 interpret=on_cpu())


def spec_verify(rng, target_logits, draft_logits, draft_tokens, *,
                temperature=1.0):
    return _verify(rng, target_logits, draft_logits, draft_tokens,
                   temperature=temperature, interpret=on_cpu())


def spec_verify_batched(rngs, target_logits, draft_logits, draft_tokens, *,
                        temperature=1.0):
    """Grouped verification (leading group axis on every operand) — the
    fused TPU twin of BatchedSpecDecoder's vmapped speculative_sample."""
    return _verify_batched(rngs, target_logits, draft_logits, draft_tokens,
                           temperature=temperature, interpret=on_cpu())


def ssd_chunk_scan(q, k, v, log_a, log_i, *, chunk=128):
    return _ssd(q, k, v, log_a, log_i, chunk=chunk, interpret=on_cpu())
