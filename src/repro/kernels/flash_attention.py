"""Flash attention (prefill/train) Pallas TPU kernel.

Online-softmax tiling: the q tile (BQ, hd) stays resident in VMEM while kv
tiles (BK, hd) stream through; running max/denominator live in VMEM scratch
across the (sequential) kv grid axis.  Causal and sliding-window masks are
applied from block coordinates.  MXU alignment: BQ/BK/hd multiples of 128
on real TPU (tests use smaller interpret-mode tiles).

Grid: (B, H, Sq//BQ, Sk//BK) — last axis is the arbitrary/sequential one.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import TPUCompilerParams

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            bq: int, bk: int, nk: int, causal: bool, window: int,
            scale: float):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (BQ, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (BK, hd)
    v = v_ref[0, 0].astype(jnp.float32)            # (BK, hd)
    s = (q @ k.T) * scale                           # (BQ, BK)

    iq = pl.program_id(2)
    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask = k_pos <= q_pos
    if window:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, NEG)

    m_prev = m_ref[...]                             # (BQ,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-20)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128, interpret: bool = False):
    """q,k,v: (B, H, S, hd) (kv already expanded over GQA groups).
    Returns (B, H, S, hd)."""
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0
    nq, nk = Sq // bq, Sk // bk
    scale = 1.0 / np.sqrt(hd)

    kern = functools.partial(_kernel, bq=bq, bk=bk, nk=nk, causal=causal,
                             window=window, scale=scale)
    return pl.pallas_call(
        kern,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
