"""Fused speculative-verification Pallas kernel.

Per draft position the verifier needs: softmax(p), softmax(q), the
acceptance test p[tok]/q[tok] vs uniform, and inverse-CDF sampling from the
residual max(p-q, 0).  Done naively that materializes several (gamma, V)
f32 temporaries in HBM; fused, each logits row is read ONCE into VMEM and
only scalars leave.  A vocab row (up to 257k x 4B = ~1MB) fits VMEM
comfortably, so the tiling is one row per grid step.

Grid: (gamma+1,). Outputs per row: accept flag (vs the supplied uniform),
residual-sampled token, and the row's target top-1 (greedy path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(tok_ref, u_acc_ref, u_res_ref, p_ref, q_ref,
            accept_ref, resid_tok_ref, argmax_ref, *, temperature: float,
            gamma: int):
    i = pl.program_id(0)
    pl_row = p_ref[0].astype(jnp.float32)           # (V,)
    q_row = q_ref[0].astype(jnp.float32)            # (V,) (zeros row at i==gamma)
    V = pl_row.shape[0]

    if temperature == 0.0:
        p = (pl_row >= jnp.max(pl_row)).astype(jnp.float32)
        p = p / jnp.sum(p)
        qq = (q_row >= jnp.max(q_row)).astype(jnp.float32)
        qq = qq / jnp.sum(qq)
    else:
        pm = pl_row / temperature
        p = jax.nn.softmax(pm)
        qm = q_row / temperature
        qq = jax.nn.softmax(qm)

    tok = tok_ref[0]
    p_tok = jnp.sum(jnp.where(jax.lax.iota(jnp.int32, V) == tok, p, 0.0))
    q_tok = jnp.sum(jnp.where(jax.lax.iota(jnp.int32, V) == tok, qq, 0.0))
    ratio = p_tok / jnp.maximum(q_tok, 1e-20)
    accept_ref[0] = (u_acc_ref[0] < jnp.minimum(ratio, 1.0)).astype(jnp.int32)

    # residual inverse-CDF sampling (bonus row i==gamma: q==0 -> resid = p)
    is_bonus = i == gamma
    resid = jnp.clip(p - jnp.where(is_bonus, 0.0, 1.0) * qq, 0.0, None)
    total = jnp.sum(resid)
    resid = jnp.where(total > 0, resid / jnp.maximum(total, 1e-20), p)
    cdf = jnp.cumsum(resid)
    sel = jnp.sum((cdf < u_res_ref[0]).astype(jnp.int32))
    resid_tok_ref[0] = jnp.minimum(sel, V - 1)
    argmax_ref[0] = jnp.argmax(p).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("temperature", "interpret"))
def spec_verify(rng, target_logits, draft_logits, draft_tokens, *,
                temperature: float = 1.0, interpret: bool = False):
    """Fused equivalent of core.speculative.speculative_sample.

    target_logits: (gamma+1, V); draft_logits: (gamma, V);
    draft_tokens: (gamma,). Returns (n_accepted (), next_token ()).
    """
    gamma, V = draft_logits.shape
    r_acc, r_res = jax.random.split(rng)
    u_acc = jax.random.uniform(r_acc, (gamma + 1,))
    u_res = jax.random.uniform(r_res, (gamma + 1,))
    toks = jnp.concatenate([jnp.asarray(draft_tokens, jnp.int32),
                            jnp.zeros((1,), jnp.int32)])
    q_pad = jnp.concatenate([draft_logits.astype(jnp.float32),
                             jnp.zeros((1, V), jnp.float32)], axis=0)

    kern = functools.partial(_kernel, temperature=temperature, gamma=gamma)
    accept, resid_tok, argmax_tok = pl.pallas_call(
        kern,
        grid=(gamma + 1,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda i: (i,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda i: (i,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, V), lambda i: (i, 0)),
            pl.BlockSpec((1, V), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (i,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda i: (i,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda i: (i,), memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((gamma + 1,), jnp.int32),
            jax.ShapeDtypeStruct((gamma + 1,), jnp.int32),
            jax.ShapeDtypeStruct((gamma + 1,), jnp.int32),
        ],
        interpret=interpret,
    )(toks, u_acc, u_res, target_logits.astype(jnp.float32), q_pad)

    n_acc = jnp.sum(jnp.cumprod(accept[:gamma]))
    next_token = resid_tok[n_acc]
    return n_acc, next_token


@functools.partial(jax.jit, static_argnames=("temperature", "interpret"))
def spec_verify_batched(rngs, target_logits, draft_logits, draft_tokens, *,
                        temperature: float = 1.0, interpret: bool = False):
    """Grouped fused verification (kernel counterpart of the pure-jnp
    ``vmap(speculative_sample)`` inside ``core.speculative
    .BatchedSpecDecoder``, which is what the engine runs on CPU — like the
    single-row ``spec_verify``, this is the TPU-targeted twin, validated
    against the reference path in tests).

    rngs: (G, 2) keys; target_logits: (G, gamma+1, V); draft_logits:
    (G, gamma, V); draft_tokens: (G, gamma).  Pallas lifts the vmapped
    kernel into an extra grid dimension, so the whole group verifies in one
    launch.  Returns (n_accepted (G,), next_token (G,)).
    """
    return jax.vmap(
        functools.partial(spec_verify, temperature=temperature,
                          interpret=interpret)
    )(rngs, target_logits, draft_logits, draft_tokens)
