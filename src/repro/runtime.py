"""Runtime distribution context.

Model code is mesh-agnostic by default; the launcher installs a mesh context
so layers that need EXPLICIT distribution (shard_map expert parallelism)
can find it at trace time.
"""
from __future__ import annotations

import contextlib
import inspect
import threading
from typing import Optional, Tuple

from repro.analysis import hot_path

_state = threading.local()


# ---------------------------------------------------------------- shard_map
def _resolve_shard_map():
    """Locate shard_map across JAX versions: newest exports it from the
    top-level ``jax`` namespace, older releases from
    ``jax.experimental.shard_map``."""
    import jax
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn
    return fn


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-compatible ``shard_map`` wrapper.

    Newer JAX calls the replication-checking flag ``check_vma``; older
    releases call it ``check_rep``.  Model code imports this shim so the
    explicitly-distributed layers (MoE expert parallelism) run on either.
    """
    fn = _resolve_shard_map()
    params = inspect.signature(fn).parameters
    kw = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs}
    if "check_vma" in params:
        kw["check_vma"] = check_vma
    elif "check_rep" in params:
        kw["check_rep"] = check_vma
    return fn(f, **kw)


def current_mesh():
    return getattr(_state, "mesh", None)


def data_axes() -> Tuple[str, ...]:
    return getattr(_state, "data_axes", ("data",))


def model_axis() -> str:
    return getattr(_state, "model_axis", "model")


def activation_sharding() -> bool:
    return getattr(_state, "activation_sharding", True)


@contextlib.contextmanager
def mesh_context(mesh, *, data_axes_: Optional[Tuple[str, ...]] = None,
                 model_axis_: str = "model", activation_sharding_: bool = True):
    prev = (getattr(_state, "mesh", None), getattr(_state, "data_axes", None),
            getattr(_state, "model_axis", None),
            getattr(_state, "activation_sharding", True))
    _state.mesh = mesh
    _state.data_axes = data_axes_ or tuple(
        a for a in mesh.axis_names if a != model_axis_)
    _state.model_axis = model_axis_
    _state.activation_sharding = activation_sharding_
    try:
        yield
    finally:
        (_state.mesh, _state.data_axes, _state.model_axis,
         _state.activation_sharding) = prev


def _dp_count(mesh) -> int:
    n = 1
    for a in data_axes():
        n *= mesh.shape[a]
    return n


@hot_path
def gather_wave(*arrays):
    """All-gather a grouped escalation wave across the data axes in ONE
    explicit collective (``shard_map`` + ``lax.all_gather``), so the
    tensor-parallel cloud verifier sees every data shard's draft tape at
    once.  Each array is (G, ...) with G sharded over the data axes on
    entry; the result is fully replicated over them.  Identity (and
    trace-identical) outside a mesh context or when G does not divide —
    the single-device path never sees a collective.  ``@hot_path``: this
    runs inside every escalation wave, so repro-lint rule R1 keeps host
    syncs out of it."""
    mesh = current_mesh()
    if mesh is None:
        return arrays if len(arrays) > 1 else arrays[0]
    n_dp = _dp_count(mesh)
    if n_dp <= 1 or any(a.ndim == 0 or a.shape[0] % n_dp != 0
                        for a in arrays):
        return arrays if len(arrays) > 1 else arrays[0]
    import jax
    from jax.sharding import PartitionSpec as P
    dp = data_axes()

    def gather(*xs):
        return tuple(jax.lax.all_gather(x, dp, axis=0, tiled=True)
                     for x in xs)

    in_specs = tuple(P(dp, *([None] * (a.ndim - 1))) for a in arrays)
    out_specs = tuple(P(*([None] * a.ndim)) for a in arrays)
    # check_vma=False: the all-gather's output IS replicated over the data
    # axes, but the static replication checker cannot infer that
    out = shard_map(gather, mesh=mesh, in_specs=in_specs,
                    out_specs=out_specs, check_vma=False)(*arrays)
    return out if len(arrays) > 1 else out[0]


@hot_path
def scatter_wave(x):
    """Constrain a (G, ...) wave result back to per-slot data sharding —
    the scatter half of the wave's mesh crossing.  No-op outside a mesh
    context or when G does not divide."""
    return shard_activation(x)


def shard_activation(x):
    """Constrain a (B, ...) activation to batch-sharding over the data axes
    (replicated over 'model').  No-op outside a mesh context or when the
    batch does not divide.  Perf iteration #1 (EXPERIMENTS.md §Perf): without
    this, XLA's SPMD resolves the FSDP-params x DP-batch conflict by
    replicating attention compute over the model axis."""
    mesh = current_mesh()
    if mesh is None or not activation_sharding():
        return x
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    dp = data_axes()
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    if x.ndim == 0 or x.shape[0] % n_dp != 0:
        return x
    spec = P(dp, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
