"""mamba2-370m [ssm] — pure Mamba2 (SSD) stack, no attention at all
[arXiv:2405.21060].

The standalone SSM family: 48 mamba2 blocks over the chunked GLA engine in
``models/ssm.py`` (the same blocks zamba2's hybrid backbone stacks, minus
the shared attention).  d_ff = 0: mamba2 blocks carry their own up/down
projections and gating, so there is no separate MLP sub-layer; num_heads = 0
because the SSD heads are ``ssm_expand * d_model / ssm_head_dim``, not
attention heads.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    source="arXiv:2405.21060",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50288,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    conv_kernel=4,
    use_rope=False,
)
