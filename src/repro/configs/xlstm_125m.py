"""xlstm-125m [xlstm] — sLSTM + mLSTM blocks, d_ff=0 [arXiv:2405.04517].

d_ff = 0: xLSTM blocks carry their own up/down projections and gating, so
there is no separate MLP sub-layer.  4 heads with kv=4 refers to the mLSTM
matrix-memory heads.  Every 4th block is an sLSTM block (recurrent,
memory-mixing); the rest are mLSTM (parallel, linear-attention form).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="xlstm",
    source="arXiv:2405.04517",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    xlstm_slstm_every=4,     # blocks 3, 7, 11 are sLSTM
    use_rope=False,
)
