"""paligemma-3b [vlm] — SigLIP vision tower (stub) + gemma decoder
[arXiv:2407.07726].  The vision tower/projector is stubbed per the
assignment carve-out: input_specs provides (B, 256, d_model) patch
embeddings; the gemma-style decoder attends over [image prefix + text].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    source="arXiv:2407.07726",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    d_ff=16384,
    vocab_size=257216,
    mlp_activation="geglu",
    num_image_tokens=256,
    logit_softcap=0.0,
)
