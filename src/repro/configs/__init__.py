"""Config registry: ``get_config(arch_id)`` for every assigned architecture."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401
    LONG_DECODE_WINDOW,
    SHAPES,
    InputShape,
    ModelConfig,
)

_MODULES = {
    "mamba2-370m": "mamba2_370m",
    "xlstm-125m": "xlstm_125m",
    "whisper-small": "whisper_small",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "granite-20b": "granite_20b",
    "paligemma-3b": "paligemma_3b",
    "smollm-135m": "smollm_135m",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "nemotron-4-15b": "nemotron_4_15b",
    "zamba2-2.7b": "zamba2_2_7b",
    "granite-8b": "granite_8b",
}


def list_archs() -> List[str]:
    return list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in _MODULES}
