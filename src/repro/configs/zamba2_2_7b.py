"""zamba2-2.7b [hybrid] — Mamba2 backbone + one shared attention block
applied periodically (weights reused, true to Zamba2) [arXiv:2411.15242]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    source="arXiv:2411.15242",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    shared_attn_every=6,     # shared block after every 6 mamba layers
)
