"""whisper-small [audio] — enc-dec; conv/mel frontend is a stub: the encoder
consumes precomputed frame embeddings [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    source="arXiv:2212.04356",
    num_layers=12,            # decoder layers
    encoder_layers=12,
    encoder_seq=1500,         # 30 s of audio at 50 Hz after the conv stub
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    mlp_activation="gelu",
    use_rope=False,           # learned positional embeddings
    max_position_embeddings=40960,   # covers decode_32k (long_500k is skipped)
)
