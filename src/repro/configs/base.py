"""Unified model/run configuration for the repro framework.

One ``ModelConfig`` dataclass covers all architecture families assigned to
this paper (dense / moe / ssm-mamba2 / xlstm / hybrid / encdec-audio / vlm).
Every field not used by a family defaults to an inert value so configs stay
comparable.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    family: str              # dense | moe | ssm | xlstm | hybrid | encdec | vlm
    source: str = ""                 # citation (arXiv id / hf model card)

    # transformer backbone
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0                # 0 -> d_model // num_heads
    mlp_activation: str = "silu"     # silu | relu2 | gelu | geglu
    use_rope: bool = True            # False -> learned positional embeddings
    max_position_embeddings: int = 1 << 20
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    logit_softcap: float = 0.0       # 0 -> disabled
    tie_embeddings: bool = True

    # attention variant (set per input shape for long-context decode)
    sliding_window: int = 0          # 0 -> full causal attention

    # MoE
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM / xLSTM / Mamba2
    ssm_state: int = 0               # Mamba2 state size N
    ssm_head_dim: int = 64           # Mamba2 P (head dim of the SSD heads)
    ssm_expand: int = 2              # d_inner = ssm_expand * d_model
    ssm_chunk: int = 128             # SSD chunk length
    xlstm_slstm_every: int = 0       # xLSTM: every k-th block is sLSTM (0 = none)
    conv_kernel: int = 4             # Mamba2 depthwise conv width

    # hybrid (zamba2-style): one *shared* attention block applied periodically
    shared_attn_every: int = 0       # 0 -> no shared attention block

    # encoder-decoder (whisper-style); encoder consumes precomputed frame
    # embeddings (conv/mel frontend is a stub per the assignment carve-out).
    encoder_layers: int = 0
    encoder_seq: int = 0             # e.g. 1500 audio frames

    # vlm (paligemma-style); vision tower is a stub: patch embeddings are
    # provided directly as a (B, num_image_tokens, d_model) input.
    num_image_tokens: int = 0

    # numerics
    param_dtype: str = "bfloat16"
    activ_dtype: str = "bfloat16"

    # training-time extras used by the collaboration library
    early_exit_layers: Tuple[int, ...] = ()   # layers with auxiliary LM heads

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def is_decoder_only(self) -> bool:
        return self.family in ("dense", "moe", "ssm", "xlstm", "hybrid", "vlm")

    @property
    def has_attention(self) -> bool:
        return self.family not in ("ssm", "xlstm")

    @property
    def supports_long_decode(self) -> bool:
        """Sub-quadratic decode: native for ssm/hybrid, via sliding window
        for dense/moe/vlm.  encdec (whisper) is skipped (see DESIGN.md)."""
        return self.family != "encdec"

    def n_params(self) -> int:
        """Approximate parameter count (for MODEL_FLOPS = 6ND roofline)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.head_dim
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.family in ("dense", "vlm"):
            attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
            ff_mult = 3 if self.mlp_activation in ("silu", "geglu") else 2
            mlp = ff_mult * d * self.d_ff
            return L * (attn + mlp) + emb
        if self.family == "moe":
            attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
            mlp = 3 * d * self.d_ff * self.num_experts
            return L * (attn + mlp) + emb
        if self.family == "xlstm":   # mlstm/slstm blocks
            per = 8 * d * d          # projections + gates (approximate)
            return L * per + emb
        if self.family == "ssm":     # mamba2 blocks
            d_in = self.ssm_expand * d
            per = 2 * d * d_in + d_in * d + d_in * (2 * self.ssm_state)
            return L * per + emb
        if self.family == "hybrid":
            d_in = self.ssm_expand * d
            mamba = 2 * d * d_in + d_in * d + d_in * (2 * self.ssm_state)
            n_shared = L // max(self.shared_attn_every, 1) if self.shared_attn_every else 0
            attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
            shared = attn + 3 * d * self.d_ff   # counted once: weights shared
            return L * mamba + (shared if n_shared else 0) + emb
        if self.family == "encdec":
            attn = 4 * d * d
            mlp = 2 * d * self.d_ff
            enc = self.encoder_layers * (attn + mlp)
            dec = L * (2 * attn + mlp)
            return enc + dec + emb
        raise ValueError(self.family)

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.family != "moe":
            return self.n_params()
        d, L = self.d_model, self.num_layers
        attn = d * self.head_dim * (self.num_heads + 2 * self.num_kv_heads) \
            + self.num_heads * self.head_dim * d
        mlp = 3 * d * self.d_ff * self.top_k
        return L * (attn + mlp) + self.vocab_size * d

    # ------------------------------------------------------------------
    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 layers, d_model<=256, <=4 experts,
        small vocab. Same family/block pattern so the code path is identical."""
        kw = dict(
            num_layers=2,
            d_model=min(self.d_model, 256),
            vocab_size=min(self.vocab_size, 512),
            max_position_embeddings=4096,
            param_dtype="float32",
            activ_dtype="float32",
        )
        if self.num_heads:
            nh = min(self.num_heads, 4)
            nkv = max(1, min(self.num_kv_heads, nh))
            while nh % nkv:
                nkv -= 1
            kw.update(num_heads=nh, num_kv_heads=nkv,
                      head_dim=min(self.d_model, 256) // nh)
        if self.d_ff:
            kw["d_ff"] = min(self.d_ff, 512)
        if self.num_experts:
            kw.update(num_experts=4, top_k=min(self.top_k, 2))
        if self.encoder_layers:
            kw.update(encoder_layers=2, encoder_seq=16)
        if self.num_image_tokens:
            kw["num_image_tokens"] = 4
        if self.shared_attn_every:
            kw["shared_attn_every"] = 2
        if self.xlstm_slstm_every:
            kw["xlstm_slstm_every"] = 2
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_chunk=8)
        if self.family in ("ssm", "xlstm"):
            kw["ssm_chunk"] = 8
        return self.replace(**kw)


# ----------------------------------------------------------------------
# Assigned input shapes (global, before sharding).
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}

# Sliding window applied to full-attention archs for long-context decode
# (see DESIGN.md "Shape/decode skips").
LONG_DECODE_WINDOW = 4_096
