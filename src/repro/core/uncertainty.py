"""Uncertainty estimation for escalation decisions (survey §2.1, §2.2.1 and
the §6 "future prospects" advocating evidence-based estimators).

All estimators map logits (..., V) -> scalar uncertainty (...,) in [0, 1]-ish
range (higher = more uncertain).  The Dirichlet evidence estimator implements
the survey's proposed direction: treat exp-logits as evidence, decompose into
epistemic (vacuity) and aleatoric (expected entropy) components.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def max_prob(logits):
    """1 - max softmax probability."""
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    return 1.0 - jnp.max(p, axis=-1)


def entropy(logits, normalize: bool = True):
    """Shannon entropy of the softmax; optionally normalized by log V."""
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    h = -jnp.sum(jnp.exp(lp) * lp, axis=-1)
    if normalize:
        h = h / jnp.log(logits.shape[-1])
    return h


def margin(logits):
    """1 - (p1 - p2): small top-2 margin = uncertain."""
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top2 = jax.lax.top_k(p, 2)[0]
    return 1.0 - (top2[..., 0] - top2[..., 1])


def energy(logits, temperature: float = 1.0):
    """Negative free energy -T*logsumexp(l/T), min-max squashed via sigmoid.
    Unlike softmax scores this preserves the raw evidential magnitude
    (survey §6: normalized probabilities obscure evidential strength)."""
    e = -temperature * jax.nn.logsumexp(logits.astype(jnp.float32) / temperature,
                                        axis=-1)
    return jax.nn.sigmoid(e)   # low evidence -> high energy -> near 1


def dirichlet_evidence(logits, clip: float = 10.0):
    """Evidence-based uncertainty (survey §6).

    alpha = 1 + exp(clip(logits)); S = sum(alpha).
      * epistemic (vacuity)  u_ep = V / S           (little total evidence)
      * aleatoric            u_al = E[H(p)] / log V  (conflicting evidence)
    Returns dict {"epistemic", "aleatoric", "total"}.
    """
    V = logits.shape[-1]
    l = jnp.clip(logits.astype(jnp.float32), -clip, clip)
    alpha = 1.0 + jnp.exp(l)
    S = jnp.sum(alpha, axis=-1)
    u_ep = V / S
    # expected entropy of Categorical(p), p ~ Dir(alpha):
    # E[H] = -sum_k alpha_k/S * (digamma(alpha_k+1) - digamma(S+1))
    dg = jax.scipy.special.digamma
    e_h = -jnp.sum(alpha / S[..., None] * (dg(alpha + 1.0) - dg(S[..., None] + 1.0)),
                   axis=-1)
    u_al = e_h / jnp.log(V)
    return {"epistemic": u_ep, "aleatoric": u_al,
            "total": jnp.clip(u_ep + u_al, 0.0, 2.0) / 2.0}


ESTIMATORS = {
    "max_prob": max_prob,
    "entropy": entropy,
    "margin": margin,
    "energy": energy,
    "dirichlet": lambda l: dirichlet_evidence(l)["total"],
}


def get_estimator(name: str):
    if name not in ESTIMATORS:
        raise KeyError(f"unknown estimator {name!r}; known: {sorted(ESTIMATORS)}")
    return ESTIMATORS[name]


def get_batched_estimator(name: str):
    """Batched per-slot estimator for the serving scheduler.

    Returns ``fn: logits (B, ..., V) -> (B,) float32`` — one scalar per
    batch slot, computed entirely on device so the scheduler's decode scan
    can accumulate uncertainty without a per-token host sync.  Singleton
    middle axes (e.g. the (B, 1, V) shape produced by a vmapped
    ``decode_step``) are squeezed into the per-slot scalar.
    """
    est = get_estimator(name)

    def batched(logits):
        u = est(logits.astype(jnp.float32))
        return jnp.reshape(u, (logits.shape[0],)).astype(jnp.float32)

    return batched
