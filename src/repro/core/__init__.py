"""Core collaboration library — the survey's taxonomy as composable modules.

Inference (survey §2): routing, uncertainty, early_exit, partition,
compression, cache, speculative, self_speculative, tree_speculation, engine.
"""
from repro.core.adaptation import AdaptationLoop  # noqa: F401
from repro.core.policy import (BanditPolicy, BudgetPolicy,  # noqa: F401
                               CascadePolicy, CollabPolicy, SkeletonPolicy,
                               SpeculativePolicy, ThresholdPolicy,
                               make_policy)
from repro.core.scheduler import BatchedEngine, RequestTrace  # noqa: F401
from repro.core.seq_state import (DenseKV, Lane, PagedKV,  # noqa: F401
                                  RecurrentState, SequenceState, SpecOps)
from repro.core.speculative import (BatchedSpecDecoder,  # noqa: F401
                                    SpecDecoder, SpecStats,
                                    autoregressive_baseline,
                                    speculative_sample)
from repro.core.uncertainty import (get_batched_estimator,  # noqa: F401
                                    get_estimator)
