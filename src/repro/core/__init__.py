"""Core collaboration library — the survey's taxonomy as composable modules.

Inference (survey §2): routing, uncertainty, early_exit, partition,
compression, cache, speculative, self_speculative, tree_speculation, engine.
"""
from repro.core.speculative import (SpecDecoder, SpecStats,  # noqa: F401
                                    autoregressive_baseline,
                                    speculative_sample)
from repro.core.uncertainty import get_estimator  # noqa: F401
