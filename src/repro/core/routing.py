"""Task assignment / routing (survey §2.1, §2.2.1).

Three router families from the survey's taxonomy:

* ``ConfidenceRouter`` — trust/semantic-aware: escalate to the cloud model
  when edge uncertainty exceeds a threshold (Tabi / FS-GEN style).
* ``CascadeRouter`` — cost-aware cascades (FrugalGPT): try models in cost
  order, stop at the first confident one.
* ``UCBRouter`` / ``LinUCBRouter`` — reward- and cost-aware bandit routing
  (PerLLM / MixLLM / LLM-Bandit style): online learning of which model to
  use, optionally conditioned on query features.

Routers are host-side control plane (NumPy); the models they select are
jitted JAX functions.  This mirrors production serving, where routing logic
lives outside the accelerator graph.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.uncertainty import get_estimator


@dataclasses.dataclass
class Route:
    model_idx: int
    uncertainty: float
    cost: float
    trace: list


class ConfidenceRouter:
    """Route to cloud (idx 1) when edge (idx 0) uncertainty > threshold."""

    def __init__(self, threshold: float = 0.5, estimator: str = "entropy"):
        self.threshold = threshold
        self.est = get_estimator(estimator)

    def __call__(self, edge_logits) -> Route:
        u = float(np.asarray(self.est(edge_logits)).mean())
        idx = 1 if u > self.threshold else 0
        return Route(idx, u, cost=0.0, trace=[("edge_unc", u)])


class CascadeRouter:
    """FrugalGPT-style cascade: models ordered by cost; escalate while the
    current model's confidence is below its acceptance threshold."""

    def __init__(self, costs: Sequence[float], thresholds: Sequence[float],
                 estimator: str = "max_prob"):
        assert len(costs) == len(thresholds)
        self.costs = list(costs)
        self.thresholds = list(thresholds)
        self.est = get_estimator(estimator)

    def route(self, u_fns: Sequence[Callable[[], float]]) -> Route:
        """Cascade over lazily-evaluated per-tier UNCERTAINTIES (the
        estimator already applied, or any other scalar the caller trusts):
        pay tier i's cost, stop at the first tier confident under its
        threshold (the last tier is unconditional).  This is the seam the
        serving ``CascadePolicy`` drives — tiers there are collaboration
        mechanisms, not just models."""
        spent, trace = 0.0, []
        for i, fn in enumerate(u_fns):
            spent += self.costs[i]
            u = float(fn())
            trace.append((i, u))
            if u <= self.thresholds[i] or i == len(u_fns) - 1:
                return Route(i, u, spent, trace)
        raise RuntimeError("unreachable")

    def run(self, score_fns: Sequence[Callable[[], np.ndarray]]) -> Route:
        """score_fns[i]() -> logits of model i (lazily evaluated: escalation
        is what costs money, so we only call what we route to)."""
        return self.route([
            lambda fn=fn: float(np.asarray(self.est(fn())).mean())
            for fn in score_fns])


class UCBRouter:
    """Upper-confidence-bound bandit over K models (PerLLM's formulation:
    constrained multi-armed bandit with cost-adjusted reward)."""

    def __init__(self, n_models: int, cost_weight: float = 0.1, c: float = 1.4):
        self.n = np.zeros(n_models)
        self.mean = np.zeros(n_models)
        self.cost_weight = cost_weight
        self.c = c
        self.t = 0

    def select(self) -> int:
        self.t += 1
        if (self.n == 0).any():
            return int(np.argmin(self.n))
        ucb = self.mean + self.c * np.sqrt(np.log(self.t) / self.n)
        return int(np.argmax(ucb))

    def update(self, idx: int, quality: float, cost: float = 0.0):
        r = quality - self.cost_weight * cost
        self.n[idx] += 1
        self.mean[idx] += (r - self.mean[idx]) / self.n[idx]

    def regret(self, oracle_mean: Optional[np.ndarray] = None) -> float:
        m = oracle_mean if oracle_mean is not None else self.mean
        return float(self.t * np.max(m) - np.sum(self.n * self.mean))


class LinUCBRouter:
    """Contextual bandit (LinUCB): route on query features (uncertainty
    signals, length, domain one-hots) — MixLLM/CITER style."""

    def __init__(self, n_models: int, dim: int, alpha: float = 0.5,
                 cost_weight: float = 0.1):
        self.A = [np.eye(dim) for _ in range(n_models)]
        self.b = [np.zeros(dim) for _ in range(n_models)]
        self.alpha = alpha
        self.cost_weight = cost_weight

    def select(self, x: np.ndarray) -> int:
        scores = []
        for A, b in zip(self.A, self.b):
            Ainv = np.linalg.inv(A)
            theta = Ainv @ b
            scores.append(theta @ x + self.alpha * np.sqrt(x @ Ainv @ x))
        return int(np.argmax(scores))

    def update(self, idx: int, x: np.ndarray, quality: float, cost: float = 0.0):
        r = quality - self.cost_weight * cost
        self.A[idx] += np.outer(x, x)
        self.b[idx] += r * x


def capability_vector(logits_samples: List[np.ndarray], estimator: str = "entropy"
                      ) -> np.ndarray:
    """Learned model-capability representation (survey §2.1): summarize a
    model's behavior on probe queries as its mean/std uncertainty profile."""
    est = get_estimator(estimator)
    us = [float(np.asarray(est(l)).mean()) for l in logits_samples]
    return np.array([np.mean(us), np.std(us), np.min(us), np.max(us)])
