"""Early exit (survey §2.2.3 — LITE / LayerSkip / EE-LLM style).

Two pieces:
* inference: confidence-gated exit over per-layer hidden states (the shared
  LM head is applied at candidate exit layers; generation stops at the first
  layer whose confidence clears the threshold);
* training: LayerSkip-style auxiliary exit loss so intermediate layers
  produce usable logits (weight grows with depth).
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp

from repro.core.uncertainty import get_estimator
from repro.models.model import cross_entropy


def exit_logits(model, params, hidden_per_layer, layers: Sequence[int]):
    """hidden_per_layer: (L, B, S, d) from forward(collect_hidden=True).
    Applies final norm + shared unembedding at each exit layer.
    Returns (n_exits, B, S, V) f32."""
    from repro.models import layers as L
    cfg = model.cfg
    head = params.get("lm_head", params["embed"])
    outs = []
    for l in layers:
        h = hidden_per_layer[l]
        if "final_norm" in params:
            h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
        else:   # encdec layernorm
            h = L.layernorm(h, params["final_norm_w"], params["final_norm_b"])
        outs.append(L.unembed(head, h))
    return jnp.stack(outs)


def early_exit_decision(exit_logits_stack, threshold: float,
                        estimator: str = "max_prob"):
    """exit_logits_stack: (n_exits, B, V) at one decode position.
    Returns (chosen_exit_idx (B,), logits (B, V)): first exit whose
    confidence clears the threshold (the last exit always 'fires')."""
    est = get_estimator(estimator)
    u = est(exit_logits_stack)                       # (n_exits, B)
    ok = u < threshold
    ok = ok.at[-1].set(True)
    idx = jnp.argmax(ok, axis=0)                     # first True
    chosen = jnp.take_along_axis(
        exit_logits_stack, idx[None, :, None], axis=0)[0]
    return idx, chosen


def layerskip_loss(model, params, batch, exit_layers: Sequence[int],
                   final_weight: float = 1.0):
    """Training loss: final CE + depth-weighted auxiliary exit CE
    (LayerSkip's curriculum, static form).  Returns (loss, per_exit_ce)."""
    logits, aux, hs = model.forward(params, batch, collect_hidden=True)
    labels = batch["labels"]
    if model.cfg.family == "vlm":
        P = batch["embeds"].shape[1]
        logits = logits[:, P:, :]
        hs = hs[:, :, P:, :]
    ce_final = cross_entropy(logits[:, :-1], labels[:, 1:])
    ex = exit_logits(model, params, hs, exit_layers)
    L_total = model.cfg.num_layers
    ces = []
    loss = final_weight * ce_final + aux
    for i, l in enumerate(exit_layers):
        w = 0.3 * (l + 1) / L_total                  # deeper exits weigh more
        ce = cross_entropy(ex[i][:, :-1], labels[:, 1:])
        ces.append(ce)
        loss = loss + w * ce
    return loss, jnp.stack(ces) if ces else jnp.zeros((0,))
