"""Paged (block) KV-cache allocation for the serving scheduler.

The dense scheduler pads every slot's KV cache to a common ``slot_len``, so
one long-prompt outlier inflates every slot (ROADMAP "Paged KV" gap).  This
module is the memory half of the fix — the vLLM-style block pool:

  * the device cache is ONE pool of ``num_blocks`` fixed-size token blocks
    per layer (``models/transformer.init_paged_cache``), shared by all
    slots;
  * each slot owns a list of block ids; the device sees them as a padded
    int32 BLOCK TABLE row ``(max_blocks,)`` — logical position ``p`` of
    slot ``b`` lives in block ``table[b, p // block_size]`` at offset
    ``p % block_size``;
  * blocks are allocated at admission (prompt prefill), GROWN on demand at
    decode time (one tick's worth at a time), and freed at retirement —
    per-slot capacity is decoupled from the batch's worst request.

Blocks are REFCOUNTED: slots whose prompts share a block-aligned prefix map
the shared prefix onto the same physical blocks (``share``), and the first
divergent write forks a private copy (``fork`` — copy-on-write).  ``used``
counts physical blocks, so sharing shows up directly in ``peak_used`` and
the benchmark's kv_savings number.

Block 0 is the TRAP block: it is never allocated, and every unused table
entry points at it.  Retired slots keep garbage-decoding behind the
scheduler's ``active`` mask until re-admission; redirecting their table
rows to the trap confines those masked writes so freed blocks can be
reallocated immediately without corruption.

``BlockPool`` is the host-side allocator (pure Python bookkeeping — block
ids only, no device arrays); ``write_pool_blocks`` / ``copy_pool_blocks``
are the jitted scatters that land a prefilled prompt's K/V blocks in the
pool and execute copy-on-write forks.
"""
from __future__ import annotations

import heapq
from typing import Any, Dict, List

import jax

TRAP_BLOCK = 0


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``n_tokens`` cache entries (0 tokens -> 0)."""
    if n_tokens <= 0:
        return 0
    return -(-n_tokens // block_size)


class BlockPool:
    """Host-side fixed-size block allocator over a device KV pool.

    Tracks only block IDS — the device arrays live in the scheduler's
    cache pytree.  Block 0 (``TRAP_BLOCK``) is reserved and never handed
    out.  ``peak_used`` is the high-water mark of live PHYSICAL blocks
    (a block shared by k owners counts once), which the benchmark converts
    to peak cache bytes.

    The free list is a min-heap, so the lowest free ids are handed out
    first no matter how allocations and frees interleave — deterministic
    block layouts in tests survive retire/admit churn.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError(f"need >= 2 blocks (one is the trap), got "
                             f"{num_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # min-heap: low ids handed out first (deterministic layouts)
        self._free: List[int] = list(range(1, num_blocks))
        heapq.heapify(self._free)
        self._owned: Dict[Any, List[int]] = {}
        self._refs: Dict[int, int] = {}
        self.peak_used = 0

    # ------------------------------------------------------------ queries
    @property
    def used(self) -> int:
        return (self.num_blocks - 1) - len(self._free)

    def blocks_for(self, n_tokens: int) -> int:
        return blocks_for(n_tokens, self.block_size)

    def can_alloc(self, n_blocks: int, owner=None) -> bool:
        """``owner`` narrows the check to that owner's shard on sharded
        pools; the single pool ignores it."""
        return len(self._free) >= n_blocks

    def usable(self) -> int:
        """Blocks an owner could ever hold (pool minus trap); on sharded
        pools this is the PER-SHARD bound — one owner never spans shards."""
        return self.num_blocks - 1

    def trap(self, owner) -> int:
        """Trap block id for ``owner``'s table-row padding (per-shard on
        sharded pools, so masked garbage writes stay shard-local)."""
        return TRAP_BLOCK

    def owned(self, owner) -> List[int]:
        return list(self._owned.get(owner, ()))

    def refcount(self, blk: int) -> int:
        return self._refs.get(blk, 0)

    # ------------------------------------------------------------ alloc
    def alloc(self, owner, n_blocks: int) -> List[int]:
        """Take ``n_blocks`` for ``owner``; raises when the pool is
        exhausted (the scheduler checks ``can_alloc`` first and defers or
        preempts instead)."""
        if n_blocks > len(self._free):
            raise RuntimeError(
                f"KV block pool exhausted: want {n_blocks}, have "
                f"{len(self._free)} free of {self.num_blocks - 1} "
                f"(raise --kv-blocks or shrink the batch)")
        got = [heapq.heappop(self._free) for _ in range(n_blocks)]
        for blk in got:
            self._refs[blk] = 1
        self._owned.setdefault(owner, []).extend(got)
        self.peak_used = max(self.peak_used, self.used)
        return got

    def grow_to(self, owner, n_tokens: int) -> List[int]:
        """Extend ``owner`` so its blocks cover ``n_tokens`` cache entries;
        returns only the NEW block ids (possibly empty)."""
        have = len(self._owned.get(owner, ()))
        need = self.blocks_for(n_tokens) - have
        if need <= 0:
            return []
        return self.alloc(owner, need)

    # ------------------------------------------------------------ sharing
    def share(self, owner, blocks: List[int]) -> None:
        """Map ``blocks`` (another owner's live prefix) into ``owner``'s
        logical block list, bumping each refcount — no physical
        allocation.  ``owner``'s list must currently be empty or end
        exactly where ``blocks`` continue (prefixes are shared front-first
        at admission)."""
        for blk in blocks:
            if self._refs.get(blk, 0) < 1:
                raise RuntimeError(f"cannot share dead block {blk}")
            self._refs[blk] += 1
        self._owned.setdefault(owner, []).extend(blocks)

    def fork(self, owner, blk: int) -> int:
        """Copy-on-write split: give ``owner`` a fresh private block in
        place of shared ``blk`` (the caller copies the device contents).
        Returns the new block id; ``blk`` keeps its remaining owners."""
        mine = self._owned.get(owner, [])
        i = mine.index(blk)          # raises if owner doesn't hold blk
        if self._refs.get(blk, 0) <= 1:
            return blk               # already private: nothing to split
        [new] = self.alloc(owner, 1)
        self._owned[owner].pop()     # alloc appended; splice in place
        mine[i] = new
        self._deref(blk)
        return new

    # ------------------------------------------------------------ free
    def _deref(self, blk: int) -> bool:
        """Drop one reference; True if the block died (returned to the
        free heap)."""
        self._refs[blk] -= 1
        if self._refs[blk] > 0:
            return False
        del self._refs[blk]
        heapq.heappush(self._free, blk)
        return True

    def free(self, owner) -> List[int]:
        """Release all of ``owner``'s references (idempotent).  Returns
        the ids that actually DIED (refcount hit zero) so callers can
        invalidate host-side indexes over their contents."""
        dead = []
        for blk in self._owned.pop(owner, ()):
            if self._deref(blk):
                dead.append(blk)
        return dead


class ShardedBlockPool:
    """Per-shard block allocation over ONE device KV pool (the sharded
    serving path — `launch/sharding.paged_cache_spec` shards the pool's
    block dim over the data axes, kv-heads over 'model').

    The device arrays stay a single global pool of ``shards * per_shard``
    blocks; shard ``s`` OWNS the contiguous id range
    ``[s * per_shard, (s + 1) * per_shard)`` — exactly the rows living on
    data shard ``s`` — and each range's first block is that shard's trap,
    so masked garbage decode and table-row padding never cross shards.
    Slots map to shards by ``shard_of`` (the scheduler's contiguous slot
    groups), and ALL host-side bookkeeping — free lists, refcounts, prefix
    sharing, copy-on-write, swap — is per-shard: an owner only ever holds
    blocks from its own range, so allocation, sharing and the masked
    writes it protects against are shard-local by construction.

    Duck-types ``BlockPool`` (same methods the ``PagedKV`` adapter calls);
    ``can_alloc``/``usable`` answer for one shard, ``used``/``peak_used``
    aggregate across shards for the capacity stats.
    """

    def __init__(self, shards: int, per_shard: int, block_size: int,
                 shard_of):
        if shards < 1:
            raise ValueError(f"need >= 1 shards, got {shards}")
        self.shards = shards
        self.per_shard = per_shard
        self.num_blocks = shards * per_shard
        self.block_size = block_size
        self._shard_of = shard_of
        # inner pools hand out LOCAL ids 1..per_shard-1 (0 = shard trap);
        # global id = shard * per_shard + local
        self._pools = [BlockPool(per_shard, block_size)
                       for _ in range(shards)]
        self.peak_used = 0

    # ------------------------------------------------------------ queries
    @property
    def used(self) -> int:
        return sum(p.used for p in self._pools)

    def blocks_for(self, n_tokens: int) -> int:
        return blocks_for(n_tokens, self.block_size)

    def can_alloc(self, n_blocks: int, owner=None) -> bool:
        if owner is None:       # no shard context: every shard must fit it
            return all(p.can_alloc(n_blocks) for p in self._pools)
        return self._pools[self._shard_of(owner)].can_alloc(n_blocks)

    def usable(self) -> int:
        return self.per_shard - 1

    def trap(self, owner) -> int:
        return self._shard_of(owner) * self.per_shard

    def owned(self, owner) -> List[int]:
        s = self._shard_of(owner)
        base = s * self.per_shard
        return [base + blk for blk in self._pools[s].owned(owner)]

    def refcount(self, blk: int) -> int:
        return self._pools[blk // self.per_shard].refcount(
            blk % self.per_shard)

    # ------------------------------------------------------------ alloc
    def _note_peak(self):
        self.peak_used = max(self.peak_used, self.used)

    def alloc(self, owner, n_blocks: int) -> List[int]:
        s = self._shard_of(owner)
        base = s * self.per_shard
        got = [base + blk for blk in self._pools[s].alloc(owner, n_blocks)]
        self._note_peak()
        return got

    def grow_to(self, owner, n_tokens: int) -> List[int]:
        s = self._shard_of(owner)
        base = s * self.per_shard
        got = [base + blk
               for blk in self._pools[s].grow_to(owner, n_tokens)]
        self._note_peak()
        return got

    # ------------------------------------------------------------ sharing
    def share(self, owner, blocks: List[int]) -> None:
        s = self._shard_of(owner)
        base = s * self.per_shard
        for blk in blocks:
            if blk // self.per_shard != s:
                raise RuntimeError(
                    f"cross-shard share: block {blk} is not in shard {s}")
        self._pools[s].share(owner, [blk - base for blk in blocks])

    def fork(self, owner, blk: int) -> int:
        s = self._shard_of(owner)
        base = s * self.per_shard
        new = base + self._pools[s].fork(owner, blk - base)
        self._note_peak()
        return new

    # ------------------------------------------------------------ free
    def free(self, owner) -> List[int]:
        s = self._shard_of(owner)
        base = s * self.per_shard
        return [base + blk for blk in self._pools[s].free(owner)]


# ---------------------------------------------------------------- device
@jax.jit
def write_pool_blocks(k_pool, v_pool, block_ids, k_blocks, v_blocks):
    """Scatter one prompt's prefilled K/V into its allocated pool blocks.

    k_pool/v_pool: (L, NB, bs, Kv, hd); block_ids: (nb,) int32;
    k_blocks/v_blocks: (L, nb, bs, Kv, hd).  One fused scatter per side —
    jit-cached per distinct nb (prompt-length bucket).
    """
    return (k_pool.at[:, block_ids].set(k_blocks.astype(k_pool.dtype)),
            v_pool.at[:, block_ids].set(v_blocks.astype(v_pool.dtype)))


@jax.jit
def copy_pool_blocks(k_pool, v_pool, src_ids, dst_ids):
    """Copy-on-write fork: duplicate blocks ``src_ids`` into ``dst_ids``
    (both (n,) int32) in one gather+scatter per side."""
    return (k_pool.at[:, dst_ids].set(k_pool[:, src_ids]),
            v_pool.at[:, dst_ids].set(v_pool[:, src_ids]))


@jax.jit
def read_pool_blocks(k_pool, v_pool, block_ids):
    """Gather blocks ``block_ids`` (n,) int32 out of the pool — the device
    half of swap-out (the caller stages the result to host memory)."""
    return k_pool[:, block_ids], v_pool[:, block_ids]


def prompt_cache_to_blocks(cache, block_size: int):
    """Reshape a single-sequence prefilled cache (padded to a multiple of
    ``block_size``) into per-block K/V: (L, 1, nb*bs, Kv, hd) ->
    (L, nb, bs, Kv, hd)."""
    k, v = cache["k"], cache["v"]
    L, _, spad, kv_heads, hd = k.shape
    nb = spad // block_size
    shape = (L, nb, block_size, kv_heads, hd)
    return k[:, 0].reshape(shape), v[:, 0].reshape(shape)
