"""Paged (block) KV-cache allocation for the serving scheduler.

The dense scheduler pads every slot's KV cache to a common ``slot_len``, so
one long-prompt outlier inflates every slot (ROADMAP "Paged KV" gap).  This
module is the memory half of the fix — the vLLM-style block pool:

  * the device cache is ONE pool of ``num_blocks`` fixed-size token blocks
    per layer (``models/transformer.init_paged_cache``), shared by all
    slots;
  * each slot owns a list of block ids; the device sees them as a padded
    int32 BLOCK TABLE row ``(max_blocks,)`` — logical position ``p`` of
    slot ``b`` lives in block ``table[b, p // block_size]`` at offset
    ``p % block_size``;
  * blocks are allocated at admission (prompt prefill), GROWN on demand at
    decode time (one tick's worth at a time), and freed at retirement —
    per-slot capacity is decoupled from the batch's worst request.

Block 0 is the TRAP block: it is never allocated, and every unused table
entry points at it.  Retired slots keep garbage-decoding behind the
scheduler's ``active`` mask until re-admission; redirecting their table
rows to the trap confines those masked writes so freed blocks can be
reallocated immediately without corruption.

``BlockPool`` is the host-side allocator (pure Python bookkeeping — block
ids only, no device arrays); ``write_pool_blocks`` is the jitted scatter
that lands a prefilled prompt's K/V blocks in the pool.
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax

TRAP_BLOCK = 0


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``n_tokens`` cache entries (0 tokens -> 0)."""
    if n_tokens <= 0:
        return 0
    return -(-n_tokens // block_size)


class BlockPool:
    """Host-side fixed-size block allocator over a device KV pool.

    Tracks only block IDS — the device arrays live in the scheduler's
    cache pytree.  Block 0 (``TRAP_BLOCK``) is reserved and never handed
    out.  ``peak_used`` is the high-water mark of live blocks, which the
    benchmark converts to peak cache bytes.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError(f"need >= 2 blocks (one is the trap), got "
                             f"{num_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # stack: low ids handed out first (deterministic layouts in tests)
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._owned: Dict[Any, List[int]] = {}
        self.peak_used = 0

    # ------------------------------------------------------------ queries
    @property
    def used(self) -> int:
        return (self.num_blocks - 1) - len(self._free)

    def blocks_for(self, n_tokens: int) -> int:
        return blocks_for(n_tokens, self.block_size)

    def can_alloc(self, n_blocks: int) -> bool:
        return len(self._free) >= n_blocks

    def owned(self, owner) -> List[int]:
        return list(self._owned.get(owner, ()))

    # ------------------------------------------------------------ alloc
    def alloc(self, owner, n_blocks: int) -> List[int]:
        """Take ``n_blocks`` for ``owner``; raises when the pool is
        exhausted (the scheduler checks ``can_alloc`` first and defers
        admission instead)."""
        if n_blocks > len(self._free):
            raise RuntimeError(
                f"KV block pool exhausted: want {n_blocks}, have "
                f"{len(self._free)} free of {self.num_blocks - 1} "
                f"(raise --kv-blocks or shrink the batch)")
        got = [self._free.pop() for _ in range(n_blocks)]
        self._owned.setdefault(owner, []).extend(got)
        self.peak_used = max(self.peak_used, self.used)
        return got

    def grow_to(self, owner, n_tokens: int) -> List[int]:
        """Extend ``owner`` so its blocks cover ``n_tokens`` cache entries;
        returns only the NEW block ids (possibly empty)."""
        have = len(self._owned.get(owner, ()))
        need = self.blocks_for(n_tokens) - have
        if need <= 0:
            return []
        return self.alloc(owner, need)

    def free(self, owner):
        """Return all of ``owner``'s blocks to the pool (idempotent)."""
        for blk in self._owned.pop(owner, ()):
            self._free.append(blk)


# ---------------------------------------------------------------- device
@jax.jit
def write_pool_blocks(k_pool, v_pool, block_ids, k_blocks, v_blocks):
    """Scatter one prompt's prefilled K/V into its allocated pool blocks.

    k_pool/v_pool: (L, NB, bs, Kv, hd); block_ids: (nb,) int32;
    k_blocks/v_blocks: (L, nb, bs, Kv, hd).  One fused scatter per side —
    jit-cached per distinct nb (prompt-length bucket).
    """
    return (k_pool.at[:, block_ids].set(k_blocks.astype(k_pool.dtype)),
            v_pool.at[:, block_ids].set(v_blocks.astype(v_pool.dtype)))


def prompt_cache_to_blocks(cache, block_size: int):
    """Reshape a single-sequence prefilled cache (padded to a multiple of
    ``block_size``) into per-block K/V: (L, 1, nb*bs, Kv, hd) ->
    (L, nb, bs, Kv, hd)."""
    k, v = cache["k"], cache["v"]
    L, _, spad, kv_heads, hd = k.shape
    nb = spad // block_size
    shape = (L, nb, block_size, kv_heads, hd)
    return k[:, 0].reshape(shape), v[:, 0].reshape(shape)
