"""Open-loop arrival simulation + latency accounting for the serving stack.

The survey's task-assignment and budget/SLA policies (§2.3) are claims
about *latency and cost under real traffic*, but a benchmark that replays
a fixed request list back-to-back measures neither: every request "arrives"
the instant the engine is free, so queueing delay, time-to-first-token and
SLO attainment are all degenerate.  This module supplies the missing
harness pieces; ``core/scheduler.py::BatchedEngine`` consumes them:

  * ARRIVAL PROCESSES — ``poisson_arrivals`` (memoryless open-loop load),
    ``bursty_arrivals`` (on/off bursts at a peak rate around the same
    long-run average — the regime that actually exercises admission
    control and preemption), and ``trace_arrivals`` (replay recorded
    timestamps).  All return sorted arrival times in milliseconds,
    deterministic under a seed, to feed ``BatchedEngine.submit(at=...)``.

  * CLOCKS — the engine reads time through one small interface
    (``now / wait_until / on_steps / on_prefill``) so the same scheduler
    runs open-loop against either:

      - ``VirtualClock``: deterministic simulated time.  One batched
        decode-scan step costs ``step_ms``; one prefilled prompt token
        costs ``prefill_token_ms`` (default ``step_ms / 8`` — prefill is
        sequence-parallel, decode is not).  Thousands of virtual requests
        can be in flight against a CI-sized batch, and every latency
        number is reproducible bit-for-bit, so CI can assert on p99s.
      - ``WallClock``: real ``time.perf_counter`` time; ``wait_until``
        sleeps until the next arrival is due.  The modeled-cost hooks are
        no-ops — elapsed time IS the cost.

  * ROLLUP — ``latency_rollup`` turns the engine's per-request lifecycle
    events (submit / admit / first-token / retire timestamps plus swap and
    defer counts) into the serving headline numbers: p50/p99 TTFT (first
    token minus SUBMIT, so queueing delay counts) and TPOT (inter-token
    time after the first), SLO attainment, and goodput-under-SLO
    (completed requests meeting the TTFT SLO per second of makespan — the
    "goodput" of sarathi/vLLM-style serving papers).

Timestamps are tick-granular: the engine stamps first-token at the end of
the decode tick that emitted it, so a virtual-clock TTFT is resolved to
``tick_tokens * step_ms``.  Escalated requests re-stamp first-token at
their escalation's first step — the discarded edge stream never reached
the client, so counting it would flatter TTFT.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Optional

import numpy as np


# ---------------------------------------------------------------- clocks
class VirtualClock:
    """Deterministic simulated clock (milliseconds).

    The engine charges modeled costs through ``on_steps`` (batched decode
    scan steps) and ``on_prefill`` (prompt tokens prefilled this tick);
    ``wait_until`` jumps over idle gaps to the next arrival.  ``step_ms``
    is the modeled cost of ONE decode-scan step over the whole batch —
    the natural time unit of the scheduler's tick loop.
    """

    def __init__(self, step_ms: float = 1.0,
                 prefill_token_ms: Optional[float] = None):
        if step_ms <= 0:
            raise ValueError(f"step_ms must be > 0, got {step_ms}")
        self.step_ms = float(step_ms)
        self.prefill_token_ms = (self.step_ms / 8.0
                                 if prefill_token_ms is None
                                 else float(prefill_token_ms))
        self._t = 0.0

    def now(self) -> float:
        return self._t

    def wait_until(self, t: float) -> None:
        self._t = max(self._t, float(t))

    def on_steps(self, n: int) -> None:
        self._t += n * self.step_ms

    def on_prefill(self, tokens: int) -> None:
        self._t += tokens * self.prefill_token_ms


class WallClock:
    """Real time (``time.perf_counter``, milliseconds since construction).

    Modeled-cost hooks are no-ops — real elapsed time is the cost; the
    step resolution ``step_ms`` is 0 (timestamps are already exact).
    ``wait_until`` sleeps, so open-loop arrival replay runs in real time.
    """

    step_ms = 0.0

    def __init__(self):
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return (time.perf_counter() - self._t0) * 1e3

    def wait_until(self, t: float) -> None:
        dt = float(t) - self.now()
        if dt > 0:
            time.sleep(dt / 1e3)

    def on_steps(self, n: int) -> None:
        pass

    def on_prefill(self, tokens: int) -> None:
        pass


# ---------------------------------------------------------------- arrivals
def poisson_arrivals(rate: float, n: int, seed: int = 0,
                     start: float = 0.0) -> np.ndarray:
    """``n`` Poisson arrival times (ms) at ``rate`` requests/second:
    i.i.d. exponential inter-arrival gaps, deterministic under ``seed``."""
    if rate <= 0:
        raise ValueError(f"rate must be > 0 req/s, got {rate}")
    if n < 0:
        raise ValueError(f"n must be >= 0, got {n}")
    rng = np.random.default_rng(seed)
    return start + np.cumsum(rng.exponential(1e3 / rate, size=n))


def bursty_arrivals(rate: float, n: int, seed: int = 0, burst: int = 8,
                    peak: float = 8.0, start: float = 0.0) -> np.ndarray:
    """``n`` on/off bursty arrival times (ms): bursts of ~``burst``
    requests (Poisson-sized) arrive at ``peak``x the mean rate, separated
    by idle gaps sized so the LONG-RUN average stays ``rate`` req/s.  The
    instantaneous overcommit is what stresses admission, chunked prefill
    and preemption; the mean rate keeps the workload comparable to
    ``poisson_arrivals`` at the same ``rate``."""
    if rate <= 0 or peak <= 1.0 or burst < 1:
        raise ValueError(f"need rate > 0, peak > 1, burst >= 1; got "
                         f"rate={rate} peak={peak} burst={burst}")
    rng = np.random.default_rng(seed)
    out, t = [], float(start)
    while len(out) < n:
        k = max(1, int(rng.poisson(burst)))
        served = min(k, n - len(out))
        for _ in range(served):
            t += rng.exponential(1e3 / (rate * peak))
            out.append(t)
        # the burst spent ~k/(rate*peak) s; the off-gap supplies the rest
        # of the k/rate s an average-rate process would have taken
        t += served * (1e3 / rate) * (1.0 - 1.0 / peak)
    return np.asarray(out, np.float64)


def trace_arrivals(times) -> np.ndarray:
    """Replay recorded arrival timestamps (ms): validated, sorted."""
    a = np.asarray(times, np.float64).reshape(-1)
    if a.size and not np.all(np.isfinite(a)):
        raise ValueError("trace arrival times must be finite")
    return np.sort(a)


# ---------------------------------------------------------------- replay
def replay(engine, edge_params, cloud_params, prompts, max_new, at):
    """Open-loop convenience: submit ``prompts`` at arrival times ``at``
    (ms, aligned), drain, return traces in submission order.  The engine's
    clock decides whether "time" is simulated or real."""
    if isinstance(max_new, int):
        max_new = [max_new] * len(prompts)
    at = np.asarray(at, np.float64).reshape(-1)
    if not (len(prompts) == len(max_new) == at.size):
        raise ValueError(f"{len(prompts)} prompts, {len(max_new)} budgets, "
                         f"{at.size} arrival times")
    rids = [engine.submit(p, m, at=float(t))
            for p, m, t in zip(prompts, max_new, at)]
    results = engine.run(edge_params, cloud_params)
    return [results[rid] for rid in rids]


# ---------------------------------------------------------------- rollup
def _pct(xs, q: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q)) if len(xs) \
        else 0.0


def latency_rollup(events: Dict[int, dict],
                   slo_ms: Optional[float] = None) -> Dict[str, Any]:
    """Roll per-request lifecycle events up into serving latency stats.

    ``events`` maps rid -> {submit_ms, admit_ms?, first_token_ms?,
    retire_ms?, tokens?, swaps?, defers?}.  TTFT counts from SUBMIT (so
    queueing delay is included); TPOT is the mean inter-token gap after
    the first token, defined only for requests that streamed >= 2 tokens
    (cache hits and instant replays carry no decode cadence).  Goodput is
    completed-requests-meeting-the-TTFT-SLO per second of makespan; with
    no SLO every completed request counts (goodput == throughput).
    """
    done = [e for e in events.values() if "retire_ms" in e]
    ttfts = [e["first_token_ms"] - e["submit_ms"] for e in done
             if "first_token_ms" in e]
    tpots = [(e["retire_ms"] - e["first_token_ms"]) / (e["tokens"] - 1)
             for e in done
             if e.get("tokens", 0) > 1 and "first_token_ms" in e
             and e["retire_ms"] > e["first_token_ms"]]
    out: Dict[str, Any] = {
        "requests": len(events),
        "completed": len(done),
        "ttft_p50_ms": _pct(ttfts, 50),
        "ttft_p99_ms": _pct(ttfts, 99),
        "ttft_mean_ms": float(np.mean(ttfts)) if ttfts else 0.0,
        "tpot_p50_ms": _pct(tpots, 50),
        "tpot_p99_ms": _pct(tpots, 99),
        "slo_ms": slo_ms,
        "swapped_requests": sum(1 for e in events.values()
                                if e.get("swaps", 0) > 0),
        "deferred_admissions": sum(e.get("defers", 0)
                                   for e in events.values()),
    }
    if done:
        makespan = (max(e["retire_ms"] for e in done)
                    - min(e["submit_ms"] for e in done))
        met = [e for e in done
               if slo_ms is None
               or ("first_token_ms" in e
                   and e["first_token_ms"] - e["submit_ms"] <= slo_ms)]
        out["makespan_ms"] = makespan
        out["slo_attainment"] = len(met) / len(done)
        out["goodput_slo"] = (len(met) / (makespan / 1e3) if makespan > 0
                              else float(len(met)))
    else:
        out["makespan_ms"] = 0.0
        out["slo_attainment"] = 0.0
        out["goodput_slo"] = 0.0
    return out
