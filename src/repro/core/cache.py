"""Semantic response cache (survey §2.3.2 — VELO-style vector-database
cache at the edge).

Requests are keyed by an embedding; a hit (cosine similarity above a
threshold) returns the cached cloud response without a cloud call.  History
store doubles as the retrieval substrate for the Hybrid-RACA-style
historical-enhancement path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import numpy as np


@dataclasses.dataclass
class CacheEntry:
    key: np.ndarray
    value: Any
    hits: int = 0


class SemanticCache:
    def __init__(self, capacity: int = 1024, threshold: float = 0.9):
        self.capacity = capacity
        self.threshold = threshold
        self.entries: List[CacheEntry] = []
        self.lookups = 0
        self.hits = 0

    @staticmethod
    def _norm(v: np.ndarray) -> np.ndarray:
        v = np.asarray(v, np.float32).reshape(-1)
        return v / (np.linalg.norm(v) + 1e-12)

    def lookup(self, key: np.ndarray) -> Optional[Any]:
        return self.lookup_batch(np.asarray(key, np.float32).reshape(1, -1))[0]

    def lookup_batch(self, keys: np.ndarray) -> List[Optional[Any]]:
        """Vectorized lookup: one (N, D) @ (D, E) similarity matmul for N
        query keys against all E entries (the scheduler admits a whole batch
        of requests per tick, so per-key matmuls would scale as N*E)."""
        keys = np.asarray(keys, np.float32)
        if keys.ndim == 1:
            keys = keys.reshape(1, -1)
        n = keys.shape[0]
        self.lookups += n
        if not self.entries:
            return [None] * n
        norms = np.linalg.norm(keys, axis=1, keepdims=True) + 1e-12
        q = keys / norms                                   # (N, D)
        mat = np.stack([e.key for e in self.entries])      # (E, D)
        sims = q @ mat.T                                   # (N, E)
        best = np.argmax(sims, axis=1)
        out: List[Optional[Any]] = []
        for row, i in enumerate(best):
            if sims[row, i] >= self.threshold:
                self.hits += 1
                self.entries[int(i)].hits += 1
                out.append(self.entries[int(i)].value)
            else:
                out.append(None)
        return out

    def insert(self, key: np.ndarray, value: Any):
        if len(self.entries) >= self.capacity:
            # evict the least-hit entry (VELO uses utility-aware eviction)
            self.entries.pop(int(np.argmin([e.hits for e in self.entries])))
        self.entries.append(CacheEntry(self._norm(key), value))

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0


def embed_tokens_mean(model, params, tokens) -> np.ndarray:
    """Cheap request embedding: mean of the model's token embeddings.
    The pull to host is explicit (``device_get``) — the cache index
    lives host-side, and an implicit transfer here would trip the
    transfer-guard tier-1 test."""
    import jax
    import jax.numpy as jnp
    emb = params["embed"]
    v = jnp.mean(jnp.take(emb, jnp.asarray(tokens, jnp.int32), axis=0), axis=-2)
    return np.array(jax.device_get(v), np.float32)
