"""Semantic response cache (survey §2.3.2 — VELO-style vector-database
cache at the edge).

Requests are keyed by an embedding; a hit (cosine similarity above a
threshold) returns the cached cloud response without a cloud call.  History
store doubles as the retrieval substrate for the Hybrid-RACA-style
historical-enhancement path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class CacheEntry:
    key: np.ndarray
    value: Any
    hits: int = 0


class SemanticCache:
    def __init__(self, capacity: int = 1024, threshold: float = 0.9):
        self.capacity = capacity
        self.threshold = threshold
        self.entries: List[CacheEntry] = []
        self.lookups = 0
        self.hits = 0

    @staticmethod
    def _norm(v: np.ndarray) -> np.ndarray:
        v = np.asarray(v, np.float32).reshape(-1)
        return v / (np.linalg.norm(v) + 1e-12)

    def lookup(self, key: np.ndarray) -> Optional[Any]:
        self.lookups += 1
        if not self.entries:
            return None
        k = self._norm(key)
        mat = np.stack([e.key for e in self.entries])
        sims = mat @ k
        i = int(np.argmax(sims))
        if sims[i] >= self.threshold:
            self.hits += 1
            self.entries[i].hits += 1
            return self.entries[i].value
        return None

    def insert(self, key: np.ndarray, value: Any):
        if len(self.entries) >= self.capacity:
            # evict the least-hit entry (VELO uses utility-aware eviction)
            self.entries.pop(int(np.argmin([e.hits for e in self.entries])))
        self.entries.append(CacheEntry(self._norm(key), value))

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0


def embed_tokens_mean(model, params, tokens) -> np.ndarray:
    """Cheap request embedding: mean of the model's token embeddings."""
    import jax.numpy as jnp
    emb = params["embed"]
    v = jnp.mean(jnp.take(emb, jnp.asarray(tokens, jnp.int32), axis=0), axis=-2)
    return np.asarray(v, np.float32)
