"""Communication optimization (survey §2.2.4).

Everything that crosses the edge-cloud boundary (activations in split
inference, logits in verification, adapter deltas in federated tuning) goes
through a ``Compressor``.  Each compressor reports exact wire bytes so the
benchmarks can trade fidelity against transfer cost, mirroring the survey's
entropy-compression / EdgeShard-style selective-transmission discussion.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Compressed:
    payload: dict
    wire_bytes: int
    method: str


def _nbytes(tree) -> int:
    return int(sum(np.asarray(x).nbytes for x in jax.tree.leaves(tree)))


class Identity:
    name = "identity"

    def compress(self, x) -> Compressed:
        return Compressed({"x": x}, _nbytes(x), self.name)

    def decompress(self, c: Compressed):
        return c.payload["x"]


class Int8Quantizer:
    """Per-channel symmetric int8 (survey: INT8 intermediate representations,
    Li et al. / Ye et al.).  axis=-1 channels."""
    name = "int8"

    def compress(self, x) -> Compressed:
        x = jnp.asarray(x)
        scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        wire = q.size * 1 + scale.size * 4
        return Compressed({"q": q, "scale": scale}, int(wire), self.name)

    def decompress(self, c: Compressed):
        return c.payload["q"].astype(jnp.float32) * c.payload["scale"]


class Int4Quantizer:
    """Per-channel symmetric int4 (packed two-per-byte on the wire)."""
    name = "int4"

    def compress(self, x) -> Compressed:
        x = jnp.asarray(x)
        scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 7.0
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.clip(jnp.round(x / scale), -7, 7).astype(jnp.int8)
        wire = (q.size + 1) // 2 + scale.size * 4
        return Compressed({"q": q, "scale": scale}, int(wire), self.name)

    def decompress(self, c: Compressed):
        return c.payload["q"].astype(jnp.float32) * c.payload["scale"]


class TopKSparsifier:
    """Keep the top-k fraction of entries by magnitude (EdgeShard-style
    'forward only inference-critical features'); optional error feedback."""
    name = "topk"

    def __init__(self, frac: float = 0.1, error_feedback: bool = False):
        self.frac = frac
        self.error_feedback = error_feedback
        self._residual = None

    def compress(self, x) -> Compressed:
        x = jnp.asarray(x)
        if self.error_feedback and self._residual is not None:
            x = x + self._residual
        flat = x.reshape(-1)
        k = max(1, int(flat.size * self.frac))
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        vals = flat[idx]
        if self.error_feedback:
            kept = jnp.zeros_like(flat).at[idx].set(vals)
            self._residual = (flat - kept).reshape(x.shape)
        wire = k * (4 + 4)   # fp32 value + int32 index
        return Compressed({"idx": idx, "vals": vals, "shape": x.shape},
                          int(wire), self.name)

    def decompress(self, c: Compressed):
        shape = c.payload["shape"]
        size = int(np.prod(shape))
        flat = jnp.zeros((size,), jnp.float32).at[c.payload["idx"]].set(
            c.payload["vals"].astype(jnp.float32))
        return flat.reshape(shape)


class TopKLogits:
    """Transmit only the top-k logits + an 'other' bucket — the standard
    trick for shipping verification distributions edge<->cloud."""
    name = "topk_logits"

    def __init__(self, k: int = 64):
        self.k = k

    def compress(self, logits) -> Compressed:
        logits = jnp.asarray(logits)
        vals, idx = jax.lax.top_k(logits, self.k)
        wire = int(np.prod(logits.shape[:-1])) * self.k * (4 + 4)
        return Compressed({"idx": idx, "vals": vals,
                           "V": logits.shape[-1]}, wire, self.name)

    def decompress(self, c: Compressed):
        """Reconstruct (…, V) with -inf outside the top-k (probability mass
        outside top-k is treated as zero; survey's semantic-fidelity
        trade-off applies)."""
        idx, vals = c.payload["idx"], c.payload["vals"]
        V = c.payload["V"]
        out = jnp.full(idx.shape[:-1] + (V,), -1e30, jnp.float32)
        return jnp.put_along_axis(out, idx, vals.astype(jnp.float32), axis=-1,
                                  inplace=False)


def entropy_bits_estimate(x, bins: int = 256) -> float:
    """Empirical entropy (bits/element) of a quantized tensor — the survey's
    entropy-compression bound [17]: a lossless coder could reach this."""
    q = np.asarray(x).reshape(-1)
    hist, _ = np.histogram(q, bins=bins)
    p = hist / max(hist.sum(), 1)
    p = p[p > 0]
    return float(-(p * np.log2(p)).sum())


def relative_error(x, y) -> float:
    x, y = np.asarray(x, np.float32), np.asarray(y, np.float32)
    return float(np.linalg.norm(x - y) / (np.linalg.norm(x) + 1e-12))


COMPRESSORS = {
    "identity": Identity,
    "int8": Int8Quantizer,
    "int4": Int4Quantizer,
    "topk": TopKSparsifier,
}
