"""First-class collaboration policies over the batched scheduler.

The survey's core contribution is a taxonomy of edge-cloud collaboration —
task assignment, task division, and mixture-based collaboration at task and
token granularity — but the serving stack used to hardcode that choice as a
three-way ``escalation: str`` plus one scalar threshold.  This module turns
the collaboration-decision surface into a pluggable protocol,
``CollabPolicy``, with three batched scheduler-driven hooks:

  * ``assign(features) -> lane`` at ADMISSION (task assignment): route a
    request to ``"edge"`` (edge-only, accept whatever the SLM produces),
    ``"cloud"`` (cloud-only, skip the edge decode entirely), or
    ``"collab"`` (edge-first with a retirement-time decision).  ``features``
    carries prompt features, live load stats, and REAL deadline state from
    the scheduler's open-loop clock — ``at_ms`` / ``now_ms`` / ``wait_ms``
    (time already spent queueing) / ``slo_ms`` — so SLA-aware policies
    classify against actual latency pressure, not proxies (see
    ``BatchedEngine`` and ``deadline_classifier``).
  * ``decide(unc, steps, budget) -> actions`` per RETIREMENT WAVE (task- /
    token-granular escalation choice), VECTORIZED over the wave: per
    retiring request, ``"accept"`` the edge output, ``"cloud"``-regenerate
    (task assignment), ``"skeleton"``-divide (cloud plans a prefix, edge
    completes — task division), or ``"speculative"``-verify (token-level
    mixture).  Inputs are aligned arrays: normalized mean uncertainty,
    edge decode steps ACTUALLY spent (a stop-token hit retires a request
    early, so ``steps`` can be < ``budget``), and the generation budget.
  * ``feedback(action, quality, cost, features)`` after COMPLETION: the
    realized quality proxy and cloud-token cost of each finished request,
    plus the realized latencies (``ttft_ms`` / ``e2e_ms`` / ``slo_met``),
    closing the online-learning loop for bandit/budget policies.

Policies are host-side control plane (NumPy) exactly like the routers in
``core/routing.py`` they compose; the scheduler keeps every action GROUPED
and batched on device.  The legacy ``escalation=``/``escalate_threshold=``
kwargs survive one release as a deprecation shim (``resolve_policy``)
mapping onto the matching policy object.
"""
from __future__ import annotations

import warnings
from typing import Any, Callable, Dict, Optional, Sequence

import numpy as np

from repro.core.routing import CascadeRouter, LinUCBRouter, UCBRouter

#: admission-time lanes (task assignment)
LANES = ("edge", "cloud", "collab")
#: retirement-wave actions (escalation mechanisms, ``accept`` included)
ACTIONS = ("accept", "cloud", "skeleton", "speculative")
#: actions that involve the cloud (valid escalation targets)
ESCALATIONS = ("cloud", "skeleton", "speculative")


# ------------------------------------------------------------ trace metrics
def cloud_tokens(trace, gamma: int) -> int:
    """Cloud-side token cost of a finished request: autoregressive paths
    pay one token per pass; a speculative verify pass scores gamma drafts
    plus the bonus token."""
    if trace.path == "speculative":
        return int(trace.cloud_passes) * (gamma + 1)
    return int(trace.cloud_passes)


def trace_quality(trace, max_new: int) -> float:
    """Quality proxy in [0, 1] for a finished request: cloud-exact outputs
    (cloud regen, lossless speculative verify) score 1.0; edge-accepted
    output scores its confidence ``1 - u``; a skeleton split interpolates
    by the cloud's token share.  Cache replays carry no quality signal of
    their own (the entry may be edge- or cloud-origin) and score 1.0 by
    convention — the engine never feeds them back to a policy."""
    if trace.path in ("cloud", "speculative", "cache"):
        return 1.0
    u = min(max(float(trace.uncertainty), 0.0), 1.0)
    if trace.path == "skeleton":
        share = min(float(trace.cloud_passes) / max(max_new, 1), 1.0)
        return share + (1.0 - share) * (1.0 - u)
    return 1.0 - u


def _as1d(x) -> np.ndarray:
    return np.reshape(np.asarray(x, np.float64), (-1,))


# ---------------------------------------------------------------- protocol
class CollabPolicy:
    """Base collaboration policy: everything to the collaborative lane,
    decisions and learning left to subclasses (see the module docstring
    for the three hooks' contracts)."""

    name = "collab"

    def assign(self, features: Dict[str, Any]) -> str:
        """Admission-time lane for one request; default: collaborative.
        The scheduler calls this exactly ONCE per request, at its first
        admission attempt (a deferred request keeps its lane), so stateful
        policies may accrue per-request state here without deduping."""
        return "collab"

    def decide(self, unc, steps, budget) -> Sequence[str]:
        """Per-wave actions for the retiring requests (aligned arrays)."""
        raise NotImplementedError

    def feedback(self, action: str, quality: float, cost: float,
                 features: Optional[Dict[str, Any]] = None) -> None:
        """Completion feedback: realized quality proxy and cloud-token
        cost of one request that took ``action``."""

    def stats(self) -> Dict[str, Any]:
        return {}


class ThresholdPolicy(CollabPolicy):
    """The survey's confidence-gated task assignment: accept the edge
    output when mean uncertainty clears ``threshold``, else regenerate
    with the fixed escalation ``action`` (default: full cloud regen)."""

    name = "threshold"
    action = "cloud"

    def __init__(self, threshold: float = 0.6, action: Optional[str] = None):
        self.threshold = float(threshold)
        if action is not None:
            if action not in ESCALATIONS:
                raise ValueError(f"unknown escalation action {action!r}; "
                                 f"known: {' | '.join(ESCALATIONS)}")
            self.action = action

    def decide(self, unc, steps, budget):
        return ["accept" if u <= self.threshold else self.action
                for u in _as1d(unc)]


class SpeculativePolicy(ThresholdPolicy):
    """Threshold gate escalating into grouped speculative verification
    (token-level mixture, the legacy ``escalation="speculative"``).

    ``mode`` picks the decoder's speculation lane — the engine reads it at
    construction (see ``BatchedEngine`` / ``BatchedSpecDecoder``):

      * ``"linear"``: the classic gamma-token draft tape (default).
      * ``"tree"``: packed token-tree drafts, ``tree_width`` first-level
        branches, verified in one tree-masked cloud pass.
      * ``"self"``: self-speculative — the edge model's early-exit prefix
        (``exit_layer`` blocks, default half depth) drafts for its own
        full-depth verify; no second model involved.
    """

    name = "speculative"
    action = "speculative"

    def __init__(self, threshold: float = 0.6, *, mode: str = "linear",
                 tree_width: int = 2, exit_layer: Optional[int] = None):
        super().__init__(threshold)
        if mode not in ("linear", "tree", "self"):
            raise ValueError(f"unknown speculation mode {mode!r}; "
                             "known: linear | tree | self")
        self.spec_mode = mode
        self.spec_tree_width = int(tree_width)
        self.spec_exit_layer = exit_layer


class SkeletonPolicy(ThresholdPolicy):
    """Threshold gate escalating into skeleton task division (cloud plans
    the prefix, edge completes — the legacy ``escalation="skeleton"``)."""

    name = "skeleton"
    action = "skeleton"


class CascadePolicy(CollabPolicy):
    """FrugalGPT-style multi-tier cascade over collaboration mechanisms,
    cost-ordered through ``CascadeRouter``: try the cheapest tier first
    (accepting the already-paid edge output), escalate only while the
    tier's predicted residual uncertainty misses its acceptance threshold.
    Tier i's residual is modeled as ``unc * relief**i`` — each costlier
    mechanism folds in more cloud involvement and leaves less uncertainty
    (the last tier is unconditional).  Note tier i+1 is only REACHABLE for
    uncertainties above ``thresholds[i] / relief**i`` — keep each
    threshold below the previous tier's residual scale (the defaults keep
    all three tiers live on the estimators' [0, 1] range).
    """

    name = "cascade"

    def __init__(self, thresholds: Sequence[float] = (0.45, 0.25),
                 tiers: Sequence[str] = ("accept", "speculative", "cloud"),
                 costs: Sequence[float] = (0.0, 1.0, 4.0),
                 relief: float = 0.35):
        tiers = tuple(tiers)
        if not tiers or tiers[0] != "accept":
            raise ValueError("cascade tier 0 must be 'accept' (the edge "
                             "output is already paid for)")
        for t in tiers[1:]:
            if t not in ESCALATIONS:
                raise ValueError(f"unknown cascade tier {t!r}; known: "
                                 f"accept | {' | '.join(ESCALATIONS)}")
        if len(costs) != len(tiers):
            raise ValueError(f"{len(tiers)} tiers but {len(costs)} costs")
        if list(costs) != sorted(costs):
            raise ValueError(f"cascade tiers must be cost-ordered "
                             f"(ascending), got {list(costs)}")
        if len(thresholds) != len(tiers) - 1:
            raise ValueError(f"{len(tiers)} tiers need {len(tiers) - 1} "
                             f"thresholds (last tier is unconditional), "
                             f"got {len(thresholds)}")
        self.tiers = tiers
        self.relief = float(relief)
        self.router = CascadeRouter(costs=list(costs),
                                    thresholds=list(thresholds)
                                    + [float("inf")])
        self._tier_counts = [0] * len(tiers)
        self._cascade_cost = 0.0

    def decide(self, unc, steps, budget):
        acts = []
        for u in _as1d(unc):
            route = self.router.route(
                [lambda i=i, u=float(u): u * self.relief ** i
                 for i in range(len(self.tiers))])
            self._tier_counts[route.model_idx] += 1
            self._cascade_cost += route.cost
            acts.append(self.tiers[route.model_idx])
        return acts

    def stats(self):
        return {"policy_tier_counts": dict(zip(self.tiers,
                                               self._tier_counts)),
                "policy_cascade_cost": self._cascade_cost}


class BanditPolicy(CollabPolicy):
    """Online reward/cost-aware routing (PerLLM / MixLLM style): a bandit
    over escalation actions, learning from completion feedback — the first
    real wiring of ``core/routing.py``'s bandit routers into serving.

    ``kind="ucb"`` runs a context-free ``UCBRouter``; ``kind="linucb"``
    runs a contextual ``LinUCBRouter`` over per-request features
    ``[1, unc, steps, budget]`` (the capability signals available at
    decide time).  Reward is ``quality - cost_weight * cloud_token_share``
    per ``feedback``.  Arms selected in one wave are pulled before any of
    their rewards land, so cold-start spreads round-robin over arms with
    no pulls outstanding.
    """

    name = "bandit"

    def __init__(self, arms: Sequence[str] = ("accept", "speculative",
                                              "cloud"),
                 kind: str = "ucb", cost_weight: float = 0.3,
                 c: float = 0.5, alpha: float = 0.3):
        arms = tuple(arms)
        for a in arms:
            if a not in ACTIONS:
                raise ValueError(f"unknown bandit arm {a!r}; known: "
                                 f"{' | '.join(ACTIONS)}")
        if len(set(arms)) != len(arms) or not arms:
            raise ValueError(f"bandit arms must be distinct and non-empty, "
                             f"got {arms}")
        self.arms = arms
        self._arm_idx = {a: i for i, a in enumerate(arms)}
        self.kind = kind
        if kind == "ucb":
            self.router = UCBRouter(len(arms), cost_weight=cost_weight, c=c)
        elif kind == "linucb":
            self.router = LinUCBRouter(len(arms), dim=4, alpha=alpha,
                                       cost_weight=cost_weight)
        else:
            raise ValueError(f"unknown bandit kind {kind!r}; "
                             "known: ucb | linucb")
        self._pending = np.zeros(len(arms))   # selected, reward not landed
        self._landed = np.zeros(len(arms))    # rewards received per arm
        self._pulls = {a: 0 for a in arms}

    @staticmethod
    def _x(u, steps, budget) -> np.ndarray:
        return np.array([1.0, float(u), min(float(steps), 64.0) / 64.0,
                         min(float(budget), 64.0) / 64.0])

    def decide(self, unc, steps, budget):
        acts = []
        for u, s, m in zip(_as1d(unc), _as1d(steps), _as1d(budget)):
            # cold start (both kinds): round-robin by landed + OUTSTANDING
            # pulls until every arm has a landed reward — the routers' own
            # cold-start behavior cannot see mid-wave pending pulls (and
            # LinUCB's identical-score argmax would pile onto arm 0)
            if (self._landed == 0).any():
                i = int(np.argmin(self._landed + self._pending))
                if self.kind == "ucb":
                    self.router.t += 1      # keep the UCB clock honest
            elif self.kind == "ucb":
                i = self.router.select()
            else:
                i = self.router.select(self._x(u, s, m))
            self._pending[i] += 1
            self._pulls[self.arms[i]] += 1
            acts.append(self.arms[i])
        return acts

    def feedback(self, action, quality, cost, features=None):
        f = features or {}
        if f.get("lane", "collab") != "collab":
            return          # lane-assigned completion: no pull to reward
        i = self._arm_idx.get(action)
        if i is None:       # foreign action: not an arm
            return
        self._pending[i] = max(self._pending[i] - 1, 0.0)
        self._landed[i] += 1
        budget = max(float(f.get("budget", 1.0)), 1.0)
        share = float(cost) / budget
        if self.kind == "ucb":
            self.router.update(i, float(quality), share)
        else:
            self.router.update(i, self._x(f.get("unc", 0.0),
                                          f.get("steps", budget), budget),
                               float(quality), share)

    def stats(self):
        out: Dict[str, Any] = {"policy_pulls": dict(self._pulls)}
        if self.kind == "ucb":
            out["policy_arm_means"] = {a: float(self.router.mean[i])
                                       for a, i in self._arm_idx.items()}
        return out


class BudgetPolicy(CollabPolicy):
    """Per-request cloud-token budgeting with SLA classes: every admitted
    request accrues ``tokens_per_request`` (scaled by its SLA class's
    multiplier) into a shared cloud-token pool; an uncertain retirement
    escalates only while the pool can cover its generation budget, and
    DEGRADES to edge-accept once spent.  ``decide`` reserves the estimated
    spend so one wave cannot over-grant; ``feedback`` reconciles the
    reservation against the realized cloud-token cost (a speculative
    escalation can overdraw slightly — the pool carries the debt).
    Accrual relies on the scheduler's contract that ``assign`` runs once
    per request.

    ``classify`` maps the admission feature dict to an SLA class name; the
    scheduler feeds it REAL deadline state (``wait_ms`` / ``slo_ms`` from
    the open-loop clock), so ``deadline_classifier`` builds the common
    case: class by fraction of the TTFT SLO already burned queueing.
    """

    name = "budget"

    def __init__(self, threshold: float = 0.6,
                 tokens_per_request: float = 8.0, action: str = "cloud",
                 sla: Optional[Dict[str, float]] = None,
                 classify: Optional[Callable[[Dict[str, Any]], str]] = None):
        if action not in ESCALATIONS:
            raise ValueError(f"unknown escalation action {action!r}; "
                             f"known: {' | '.join(ESCALATIONS)}")
        self.threshold = float(threshold)
        self.action = action
        self.tokens_per_request = float(tokens_per_request)
        self.sla = dict(sla) if sla else {"standard": 1.0}
        self._classify = classify or (lambda feats: next(iter(self.sla)))
        self._pool = 0.0
        self._granted = 0
        self._degraded = 0
        self._class_counts: Dict[str, int] = {}

    def assign(self, features):
        cls = self._classify(features)
        self._class_counts[cls] = self._class_counts.get(cls, 0) + 1
        self._pool += self.tokens_per_request * float(self.sla.get(cls, 1.0))
        return "collab"

    def decide(self, unc, steps, budget):
        acts = []
        for u, m in zip(_as1d(unc), _as1d(budget)):
            if u <= self.threshold:
                acts.append("accept")
            elif self._pool >= m:
                self._pool -= m
                self._granted += 1
                acts.append(self.action)
            else:
                self._degraded += 1
                acts.append("accept")
        return acts

    def feedback(self, action, quality, cost, features=None):
        if action not in ESCALATIONS:
            return
        f = features or {}
        if "budget" not in f:
            return      # no estimate known: the reservation stands as spend
        self._pool += float(f["budget"]) - float(cost)  # est -> realized

    def stats(self):
        return {"policy_cloud_pool": self._pool,
                "policy_granted": self._granted,
                "policy_degraded": self._degraded,
                "policy_sla_classes": dict(self._class_counts)}


def deadline_classifier(boundaries: Dict[str, float]
                        ) -> Callable[[Dict[str, Any]], str]:
    """Build a ``BudgetPolicy`` SLA classifier keyed on REAL deadline
    pressure: ``boundaries`` maps class name -> max fraction of the TTFT
    SLO a request may already have burned queueing (``wait_ms / slo_ms``
    from the scheduler's open-loop clock) and the first boundary that
    covers the request wins, e.g. ``{"relaxed": 0.25, "standard": 0.5,
    "urgent": float("inf")}``.  With no SLO configured (or in closed-loop
    runs where ``wait_ms`` is 0) every request lands in the first class —
    the deadline feed degrades gracefully to the legacy behavior."""
    if not boundaries:
        raise ValueError("boundaries must name at least one SLA class")
    ordered = sorted(boundaries.items(), key=lambda kv: kv[1])

    def classify(feats: Dict[str, Any]) -> str:
        slo, wait = feats.get("slo_ms"), feats.get("wait_ms")
        if not slo or wait is None:
            return ordered[0][0]
        frac = float(wait) / float(slo)
        for name, bound in ordered:
            if frac <= bound:
                return name
        return ordered[-1][0]

    return classify


# ---------------------------------------------------------------- factories
POLICIES = {
    "threshold": ThresholdPolicy,
    "speculative": SpeculativePolicy,
    "skeleton": SkeletonPolicy,
    "cascade": CascadePolicy,
    "bandit": BanditPolicy,
    "budget": BudgetPolicy,
}

_LEGACY = {"cloud": ThresholdPolicy, "speculative": SpeculativePolicy,
           "skeleton": SkeletonPolicy}


def make_policy(name: str, **kwargs) -> CollabPolicy:
    """Build a shipped policy by name (the ``--policy`` CLI surface)."""
    if name not in POLICIES:
        raise KeyError(f"unknown policy {name!r}; known: "
                       f"{sorted(POLICIES)}")
    return POLICIES[name](**kwargs)


def policy_from_legacy(escalation: str, threshold: float) -> CollabPolicy:
    """Map the legacy ``escalation=`` mode string + threshold onto the
    equivalent policy object (byte-identical serving decisions)."""
    if escalation not in _LEGACY:
        raise ValueError(f"unknown escalation mode {escalation!r}; "
                         "known: speculative | cloud | skeleton")
    return _LEGACY[escalation](threshold=threshold)


def resolve_policy(policy, escalation: Optional[str] = None,
                   escalate_threshold: Optional[float] = None, *,
                   stacklevel: int = 3) -> CollabPolicy:
    """Engine-constructor shim: return ``policy`` (a ``CollabPolicy`` or a
    ``make_policy`` name), or map the DEPRECATED ``escalation=`` /
    ``escalate_threshold=`` kwargs onto the matching policy with a
    ``DeprecationWarning``.  No kwargs at all keeps the historical default
    (speculative verification at threshold 0.6)."""
    if policy is not None:
        if escalation is not None or escalate_threshold is not None:
            raise ValueError(
                "pass either policy= or the legacy escalation=/"
                "escalate_threshold= kwargs, not both")
        if isinstance(policy, str):
            return make_policy(policy)
        return policy
    if escalation is None and escalate_threshold is None:
        return SpeculativePolicy()
    warnings.warn(
        "escalation=/escalate_threshold= are deprecated and will be "
        "removed next release; pass policy= instead (e.g. "
        "policy=SpeculativePolicy(threshold=...)) — the legacy kwargs map "
        "onto the matching CollabPolicy",
        DeprecationWarning, stacklevel=stacklevel)
    return policy_from_legacy(
        "speculative" if escalation is None else escalation,
        0.6 if escalate_threshold is None else escalate_threshold)
