"""Family-agnostic per-sequence decode state for the serving scheduler.

The scheduler used to special-case every cache family: dense KV slabs,
paged KV block tables, and a per-request snapshot+replay fallback for
recurrent state.  This module hides all of that behind ONE adapter
protocol, so ``core/scheduler.py`` and ``core/speculative.py`` drive every
model family — transformer, moe, ssm (mamba2), hybrid, xlstm — through the
same slot/tick/escalation machinery:

  * ``SequenceState`` — the host-side slot-state owner: ``admit`` (prefill
    + capacity reservation), ``flush`` (batched device writes),
    ``prepare_tick`` (per-tick capacity growth), ``retire`` (free), and the
    ``peak_bytes`` / ``capacity_bytes`` / ``stats`` accounting the
    benchmarks read.  One implementation per layout:

      - ``DenseKV``    — stacked per-slot caches padded to a common
        ``slot_len`` (the parity oracle).
      - ``PagedKV``    — one shared block pool + per-slot block tables
        (``core/paged_cache.py``).
      - ``RecurrentState`` — fixed-size recurrent state (ssm/xlstm/hybrid):
        dense stacked storage (there is no sequence axis to page), its own
        class so layout policy stays out of the scheduler.

  * ``SpecOps`` — the traceable (jit-safe) per-model ops speculative
    decoding composes: ``step`` / ``extend`` for drafting and verification,
    and ``snapshot`` / ``commit`` for the per-round rewind.  KV layouts
    snapshot ``pos`` and commit with a ``pos`` write; the recurrent layout
    snapshots the state pytree (a reference, not a copy — snapshot-free on
    the host) and commits by replaying each slot's accepted prefix through
    the model's batched ``replay_step`` (padded draft tape + per-slot
    ``jnp.where`` state select), replacing the old host-side per-request
    snapshot+replay fallback.

  * ``Lane`` — the per-model jitted machinery (batched decode step,
    per-prompt-length prefill, multi-token decode scan) plus the
    ``make_state`` factory.  ALL layout/family dispatch lives here, in
    ``layout_for`` / ``resolve_kv_layout`` / ``make_spec_ops``.
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.paged_cache import (BlockPool, blocks_for,
                                    prompt_cache_to_blocks, write_pool_blocks)
from repro.core.uncertainty import get_batched_estimator


# ---------------------------------------------------------------- slot utils
def stack_slot_caches(model, batch: int, slot_len: int):
    """Zero-initialized stacked per-slot caches: each leaf of the model's
    single-sequence cache gains a leading slot axis."""
    one = model.init_cache(1, slot_len)
    return jax.tree.map(lambda x: jnp.zeros((batch,) + x.shape, x.dtype), one)


def write_slots(slots, bs: List[int], caches: List):
    """Overwrite slots ``bs`` with freshly prefilled single-sequence caches
    in ONE scatter per leaf (k separate ``.at[b].set`` writes would copy the
    whole stacked cache k times).  Also wipes any garbage a retired occupant
    decoded past its budget."""
    idx = jnp.asarray(bs, jnp.int32)
    return jax.tree.map(
        lambda big, *smalls: big.at[idx].set(jnp.stack(smalls)),
        slots, *caches)


def write_slot(slots, b: int, cache):
    """Single-slot convenience wrapper over ``write_slots``."""
    return write_slots(slots, [b], [cache])


def pow2_steps(n: int, cap: int) -> int:
    """Round a residual step count up to a power of two (capped): the decode
    scan is jit-compiled per static ``n_steps``, so bucketing keeps the
    compile set at O(log cap) while the active mask absorbs the overshoot."""
    p = 1
    while p < n:
        p *= 2
    return min(p, cap)


# ---------------------------------------------------------------- layouts
def layout_for(model, kv_layout: str) -> str:
    """Effective per-model layout under the engine-level ``kv_layout``:
    "paged" where the engine runs paged and the family supports it,
    "recurrent" for state-cache families, else "dense"."""
    if kv_layout == "paged" and model.paged_kv:
        return "paged"
    if not model.rewindable_cache:
        return "recurrent"
    return "dense"


def resolve_kv_layout(edge_model, cloud_model, kv_layout: str) -> str:
    """Resolve the engine-level KV layout ("auto" -> paged where BOTH
    models' families page); validates explicit requests."""
    if kv_layout not in ("auto", "paged", "dense"):
        raise ValueError(f"unknown kv_layout {kv_layout!r}; "
                         "known: auto | paged | dense")
    paged_ok = edge_model.paged_kv and cloud_model.paged_kv
    if kv_layout == "paged" and not paged_ok:
        raise ValueError(
            "kv_layout='paged' needs KV-cache transformer families on "
            f"both models, got {edge_model.cfg.family!r} / "
            f"{cloud_model.cfg.family!r}")
    if kv_layout == "auto":
        return "paged" if paged_ok else "dense"
    return kv_layout


# ---------------------------------------------------------------- spec ops
class SpecOps:
    """Traceable per-(model, layout) ops for batched speculative decoding.

    ``step``/``extend`` run one decode step / a multi-token extend over the
    whole group; ``snapshot``/``commit`` implement the per-round rewind.
    Every method is safe to call inside ``jax.jit``.
    """

    def __init__(self, model, layout: str):
        self.model = model
        self.layout = layout
        if layout == "paged":
            self._step = lambda p, t, c: model.paged_decode_step(p, t[:, :, 0], c)
            self._extend = model.paged_extend_step
        else:
            vstep = jax.vmap(lambda p, t, c: model.decode_step(p, t, c),
                             in_axes=(None, 0, 0))
            vext = jax.vmap(lambda p, t, c: model.extend_step(p, t, c),
                            in_axes=(None, 0, 0))
            self._step = lambda p, t, c: _squeeze1(vstep(p, t, c))
            self._extend = lambda p, t, c: _squeeze1(vext(p, t[:, None, :], c))
        if layout == "recurrent":
            self._vreplay = jax.vmap(
                lambda p, t, c, n: model.replay_step(p, t[None, :], c, n),
                in_axes=(None, 0, 0, 0))

    def step(self, params, tok, caches):
        """tok (G, 1, 1) -> (logits (G, V), caches)."""
        return self._step(params, tok, caches)

    def extend(self, params, tokens, caches):
        """tokens (G, T) -> (logits (G, T, V), caches)."""
        return self._extend(params, tokens, caches)

    def snapshot(self, caches):
        """Pre-round rewind anchor: ``pos`` (G,) for KV layouts, the cache
        pytree itself (a device reference, no copy) for recurrent state."""
        if self.layout == "recurrent":
            return caches
        return caches["pos"]

    def commit(self, params, caches, snap, tokens, counts):
        """Rewind the post-round ``caches`` to each slot's accepted prefix:
        ``tokens`` (G, T) is the round's draft tape [pending, d_0..], and
        ``counts`` (G,) int32 (0 for frozen slots) how many of its entries
        each slot commits.  KV: one ``pos`` write (rejected entries stay,
        masked and overwritten).  Recurrent: vmapped ``replay_step`` from
        the snapshot — each slot re-advances through its own prefix in one
        fused scan."""
        if self.layout == "recurrent":
            return self._vreplay(params, tokens, snap, counts)
        return {**caches, "pos": snap + counts}


def _squeeze1(out):
    logits, caches = out
    return logits[:, 0], caches


# ---------------------------------------------------------------- states
class SequenceState:
    """Adapter protocol for the scheduler's per-slot decode state (see the
    module docstring).  ``caches`` is the device pytree the lane's jitted
    step/scan functions consume; everything else is host bookkeeping."""

    layout = "dense"
    caches: Any

    def admit(self, b: int, prompt, need_tokens: int) -> bool:
        """Stage slot ``b``'s prompt prefill; reserve worst-case capacity
        (``need_tokens`` cache entries).  False = defer (capacity full)."""
        raise NotImplementedError

    def flush(self):
        """Land all staged admissions/retirements in batched device writes."""

    def prepare_tick(self, occupied, steps_h, n: int):
        """Grow capacity to cover this tick's real decode steps."""

    def retire(self, b: int):
        """Release slot ``b``'s capacity."""

    @property
    def capacity_bytes(self) -> int:
        return sum(x.nbytes for x in jax.tree.leaves(self.caches))

    @property
    def peak_bytes(self) -> int:
        return self.capacity_bytes

    def stats(self) -> dict:
        return {}


class DenseKV(SequenceState):
    """Dense stacked slot caches: every slot padded to a common
    ``slot_len`` (the original layout, kept as the parity oracle)."""

    layout = "dense"

    def __init__(self, lane: "Lane", params, batch: int, slot_len: int):
        self.lane = lane
        self.params = params
        self.slot_len = slot_len
        self.caches = stack_slot_caches(lane.model, batch, slot_len)
        self._pend_bs: List[int] = []
        self._pend_caches: List[Any] = []

    def admit(self, b: int, prompt, need_tokens: int) -> bool:
        _, c1 = self.lane.prefill(self.params, prompt, self.slot_len)
        self._pend_bs.append(b)
        self._pend_caches.append(c1)
        return True

    def flush(self):
        if self._pend_bs:   # one scatter for the whole admission wave
            self.caches = write_slots(self.caches, self._pend_bs,
                                      self._pend_caches)
            self._pend_bs, self._pend_caches = [], []


class RecurrentState(DenseKV):
    """Fixed-size recurrent state (ssm / xlstm / hybrid): stacked like the
    dense layout — recurrent state has no sequence axis to page, so slots
    are O(1)-sized regardless of ``slot_len`` (hybrid's shared-attention
    K/V slabs are the exception and do pad to ``slot_len``).  Differs from
    ``DenseKV`` only in rewind semantics, which live in ``SpecOps``."""

    layout = "recurrent"


class PagedKV(SequenceState):
    """Paged slot caches: one shared block pool + per-slot block tables.

    Host side this owns a ``BlockPool`` (block ids only) and mirrors each
    slot's real content length; device side it owns the cache pytree
    ``{k, v, table, pos}``.  Writes are batched: admissions/retirements
    accumulate and land in ``flush`` (block scatters + ONE table-row/pos
    scatter), per-tick growth lands in ``prepare_tick`` (one table-entry
    scatter).  Retired slots' rows are redirected to the trap block so
    their masked garbage decode cannot corrupt re-allocated blocks.
    """

    layout = "paged"

    def __init__(self, lane: "Lane", params, batch: int, slot_len: int,
                 block_size: int, num_blocks: Optional[int] = None):
        self.lane = lane
        self.params = params
        self.block_size = block_size
        self.max_blocks = blocks_for(slot_len, block_size)
        if num_blocks is None:      # worst-case-safe default: dense capacity
            num_blocks = batch * self.max_blocks + 1
        num_blocks = max(num_blocks, 2)
        self.pool = BlockPool(num_blocks, block_size)
        self.caches = lane.model.init_paged_cache(
            num_blocks, block_size, batch, self.max_blocks)
        self._block_bytes = (self.caches["k"].nbytes +
                             self.caches["v"].nbytes) // num_blocks
        self._len = [0] * batch     # real cache entries written per slot
        self._commit = [0] * batch  # blocks reserved for future growth
        self._stale: set = set()    # retired slots awaiting a trap row
        self._pend: List[Tuple[int, np.ndarray, int]] = []  # (b, row, pos)

    def admit(self, b: int, prompt, need_tokens: int) -> bool:
        """Allocate the prompt's blocks and stage the prefill; returns
        False (admission deferred) when the pool cannot back the request.

        Admission is reservation-based: the request's WORST-CASE block need
        (``need_tokens`` = prompt + budget [+ overdraft]) is committed up
        front so on-demand growth can never fail mid-flight, but blocks are
        only physically allocated as decode reaches them — the reservation
        is per-request, not the batch maximum, which is where the paged
        layout beats the dense slabs."""
        S = int(np.asarray(prompt).size)
        nb = self.pool.blocks_for(S - 1)
        total = self.pool.blocks_for(need_tokens)
        if not self.pool.can_alloc(total + sum(self._commit)):
            return False
        blocks = self.pool.alloc(b, nb)
        self._commit[b] = total - nb
        _, c1 = self.lane.prefill(self.params, prompt, nb * self.block_size)
        kb, vb = prompt_cache_to_blocks(c1, self.block_size)
        self.caches["k"], self.caches["v"] = write_pool_blocks(
            self.caches["k"], self.caches["v"],
            jnp.asarray(blocks, jnp.int32), kb, vb)
        row = np.zeros((self.max_blocks,), np.int32)    # pad = trap block
        row[:nb] = blocks
        self._pend.append((b, row, S - 1))
        self._len[b] = S - 1
        self._stale.discard(b)
        return True

    def flush(self):
        if not (self._pend or self._stale):
            return
        idx, rows, poss = [], [], []
        for b, row, p in self._pend:
            idx.append(b)
            rows.append(row)
            poss.append(p)
        for b in self._stale:       # retired, not re-admitted: trap row
            idx.append(b)
            rows.append(np.zeros((self.max_blocks,), np.int32))
            poss.append(0)
        ii = jnp.asarray(idx, jnp.int32)
        self.caches["table"] = self.caches["table"].at[ii].set(
            jnp.asarray(np.stack(rows)))
        self.caches["pos"] = self.caches["pos"].at[ii].set(
            jnp.asarray(poss, jnp.int32))
        self._pend, self._stale = [], set()

    def prepare_tick(self, occupied, steps_h, n: int):
        """Grow every occupied slot to cover this tick's REAL decode steps
        (``min(steps_left, n)``); the masked garbage tail past a slot's
        budget clamps into the trap.  Growth draws down the slot's
        admission-time reservation, so it cannot fail."""
        upd_b, upd_i, upd_blk = [], [], []
        for b in occupied:
            target = self._len[b] + min(int(steps_h[b]), n)
            new = self.pool.grow_to(b, target)
            self._commit[b] = max(self._commit[b] - len(new), 0)
            base = len(self.pool.owned(b)) - len(new)
            for j, blk in enumerate(new):
                upd_b.append(b)
                upd_i.append(base + j)
                upd_blk.append(blk)
            self._len[b] = target
        if upd_b:
            self.caches["table"] = self.caches["table"].at[
                jnp.asarray(upd_b, jnp.int32),
                jnp.asarray(upd_i, jnp.int32)].set(
                jnp.asarray(upd_blk, jnp.int32))

    def retire(self, b: int):
        self.pool.free(b)
        self._len[b] = 0
        self._commit[b] = 0
        self._stale.add(b)

    @property
    def peak_bytes(self) -> int:
        """High-water mark of LIVE block bytes — what a right-sized pool
        would have to hold (the benchmark's headline number)."""
        return self.pool.peak_used * self._block_bytes

    @property
    def capacity_bytes(self) -> int:
        return self.caches["k"].nbytes + self.caches["v"].nbytes

    def stats(self) -> dict:
        return {"kv_blocks_peak": self.pool.peak_used,
                "kv_block_size": self.block_size}


# ---------------------------------------------------------------- lane
class Lane:
    """Jitted batched machinery for ONE model in ONE layout: the batched
    decode step (``SpecOps.step``), a per-prompt-length prefill, the
    multi-token decode scan shared by all layouts, and the ``make_state``
    factory the scheduler calls instead of picking adapters itself."""

    def __init__(self, model, estimator: str, temperature: float,
                 layout: str = "dense", block_size: int = 32):
        self.model = model
        self.layout = layout
        self.block_size = block_size
        self.ops = SpecOps(model, layout)
        est = get_batched_estimator(estimator)
        step = self.ops.step
        self._jit_prefill = jax.jit(
            lambda p, toks, max_seq: model.prefill(
                p, {"tokens": toks}, max_seq=max_seq),
            static_argnames=("max_seq",))

        def chunk(params, caches, tok, steps_left, unc_sum, rng,
                  n_steps: int):
            """n_steps decode steps over all slots in one scan.  Returns the
            advanced state plus per-step (token, active) for the host."""
            def body(carry, r):
                caches, tok, steps_left, unc_sum = carry
                lg, caches = step(params, tok, caches)       # (B, V)
                active = steps_left > 0
                if temperature == 0.0:
                    nxt = jnp.argmax(lg, -1).astype(jnp.int32)
                else:
                    nxt = jax.random.categorical(
                        r, lg / temperature, axis=-1).astype(jnp.int32)
                unc_sum = unc_sum + jnp.where(active, est(lg), 0.0)
                steps_left = steps_left - active.astype(jnp.int32)
                return (caches, nxt[:, None, None], steps_left, unc_sum), \
                    (nxt, active)

            (caches, tok, steps_left, unc_sum), (toks, actives) = \
                jax.lax.scan(body, (caches, tok, steps_left, unc_sum),
                             jax.random.split(rng, n_steps))
            return caches, tok, steps_left, unc_sum, toks, actives

        self._chunk = jax.jit(chunk, static_argnames=("n_steps",))

    def prefill(self, params, prompt, max_seq: int):
        """Prefill ``prompt[:-1]`` into a fresh cache padded to ``max_seq``.
        Recompiles per distinct prompt length; the jit cache makes repeats
        free."""
        toks = jnp.asarray(np.asarray(prompt, np.int32)[None, :-1])
        return self._jit_prefill(params, toks, max_seq=max_seq)

    def make_state(self, params, batch: int, slot_len: int, *,
                   need_tokens: Optional[Sequence[int]] = None,
                   num_blocks: Optional[int] = None) -> SequenceState:
        """Build this lane's decode-state adapter.  ``need_tokens``
        (escalation groups) sizes a paged pool to exactly the group's
        residency instead of the worst case."""
        if self.layout == "recurrent":
            return RecurrentState(self, params, batch, slot_len)
        if self.layout == "dense":
            return DenseKV(self, params, batch, slot_len)
        if num_blocks is None and need_tokens is not None:
            needed = sum(blocks_for(t, self.block_size) for t in need_tokens)
            # pow2-bucket the pool so escalation groups with different
            # residencies reuse one compiled scan/spec-round shape (the
            # peak-bytes stat tracks LIVE blocks, not this capacity)
            num_blocks = 1 + pow2_steps(needed, 1 << 30)
        return PagedKV(self, params, batch, slot_len, self.block_size,
                       num_blocks)
