"""Family-agnostic per-sequence decode state for the serving scheduler.

The scheduler used to special-case every cache family: dense KV slabs,
paged KV block tables, and a per-request snapshot+replay fallback for
recurrent state.  This module hides all of that behind ONE adapter
protocol, so ``core/scheduler.py`` and ``core/speculative.py`` drive every
model family — transformer, moe, ssm (mamba2), hybrid, xlstm — through the
same slot/tick/escalation machinery:

  * ``SequenceState`` — the host-side slot-state owner: ``admit`` (prefill
    + capacity reservation), ``flush`` (batched device writes),
    ``prepare_tick`` (per-tick capacity growth), ``retire`` (free), and the
    ``peak_bytes`` / ``capacity_bytes`` / ``stats`` accounting the
    benchmarks read.  One implementation per layout:

      - ``DenseKV``    — stacked per-slot caches padded to a common
        ``slot_len`` (the parity oracle).
      - ``PagedKV``    — one shared block pool + per-slot block tables
        (``core/paged_cache.py``), with refcounted block-level prefix
        sharing + copy-on-write (``share_prefix`` / ``cow_split``) and
        host-buffer swap (``swap_out`` / ``swap_in``) backing the
        scheduler's preemption path.
      - ``RecurrentState`` — fixed-size recurrent state (ssm/xlstm/hybrid):
        dense stacked storage (there is no sequence axis to page), its own
        class so layout policy stays out of the scheduler.

  * ``SpecOps`` — the traceable (jit-safe) per-model ops speculative
    decoding composes: ``step`` / ``extend`` for drafting and verification,
    and ``snapshot`` / ``commit`` for the per-round rewind.  KV layouts
    snapshot ``pos`` and commit with a ``pos`` write; the recurrent layout
    snapshots the state pytree (a reference, not a copy — snapshot-free on
    the host) and commits by replaying each slot's accepted prefix through
    the model's batched ``replay_step`` (padded draft tape + per-slot
    ``jnp.where`` state select), replacing the old host-side per-request
    snapshot+replay fallback.

  * ``Lane`` — the per-model jitted machinery (batched decode step,
    per-prompt-length prefill, multi-token decode scan) plus the
    ``make_state`` factory.  ALL layout/family dispatch lives here, in
    ``layout_for`` / ``resolve_kv_layout`` / ``dense_side``.

Contracts pinned by ``repro-lint`` (``scripts/repro_lint.py``): every
``SequenceState``/``SpecOps`` implementor must define the required
surface with matching arity (rule R4); the per-tick methods marked
``@hot_path`` (``PagedKV.flush`` / ``prepare_tick``, the ``Lane`` decode
scan) must stay free of host syncs (rule R1); and the jitted scan must
keep its step count static so steady-state decode never retraces (rule
R2, asserted at runtime by the ``compile_stability`` bench arm).
"""
from __future__ import annotations

import hashlib
from collections import Counter
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import hot_path
from repro.core.paged_cache import (BlockPool, ShardedBlockPool, blocks_for,
                                    copy_pool_blocks, prompt_cache_to_blocks,
                                    read_pool_blocks, write_pool_blocks)
from repro.core.uncertainty import get_batched_estimator
from repro.launch.sharding import (cache_shardings, kv_shard_ways,
                                   paged_cache_shardings)


# ---------------------------------------------------------------- slot utils
def stack_slot_caches(model, batch: int, slot_len: int):
    """Zero-initialized stacked per-slot caches: each leaf of the model's
    single-sequence cache gains a leading slot axis."""
    one = model.init_cache(1, slot_len)
    return jax.tree.map(lambda x: jnp.zeros((batch,) + x.shape, x.dtype), one)


def write_slots(slots, bs: List[int], caches: List):
    """Overwrite slots ``bs`` with freshly prefilled single-sequence caches
    in ONE scatter per leaf (k separate ``.at[b].set`` writes would copy the
    whole stacked cache k times).  Also wipes any garbage a retired occupant
    decoded past its budget."""
    idx = jnp.asarray(bs, jnp.int32)
    return jax.tree.map(
        lambda big, *smalls: big.at[idx].set(jnp.stack(smalls)),
        slots, *caches)


def write_slot(slots, b: int, cache):
    """Single-slot convenience wrapper over ``write_slots``."""
    return write_slots(slots, [b], [cache])


def pow2_steps(n: int, cap: int) -> int:
    """Round a residual step count up to a power of two (capped): the decode
    scan is jit-compiled per static ``n_steps``, so bucketing keeps the
    compile set at O(log cap) while the active mask absorbs the overshoot."""
    p = 1
    while p < n:
        p *= 2
    return min(p, cap)


# ---------------------------------------------------------------- layouts
def layout_for(model, kv_layout: str) -> str:
    """Effective per-model layout under the engine-level ``kv_layout``:
    "paged" where the engine runs paged and the family supports it,
    "recurrent" for state-cache families, else "dense"."""
    if kv_layout == "paged" and model.paged_kv:
        return "paged"
    if not model.rewindable_cache:
        return "recurrent"
    return "dense"


def resolve_kv_layout(edge_model, cloud_model, kv_layout: str) -> str:
    """Resolve the engine-level KV layout ("auto" -> paged where BOTH
    models' families page); validates explicit requests."""
    if kv_layout not in ("auto", "paged", "dense"):
        raise ValueError(f"unknown kv_layout {kv_layout!r}; "
                         "known: auto | paged | dense")
    paged_ok = edge_model.paged_kv and cloud_model.paged_kv
    if kv_layout == "paged" and not paged_ok:
        raise ValueError(
            "kv_layout='paged' needs KV-cache transformer families on "
            f"both models, got {edge_model.cfg.family!r} / "
            f"{cloud_model.cfg.family!r}")
    if kv_layout == "auto":
        return "paged" if paged_ok else "dense"
    return kv_layout


# ---------------------------------------------------------------- spec ops
class SpecOps:
    """Traceable per-(model, layout) ops for batched speculative decoding.

    ``step``/``extend`` run one decode step / a multi-token extend over the
    whole group; ``snapshot``/``commit`` implement the per-round rewind.
    Every method is safe to call inside ``jax.jit``.
    """

    def __init__(self, model, layout: str):
        self.model = model
        self.layout = layout
        if layout == "paged":
            self._step = lambda p, t, c: model.paged_decode_step(p, t[:, :, 0], c)
            self._extend = model.paged_extend_step
        else:
            vstep = jax.vmap(lambda p, t, c: model.decode_step(p, t, c),
                             in_axes=(None, 0, 0))
            vext = jax.vmap(lambda p, t, c: model.extend_step(p, t, c),
                            in_axes=(None, 0, 0))
            self._step = lambda p, t, c: _squeeze1(vstep(p, t, c))
            self._extend = lambda p, t, c: _squeeze1(vext(p, t[:, None, :], c))
        if layout == "recurrent":
            self._vreplay = jax.vmap(
                lambda p, t, c, n: model.replay_step(p, t[None, :], c, n),
                in_axes=(None, 0, 0, 0))
        # token trees need a customizable intra-block mask: dense-layout
        # attention families only (paged extends and recurrent scans are
        # linear-order — see DESIGN.md §Arch-applicability)
        self.tree_ok = (layout == "dense"
                        and model.cfg.family in ("dense", "moe", "vlm"))
        if self.tree_ok:
            self._vext_tree = jax.vmap(
                lambda p, t, c, m, d: model.extend_step(
                    p, t, c, block_mask=m, q_positions=c["pos"] + d),
                in_axes=(None, 0, 0, None, None))

    def step(self, params, tok, caches):
        """tok (G, 1, 1) -> (logits (G, V), caches)."""
        return self._step(params, tok, caches)

    def extend(self, params, tokens, caches):
        """tokens (G, T) -> (logits (G, T, V), caches)."""
        return self._extend(params, tokens, caches)

    def extend_tree(self, params, tokens, caches, block_mask, depths):
        """Tree-masked extend: each slot's ``tokens`` (G, T) row is a packed
        token tree whose node ``i`` attends the cache prefix plus
        ``block_mask[i]`` of the block itself, with RoPE positions
        ``pos + depths``.  Dense attention layouts only (``tree_ok``)."""
        if not self.tree_ok:
            raise ValueError(
                f"token trees need a dense-layout attention model; got "
                f"family {self.model.cfg.family!r} on layout {self.layout!r}")
        logits, caches = self._vext_tree(params, tokens[:, None, :], caches,
                                         block_mask, depths)
        return logits[:, 0], caches

    def reset(self, caches, snap):
        """Roll the group back to the pre-round snapshot WITHOUT committing
        anything (tree rounds re-anchor between draft levels and before the
        replay commit)."""
        if self.layout == "recurrent":
            return snap
        return {**caches, "pos": snap}

    def commit_replay(self, params, caches, snap, tokens, counts):
        """Replay-based commit for tree rounds: the accepted root path's
        K/V live at non-contiguous tree positions, so a bare ``pos`` write
        (``commit``) would keep sibling garbage inside the visible prefix.
        Rewind to the snapshot, re-extend through the padded accepted tape
        ``tokens`` (G, T), then mask to each slot's ``counts`` — one extra
        target pass per round, exactly the seed ``TreeSpecDecoder`` rewind.
        Recurrent layouts already commit by replay."""
        if self.layout == "recurrent":
            return self._vreplay(params, tokens, snap, counts)
        caches = {**caches, "pos": snap}
        _, caches = self.extend(params, tokens, caches)
        return {**caches, "pos": snap + counts}

    def commit_permute(self, caches, snap, perm, counts):
        """Gather-based tree commit for KV layouts: the verify extend
        wrote every tree node's K/V at cache row ``snap + node`` with RoPE
        position ``snap + depth(node)``, and the accepted root path has
        exactly one node per depth — so its rows are already
        position-correct and merely sit at the wrong cache index.  Gather
        them down to the contiguous prefix [snap, snap + T) and advance
        ``pos``: no replay forward pass.  ``perm`` (G, T) holds the path's
        node indices per slot (entries past ``counts`` land beyond ``pos``
        and are dead).  Tree-capable families share the transformer cache
        layout (``k``/``v`` with the sequence on axis -3); recurrent tree
        groups cannot exist (``tree_ok``)."""
        def one(cache, s, pm):
            def move(x):
                rows = jnp.take(x, s + pm, axis=-3, mode="clip")
                return jax.lax.dynamic_update_slice_in_dim(x, rows, s,
                                                           axis=-3)
            return {**cache, "k": move(cache["k"]), "v": move(cache["v"])}

        caches = jax.vmap(one)(caches, snap, perm)
        return {**caches, "pos": snap + counts}

    def snapshot(self, caches):
        """Pre-round rewind anchor: ``pos`` (G,) for KV layouts, the cache
        pytree itself (a device reference, no copy) for recurrent state."""
        if self.layout == "recurrent":
            return caches
        return caches["pos"]

    def commit(self, params, caches, snap, tokens, counts):
        """Rewind the post-round ``caches`` to each slot's accepted prefix:
        ``tokens`` (G, T) is the round's draft tape [pending, d_0..], and
        ``counts`` (G,) int32 (0 for frozen slots) how many of its entries
        each slot commits.  KV: one ``pos`` write (rejected entries stay,
        masked and overwritten).  Recurrent: vmapped ``replay_step`` from
        the snapshot — each slot re-advances through its own prefix in one
        fused scan."""
        if self.layout == "recurrent":
            return self._vreplay(params, tokens, snap, counts)
        return {**caches, "pos": snap + counts}


def _squeeze1(out):
    logits, caches = out
    return logits[:, 0], caches


# ---------------------------------------------------------------- states
class SequenceState:
    """Adapter protocol for the scheduler's per-slot decode state (see the
    module docstring).  ``caches`` is the device pytree the lane's jitted
    step/scan functions consume; everything else is host bookkeeping."""

    layout = "dense"
    caches: Any

    def admit(self, b: int, prompt, need_tokens: int) -> bool:
        """Stage slot ``b``'s prompt prefill; reserve worst-case capacity
        (``need_tokens`` cache entries).  False = defer (capacity full)."""
        raise NotImplementedError

    def begin(self, b: int, prompt, need_tokens: int) -> bool:
        """Reserve capacity for a CHUNKED prefill of slot ``b`` without
        staging any writes — the prompt's cache is built detached (one
        ``Lane.advance_prefill`` chunk per tick) and lands via
        ``finalize``.  Same return contract as ``admit``; layouts without
        reservations accept unconditionally.  Until ``finalize``, the
        slot's device row must stay inert (zero budget masks its decode;
        paged layouts keep the trap row), and the slot must not be picked
        as a preemption victim."""
        return True

    def finalize(self, b: int, cache):
        """Land a finished detached prefill cache into slot ``b`` (staged;
        ``flush`` batches the device writes as for ``admit``)."""
        raise NotImplementedError

    def detached_len(self, entry_count: int) -> int:
        """Padded length of a detached chunked-prefill cache for a prompt
        with ``entry_count`` entries (layout-dependent: dense slots pad to
        the common slot length, paged to the prompt's own blocks)."""
        raise NotImplementedError

    def share_hints(self, prompts: List[Any]) -> List[bool]:
        """For each prompt in an admission wave: True when admitting it
        MONOLITHICALLY (``admit``) would likely share cache with live or
        same-wave state, so the scheduler should skip chunked prefill for
        it.  A chunked ``begin`` keeps the prompt out of the prefix index
        until ``finalize`` (its blocks hold garbage until then), which
        would silently forfeit sharing between same-wave twins.  Layouts
        without cross-request sharing never prefer the monolithic path."""
        return [False] * len(prompts)

    def flush(self):
        """Land all staged admissions/retirements in batched device writes."""

    def prepare_tick(self, occupied, steps_h, n: int):
        """Grow capacity to cover this tick's real decode steps."""

    def retire(self, b: int):
        """Release slot ``b``'s capacity."""

    def fits_empty(self, need_tokens: int, prompt=None) -> bool:
        """True if a request reserving ``need_tokens`` cache entries could
        EVER be admitted (fits an otherwise-empty pool, or its live
        shareable prefix covers the overshoot).  Dense layouts always fit;
        the scheduler uses False to fail fast instead of preempting the
        whole batch for a hopeless request."""
        return True

    def swappable(self, b: int) -> bool:
        """True if slot ``b`` may be chosen as a preemption victim (its
        ``swap_in`` restore is guaranteed to fit the pool eventually)."""
        return False

    def owned_blocks(self, b: int) -> int:
        """KV blocks slot ``b`` currently owns (0 on layouts without a
        block pool) — the preemption cost model's swap-cost proxy, kept
        on the protocol so the scheduler never probes pool internals
        (rule R4)."""
        return 0

    def swap_out(self, b: int):
        """Stage slot ``b``'s cache content to host memory and release its
        device capacity; returns an opaque handle for ``swap_in``.  Only
        meaningful on layouts whose admission can fail (paged)."""
        raise NotImplementedError(f"{type(self).__name__} does not swap")

    def swap_in(self, b: int, handle) -> bool:
        """Restore a swapped-out cache into slot ``b``; False if the pool
        cannot back it yet (the scheduler retries next tick)."""
        raise NotImplementedError(f"{type(self).__name__} does not swap")

    def rebind(self, params):
        """Point future prefills (``admit``/``begin``) at hot-swapped
        ``params``.  An online-adaptation swap is a pure pytree swap —
        same treedef, shapes and dtypes — so caches already staged stay
        valid: decode just reads the new weights the scheduler passes to
        the lane's jitted step.  Kept on the protocol so the scheduler
        never reaches into state internals (rule R4)."""
        self.params = params

    @property
    def capacity_bytes(self) -> int:
        return sum(x.nbytes for x in jax.tree.leaves(self.caches))

    @property
    def peak_bytes(self) -> int:
        return self.capacity_bytes

    def stats(self) -> dict:
        return {}


class DenseKV(SequenceState):
    """Dense stacked slot caches: every slot padded to a common
    ``slot_len`` (the original layout, kept as the parity oracle)."""

    layout = "dense"

    def __init__(self, lane: "Lane", params, batch: int, slot_len: int):
        self.lane = lane
        self.params = params
        self.slot_len = slot_len
        self.caches = stack_slot_caches(lane.model, batch, slot_len)
        self._pend_bs: List[int] = []
        self._pend_caches: List[Any] = []

    def admit(self, b: int, prompt, need_tokens: int) -> bool:
        _, c1 = self.lane.prefill(self.params, prompt, self.slot_len)
        self._pend_bs.append(b)
        self._pend_caches.append(c1)
        return True

    def begin(self, b: int, prompt, need_tokens: int) -> bool:
        return True     # dense slots are pre-reserved; nothing to stage

    def finalize(self, b: int, cache):
        # the whole-slot scatter overwrites whatever masked garbage the
        # slot decoded while the detached prefill was in flight
        self._pend_bs.append(b)
        self._pend_caches.append(cache)

    def detached_len(self, entry_count: int) -> int:
        return self.slot_len

    def flush(self):
        if self._pend_bs:   # one scatter for the whole admission wave
            self.caches = write_slots(self.caches, self._pend_bs,
                                      self._pend_caches)
            self._pend_bs, self._pend_caches = [], []


class RecurrentState(DenseKV):
    """Fixed-size recurrent state (ssm / xlstm / hybrid): stacked like the
    dense layout — recurrent state has no sequence axis to page, so slots
    are O(1)-sized regardless of ``slot_len`` (hybrid's shared-attention
    K/V slabs are the exception and do pad to ``slot_len``).  Differs from
    ``DenseKV`` only in rewind semantics, which live in ``SpecOps``."""

    layout = "recurrent"


class PagedKV(SequenceState):
    """Paged slot caches: one shared block pool + per-slot block tables.

    Host side this owns a ``BlockPool`` (block ids only) and mirrors each
    slot's real content length; device side it owns the cache pytree
    ``{k, v, table, pos}``.  Writes are batched: admissions/retirements
    accumulate and land in ``flush`` (block scatters + ONE table-row/pos
    scatter), per-tick growth lands in ``prepare_tick`` (one table-entry
    scatter).  Retired slots' rows are redirected to the trap block so
    their masked garbage decode cannot corrupt re-allocated blocks.

    PREFIX SHARING: admission consults a host-side prefix-block index
    (prompt-entry bytes -> live block ids).  A new request whose prompt
    shares a block-aligned prefix — or is an exact twin — of an in-flight
    slot's prompt maps those blocks into its own table via refcount bumps
    (``share_prefix``) instead of re-allocating and re-prefilling them;
    causal attention makes prefix K/V bit-identical across prompts, so
    token parity with the dense oracle is exact.  The first divergent
    decode write into a shared block forks a private copy first
    (``cow_split`` — copy-on-write), and index entries are invalidated the
    moment their backing block dies or is mutated.

    SWAP: ``swap_out`` stages a slot's blocks to host memory
    (``jax.device_get``) and releases them; ``swap_in`` restores the
    content bit-for-bit, so a preempted request resumes mid-decode with
    identical tokens.  On restore, ``swap_in`` RE-CONSULTS the prefix-block
    index: the full blocks of the victim's prompt that are still live (a
    resident twin, a shared system prefix) are re-shared via refcount bumps
    instead of paying private copies — only the tail past the indexed
    prefix is re-allocated and re-written.  Shared full prompt blocks are
    never decode-written by the resumed slot (its write frontier sits past
    the prompt), so no CoW reservation is needed on restore.
    """

    layout = "paged"

    def __init__(self, lane: "Lane", params, batch: int, slot_len: int,
                 block_size: int, num_blocks: Optional[int] = None, *,
                 data_shards: int = 1, kv_ways: int = 1):
        self.lane = lane
        self.params = params
        self.block_size = block_size
        self.max_blocks = blocks_for(slot_len, block_size)
        self.data_shards = data_shards
        self.kv_ways = kv_ways
        if data_shards > 1 and batch % data_shards != 0:
            raise ValueError(f"batch {batch} does not divide into "
                             f"{data_shards} data shards")
        self._spb = batch // max(data_shards, 1)    # slots per shard
        # sharded pools keep the SINGLE-DEVICE default's per-device byte
        # budget: each block's bytes divide kv_ways ways over 'model' and
        # the block dim data_shards ways over the data axes, so total
        # capacity scales with kv_shards = data_shards * kv_ways at the
        # same per-device HBM — the point of sharding the pool
        if data_shards > 1:
            if num_blocks is None:
                per_shard = (batch * self.max_blocks + 1) * kv_ways
            else:                   # explicit num_blocks = TOTAL blocks
                per_shard = -(-num_blocks // data_shards)
            per_shard = max(per_shard, 2)
            num_blocks = data_shards * per_shard
            self.pool = ShardedBlockPool(data_shards, per_shard,
                                         block_size, self._shard_of)
        else:
            if num_blocks is None:  # worst-case-safe default: dense capacity
                num_blocks = (batch * self.max_blocks + 1) * kv_ways
            num_blocks = max(num_blocks, 2)
            self.pool = BlockPool(num_blocks, block_size)
        self.caches = lane.model.init_paged_cache(
            num_blocks, block_size, batch, self.max_blocks)
        self._block_bytes = (self.caches["k"].nbytes +
                             self.caches["v"].nbytes) // num_blocks
        self._len = [0] * batch     # real cache entries written per slot
        self._commit = [0] * batch  # blocks reserved for future growth
        self._entries: List[Optional[np.ndarray]] = [None] * batch  # prompts
        self._stale: set = set()    # retired slots awaiting a trap row
        self._pend: List[Tuple[int, np.ndarray, int]] = []  # (b, row, pos)
        # prefix-block index: prompt-entry bytes -> block ids holding them
        self._prefix_index: Dict[bytes, Tuple[int, ...]] = {}
        self._indexed: set = set()  # blocks referenced by any index entry
        # CoW reservations: shared tail block -> slots that reserved one
        # future fork block for it (their _commit carries the headroom)
        self._cow_rsv: Dict[int, List[int]] = {}
        self._prefix_hits = 0       # admissions that shared >= 1 block
        self._shared_blocks = 0     # physical allocations avoided
        self._cow_forks = 0
        self._swaps = 0
        # chunked prefills in flight: slot -> (entries, new blocks, shared)
        self._begun: Dict[int, Tuple[np.ndarray, List[int], int]] = {}

    # ------------------------------------------------------------ shards
    def _shard_of(self, b: int) -> int:
        """Data shard owning slot ``b`` (contiguous slot groups; 0 when the
        pool is unsharded)."""
        return b // self._spb if self.data_shards > 1 else 0

    def _pkey(self, shard: int, key: bytes):
        """Prefix-index key: the digest alone on the single pool; scoped by
        shard on sharded pools — prefix sharing/CoW stay host-side
        PER-SHARD, a slot can only map blocks its own shard owns."""
        return key if self.data_shards <= 1 else (shard, key)

    def _commit_sum(self, b: int) -> int:
        """Outstanding growth reservations charged against slot ``b``'s
        shard (all slots on the single pool)."""
        if self.data_shards <= 1:
            return sum(self._commit)
        s = self._shard_of(b)
        return sum(self._commit[s * self._spb:(s + 1) * self._spb])

    # ------------------------------------------------------------ prefix
    def _prefix_keys(self, entries: np.ndarray) -> List[bytes]:
        """Chained per-block digests: ``key[j]`` identifies the token
        prefix covering blocks 0..j (``min((j+1)*bs, E)`` entries), as
        ``blake2b(key[j-1] || block_j_bytes)``.  One O(E) pass yields
        every prefix key as a 16-byte digest — raw prefix byte-strings as
        keys would cost O(E^2/bs) hashing and index memory per prompt,
        quadratic on the admission path for long prompts."""
        E, bs = entries.size, self.block_size
        keys, prev = [], b""
        for j in range(blocks_for(E, bs)):
            prev = hashlib.blake2b(
                prev + entries[j * bs:min((j + 1) * bs, E)].tobytes(),
                digest_size=16).digest()
            keys.append(prev)
        return keys

    def _lookup_prefix(self, entries: np.ndarray,
                       shard: int = 0) -> Tuple[int, List[int]]:
        """Longest indexed prefix of ``entries`` within ``shard``: the
        exact entry count first (twin — shares the partial tail block
        too), then block-aligned lengths descending.  Returns (entries
        matched, block ids)."""
        E, bs = entries.size, self.block_size
        keys = self._prefix_keys(entries)
        for j in range(len(keys) - 1, -1, -1):
            got = self._prefix_index.get(self._pkey(shard, keys[j]))
            if got is not None:
                return min((j + 1) * bs, E), list(got)
        return 0, []

    def _register(self, entries: np.ndarray, blocks: List[int],
                  shard: int = 0):
        """Index every block-aligned prefix of ``entries`` (plus the full
        partial-tail prefix) under the blocks that hold it.  First
        registrant wins — twins share the original's blocks."""
        for j, key in enumerate(self._prefix_keys(entries)):
            self._prefix_index.setdefault(self._pkey(shard, key),
                                          tuple(blocks[:j + 1]))
        self._indexed.update(blocks)

    def _reindex(self):
        self._indexed = {blk for v in self._prefix_index.values()
                         for blk in v}

    def _purge_blocks(self, dead):
        """Drop index entries backed by any block that died."""
        dd = set(dead) & self._indexed
        if dd:
            self._prefix_index = {k: v for k, v in self._prefix_index.items()
                                  if not dd.intersection(v)}
            self._reindex()

    def _purge_written(self, blk: int):
        """Drop index entries referencing ``blk`` — its content is about
        to be mutated by a decode write.  O(1) when the block is not
        indexed (the steady state after the first write)."""
        if blk in self._indexed:
            self._prefix_index = {k: v for k, v in self._prefix_index.items()
                                  if blk not in v}
            self._reindex()

    def share_prefix(self, b: int, entries: np.ndarray,
                     _peek: Optional[Tuple[int, List[int]]] = None) -> int:
        """Map the longest indexed prefix of ``entries`` into slot ``b``
        (refcount bumps, no allocation), registering a CoW reservation
        when the shared tail is partial (slot ``b``'s ``_commit`` must
        already carry that one-block headroom).  Returns the number of
        cache entries covered (0 = no match; caller prefills everything).
        ``_peek`` lets ``admit`` reuse its sizing lookup instead of
        re-hashing every prefix slice."""
        m, shared = _peek if _peek is not None else \
            self._lookup_prefix(entries, self._shard_of(b))
        if shared:
            self.pool.share(b, shared)
            if m % self.block_size:
                self._cow_rsv.setdefault(shared[-1], []).append(b)
            self._prefix_hits += 1
            self._shared_blocks += len(shared)
        return m

    def _drop_cow_rsv(self, b: int) -> int:
        """Remove slot ``b``'s outstanding CoW reservations (its commit
        headroom leaves with it); returns how many were dropped."""
        n = 0
        for blk in list(self._cow_rsv):
            lst = self._cow_rsv[blk]
            while b in lst:
                lst.remove(b)
                n += 1
            if not lst:
                del self._cow_rsv[blk]
        return n

    def cow_split(self, b: int):
        """Make slot ``b``'s next decode-write target block private.

        The only pre-existing block a decode write can land in is the
        partial tail block at ``_len // block_size`` (growth allocates the
        rest fresh).  If it is shared (refcount > 1) fork a private copy —
        copy-on-write at first divergence; if it is exclusively owned,
        just invalidate any index entries over its (about to change)
        content.  Returns (src, dst, table_index) for the staged device
        copy, or None.

        The fork block is drawn from a SHARER's reservation, not
        necessarily the forking slot's: a tail shared by k sharers forks
        exactly k-1 times (the last writer keeps the original in place),
        and it is the k sharers — never the original registrant — whose
        admissions reserved the headroom.  Whichever slot forks first
        consumes one of those reservations, keeping ``free >=
        sum(_commit)`` exact however retire/preempt interleave."""
        E, bs = self._len[b], self.block_size
        if E % bs == 0:
            return None             # next write opens a fresh block
        i0 = E // bs
        blk = self.pool.owned(b)[i0]
        if self.pool.refcount(blk) > 1:
            new = self.pool.fork(b, blk)
            rsv = self._cow_rsv.get(blk)
            if rsv:
                s = rsv.pop()
                self._commit[s] = max(self._commit[s] - 1, 0)
                if not rsv:
                    del self._cow_rsv[blk]
            self._cow_forks += 1
            return blk, new, i0
        self._purge_written(blk)
        return None

    # ------------------------------------------------------------ admit
    def admit(self, b: int, prompt, need_tokens: int) -> bool:
        """Allocate the prompt's blocks and stage the prefill; returns
        False (admission deferred/preempted) when the pool cannot back the
        request.

        Admission is reservation-based: the request's WORST-CASE block need
        (``need_tokens`` = prompt + budget [+ overdraft], plus one block if
        a shared partial tail will need a copy-on-write fork) is committed
        up front so on-demand growth can never fail mid-flight, but blocks
        are only physically allocated as decode reaches them — the
        reservation is per-request, not the batch maximum, which is where
        the paged layout beats the dense slabs.  Shared prefix blocks
        count against nobody's reservation: they are live already."""
        prompt = np.asarray(prompt, np.int32)
        entries = prompt[:-1]
        got = self._reserve(b, entries, need_tokens)
        if got is None:
            return False
        ns, blocks = got
        if blocks:                  # prefill; write only the unshared tail
            nb = self.pool.blocks_for(entries.size)
            _, c1 = self.lane.prefill(self.params, prompt,
                                      nb * self.block_size)
            self._land(b, entries, blocks, ns, c1)
        else:
            self._land(b, entries, blocks, ns, None)
        return True

    def _reserve(self, b: int, entries: np.ndarray,
                 need_tokens: int) -> Optional[Tuple[int, List[int]]]:
        """Shared half of ``admit``/``begin``: map the live shared prefix,
        allocate the prompt's own blocks, commit worst-case growth.
        Returns (shared block count, newly allocated block ids), or None
        when the pool cannot back the request (nothing mutated)."""
        E = entries.size
        nb = self.pool.blocks_for(E)
        total = self.pool.blocks_for(need_tokens)
        m, shared = self._lookup_prefix(entries,        # sizing peek
                                        self._shard_of(b))
        own_new = nb - len(shared)
        cow_extra = 1 if shared and (m % self.block_size) else 0
        if not self.pool.can_alloc(own_new + (total - nb) + cow_extra
                                   + self._commit_sum(b), owner=b):
            return None
        ns = 0
        if shared:
            self.share_prefix(b, entries, _peek=(m, shared))
            ns = len(shared)
        blocks = self.pool.alloc(b, own_new) if own_new else []
        self._commit[b] = (total - nb) + cow_extra
        return ns, blocks

    def _land(self, b: int, entries: np.ndarray, blocks: List[int],
              ns: int, c1) -> None:
        """Stage a fully prefilled prompt into slot ``b``'s table row and
        the prefix index (``c1``: the prompt's single-sequence cache, or
        None when every block was shared)."""
        E = entries.size
        if blocks:
            nb = self.pool.blocks_for(E)
            kb, vb = prompt_cache_to_blocks(
                {"k": c1["k"][:, :, :nb * self.block_size],
                 "v": c1["v"][:, :, :nb * self.block_size]},
                self.block_size)
            self.caches["k"], self.caches["v"] = write_pool_blocks(
                self.caches["k"], self.caches["v"],
                jnp.asarray(blocks, jnp.int32), kb[:, ns:], vb[:, ns:])
        mine = self.pool.owned(b)
        # pad = trap block (the slot's shard's trap on sharded pools)
        row = np.full((self.max_blocks,), self.pool.trap(b), np.int32)
        row[:len(mine)] = mine
        self._pend.append((b, row, E))
        self._len[b] = E
        self._entries[b] = entries
        self._stale.discard(b)
        self._register(entries, mine, self._shard_of(b))

    def begin(self, b: int, prompt, need_tokens: int) -> bool:
        """Reserve blocks for a chunked prefill; the slot's device row
        stays a TRAP row until ``finalize`` (it decodes masked garbage
        while the detached prefill runs), and ``_register`` waits too —
        the reserved blocks hold garbage until the finalize write."""
        entries = np.asarray(prompt, np.int32)[:-1]
        got = self._reserve(b, entries, need_tokens)
        if got is None:
            return False
        ns, blocks = got
        self._begun[b] = (entries, blocks, ns)
        return True

    def finalize(self, b: int, cache):
        entries, blocks, ns = self._begun.pop(b)
        self._land(b, entries, blocks, ns, cache)

    def detached_len(self, entry_count: int) -> int:
        return self.pool.blocks_for(entry_count) * self.block_size

    def share_hints(self, prompts: List[Any]) -> List[bool]:
        """A prompt prefers the monolithic path when its first-block
        prefix key is already live in the index, or at least one other
        prompt in the same wave opens with the same block (the pair would
        have shared had the leader landed first).  Only the first block's
        key is probed — the cheapest sound signal: any shared prefix at
        all implies a shared first block."""
        firsts: List[Optional[bytes]] = []
        for p in prompts:
            entries = np.asarray(p, np.int32)[:-1]
            if entries.size == 0:
                firsts.append(None)
                continue
            firsts.append(hashlib.blake2b(
                entries[:self.block_size].tobytes(),
                digest_size=16).digest())
        counts = Counter(k for k in firsts if k is not None)
        # slot (and so shard) assignment happens after the hint, so probe
        # every shard's index — a miss only costs a chunking opportunity
        shards = range(max(self.data_shards, 1))
        return [k is not None
                and (any(self._pkey(s, k) in self._prefix_index
                         for s in shards) or counts[k] > 1)
                for k in firsts]

    def fits_empty(self, need_tokens: int, prompt=None) -> bool:
        total = self.pool.blocks_for(need_tokens)
        if total <= self.pool.usable():
            return True
        if prompt is not None:      # admissible via currently-live sharing?
            entries = np.asarray(prompt, np.int32)[:-1]
            for s in range(max(self.data_shards, 1)):
                m, shared = self._lookup_prefix(entries, s)
                cow = 1 if shared and (m % self.block_size) else 0
                if total - len(shared) + cow <= self.pool.usable():
                    return True
        return False

    def swappable(self, b: int) -> bool:
        """A victim is only worth swapping if its restore is GUARANTEED.
        ``swap_in`` does re-share still-indexed full prompt blocks, but
        that is opportunistic — the index entries can die while the victim
        sits on the host (the twin retires, a block is written) — so the
        guarantee must assume the worst case: every logical block restored
        privately.  A slot admitted only thanks to prefix sharing, with a
        private footprint larger than the pool, could otherwise never come
        back."""
        rsv = sum(b in lst for lst in self._cow_rsv.values())
        return (len(self.pool.owned(b)) + self._commit[b] - rsv
                <= self.pool.usable())

    def owned_blocks(self, b: int) -> int:
        return len(self.pool.owned(b))

    @hot_path
    def flush(self):
        if not (self._pend or self._stale):
            return
        idx, rows, poss = [], [], []
        for b, row, p in self._pend:
            idx.append(b)
            rows.append(row)
            poss.append(p)
        for b in self._stale:       # retired, not re-admitted: trap row
            idx.append(b)
            rows.append(np.full((self.max_blocks,), self.pool.trap(b),
                                np.int32))
            poss.append(0)
        ii = jnp.asarray(idx, jnp.int32)
        self.caches["table"] = self.caches["table"].at[ii].set(
            jnp.asarray(np.stack(rows)))
        self.caches["pos"] = self.caches["pos"].at[ii].set(
            jnp.asarray(poss, jnp.int32))
        self._pend, self._stale = [], set()

    @hot_path
    def prepare_tick(self, occupied, steps_h, n: int):
        """Grow every occupied slot to cover this tick's REAL decode steps
        (``min(steps_left, n)``); the masked garbage tail past a slot's
        budget clamps into the trap.  Growth draws down the slot's
        admission-time reservation, so it cannot fail.  Before growing,
        ``cow_split`` forks any shared partial tail block the tick is
        about to write into (one batched device copy for the wave)."""
        upd_b, upd_i, upd_blk = [], [], []
        cow_src, cow_dst = [], []
        for b in occupied:
            steps = min(int(steps_h[b]), n)
            if steps <= 0:
                continue
            cow = self.cow_split(b)
            if cow is not None:
                src, dst, i0 = cow
                cow_src.append(src)
                cow_dst.append(dst)
                upd_b.append(b)
                upd_i.append(i0)
                upd_blk.append(dst)
            target = self._len[b] + steps
            new = self.pool.grow_to(b, target)
            self._commit[b] = max(self._commit[b] - len(new), 0)
            base = len(self.pool.owned(b)) - len(new)
            for j, blk in enumerate(new):
                upd_b.append(b)
                upd_i.append(base + j)
                upd_blk.append(blk)
            self._len[b] = target
        if cow_src:
            self.caches["k"], self.caches["v"] = copy_pool_blocks(
                self.caches["k"], self.caches["v"],
                jnp.asarray(cow_src, jnp.int32),
                jnp.asarray(cow_dst, jnp.int32))
        if upd_b:
            self.caches["table"] = self.caches["table"].at[
                jnp.asarray(upd_b, jnp.int32),
                jnp.asarray(upd_i, jnp.int32)].set(
                jnp.asarray(upd_blk, jnp.int32))

    def retire(self, b: int):
        self._drop_cow_rsv(b)
        self._purge_blocks(self.pool.free(b))
        self._len[b] = 0
        self._commit[b] = 0
        self._entries[b] = None
        self._stale.add(b)

    # ------------------------------------------------------------ swap
    def swap_out(self, b: int) -> dict:
        """Stage slot ``b``'s blocks to host memory and free them.  The
        handle is self-contained (content, entry count, outstanding
        reservation): ``swap_in`` restores it bit-for-bit, so the resumed
        decode emits exactly the tokens the uninterrupted run would.  Any
        unconsumed CoW reservation is shed — the restored copy is fully
        private, so no fork can ever hit it."""
        ids = self.pool.owned(b)
        k, v = read_pool_blocks(self.caches["k"], self.caches["v"],
                                jnp.asarray(ids, jnp.int32))
        commit = max(self._commit[b] - self._drop_cow_rsv(b), 0)
        handle = {"k": jax.device_get(k), "v": jax.device_get(v),
                  "len": self._len[b], "commit": commit,
                  "entries": self._entries[b]}
        self._purge_blocks(self.pool.free(b))
        self._len[b] = 0
        self._commit[b] = 0
        self._entries[b] = None
        self._stale.add(b)
        self._swaps += 1
        return handle

    def swap_in(self, b: int, handle: dict) -> bool:
        """Restore a swapped-out slot into ``b``; False when the pool
        cannot back its blocks + outstanding reservation yet.

        Re-consults the prefix-block index over the victim's prompt: FULL
        prompt blocks still live in the index (a resident twin's, a shared
        system prefix) are mapped back by refcount bump instead of a
        private re-allocation + re-write.  Only full-block matches are
        taken — the restored content past the indexed prefix (partial tail
        block, generated tokens) is private by construction, and the
        resumed slot's write frontier (``len >= prompt entries``) can
        never land in a shared full prompt block, so no CoW reservation is
        needed."""
        nb = handle["k"].shape[1]
        entries = handle.get("entries")
        ns, shared = 0, []
        if entries is not None:
            m, cand = self._lookup_prefix(entries, self._shard_of(b))
            ns = min(m // self.block_size, nb)
            shared = cand[:ns]
        if not self.pool.can_alloc((nb - ns) + handle["commit"]
                                   + self._commit_sum(b), owner=b):
            return False
        if shared:
            self.pool.share(b, shared)
            self._prefix_hits += 1
            self._shared_blocks += ns
        blocks = self.pool.alloc(b, nb - ns) if nb > ns else []
        self._commit[b] = handle["commit"]
        if nb > ns:
            self.caches["k"], self.caches["v"] = write_pool_blocks(
                self.caches["k"], self.caches["v"],
                jnp.asarray(blocks, jnp.int32),
                jnp.asarray(handle["k"][:, ns:]),
                jnp.asarray(handle["v"][:, ns:]))
        mine = self.pool.owned(b)
        row = np.full((self.max_blocks,), self.pool.trap(b), np.int32)
        row[:nb] = mine
        self._pend.append((b, row, handle["len"]))
        self._len[b] = handle["len"]
        self._entries[b] = entries
        self._stale.discard(b)
        if entries is not None:
            # restored PROMPT blocks are index-worthy again (first
            # registrant wins, so a live twin's entries are untouched);
            # generated-token blocks past the prompt stay out of the index
            # so their first write keeps the O(1) purge fast path
            self._register(entries, mine[:blocks_for(entries.size,
                                                     self.block_size)],
                           self._shard_of(b))
        return True

    @property
    def peak_bytes(self) -> int:
        """High-water mark of LIVE block bytes — what a right-sized pool
        would have to hold (the benchmark's headline number).  Shared
        blocks count once: prefix sharing lowers this directly."""
        return self.pool.peak_used * self._block_bytes

    @property
    def capacity_bytes(self) -> int:
        return self.caches["k"].nbytes + self.caches["v"].nbytes

    def stats(self) -> dict:
        # usable capacity: pool minus trap(s) — per-shard traps on sharded
        # pools.  kv_shards is the total byte-division factor (data shards
        # x model-axis kv ways): the per-device footprint of this capacity
        # is capacity_bytes / kv_shards
        if self.data_shards > 1:
            cap = self.data_shards * (self.pool.per_shard - 1)
        else:
            cap = self.pool.num_blocks - 1
        return {"kv_blocks_peak": self.pool.peak_used,
                "kv_block_size": self.block_size,
                "kv_prefix_hits": self._prefix_hits,
                "kv_shared_blocks": self._shared_blocks,
                "kv_cow_forks": self._cow_forks,
                "kv_swaps": self._swaps,
                "kv_shards": self.data_shards * self.kv_ways,
                "kv_capacity_blocks": cap}


# ---------------------------------------------------------------- lane
class Lane:
    """Jitted batched machinery for ONE model in ONE layout: the batched
    decode step (``SpecOps.step``), a per-prompt-length prefill, the
    multi-token decode scan shared by all layouts, and the ``make_state``
    factory the scheduler calls instead of picking adapters itself."""

    def __init__(self, model, estimator: str, temperature: float,
                 layout: str = "dense", block_size: int = 32,
                 mesh=None, data_shards: int = 1):
        self.model = model
        self.estimator = estimator
        self.temperature = temperature
        self.layout = layout
        self.block_size = block_size
        self.mesh = mesh
        self._dense_side: Optional["Lane"] = None
        self.data_shards = data_shards if mesh is not None else 1
        # model-axis byte division of the paged pool (1 when this model's
        # kv-heads/head-dim don't divide — replication fallback)
        self.kv_ways = kv_shard_ways(mesh, model.cfg) if mesh is not None \
            else 1
        self.ops = SpecOps(model, layout)
        est = get_batched_estimator(estimator)
        step = self.ops.step
        # KV-transformer attention masks every key row past ``pos``
        # (score -> -inf -> exp = 0 exactly), so prefilling a prompt PADDED
        # to a pow2 bucket and then pinning ``pos`` back to the real length
        # is bit-identical to an exact-length prefill — that is what lets
        # admission bucket prompt lengths instead of compiling one prefill
        # per distinct length.  Recurrent families (ssm/xlstm/hybrid)
        # advance state through EVERY input token, pads included, so they
        # must keep exact-length compiles; encdec's cross-attention reads
        # the full encoder output and is excluded for the same reason.
        self._bucket_prefill = layout in ("dense", "paged") and \
            model.cfg.family in ("dense", "moe", "vlm")
        self._jit_prefill = jax.jit(
            lambda p, toks, max_seq: model.prefill(
                p, {"tokens": toks}, max_seq=max_seq),
            static_argnames=("max_seq",))
        self._jit_extend = jax.jit(
            lambda p, toks, cache: model.extend_step(p, toks, cache))

        @hot_path
        def chunk(params, caches, tok, steps_left, unc_sum, rng, stop,
                  n_steps: int, topk: int = 0):
            """n_steps decode steps over all slots in one scan.  Returns the
            advanced state plus per-step (token, active) for the host.
            ``stop`` is a traced int32 stop-token id (-1 = never): a slot
            that emits it keeps the token but zeroes its remaining budget,
            so it retires early with steps-spent < budget.  ``topk > 0``
            (static) additionally emits each step's top-k logit values and
            vocab indices — teacher supervision for serve-time adaptation,
            coming out through the SAME batched pull as the token tape
            (capture never adds a sync); the default-0 path traces the
            exact tuple it always has, byte-identical."""
            def body(carry, r):
                caches, tok, steps_left, unc_sum = carry
                lg, caches = step(params, tok, caches)       # (B, V)
                active = steps_left > 0
                if temperature == 0.0:
                    nxt = jnp.argmax(lg, -1).astype(jnp.int32)
                else:
                    nxt = jax.random.categorical(
                        r, lg / temperature, axis=-1).astype(jnp.int32)
                unc_sum = unc_sum + jnp.where(active, est(lg), 0.0)
                steps_left = jnp.where(active & (nxt == stop),
                                       0, steps_left - active.astype(jnp.int32))
                out = (nxt, active)
                if topk:
                    tv, ti = jax.lax.top_k(lg.astype(jnp.float32), topk)
                    out = (nxt, active, tv, ti.astype(jnp.int32))
                return (caches, nxt[:, None, None], steps_left, unc_sum), out

            carry = (caches, tok, steps_left, unc_sum)
            keys = jax.random.split(rng, n_steps)
            if topk:
                (caches, tok, steps_left, unc_sum), \
                    (toks, actives, tvals, tidx) = \
                    jax.lax.scan(body, carry, keys)
                return (caches, tok, steps_left, unc_sum, toks, actives,
                        tvals, tidx)
            (caches, tok, steps_left, unc_sum), (toks, actives) = \
                jax.lax.scan(body, carry, keys)
            return caches, tok, steps_left, unc_sum, toks, actives

        self._chunk = jax.jit(chunk, static_argnames=("n_steps", "topk"))

    def dense_side(self) -> "Lane":
        """This lane's model re-hosted on dense per-slot caches (cached
        after the first call).  Tree/self speculation needs block-masked
        extends — a dense-layout feature — so escalation groups build
        their side states through here instead of the scheduler ever
        comparing ``.layout`` (rule R4 keeps layout dispatch out of it).
        Identity on lanes that are already dense."""
        if self.layout == "dense":
            return self
        if self._dense_side is None:
            self._dense_side = Lane(self.model, self.estimator,
                                    self.temperature, layout="dense",
                                    block_size=self.block_size,
                                    mesh=self.mesh,
                                    data_shards=self.data_shards)
        return self._dense_side

    def prefill(self, params, prompt, max_seq: int):
        """Prefill ``prompt[:-1]`` into a fresh cache padded to ``max_seq``.
        KV-transformer lanes pad the ENTRY COUNT to a pow2 bucket (capped
        at ``max_seq``) and pin ``pos`` back to the real length — bit-exact
        (see ``_bucket_prefill``), and it bounds the compile set at
        O(log max prompt) instead of one compile per distinct length.
        Recurrent/encdec lanes still recompile per distinct prompt length."""
        entries = np.asarray(prompt, np.int32)[:-1]
        E = entries.size
        Ep = min(pow2_steps(E, 1 << 30), max_seq) if self._bucket_prefill \
            else E
        if Ep > E:
            entries = np.concatenate([entries, np.zeros(Ep - E, np.int32)])
        lg, cache = self._jit_prefill(params, jnp.asarray(entries[None]),
                                      max_seq=max_seq)
        if Ep > E:
            cache = {**cache, "pos": jnp.full_like(cache["pos"], E)}
        return lg, cache

    # ------------------------------------------------------------ chunked
    def start_prefill(self, params, prompt, max_seq: int, chunk: int) -> dict:
        """Open a CHUNKED prefill job: the prompt's entries are advanced
        ``chunk`` tokens per ``advance_prefill`` call into a DETACHED
        single-sequence cache (padded to ``max_seq``), so a long prompt
        never stalls the in-flight decode batch behind one monolithic
        prefill — the scheduler interleaves one chunk per tick with decode
        and lands the finished cache through ``SequenceState.finalize``."""
        entries = np.asarray(prompt, np.int32)[:-1]
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        return {"entries": entries, "done": 0, "cache": None,
                "max_seq": max_seq, "chunk": chunk}

    def advance_prefill(self, params, job: dict) -> bool:
        """Advance one chunk of a ``start_prefill`` job; True when every
        prompt entry is in the detached cache.  The first chunk compiles
        like a short prompt; middle chunks share ONE extend compile per
        chunk size; the final partial chunk pow2-pads on KV lanes (``pos``
        pinned back, bit-exact) and runs exact-length on recurrent lanes,
        so the whole job's compile set is O(log chunk), not O(prompt)."""
        entries, done, C = job["entries"], job["done"], job["chunk"]
        take = min(C, entries.size - done)
        toks = entries[done:done + take]
        if job["cache"] is None:
            _, cache = self._jit_prefill(params, jnp.asarray(toks[None]),
                                         max_seq=job["max_seq"])
        else:
            Tp = min(pow2_steps(take, C), job["max_seq"] - done) \
                if self._bucket_prefill else take
            if Tp > take:
                toks = np.concatenate([toks, np.zeros(Tp - take, np.int32)])
            _, cache = self._jit_extend(params, jnp.asarray(toks[None]),
                                        job["cache"])
            if Tp > take:
                cache = {**cache,
                         "pos": jnp.full_like(cache["pos"], done + take)}
        job["cache"] = cache
        job["done"] = done + take
        return job["done"] >= entries.size

    def make_state(self, params, batch: int, slot_len: int, *,
                   need_tokens: Optional[Sequence[int]] = None,
                   num_blocks: Optional[int] = None) -> SequenceState:
        """Build this lane's decode-state adapter.  ``need_tokens``
        (escalation groups) sizes a paged pool to exactly the group's
        residency instead of the worst case."""
        if self.layout == "recurrent":
            return self._place(RecurrentState(self, params, batch, slot_len),
                               batch)
        if self.layout == "dense":
            return self._place(DenseKV(self, params, batch, slot_len), batch)
        shards = self.data_shards if batch % max(self.data_shards, 1) == 0 \
            else 1
        if num_blocks is None and need_tokens is not None:
            if shards > 1:
                # per-shard demand: slot i lives on shard i // (batch/S), so
                # size every shard's range to the HEAVIEST shard (pools are
                # uniform) and pow2-bucket that for compile-shape reuse
                spb = batch // shards
                per = [0] * shards
                for i, t in enumerate(need_tokens):
                    per[i // spb] += blocks_for(t, self.block_size)
                num_blocks = shards * (1 + pow2_steps(max(per), 1 << 30))
            else:
                needed = sum(blocks_for(t, self.block_size)
                             for t in need_tokens)
                # pow2-bucket the pool so escalation groups with different
                # residencies reuse one compiled scan/spec-round shape (the
                # peak-bytes stat tracks LIVE blocks, not this capacity)
                num_blocks = 1 + pow2_steps(needed, 1 << 30)
        return self._place(
            PagedKV(self, params, batch, slot_len, self.block_size,
                    num_blocks, data_shards=shards, kv_ways=self.kv_ways),
            batch)

    def _place(self, state: SequenceState, batch: int) -> SequenceState:
        """Pin a fresh state's device arrays to the mesh (no-op off-mesh):
        paged pools get block-dim/data + kv-head/'model' sharding, dense
        and recurrent stacks the batch/data + head/'model' rules."""
        if self.mesh is None:
            return state
        if state.layout == "paged":
            sh = paged_cache_shardings(state.caches, self.mesh,
                                       self.model.cfg, state.data_shards)
        else:
            sh = cache_shardings(state.caches, self.mesh, self.model.cfg,
                                 batch)
        state.caches = jax.device_put(state.caches, sh)
        return state
