"""Token-tree speculation (survey §2.4.4 — LLMCad / SpecInfer / Sequoia /
OPT-Tree style).

Instead of a single gamma-token chain, the draft expands a TREE of candidate
continuations; the target verifies every node in ONE pass using a tree
attention mask (each node attends to its ancestors only), then the longest
target-consistent root path is accepted via per-node rejection sampling.

Only attention-family targets support tree masks (``Model.extend_step
block_mask``); SSM/hybrid recurrences are linear-order (DESIGN.md
§Arch-applicability) and fall back to chain speculation.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TokenTree:
    """Flattened tree. Node 0 is the root token (the pending "last token");
    nodes are topologically ordered (parent index < child index)."""
    tokens: np.ndarray          # (n,) int32
    parent: np.ndarray          # (n,) int32; parent[0] = -1
    draft_logp: np.ndarray      # (n, V) draft log-probs AT each node's position
                                # (i.e. distribution the node's token was drawn from)

    @property
    def n(self) -> int:
        return len(self.tokens)

    def ancestors(self, i: int) -> List[int]:
        path = []
        while i != -1:
            path.append(i)
            i = int(self.parent[i])
        return path[::-1]

    def attention_mask(self) -> np.ndarray:
        """(n, n) bool: node i attends to j iff j is an ancestor of i (or i)."""
        m = np.zeros((self.n, self.n), bool)
        for i in range(self.n):
            for j in self.ancestors(i):
                m[i, j] = True
        return m

    def children(self, i: int) -> List[int]:
        return [j for j in range(self.n) if self.parent[j] == i]

    def depths(self) -> np.ndarray:
        d = np.zeros(self.n, np.int32)
        for i in range(1, self.n):
            d[i] = d[self.parent[i]] + 1
        return d


class TreePlan:
    """Static packed topology for BATCHED tree speculation.

    A fixed branching plan makes every per-round shape static: node i's
    parent, depth and ancestor mask are numpy constants, so the batched
    decoder's draft expansion, verify mask and acceptance walk all trace
    once.  Nodes are level-contiguous (root = node 0, then every level-1
    node, …), which makes the children of the level-``l`` node of rank
    ``r`` a pure arithmetic range — the acceptance walk needs no gather
    over a parent table.

    The packed width is pow2-padded (``n_pad``); pad nodes carry a
    self-only mask row (so their softmax rows stay finite) and are never
    visited by the walk.
    """

    def __init__(self, branching: Sequence[int]):
        branching = tuple(int(b) for b in branching)
        if not branching or any(b < 1 for b in branching):
            raise ValueError(f"bad branching plan {branching!r}")
        widths = np.cumprod(branching)               # level 1..D node counts
        self.branching = branching
        self.depth = len(branching)                  # accepted path <= depth
        self.n = 1 + int(widths.sum())
        self.n_pad = 1 << (self.n - 1).bit_length()
        # level_lo[l] = first node index of level l (level 0 = the root)
        self.level_lo = (0,) + tuple(1 + int(widths[:l].sum())
                                     for l in range(self.depth))
        # children of the rank-r node of level l:
        #   level_lo[l+1] + r*branching[l] + [0, branching[l])
        parent = np.full(self.n_pad, -1, np.int32)
        depths = np.zeros(self.n_pad, np.int32)
        for l in range(1, self.depth + 1):
            lo, w, k = self.level_lo[l], int(widths[l - 1]), branching[l - 1]
            for r in range(w):
                parent[lo + r] = self.level_lo[l - 1] + r // k
                depths[lo + r] = l
        self.parent = parent                         # pads: -1
        self.depths = depths                         # pads: 0
        mask = np.eye(self.n_pad, dtype=bool)        # pads: self-only rows
        for i in range(self.n):
            j = i
            while j != -1:
                mask[i, j] = True
                j = int(parent[j]) if j else -1
        self.mask = mask
        # draft expansion: level l's new nodes are [lo, hi) and their
        # parents are the previous level — one tree-masked extend over the
        # prefix [0, lo) yields every parent row's logits
        self.levels = tuple((self.level_lo[l],
                             self.level_lo[l] + int(widths[l - 1]))
                            for l in range(1, self.depth + 1))


def branching_for(width: int, gamma: int) -> tuple:
    """Default branching plan for ``--spec-tree-width`` at draft depth
    ``gamma``: fan out wide at the root (where the draft is least certain),
    once more below it, then single chains — the Sequoia/OPT-Tree shape
    that keeps node count linear in depth."""
    width, gamma = max(int(width), 1), max(int(gamma), 1)
    return (width,) if gamma == 1 else (width, 2) + (1,) * (gamma - 2)


def tree_accept(rng, t_logits, q_logits, tokens, plan: TreePlan, *,
                temperature: float = 1.0):
    """Packed-tree acceptance walk for ONE slot (vmapped by
    ``BatchedSpecDecoder``): from the root, rejection-sample one child per
    level against the draft distribution it was drawn from (siblings tried
    in order, union-bound residual on total rejection — the ``verify_tree``
    math, statically unrolled).

    t_logits/q_logits: (n_pad, V) target/draft logits per node (q at node c
    = its PARENT's draft logits — the distribution c's token was drawn
    from); tokens: (n_pad,) int32.  Returns (n_acc, emitted (depth+1,),
    path (depth+1,)): the round emits ``emitted[:n_acc+1]``, whose last
    entry is the resample/bonus token, and ``path[d]`` is the accepted
    node INDEX at depth d (``path[0] = 0``, the root; entries past
    ``n_acc`` are dead) — the permutation ``SpecOps.commit_permute`` uses
    to relocate the accepted K/V rows.  temperature == 0 degenerates to
    the exact greedy walk (accept iff a child carries the target argmax).
    """
    D, V = plan.depth, t_logits.shape[-1]
    kmax = max(plan.branching)
    r_acc, r_res = jax.random.split(rng)
    u_acc = jax.random.uniform(r_acc, (D, kmax))
    u_res = jax.random.uniform(r_res, (D + 1,))

    def probs(l):
        l = l.astype(jnp.float32)
        if temperature == 0.0:
            p = (l >= jnp.max(l, -1, keepdims=True)).astype(jnp.float32)
            return p / jnp.sum(p, -1, keepdims=True)
        return jax.nn.softmax(l / temperature, -1)

    def sample(dist, u):                     # inverse-CDF, as spec_verify
        cdf = jnp.cumsum(dist, -1)
        return jnp.minimum(jnp.sum((cdf < u).astype(jnp.int32), -1), V - 1)

    cur = jnp.int32(0)
    alive = jnp.bool_(True)
    n_acc = jnp.int32(0)
    emitted = []
    path = [jnp.int32(0)]
    for l in range(D):
        k = plan.branching[l]
        child0 = plan.level_lo[l + 1] + (cur - plan.level_lo[l]) * k
        p = probs(t_logits[cur])
        chosen = jnp.int32(-1)
        q_total = jnp.zeros((V,), jnp.float32)
        for j in range(k):
            c = child0 + j
            tok_c = tokens[c]
            q_c = probs(q_logits[c])
            ratio = p[tok_c] / jnp.maximum(q_c[tok_c], 1e-20)
            tried = chosen < 0
            acc_j = tried & (u_acc[l, j] < jnp.minimum(ratio, 1.0))
            q_total = jnp.where(tried & ~acc_j,
                                jnp.maximum(q_total, q_c), q_total)
            chosen = jnp.where(acc_j, c, chosen)
        resid = jnp.clip(p - q_total, 0.0, None)
        tot = jnp.sum(resid)
        resid = jnp.where(tot > 0, resid / jnp.maximum(tot, 1e-20), p)
        hit = chosen >= 0
        emit = jnp.where(hit, tokens[jnp.maximum(chosen, 0)],
                         sample(resid, u_res[l]))
        emitted.append(jnp.where(alive, emit, 0))
        n_acc = n_acc + (alive & hit)
        cur = jnp.where(hit, jnp.maximum(chosen, 0), cur)
        path.append(cur)
        alive = alive & hit
    emitted.append(jnp.where(alive, sample(probs(t_logits[cur]), u_res[D]), 0))
    return n_acc, jnp.stack(emitted), jnp.stack(path)


def tree_accept_ref(rng, t_logits, q_logits, tokens, plan: TreePlan, *,
                    temperature: float = 1.0):
    """Sequential rejection-sampling oracle for ``tree_accept`` — same rng
    stream (split + uniform draws of the same shapes), python control flow.
    Returns (n_acc, emitted list of n_acc+1 ints)."""
    r_acc, r_res = jax.random.split(rng)
    u_acc = np.asarray(jax.random.uniform(r_acc, (plan.depth,
                                                  max(plan.branching))))
    u_res = np.asarray(jax.random.uniform(r_res, (plan.depth + 1,)))
    t_logits = np.asarray(t_logits, np.float32)
    q_logits = np.asarray(q_logits, np.float32)
    tokens = np.asarray(tokens)
    V = t_logits.shape[-1]

    def probs(l):
        if temperature == 0.0:
            p = (l >= l.max()).astype(np.float32)
            return p / p.sum()
        z = np.exp((l - l.max()) / temperature)
        return z / z.sum()

    def sample(dist, u):
        return min(int((np.cumsum(dist) < u).sum()), V - 1)

    cur, n_acc, emitted = 0, 0, []
    for l in range(plan.depth):
        k = plan.branching[l]
        child0 = plan.level_lo[l + 1] + (cur - plan.level_lo[l]) * k
        p = probs(t_logits[cur])
        chosen = None
        q_total = np.zeros(V, np.float32)
        for j in range(k):
            c = child0 + j
            q_c = probs(q_logits[c])
            tok = int(tokens[c])
            if u_acc[l, j] < min(1.0, p[tok] / max(q_c[tok], 1e-20)):
                chosen = c
                break
            q_total = np.maximum(q_total, q_c)
        if chosen is None:
            resid = np.clip(p - q_total, 0.0, None)
            resid = resid / resid.sum() if resid.sum() > 0 else p
            emitted.append(sample(resid, u_res[l]))
            return n_acc, emitted
        emitted.append(int(tokens[chosen]))
        n_acc += 1
        cur = chosen
    emitted.append(sample(probs(t_logits[cur]), u_res[plan.depth]))
    return n_acc, emitted


def build_tree(draft_model, draft_params, draft_cache, last_token: int,
               branching: Sequence[int], rng, temperature: float = 1.0):
    """Greedy top-k tree expansion (OPT-Tree style, static branching plan).

    branching: e.g. (3, 2, 1) — 3 children of the root, 2 of each of those, …
    Draft cache is advanced level-by-level by replaying each node's ancestor
    path (the draft is cheap; this mirrors LLMCad's on-device tree growth).
    Returns (TokenTree, draft_calls).
    """
    step = jax.jit(lambda p, t, c: draft_model.decode_step(p, t, c))
    extend = jax.jit(lambda p, t, c: draft_model.extend_step(p, t, c))
    snap_pos = draft_cache["pos"] if draft_model.rewindable_cache else None

    tokens = [int(last_token)]
    parent = [-1]
    logps: List[Optional[np.ndarray]] = [None]
    frontier = [0]
    calls = 0
    for level, width in enumerate(branching):
        new_frontier = []
        for node in frontier:
            # bring cache to contain the ancestor path of `node` (minus itself)
            path = [tokens[i] for i in _ancestor_indices(parent, node)]
            if draft_model.rewindable_cache:
                cache = dict(draft_cache, pos=snap_pos)
            else:
                cache = jax.tree.map(lambda x: x, draft_cache)
            if len(path) > 1:
                _, cache = extend(draft_params,
                                  jnp.asarray(path[:-1], jnp.int32)[None], cache)
                calls += 1
            lg, cache = step(draft_params,
                             jnp.asarray([[path[-1]]], jnp.int32), cache)
            calls += 1
            logp = jax.nn.log_softmax(lg[0].astype(jnp.float32) /
                                      max(temperature, 1e-6))
            top = jax.lax.top_k(logp, width)[1]
            for t in np.asarray(top):
                tokens.append(int(t))
                parent.append(node)
                logps.append(np.asarray(logp))
                new_frontier.append(len(tokens) - 1)
        frontier = new_frontier
    V = logps[1].shape[0] if len(logps) > 1 else 1
    logp_arr = np.stack([np.zeros(V, np.float32) if l is None else l
                         for l in logps])
    return TokenTree(np.asarray(tokens, np.int32),
                     np.asarray(parent, np.int32), logp_arr), calls


def _ancestor_indices(parent, i):
    path = []
    while i != -1:
        path.append(i)
        i = int(parent[i])
    return path[::-1]


def verify_tree(target_model, target_params, target_cache, tree: TokenTree,
                rng, temperature: float = 1.0):
    """One target pass over all tree nodes with the tree attention mask, then
    greedy/stochastic path acceptance from the root (Traversal-Verification
    style: walk down, at each node accept one child via rejection sampling
    against the draft distribution, else resample and stop).

    Returns (accepted_tokens (without the root), next_token, new_target_cache,
    n_nodes_verified).
    """
    mask = jnp.asarray(tree.attention_mask())
    toks = jnp.asarray(tree.tokens, jnp.int32)[None, :]
    q_pos = target_cache["pos"] + jnp.asarray(tree.depths())   # RoPE by depth
    t_logits, new_cache = target_model.extend_step(
        target_params, toks, target_cache, block_mask=mask, q_positions=q_pos)
    t_logits = t_logits[0].astype(jnp.float32)          # (n, V)

    def probs(l):
        if temperature == 0.0:
            return jax.nn.one_hot(jnp.argmax(l, -1), l.shape[-1], dtype=jnp.float32)
        return jax.nn.softmax(l / temperature, -1)

    accepted: List[int] = []
    node = 0
    rng_np = np.random.default_rng(int(jax.random.randint(rng, (), 0, 2**31 - 1)))
    while True:
        p = np.asarray(probs(t_logits[node]))
        kids = tree.children(node)
        chosen = None
        q_total = np.zeros_like(p)
        for c in kids:
            q = np.exp(tree.draft_logp[c])
            q = q / q.sum()
            tok = int(tree.tokens[c])
            if rng_np.uniform() < min(1.0, p[tok] / max(q[tok], 1e-20)):
                chosen = c
                break
            q_total = np.maximum(q_total, q)   # union bound on tried branches
        if chosen is None:
            resid = np.clip(p - q_total, 0.0, None)
            if resid.sum() <= 0:
                resid = p
            resid = resid / resid.sum()
            nxt = int(rng_np.choice(len(resid), p=resid))
            return accepted, nxt, new_cache, tree.n
        accepted.append(int(tree.tokens[chosen]))
        node = chosen
        if not tree.children(node):
            p_leaf = np.asarray(probs(t_logits[node]))
            nxt = int(rng_np.choice(len(p_leaf), p=p_leaf))
            return accepted, nxt, new_cache, tree.n


class TreeSpecDecoder:
    """Tree-speculative decoding loop (KV-cache targets only)."""

    def __init__(self, draft_model, target_model, *,
                 branching: Sequence[int] = (3, 2, 1),
                 temperature: float = 1.0):
        if not target_model.rewindable_cache:
            raise ValueError("tree speculation needs an attention target "
                             "(see DESIGN.md §Arch-applicability)")
        self.draft, self.target = draft_model, target_model
        self.branching = tuple(branching)
        self.temperature = temperature

    def generate(self, draft_params, target_params, prompt, max_new: int,
                 rng=None):
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        prompt = jnp.atleast_2d(jnp.asarray(prompt, jnp.int32))
        n_tree = 1 + int(np.sum(np.cumprod(self.branching)))
        max_seq = prompt.shape[1] + max_new + (max_new + 1) * n_tree + 8
        _, d_cache = self.draft.prefill(draft_params,
                                        {"tokens": prompt[:, :-1]},
                                        max_seq=max_seq)
        _, t_cache = self.target.prefill(target_params,
                                         {"tokens": prompt[:, :-1]},
                                         max_seq=max_seq)
        out: List[int] = []
        last = int(prompt[0, -1])
        stats = {"rounds": 0, "target_passes": 0, "draft_calls": 0,
                 "nodes_verified": 0, "accepted_per_round": []}
        while len(out) < max_new:
            rng, r1, r2 = jax.random.split(rng, 3)
            t_pos0 = int(t_cache["pos"])
            tree, calls = build_tree(self.draft, draft_params, d_cache, last,
                                     self.branching, r1, self.temperature)
            stats["draft_calls"] += calls
            acc, nxt, t_cache, n_nodes = verify_tree(
                self.target, target_params, t_cache, tree, r2, self.temperature)
            stats["rounds"] += 1
            stats["target_passes"] += 1
            stats["nodes_verified"] += n_nodes
            stats["accepted_per_round"].append(len(acc))
            emitted = acc + [nxt]
            out.extend(emitted)
            # target cache: rewind, then replay the accepted linear path so
            # the cache layout is linear again (tree slots are discarded).
            t_cache = self.target.rewind(t_cache, t_pos0)
            replay = jnp.asarray([last] + acc, jnp.int32)[None]
            _, t_cache = self.target.extend_step(target_params, replay, t_cache)
            stats["target_passes"] += 1
            # draft cache: same linear replay
            if self.draft.rewindable_cache:
                d_cache = self.draft.rewind(d_cache, t_pos0)
            _, d_cache = self.draft.extend_step(draft_params, replay, d_cache)
            stats["draft_calls"] += 1
            last = nxt
        return out[:max_new], stats
