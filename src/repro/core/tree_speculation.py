"""Token-tree speculation (survey §2.4.4 — LLMCad / SpecInfer / Sequoia /
OPT-Tree style).

Instead of a single gamma-token chain, the draft expands a TREE of candidate
continuations; the target verifies every node in ONE pass using a tree
attention mask (each node attends to its ancestors only), then the longest
target-consistent root path is accepted via per-node rejection sampling.

Only attention-family targets support tree masks (``Model.extend_step
block_mask``); SSM/hybrid recurrences are linear-order (DESIGN.md
§Arch-applicability) and fall back to chain speculation.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TokenTree:
    """Flattened tree. Node 0 is the root token (the pending "last token");
    nodes are topologically ordered (parent index < child index)."""
    tokens: np.ndarray          # (n,) int32
    parent: np.ndarray          # (n,) int32; parent[0] = -1
    draft_logp: np.ndarray      # (n, V) draft log-probs AT each node's position
                                # (i.e. distribution the node's token was drawn from)

    @property
    def n(self) -> int:
        return len(self.tokens)

    def ancestors(self, i: int) -> List[int]:
        path = []
        while i != -1:
            path.append(i)
            i = int(self.parent[i])
        return path[::-1]

    def attention_mask(self) -> np.ndarray:
        """(n, n) bool: node i attends to j iff j is an ancestor of i (or i)."""
        m = np.zeros((self.n, self.n), bool)
        for i in range(self.n):
            for j in self.ancestors(i):
                m[i, j] = True
        return m

    def children(self, i: int) -> List[int]:
        return [j for j in range(self.n) if self.parent[j] == i]

    def depths(self) -> np.ndarray:
        d = np.zeros(self.n, np.int32)
        for i in range(1, self.n):
            d[i] = d[self.parent[i]] + 1
        return d


def build_tree(draft_model, draft_params, draft_cache, last_token: int,
               branching: Sequence[int], rng, temperature: float = 1.0):
    """Greedy top-k tree expansion (OPT-Tree style, static branching plan).

    branching: e.g. (3, 2, 1) — 3 children of the root, 2 of each of those, …
    Draft cache is advanced level-by-level by replaying each node's ancestor
    path (the draft is cheap; this mirrors LLMCad's on-device tree growth).
    Returns (TokenTree, draft_calls).
    """
    step = jax.jit(lambda p, t, c: draft_model.decode_step(p, t, c))
    extend = jax.jit(lambda p, t, c: draft_model.extend_step(p, t, c))
    snap_pos = draft_cache["pos"] if draft_model.rewindable_cache else None

    tokens = [int(last_token)]
    parent = [-1]
    logps: List[Optional[np.ndarray]] = [None]
    frontier = [0]
    calls = 0
    for level, width in enumerate(branching):
        new_frontier = []
        for node in frontier:
            # bring cache to contain the ancestor path of `node` (minus itself)
            path = [tokens[i] for i in _ancestor_indices(parent, node)]
            if draft_model.rewindable_cache:
                cache = dict(draft_cache, pos=snap_pos)
            else:
                cache = jax.tree.map(lambda x: x, draft_cache)
            if len(path) > 1:
                _, cache = extend(draft_params,
                                  jnp.asarray(path[:-1], jnp.int32)[None], cache)
                calls += 1
            lg, cache = step(draft_params,
                             jnp.asarray([[path[-1]]], jnp.int32), cache)
            calls += 1
            logp = jax.nn.log_softmax(lg[0].astype(jnp.float32) /
                                      max(temperature, 1e-6))
            top = jax.lax.top_k(logp, width)[1]
            for t in np.asarray(top):
                tokens.append(int(t))
                parent.append(node)
                logps.append(np.asarray(logp))
                new_frontier.append(len(tokens) - 1)
        frontier = new_frontier
    V = logps[1].shape[0] if len(logps) > 1 else 1
    logp_arr = np.stack([np.zeros(V, np.float32) if l is None else l
                         for l in logps])
    return TokenTree(np.asarray(tokens, np.int32),
                     np.asarray(parent, np.int32), logp_arr), calls


def _ancestor_indices(parent, i):
    path = []
    while i != -1:
        path.append(i)
        i = int(parent[i])
    return path[::-1]


def verify_tree(target_model, target_params, target_cache, tree: TokenTree,
                rng, temperature: float = 1.0):
    """One target pass over all tree nodes with the tree attention mask, then
    greedy/stochastic path acceptance from the root (Traversal-Verification
    style: walk down, at each node accept one child via rejection sampling
    against the draft distribution, else resample and stop).

    Returns (accepted_tokens (without the root), next_token, new_target_cache,
    n_nodes_verified).
    """
    mask = jnp.asarray(tree.attention_mask())
    toks = jnp.asarray(tree.tokens, jnp.int32)[None, :]
    q_pos = target_cache["pos"] + jnp.asarray(tree.depths())   # RoPE by depth
    t_logits, new_cache = target_model.extend_step(
        target_params, toks, target_cache, block_mask=mask, q_positions=q_pos)
    t_logits = t_logits[0].astype(jnp.float32)          # (n, V)

    def probs(l):
        if temperature == 0.0:
            return jax.nn.one_hot(jnp.argmax(l, -1), l.shape[-1], dtype=jnp.float32)
        return jax.nn.softmax(l / temperature, -1)

    accepted: List[int] = []
    node = 0
    rng_np = np.random.default_rng(int(jax.random.randint(rng, (), 0, 2**31 - 1)))
    while True:
        p = np.asarray(probs(t_logits[node]))
        kids = tree.children(node)
        chosen = None
        q_total = np.zeros_like(p)
        for c in kids:
            q = np.exp(tree.draft_logp[c])
            q = q / q.sum()
            tok = int(tree.tokens[c])
            if rng_np.uniform() < min(1.0, p[tok] / max(q[tok], 1e-20)):
                chosen = c
                break
            q_total = np.maximum(q_total, q)   # union bound on tried branches
        if chosen is None:
            resid = np.clip(p - q_total, 0.0, None)
            if resid.sum() <= 0:
                resid = p
            resid = resid / resid.sum()
            nxt = int(rng_np.choice(len(resid), p=resid))
            return accepted, nxt, new_cache, tree.n
        accepted.append(int(tree.tokens[chosen]))
        node = chosen
        if not tree.children(node):
            p_leaf = np.asarray(probs(t_logits[node]))
            nxt = int(rng_np.choice(len(p_leaf), p=p_leaf))
            return accepted, nxt, new_cache, tree.n


class TreeSpecDecoder:
    """Tree-speculative decoding loop (KV-cache targets only)."""

    def __init__(self, draft_model, target_model, *,
                 branching: Sequence[int] = (3, 2, 1),
                 temperature: float = 1.0):
        if not target_model.rewindable_cache:
            raise ValueError("tree speculation needs an attention target "
                             "(see DESIGN.md §Arch-applicability)")
        self.draft, self.target = draft_model, target_model
        self.branching = tuple(branching)
        self.temperature = temperature

    def generate(self, draft_params, target_params, prompt, max_new: int,
                 rng=None):
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        prompt = jnp.atleast_2d(jnp.asarray(prompt, jnp.int32))
        n_tree = 1 + int(np.sum(np.cumprod(self.branching)))
        max_seq = prompt.shape[1] + max_new + (max_new + 1) * n_tree + 8
        _, d_cache = self.draft.prefill(draft_params,
                                        {"tokens": prompt[:, :-1]},
                                        max_seq=max_seq)
        _, t_cache = self.target.prefill(target_params,
                                         {"tokens": prompt[:, :-1]},
                                         max_seq=max_seq)
        out: List[int] = []
        last = int(prompt[0, -1])
        stats = {"rounds": 0, "target_passes": 0, "draft_calls": 0,
                 "nodes_verified": 0, "accepted_per_round": []}
        while len(out) < max_new:
            rng, r1, r2 = jax.random.split(rng, 3)
            t_pos0 = int(t_cache["pos"])
            tree, calls = build_tree(self.draft, draft_params, d_cache, last,
                                     self.branching, r1, self.temperature)
            stats["draft_calls"] += calls
            acc, nxt, t_cache, n_nodes = verify_tree(
                self.target, target_params, t_cache, tree, r2, self.temperature)
            stats["rounds"] += 1
            stats["target_passes"] += 1
            stats["nodes_verified"] += n_nodes
            stats["accepted_per_round"].append(len(acc))
            emitted = acc + [nxt]
            out.extend(emitted)
            # target cache: rewind, then replay the accepted linear path so
            # the cache layout is linear again (tree slots are discarded).
            t_cache = self.target.rewind(t_cache, t_pos0)
            replay = jnp.asarray([last] + acc, jnp.int32)[None]
            _, t_cache = self.target.extend_step(target_params, replay, t_cache)
            stats["target_passes"] += 1
            # draft cache: same linear replay
            if self.draft.rewindable_cache:
                d_cache = self.draft.rewind(d_cache, t_pos0)
            _, d_cache = self.draft.extend_step(draft_params, replay, d_cache)
            stats["draft_calls"] += 1
            last = nxt
        return out[:max_new], stats
