"""Token-level mixture: speculative decoding (survey §2.4).

Edge SLM drafts gamma tokens; cloud LLM verifies them in ONE parallel pass
(modified rejection sampling, Leviathan et al. / survey §2.4.1).  The scheme
is *lossless*: the output distribution equals sampling from the target model
alone — `speculative_sample` is the pure, property-tested core.

Cache bookkeeping (the part the survey leaves implicit, and where the
architecture families differ):

* KV-cache models (dense/moe/vlm/encdec) roll back rejected tokens by
  resetting ``pos`` — stale entries are masked out and later overwritten.
* Recurrent-state models (ssm/xlstm/hybrid) cannot rewind; the reference
  ``SpecDecoder`` snapshots the state before each round and REPLAYS the
  accepted prefix (one extra extend pass — this cost shows up in
  SpecStats.replay_passes and in the benchmarks).  ``BatchedSpecDecoder``
  replays on device instead: each slot re-advances through its own accepted
  prefix via the model's batched ``replay_step`` (``core/seq_state.py``).

Invariant maintained by ``SpecDecoder.generate``: both caches contain
``sequence[:-1]``; ``sequence[-1]`` ("last token") is pending.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro import runtime
from repro.analysis import hot_path


def _probs(logits, temperature: float):
    """softmax(l/T) with T=0 -> one-hot argmax (greedy)."""
    logits = logits.astype(jnp.float32)
    if temperature == 0.0:
        return jax.nn.one_hot(jnp.argmax(logits, -1), logits.shape[-1],
                              dtype=jnp.float32)
    return jax.nn.softmax(logits / temperature, axis=-1)


@functools.partial(jax.jit, static_argnames=("temperature",))
def speculative_sample(rng, target_logits, draft_logits, draft_tokens,
                       temperature: float = 1.0):
    """Modified rejection sampling over a gamma-token draft.

    target_logits: (gamma+1, V) — logits for draft positions 0..gamma-1 plus
        the bonus position after a fully-accepted draft.
    draft_logits: (gamma, V); draft_tokens: (gamma,) int32.
    Returns (n_accepted (), next_token ()): the emitted tokens are
    draft_tokens[:n_accepted] + [next_token].
    """
    gamma = draft_tokens.shape[0]
    p = _probs(target_logits, temperature)            # (gamma+1, V)
    q = _probs(draft_logits, temperature)             # (gamma, V)
    r_accept, r_resample = jax.random.split(rng)

    p_tok = jnp.take_along_axis(p[:gamma], draft_tokens[:, None], axis=1)[:, 0]
    q_tok = jnp.take_along_axis(q, draft_tokens[:, None], axis=1)[:, 0]
    ratio = p_tok / jnp.maximum(q_tok, 1e-20)
    u = jax.random.uniform(r_accept, (gamma,))
    accept = u < jnp.minimum(ratio, 1.0)
    n_acc = jnp.sum(jnp.cumprod(accept.astype(jnp.int32)))

    # residual distribution at the first rejected position (or bonus at gamma)
    q_pad = jnp.concatenate([q, jnp.zeros((1, q.shape[1]), q.dtype)], axis=0)
    resid = jnp.clip(p[n_acc] - q_pad[n_acc], 0.0, None)
    resid_sum = jnp.sum(resid)
    resid = jnp.where(resid_sum > 0, resid / jnp.maximum(resid_sum, 1e-20),
                      p[n_acc])
    next_token = jax.random.categorical(r_resample, jnp.log(resid + 1e-20))
    return n_acc, next_token.astype(jnp.int32)


def acceptance_rate_bound(p, q):
    """Theoretical per-token acceptance prob: 1 - TV(p, q) = sum min(p, q).
    Used by tests and by the gamma controller."""
    return jnp.sum(jnp.minimum(p, q), axis=-1)


@dataclasses.dataclass
class SpecStats:
    draft_calls: int = 0
    target_passes: int = 0
    replay_passes: int = 0
    rounds: int = 0
    accepted: List[int] = dataclasses.field(default_factory=list)
    tokens_out: int = 0

    @property
    def mean_accepted(self) -> float:
        return float(np.mean(self.accepted)) if self.accepted else 0.0

    @property
    def tokens_per_target_pass(self) -> float:
        tp = self.target_passes + self.replay_passes
        return self.tokens_out / tp if tp else 0.0

    def summary(self) -> dict:
        return {
            "rounds": self.rounds,
            "draft_calls": self.draft_calls,
            "target_passes": self.target_passes,
            "replay_passes": self.replay_passes,
            "mean_accepted": self.mean_accepted,
            "tokens_out": self.tokens_out,
            "tokens_per_target_pass": self.tokens_per_target_pass,
        }


class AdaptiveGamma:
    """PEARL/DISCO-style draft-length control: lengthen the draft when
    acceptance is high, shorten when the target keeps rejecting."""

    def __init__(self, gamma: int = 4, lo: int = 1, hi: int = 16,
                 up: float = 0.85, down: float = 0.4):
        self.gamma, self.lo, self.hi, self.up, self.down = gamma, lo, hi, up, down

    def update(self, n_acc: int, gamma_used: int) -> int:
        rate = n_acc / max(gamma_used, 1)
        if rate >= self.up:
            self.gamma = min(self.gamma + 1, self.hi)
        elif rate <= self.down:
            self.gamma = max(self.gamma - 1, self.lo)
        return self.gamma


class SpecDecoder:
    """Edge-draft / cloud-verify decoding loop (B=1 sequences).

    draft_model / target_model: repro Model objects sharing a vocabulary.
    """

    def __init__(self, draft_model, target_model, *, gamma: int = 4,
                 temperature: float = 1.0, adaptive: bool = False):
        self.draft = draft_model
        self.target = target_model
        self.gamma = gamma
        self.temperature = temperature
        self.adaptive = AdaptiveGamma(gamma) if adaptive else None
        self._draft_step = jax.jit(
            lambda p, t, c: draft_model.decode_step(p, t, c))
        self._target_extend = jax.jit(
            lambda p, t, c: target_model.extend_step(p, t, c))
        self._draft_extend = jax.jit(
            lambda p, t, c: draft_model.extend_step(p, t, c))

    # ----------------------------------------------------------------
    def _snapshot(self, model, cache):
        if model.rewindable_cache:
            return cache["pos"]
        return jax.tree.map(lambda x: x, cache)     # shallow copy of pytree

    def _restore_and_replay(self, model, params, cache, snap, tokens):
        """Bring `model`'s cache to contain ...prefix + tokens."""
        if model.rewindable_cache:
            cache = model.rewind(cache, snap)
            if tokens.size:
                _, cache = (self._target_extend if model is self.target
                            else self._draft_extend)(params, tokens[None, :], cache)
            return cache, (1 if tokens.size else 0)
        # recurrent: replay from snapshot
        if tokens.size:
            _, cache = (self._target_extend if model is self.target
                        else self._draft_extend)(params, tokens[None, :], snap)
            return cache, 1
        return snap, 0

    # ----------------------------------------------------------------
    def generate(self, draft_params, target_params, prompt, max_new: int,
                 rng=None):
        """prompt: (S,) or (1,S) int32. Returns (tokens list, SpecStats)."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        prompt = jnp.atleast_2d(jnp.asarray(prompt, jnp.int32))
        assert prompt.shape[0] == 1, "SpecDecoder operates on B=1 sequences"
        S = prompt.shape[1]
        max_seq = S + max_new + 2 * max(self.gamma, 16) + 8

        d_lg, d_cache = self.draft.prefill(
            draft_params, {"tokens": prompt[:, :-1]}, max_seq=max_seq)
        t_lg, t_cache = self.target.prefill(
            target_params, {"tokens": prompt[:, :-1]}, max_seq=max_seq)

        stats = SpecStats()
        out: List[int] = []
        last = prompt[:, -1:]                          # pending token (1,1)

        while len(out) < max_new:
            gamma = self.adaptive.gamma if self.adaptive else self.gamma
            rng, r_draft, r_ver = jax.random.split(rng, 3)

            d_snap = self._snapshot(self.draft, d_cache)
            t_snap = self._snapshot(self.target, t_cache)

            # ---- draft gamma tokens (+1 call to keep the cache aligned)
            draft_tokens, draft_logits = [], []
            tok = last
            for i in range(gamma):
                lg, d_cache = self._draft_step(draft_params, tok, d_cache)
                stats.draft_calls += 1
                if self.temperature == 0.0:
                    nxt = jnp.argmax(lg, -1).astype(jnp.int32)
                else:
                    r_draft, rr = jax.random.split(r_draft)
                    nxt = jax.random.categorical(
                        rr, lg / self.temperature, axis=-1).astype(jnp.int32)
                draft_logits.append(lg[0])
                draft_tokens.append(int(nxt[0]))
                tok = nxt[:, None]
            _, d_cache = self._draft_step(draft_params, tok, d_cache)
            stats.draft_calls += 1

            # ---- verify in one target pass over [last, d_0..d_{gamma-1}]
            ver_in = jnp.concatenate(
                [last, jnp.asarray(draft_tokens, jnp.int32)[None, :]], axis=1)
            t_logits, t_cache = self._target_extend(target_params, ver_in, t_cache)
            stats.target_passes += 1

            n_acc, next_tok = speculative_sample(
                r_ver, t_logits[0], jnp.stack(draft_logits),
                jnp.asarray(draft_tokens, jnp.int32),
                temperature=self.temperature)
            n_acc, next_tok = int(n_acc), int(next_tok)

            # ---- commit & resync
            emitted = draft_tokens[:n_acc] + [next_tok]
            out.extend(emitted)
            stats.rounds += 1
            stats.accepted.append(n_acc)
            if self.adaptive:
                self.adaptive.update(n_acc, gamma)

            acc_tokens = jnp.asarray([int(last[0, 0])] + draft_tokens[:n_acc],
                                     jnp.int32)
            if self.target.rewindable_cache:
                t_cache = self.target.rewind(t_cache, int(t_snap) + n_acc + 1)
            else:
                _, t_cache = self._target_extend(
                    target_params, acc_tokens[None, :], t_snap)
                stats.replay_passes += 1
            if self.draft.rewindable_cache:
                d_cache = self.draft.rewind(d_cache, int(d_snap) + n_acc + 1)
            else:
                _, d_cache = self._draft_extend(
                    draft_params, acc_tokens[None, :], d_snap)
                stats.replay_passes += 1
            last = jnp.asarray([[next_tok]], jnp.int32)

        stats.tokens_out = len(out)
        return out[:max_new], stats


class BatchedSpecDecoder:
    """Grouped edge-draft / cloud-verify decoding for the serving scheduler.

    Where ``SpecDecoder`` runs one request with a host round-trip per draft
    token, this operates on a padded GROUP of requests with stacked per-slot
    caches (leading slot axis, per-slot scalar ``pos``):

      * drafting is ONE jitted ``lax.scan`` of gamma+1 steps over the whole
        group;
      * verification is ONE batched target extend over all slots;
      * acceptance (vmapped ``speculative_sample``) and the per-slot cache
        rewind both happen on device — one host sync per ROUND, per group.

    Cache handling is family-agnostic: each model's step/extend/rewind go
    through ``core.seq_state.SpecOps``, so any edge/cloud family pair —
    mixed ones included — shares the same rounds.  KV caches (dense or
    paged) rewind with a ``pos`` write; recurrent-state families
    (ssm/xlstm/hybrid) rewind by replaying each slot's accepted prefix
    from the pre-round state via the model's batched ``replay_step``
    (padded draft tape + per-slot ``jnp.where`` state select) — no
    per-request snapshot+replay anywhere.

    The caller owns admission: ``generate_group`` takes already-prefilled
    stacked caches (see ``core.seq_state.stack_slot_caches`` /
    ``write_slot``) so the scheduler can reuse its slot machinery.

    ``kv_layout="paged"`` runs the same rounds over paged caches (shared
    block pool + per-slot block tables, ``core/paged_cache.py``): drafting
    and verification go through the models' batched ``paged_decode_step`` /
    ``paged_extend_step``, and the per-slot rewind is STILL just the
    ``pos`` write — rejected draft entries stay in their allocated blocks,
    masked out and overwritten by the next round.  The caller must have
    grown each slot's block table to cover prompt + budget + one round of
    draft overdraft before calling ``generate_group``.

    ``mode`` picks the speculation lane:

    * ``"linear"`` (default) — the gamma-token chain above, any family pair.
    * ``"tree"`` — each slot drafts a PACKED TOKEN TREE (static
      ``TreePlan`` topology, pow2-padded width) level-by-level via top-k
      expansion, each level a rectangular-masked extend over ONLY its new
      nodes (each node forwarded exactly once per round); verification is
      ONE batched tree-masked target extend (``SpecOps.extend_tree`` — the
      Pallas tree-attention kernel on TPU) and acceptance walks the
      longest target-consistent root path (``tree_accept``).  The accepted
      path's K/V sit at non-contiguous but position-correct tree rows, so
      BOTH commits are row gathers down to the contiguous prefix
      (``SpecOps.commit_permute`` — no replay forward pass).  Dense-layout
      attention families only (``tree_supported``); group states are
      always dense.
    * ``"self"`` — no second model: the draft model's OWN early-exit head
      (first ``exit_layer`` blocks + shared LM head,
      ``self_speculative.partial_extend_step``) drafts into the shared
      cache and the full depth verifies, overwriting the shallow K/V.
      One cache, one params pytree (``second_model_params == 0``); use
      ``generate_group_self``.

    ``counters`` accumulates per-lane totals across ``generate_group``
    calls: member_rounds (active member-rounds = verify passes),
    draft_tokens (candidate tokens drafted), verify_tokens (positions the
    target forward covers, replay included), accepted_tokens and
    emitted_tokens — the engine's ``stats()`` derives
    ``spec_accept_rate`` / ``accepted_tokens_per_step`` from these.
    """

    def __init__(self, draft_model, target_model, *, gamma: int = 4,
                 temperature: float = 0.0, kv_layout: str = "dense",
                 mode: str = "linear", branching=None, exit_layer=None):
        from repro.core.seq_state import SpecOps, layout_for
        if kv_layout not in ("dense", "paged"):
            raise ValueError(f"unknown kv_layout {kv_layout!r}")
        if mode not in ("linear", "tree", "self"):
            raise ValueError(f"unknown speculation mode {mode!r}; "
                             "known: linear | tree | self")
        self.gamma = gamma
        self.temperature = temperature
        self.kv_layout = kv_layout
        self.mode = mode
        self.counters = {"member_rounds": 0, "draft_tokens": 0,
                         "verify_tokens": 0, "accepted_tokens": 0,
                         "emitted_tokens": 0}
        if mode == "linear":
            self._dops = SpecOps(draft_model, layout_for(draft_model, kv_layout))
            self._tops = SpecOps(target_model, layout_for(target_model, kv_layout))
            self._round = jax.jit(self._round_impl)
            self._per_round = (gamma, gamma + 1)
        elif mode == "tree":
            from repro.core.tree_speculation import TreePlan, branching_for
            if not self.tree_supported(draft_model, target_model):
                raise ValueError(
                    "tree speculation needs dense-layout attention families "
                    f"on both models, got {draft_model.cfg.family!r} / "
                    f"{target_model.cfg.family!r} (DESIGN.md "
                    "§Arch-applicability)")
            # tree groups always run dense per-slot caches: block masks are
            # a dense-layout feature (paged extends stay linear-order)
            self._dops = SpecOps(draft_model, "dense")
            self._tops = SpecOps(target_model, "dense")
            self.plan = TreePlan(branching if branching is not None
                                 else branching_for(2, gamma))
            self._round = jax.jit(self._tree_round_impl)
            self._per_round = (self.plan.n - 1, self.plan.n_pad)
        else:                                            # self
            from repro.core.self_speculative import partial_extend_step
            model = draft_model
            if not self.self_supported(model):
                raise ValueError(
                    "self-speculation needs a scan-stacked attention edge "
                    f"model, got family {model.cfg.family!r}")
            k = exit_layer if exit_layer is not None \
                else max(model.cfg.num_layers // 2, 1)
            if not 0 < k < model.cfg.num_layers:
                raise ValueError(f"exit_layer {k} out of range "
                                 f"(0, {model.cfg.num_layers})")
            self.exit_layer = k
            self.second_model_params = 0
            cfg = model.cfg
            self._tops = SpecOps(model, "dense")
            self._vpartial = jax.vmap(
                lambda p, t, c: partial_extend_step(p, t, c, cfg, k),
                in_axes=(None, 0, 0))
            self._round = jax.jit(self._self_round_impl)
            self._per_round = (gamma, gamma + 1)

    @staticmethod
    def tree_supported(draft_model, target_model) -> bool:
        fams = ("dense", "moe", "vlm")
        return (draft_model.cfg.family in fams
                and target_model.cfg.family in fams)

    @staticmethod
    def self_supported(model) -> bool:
        return model.cfg.family in ("dense", "moe", "vlm")

    def _round_impl(self, draft_params, target_params, d_slots, t_slots,
                    last, active, rng):
        """One draft/verify/commit round over the whole group.

        last: (G, 1, 1) pending tokens; active: (G,) bool — frozen slots
        keep their cache position and pending token.  Both caches satisfy
        the SpecDecoder invariant (contain sequence[:-1]) on entry and exit.
        """
        gamma = self.gamma
        G = last.shape[0]
        d_snap = self._dops.snapshot(d_slots)
        t_snap = self._tops.snapshot(t_slots)
        r_draft, r_ver = jax.random.split(rng)

        # ---- draft gamma tokens (+1 step so a fully-accepted draft's last
        # token is already in the cache when we commit gamma+1 tokens)
        def body(carry, r):
            caches, tok = carry
            lg, caches = self._dops.step(draft_params, tok, caches)  # (G, V)
            if self.temperature == 0.0:
                nxt = jnp.argmax(lg, -1).astype(jnp.int32)
            else:
                nxt = jax.random.categorical(
                    r, lg / self.temperature, axis=-1).astype(jnp.int32)
            return (caches, nxt[:, None, None]), (nxt, lg)

        (d_slots, _), (toks, lgs) = jax.lax.scan(
            body, (d_slots, last), jax.random.split(r_draft, gamma + 1))
        draft_toks = toks[:gamma].T                  # (G, gamma)
        draft_lgs = jnp.moveaxis(lgs[:gamma], 0, 1)  # (G, gamma, V)

        # ---- verify in one batched target pass over [last, d_0..d_{g-1}].
        # On a mesh this is THE wave crossing: the edge's data-sharded
        # draft tape is all-gathered over the data axes in one collective
        # per round, the tensor-parallel cloud verifies the replicated
        # wave, and the committed result is constrained back to per-slot
        # data sharding below (scatter_wave).  Identity off-mesh.
        ver_in = jnp.concatenate([last[:, :, 0], draft_toks], axis=1)  # (G,g+1)
        ver_in, draft_toks = runtime.gather_wave(ver_in, draft_toks)
        t_logits, t_slots = self._tops.extend(target_params, ver_in, t_slots)

        n_acc, next_tok = jax.vmap(
            functools.partial(speculative_sample,
                              temperature=self.temperature)
        )(jax.random.split(r_ver, G), t_logits, draft_lgs, draft_toks)

        # ---- per-slot rewind: caches now hold sequence + the full draft;
        # commit each slot's accepted prefix [last, d_0..d_{n_acc-1}]
        # (counts = 0 freezes inactive slots on their snapshot).
        counts = jnp.where(active, n_acc + 1, 0).astype(jnp.int32)
        d_slots = self._dops.commit(draft_params, d_slots, d_snap,
                                    ver_in, counts)
        t_slots = self._tops.commit(target_params, t_slots, t_snap,
                                    ver_in, counts)
        last = runtime.scatter_wave(
            jnp.where(active[:, None, None], next_tok[:, None, None], last))
        return d_slots, t_slots, last, draft_toks, n_acc, next_tok

    def _tree_round_impl(self, draft_params, target_params, d_slots, t_slots,
                         last, active, rng):
        """One packed-tree draft/verify/commit round over the whole group.

        Drafting expands the static ``TreePlan`` level-by-level and
        INCREMENTALLY: each span (root, then each level) is one rectangular
        tree-masked extend over only that span's NEW nodes — the mask's
        earlier columns cover the tree rows previous spans already wrote to
        the cache — so a round forwards each of the ``n`` nodes exactly
        once (O(n), not the O(n^2) recompute-from-snapshot alternative).
        Parent-row logits feed static top-k child selection.  Verification
        is one batched tree-masked target extend over all ``n_pad`` nodes —
        the same gather/scatter wave crossing as the linear round — and
        ``tree_accept`` walks the longest target-consistent root path per
        slot.  Accepted-path K/V sit at non-contiguous tree positions, so a
        bare ``pos`` write would keep sibling garbage inside the visible
        prefix — but every node's row is position-correct (written once at
        RoPE position snap + depth), so BOTH commits are row permutes
        (``commit_permute``): gather the accepted path down to the
        contiguous prefix, zero extra forward passes.
        """
        from repro.core.tree_speculation import tree_accept
        plan = self.plan
        G = last.shape[0]
        D = plan.depth
        mask = jnp.asarray(plan.mask)
        depths = jnp.asarray(plan.depths)
        d_snap = self._dops.snapshot(d_slots)
        t_snap = self._tops.snapshot(t_slots)

        # ---- draft: deterministic top-k tree expansion (OPT-Tree style);
        # node c's acceptance distribution q is its PARENT's draft logits
        toks = jnp.zeros((G, plan.n_pad), jnp.int32).at[:, 0].set(last[:, 0, 0])
        q_lgs = [None] * plan.n_pad
        spans = [(0, 1)] + list(plan.levels)     # contiguous: b_i == a_{i+1}
        for si, (a, b) in enumerate(spans):
            # extend ONLY nodes [a, b); mask rows a..b over all b tree
            # columns written so far; RoPE offset depths - a because the
            # cache pos already advanced to snap + a
            lgs, d_slots = self._dops.extend_tree(
                draft_params, toks[:, a:b], d_slots,
                mask[a:b, :b], depths[a:b] - a)
            if si + 1 == len(spans):
                break                            # deepest level: K/V only
            lo, hi = spans[si + 1]
            by_parent = {}
            for c in range(lo, hi):
                by_parent.setdefault(int(plan.parent[c]), []).append(c)
            for pnode, kids in sorted(by_parent.items()):
                plg = lgs[:, pnode - a]                      # (G, V)
                top = jax.lax.top_k(plg, len(kids))[1]
                for j, c in enumerate(kids):
                    toks = toks.at[:, c].set(top[:, j].astype(jnp.int32))
                    q_lgs[c] = plg
        V = q_lgs[plan.levels[0][0]].shape[-1]
        zero = jnp.zeros((G, V), jnp.float32)
        q_logits = jnp.stack([zero if l is None else l.astype(jnp.float32)
                              for l in q_lgs], axis=1)       # (G, n_pad, V)

        # ---- verify: ONE batched tree-masked target extend over the
        # flattened trees.  Same wave crossing as the linear round: the
        # data-sharded trees are all-gathered for the tensor-parallel
        # verifier, the committed result scattered back below.
        toks = runtime.gather_wave(toks)
        t_lgs, t_slots = self._tops.extend_tree(target_params, toks, t_slots,
                                                mask, depths)

        n_acc, em, path = jax.vmap(
            functools.partial(tree_accept, plan=plan,
                              temperature=self.temperature)
        )(jax.random.split(rng, G), t_lgs, q_logits, toks)
        next_tok = jnp.take_along_axis(em, n_acc[:, None], axis=1)[:, 0]

        # ---- commit the accepted root path.  Both caches hold every tree
        # node's K/V at row snap + node with RoPE position snap + depth
        # (the draft wrote them level by level, the verify in one pass), so
        # both commits are row PERMUTES — gather the accepted path down to
        # the contiguous prefix — with zero extra forward passes.
        counts = jnp.where(active, n_acc + 1, 0).astype(jnp.int32)
        d_slots = self._dops.commit_permute(d_slots, d_snap, path, counts)
        t_slots = self._tops.commit_permute(t_slots, t_snap, path, counts)
        last = runtime.scatter_wave(
            jnp.where(active[:, None, None], next_tok[:, None, None], last))
        return d_slots, t_slots, last, em[:, :D], n_acc, next_tok

    def _self_round_impl(self, params, slots, last, active, rng):
        """One self-speculative round: the model's first ``exit_layer``
        blocks + shared head draft a gamma-chain into the SHARED cache
        (shallow K/V at the draft positions, ``pos`` advanced manually),
        then the full depth verifies from the snapshot — overwriting every
        layer's K/V at those positions — and the commit is the usual
        ``pos`` write.  One cache, one params pytree."""
        gamma = self.gamma
        G = last.shape[0]
        snap = self._tops.snapshot(slots)
        r_draft, r_ver = jax.random.split(rng)

        def body(carry, r):
            caches, tok = carry
            lg, caches = self._vpartial(params, tok, caches)  # tok (G,1,1)
            lg = lg[:, 0, 0]                                 # (G, V)
            caches = {**caches, "pos": caches["pos"] + 1}
            if self.temperature == 0.0:
                nxt = jnp.argmax(lg, -1).astype(jnp.int32)
            else:
                nxt = jax.random.categorical(
                    r, lg / self.temperature, axis=-1).astype(jnp.int32)
            return (caches, nxt[:, None, None]), (nxt, lg)

        (slots, _), (toks, lgs) = jax.lax.scan(
            body, (slots, last), jax.random.split(r_draft, gamma))
        draft_toks = toks.T                                  # (G, gamma)
        draft_lgs = jnp.moveaxis(lgs, 0, 1)                  # (G, gamma, V)

        ver_in = jnp.concatenate([last[:, :, 0], draft_toks], axis=1)
        ver_in, draft_toks = runtime.gather_wave(ver_in, draft_toks)
        slots = self._tops.reset(slots, snap)
        t_logits, slots = self._tops.extend(params, ver_in, slots)

        n_acc, next_tok = jax.vmap(
            functools.partial(speculative_sample,
                              temperature=self.temperature)
        )(jax.random.split(r_ver, G), t_logits, draft_lgs, draft_toks)

        counts = jnp.where(active, n_acc + 1, 0).astype(jnp.int32)
        slots = self._tops.commit(params, slots, snap, ver_in, counts)
        last = runtime.scatter_wave(
            jnp.where(active[:, None, None], next_tok[:, None, None], last))
        return slots, last, draft_toks, n_acc, next_tok

    @hot_path
    def generate_group(self, draft_params, target_params, d_slots, t_slots,
                       last, max_news, rng=None):
        """Decode a prefilled group until every member has its tokens.

        max_news: per-slot budget (0 for padding slots).  Returns
        (token lists, per-member stats dicts with rounds/accepted).
        ``mode="linear"`` and ``mode="tree"`` share this loop — a tree
        round's tape is its emitted-path tokens, so the per-round emission
        is ``tape[i, :n_acc] + [next_tok]`` in both.
        """
        assert self.mode in ("linear", "tree"), \
            "self mode decodes one shared state: use generate_group_self"
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        G = last.shape[0]
        remaining = np.array(max_news, np.int64)    # host list, not a sync
        out: List[List[int]] = [[] for _ in range(G)]
        member_stats = [{"rounds": 0, "accepted": []} for _ in range(G)]

        while (remaining > 0).any():
            active = jnp.asarray(remaining > 0)
            rng, r = jax.random.split(rng)
            d_slots, t_slots, last, draft_toks, n_acc, next_tok = self._round(
                draft_params, target_params, d_slots, t_slots, last, active, r)
            self._collect(remaining, draft_toks, n_acc, next_tok, out,
                          member_stats)
        return out, member_stats

    @hot_path
    def generate_group_self(self, params, slots, last, max_news, rng=None):
        """Self-speculative twin of ``generate_group``: ONE model, ONE
        stacked dense cache (shallow draft + full-depth verify share it)."""
        assert self.mode == "self"
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        G = last.shape[0]
        remaining = np.array(max_news, np.int64)    # host list, not a sync
        out: List[List[int]] = [[] for _ in range(G)]
        member_stats = [{"rounds": 0, "accepted": []} for _ in range(G)]

        while (remaining > 0).any():
            active = jnp.asarray(remaining > 0)
            rng, r = jax.random.split(rng)
            slots, last, draft_toks, n_acc, next_tok = self._round(
                params, slots, last, active, r)
            self._collect(remaining, draft_toks, n_acc, next_tok, out,
                          member_stats)
        return out, member_stats

    @hot_path
    def _collect(self, remaining, draft_toks, n_acc, next_tok, out,
                 member_stats):
        """Host half of a round: slice each active member's emission off
        the padded tape and accumulate the lane counters — fed by ONE
        batched pull of the round's device outputs (rule R1)."""
        dt, na, nt = jax.device_get((draft_toks, n_acc, next_tok))  # repro-lint: ok(R1, the single batched per-round device pull)
        per_draft, per_verify = self._per_round
        for i in range(len(out)):
            if remaining[i] <= 0:
                continue
            emitted = [int(t) for t in dt[i, :int(na[i])]] + [int(nt[i])]
            take = min(len(emitted), int(remaining[i]))
            out[i].extend(emitted[:take])
            remaining[i] -= take
            member_stats[i]["rounds"] += 1
            member_stats[i]["accepted"].append(int(na[i]))
            c = self.counters
            c["member_rounds"] += 1
            c["draft_tokens"] += per_draft
            c["verify_tokens"] += per_verify
            c["accepted_tokens"] += int(na[i])
            c["emitted_tokens"] += take


def autoregressive_baseline(model, params, prompt, max_new: int, rng=None,
                            temperature: float = 1.0):
    """Plain target-only decoding — the survey's cloud-only baseline."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    prompt = jnp.atleast_2d(jnp.asarray(prompt, jnp.int32))
    max_seq = prompt.shape[1] + max_new + 4
    step = jax.jit(lambda p, t, c: model.decode_step(p, t, c))
    _, cache = model.prefill(params, {"tokens": prompt[:, :-1]}, max_seq=max_seq)
    tok = prompt[:, -1:]
    out = []
    for _ in range(max_new):
        lg, cache = step(params, tok, cache)
        if temperature == 0.0:
            nxt = jnp.argmax(lg, -1).astype(jnp.int32)
        else:
            rng, rr = jax.random.split(rng)
            nxt = jax.random.categorical(rr, lg / temperature, -1).astype(jnp.int32)
        out.append(int(nxt[0]))
        tok = nxt[:, None]
    return out
