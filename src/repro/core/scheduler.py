"""Batched continuous-batching serving scheduler (survey §2.3 at throughput).

The original ``CollaborativeEngine`` serves one request at a time with a
host round-trip per decoded token — fine for tracing the taxonomy, hopeless
for the ROADMAP's "heavy traffic" north star.  ``BatchedEngine`` keeps the
same per-request semantics (cache -> edge -> escalation, identical greedy
tokens) but executes them slot-based and batched:

  * SLOTS — ``batch_size`` slots, each holding one in-flight request.  All
    per-slot device state is a stacked pytree with a leading slot axis; the
    KV cache is padded to a common ``slot_len`` and each slot carries its
    own scalar ``pos`` (vmapped ``decode_step`` turns the cache update into
    a per-slot scatter).
  * PREFILL on admission: the exact-length prompt is prefilled once
    (jit-cached per prompt length) and the resulting padded cache is
    written into the slot wholesale — which also wipes whatever a retired
    occupant left behind.
  * DECODE — one jitted ``lax.scan`` of up to ``tick_tokens`` steps over
    the whole batch, with per-slot uncertainty accumulated ON DEVICE
    (``uncertainty.get_batched_estimator``).  One host sync per tick, not
    per token.  Slots that run out of budget mid-tick keep decoding
    garbage behind an ``active`` mask; their emissions are dropped and the
    slot cache is overwritten on the next admission.
  * RETIRE / ADMIT each tick: finished slots are classified by mean
    uncertainty (edge-confident vs escalate) and freed; queued requests are
    admitted into the freed slots.
  * ESCALATION runs GROUPED: all slots retired-uncertain in a tick share
    one batched cloud decode ("cloud"), one batched skeleton + batched edge
    completion ("skeleton"), or one ``BatchedSpecDecoder`` group
    ("speculative").  Groups are padded to ``batch_size`` so every jitted
    shape is compiled once.

Remaining gaps (see ROADMAP "Serving architecture"): the per-slot cache is
padded, not paged — long-prompt slots reserve ``slot_len`` everywhere —
and scheduling is single-host/single-device.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import SemanticCache, embed_tokens_mean
from repro.core.speculative import BatchedSpecDecoder, SpecDecoder
from repro.core.uncertainty import get_batched_estimator


@dataclasses.dataclass
class RequestTrace:
    path: str                       # cache | edge | speculative | cloud | skeleton
    edge_calls: int = 0
    cloud_passes: int = 0
    uncertainty: float = 0.0
    tokens: Optional[List[int]] = None


# ---------------------------------------------------------------- slot utils
def stack_slot_caches(model, batch: int, slot_len: int):
    """Zero-initialized stacked per-slot caches: each leaf of the model's
    single-sequence cache gains a leading slot axis."""
    one = model.init_cache(1, slot_len)
    return jax.tree.map(lambda x: jnp.zeros((batch,) + x.shape, x.dtype), one)


def write_slots(slots, bs: List[int], caches: List):
    """Overwrite slots ``bs`` with freshly prefilled single-sequence caches
    in ONE scatter per leaf (k separate ``.at[b].set`` writes would copy the
    whole stacked cache k times).  Also wipes any garbage a retired occupant
    decoded past its budget."""
    idx = jnp.asarray(bs, jnp.int32)
    return jax.tree.map(
        lambda big, *smalls: big.at[idx].set(jnp.stack(smalls)),
        slots, *caches)


def write_slot(slots, b: int, cache):
    """Single-slot convenience wrapper over ``write_slots``."""
    return write_slots(slots, [b], [cache])


def _pow2_steps(n: int, cap: int) -> int:
    """Round a residual step count up to a power of two (capped): the decode
    scan is jit-compiled per static ``n_steps``, so bucketing keeps the
    compile set at O(log cap) while the active mask absorbs the overshoot."""
    p = 1
    while p < n:
        p *= 2
    return min(p, cap)


class _Lane:
    """Jitted batched machinery for ONE model: a vmapped decode step, a
    per-prompt-length prefill, and the multi-token decode scan."""

    def __init__(self, model, estimator: str, temperature: float):
        self.model = model
        est = get_batched_estimator(estimator)
        vstep = jax.vmap(lambda p, t, c: model.decode_step(p, t, c),
                         in_axes=(None, 0, 0))
        self._jit_prefill = jax.jit(
            lambda p, toks, max_seq: model.prefill(
                p, {"tokens": toks}, max_seq=max_seq),
            static_argnames=("max_seq",))

        def chunk(params, caches, tok, steps_left, unc_sum, rng,
                  n_steps: int):
            """n_steps decode steps over all slots in one scan.  Returns the
            advanced state plus per-step (token, active) for the host."""
            def body(carry, r):
                caches, tok, steps_left, unc_sum = carry
                lg, caches = vstep(params, tok, caches)      # (B, 1, V)
                lg = lg.reshape(lg.shape[0], -1)
                active = steps_left > 0
                if temperature == 0.0:
                    nxt = jnp.argmax(lg, -1).astype(jnp.int32)
                else:
                    nxt = jax.random.categorical(
                        r, lg / temperature, axis=-1).astype(jnp.int32)
                unc_sum = unc_sum + jnp.where(active, est(lg), 0.0)
                steps_left = steps_left - active.astype(jnp.int32)
                return (caches, nxt[:, None, None], steps_left, unc_sum), \
                    (nxt, active)

            (caches, tok, steps_left, unc_sum), (toks, actives) = \
                jax.lax.scan(body, (caches, tok, steps_left, unc_sum),
                             jax.random.split(rng, n_steps))
            return caches, tok, steps_left, unc_sum, toks, actives

        self._chunk = jax.jit(chunk, static_argnames=("n_steps",))

    def prefill(self, params, prompt, slot_len: int):
        """Prefill ``prompt[:-1]`` into a fresh cache padded to slot_len.
        Recompiles per distinct prompt length; the jit cache makes repeats
        free."""
        toks = jnp.asarray(np.asarray(prompt, np.int32)[None, :-1])
        return self._jit_prefill(params, toks, max_seq=slot_len)


# ---------------------------------------------------------------- requests
@dataclasses.dataclass
class _Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    key: Optional[np.ndarray] = None    # semantic-cache key (set at admit)


@dataclasses.dataclass
class _Slot:
    req: Optional[_Request] = None
    tokens: List[int] = dataclasses.field(default_factory=list)


class BatchedEngine:
    """Slot-based collaborative serving engine (see module docstring).

    Mirrors ``CollaborativeEngine``'s decision semantics exactly — same
    estimator, threshold, escalation modes, semantic cache — so greedy
    traces match the per-request engine token for token.
    """

    def __init__(self, edge_model, cloud_model, *, batch_size: int = 8,
                 gamma: int = 4, temperature: float = 0.0,
                 escalate_threshold: float = 0.6, estimator: str = "entropy",
                 escalation: str = "speculative", use_cache: bool = True,
                 cache_threshold: float = 0.95, skeleton_len: int = 8,
                 tick_tokens: int = 16, seed: int = 0):
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        if tick_tokens < 1:
            raise ValueError(f"tick_tokens must be >= 1, got {tick_tokens}")
        if escalation not in ("speculative", "cloud", "skeleton"):
            raise ValueError(f"unknown escalation mode {escalation!r}; "
                             "known: speculative | cloud | skeleton")
        self.edge_model = edge_model
        self.cloud_model = cloud_model
        self.batch_size = batch_size
        self.gamma = gamma
        self.temperature = temperature
        self.threshold = escalate_threshold
        self.escalation = escalation
        self.skeleton_len = skeleton_len
        self.tick_tokens = tick_tokens
        self.seed = seed
        self.edge = _Lane(edge_model, estimator, temperature)
        self.cloud = _Lane(cloud_model, estimator, temperature)
        self.cache = SemanticCache(threshold=cache_threshold) if use_cache \
            else None
        if edge_model.rewindable_cache and cloud_model.rewindable_cache:
            self.spec: Optional[BatchedSpecDecoder] = BatchedSpecDecoder(
                edge_model, cloud_model, gamma=gamma, temperature=temperature)
            self._spec_fallback = None
        else:       # recurrent-state caches: per-request snapshot/replay
            self.spec = None
            self._spec_fallback = SpecDecoder(edge_model, cloud_model,
                                              gamma=gamma,
                                              temperature=temperature)
        self._queue: collections.deque = collections.deque()
        self._next_rid = 0

    # ------------------------------------------------------------ submit
    def submit(self, prompt, max_new: int) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        assert prompt.size >= 2, "scheduler needs >= 2 prompt tokens"
        assert max_new >= 1
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(_Request(rid, prompt, max_new))
        return rid

    # ------------------------------------------------------------ run
    def run(self, edge_params, cloud_params) -> Dict[int, RequestTrace]:
        """Drain the queue; returns {rid: RequestTrace} for this drain."""
        if not self._queue:
            return {}
        B = self.batch_size
        # slot capacity: prompt + generation + speculative overdraft margin
        # (matches SpecDecoder's max_seq so escalation reuses the same pads)
        self._slot_len = max(r.prompt.size + r.max_new for r in self._queue) \
            + 2 * max(self.gamma, 16) + 8
        slots_cache = stack_slot_caches(self.edge_model, B, self._slot_len)
        tok = jnp.zeros((B, 1, 1), jnp.int32)
        steps = jnp.zeros((B,), jnp.int32)
        unc = jnp.zeros((B,), jnp.float32)
        slots = [_Slot() for _ in range(B)]
        rng = jax.random.PRNGKey(self.seed)
        results: Dict[int, RequestTrace] = {}

        while self._queue or any(s.req is not None for s in slots):
            # ---- admit queued requests into free slots (batched cache probe)
            free = [b for b in range(B) if slots[b].req is None]
            if free and self._queue:
                cands = [self._queue.popleft()
                         for _ in range(min(len(free), len(self._queue)))]
                hits: List[Optional[Any]] = [None] * len(cands)
                if self.cache is not None:
                    for r in cands:
                        r.key = embed_tokens_mean(self.edge_model,
                                                  edge_params, r.prompt)
                    hits = self.cache.lookup_batch(
                        np.stack([r.key for r in cands]))
                bs, caches = [], []
                for r, hit in zip(cands, hits):
                    if hit is not None:
                        results[r.rid] = RequestTrace("cache",
                                                      tokens=list(hit))
                        continue
                    b = free.pop(0)
                    _, c1 = self.edge.prefill(edge_params, r.prompt,
                                              self._slot_len)
                    bs.append(b)
                    caches.append(c1)
                    slots[b] = _Slot(req=r)
                if bs:      # one scatter for the whole admission wave
                    slots_cache = write_slots(slots_cache, bs, caches)
                    idx = jnp.asarray(bs, jnp.int32)
                    lasts = jnp.asarray(
                        [[[int(slots[b].req.prompt[-1])]] for b in bs],
                        jnp.int32)
                    tok = tok.at[idx].set(lasts)
                    steps = steps.at[idx].set(jnp.asarray(
                        [slots[b].req.max_new for b in bs], jnp.int32))
                    unc = unc.at[idx].set(0.0)

            occupied = [b for b in range(B) if slots[b].req is not None]
            if not occupied:
                continue            # this round was all cache hits

            # ---- one batched decode tick (pow2-bucketed step count: the
            # scan recompiles per static n_steps, so bucketing bounds the
            # compile set; overshoot decodes masked garbage)
            steps_h = np.asarray(steps)
            n = _pow2_steps(int(min(self.tick_tokens,
                                    steps_h[occupied].max())),
                            self.tick_tokens)
            rng, r = jax.random.split(rng)
            slots_cache, tok, steps, unc, toks, actives = self.edge._chunk(
                edge_params, slots_cache, tok, steps, unc, r, n_steps=n)
            toks_h, act_h = np.asarray(toks), np.asarray(actives)
            for b in occupied:
                slots[b].tokens.extend(
                    int(t) for t, a in zip(toks_h[:, b], act_h[:, b]) if a)

            # ---- retire finished slots; group the uncertain ones
            steps_h, unc_h = np.asarray(steps), np.asarray(unc)
            group: List[Tuple[_Request, float]] = []
            for b in occupied:
                if steps_h[b] > 0:
                    continue
                req = slots[b].req
                u = float(unc_h[b]) / req.max_new
                if u <= self.threshold:
                    self._finish(results, req, RequestTrace(
                        "edge", edge_calls=req.max_new, uncertainty=u,
                        tokens=slots[b].tokens[:req.max_new]))
                else:
                    # edge tokens are discarded — escalation regenerates
                    # with cloud involvement (same as the reference engine)
                    group.append((req, u))
                slots[b] = _Slot()

            if group:
                rng, r = jax.random.split(rng)
                for req, tr in self._escalate(edge_params, cloud_params,
                                              group, r):
                    self._finish(results, req, tr)

        return results

    def serve_batch(self, edge_params, cloud_params, prompts,
                    max_new) -> List[RequestTrace]:
        """Convenience: submit ``prompts``, drain, return traces in order.
        ``max_new`` may be an int or a per-request sequence."""
        if isinstance(max_new, int):
            max_new = [max_new] * len(prompts)
        if len(max_new) != len(prompts):
            raise ValueError(f"{len(prompts)} prompts but {len(max_new)} "
                             "max_new budgets")
        rids = [self.submit(p, m) for p, m in zip(prompts, max_new)]
        results = self.run(edge_params, cloud_params)
        return [results[rid] for rid in rids]

    # ------------------------------------------------------------ internals
    def _finish(self, results, req: _Request, tr: RequestTrace):
        if self.cache is not None and tr.tokens is not None \
                and req.key is not None:
            self.cache.insert(req.key, tr.tokens)
        results[req.rid] = tr

    def _group_generate(self, lane: _Lane, params, prompts,
                        max_news: List[int], rng) -> List[List[int]]:
        """Batched greedy/sampled generation for an escalation group: per-
        request prefill, then ONE decode scan over the padded group."""
        if max(max_news) == 0:
            return [[] for _ in prompts]
        n = _pow2_steps(max(max_news), 1 << 30)     # bound scan compiles
        G = self.batch_size                         # pad: stable jit shapes
        caches = stack_slot_caches(lane.model, G, self._slot_len)
        tok = jnp.zeros((G, 1, 1), jnp.int32)
        steps = jnp.zeros((G,), jnp.int32)
        bs, c1s = [], []
        for i, (p, m) in enumerate(zip(prompts, max_news)):
            if m <= 0:
                continue
            _, c1 = lane.prefill(params, p, self._slot_len)
            bs.append(i)
            c1s.append(c1)
            tok = tok.at[i, 0, 0].set(int(p[-1]))
            steps = steps.at[i].set(m)
        caches = write_slots(caches, bs, c1s)
        _, _, _, _, toks, actives = lane._chunk(
            params, caches, tok, steps, jnp.zeros((G,), jnp.float32), rng,
            n_steps=n)
        toks_h, act_h = np.asarray(toks), np.asarray(actives)
        return [[int(t) for t, a in zip(toks_h[:, i], act_h[:, i]) if a]
                for i in range(len(prompts))]

    def _escalate(self, edge_params, cloud_params, group, rng):
        """Batched escalation of the slots retired-uncertain this tick.
        group: list of (request, mean uncertainty)."""
        reqs = [g[0] for g in group]
        uncs = [g[1] for g in group]
        out: List[Tuple[_Request, RequestTrace]] = []

        if self.escalation == "cloud":
            toks = self._group_generate(self.cloud, cloud_params,
                                        [r.prompt for r in reqs],
                                        [r.max_new for r in reqs], rng)
            for r, u, t in zip(reqs, uncs, toks):
                out.append((r, RequestTrace(
                    "cloud", edge_calls=r.max_new, cloud_passes=r.max_new,
                    uncertainty=u, tokens=t)))

        elif self.escalation == "skeleton":
            r1, r2 = jax.random.split(rng)
            ks = [min(self.skeleton_len, r.max_new) for r in reqs]
            skels = self._group_generate(self.cloud, cloud_params,
                                         [r.prompt for r in reqs], ks, r1)
            exts = [np.concatenate([r.prompt, np.asarray(s, np.int32)])
                    for r, s in zip(reqs, skels)]
            rests = self._group_generate(
                self.edge, edge_params, exts,
                [r.max_new - k for r, k in zip(reqs, ks)], r2)
            for r, u, k, s, rest in zip(reqs, uncs, ks, skels, rests):
                out.append((r, RequestTrace(
                    "skeleton", edge_calls=r.max_new + (r.max_new - k),
                    cloud_passes=k, uncertainty=u, tokens=s + rest)))

        else:   # speculative
            if self.spec is not None:
                out.extend(self._spec_escalate(edge_params, cloud_params,
                                               reqs, uncs, rng))
            else:   # recurrent caches: per-request snapshot/replay path
                for r, u in zip(reqs, uncs):
                    toks, st = self._spec_fallback.generate(
                        edge_params, cloud_params, r.prompt, r.max_new)
                    out.append((r, RequestTrace(
                        "speculative",
                        edge_calls=r.max_new + st.draft_calls,
                        cloud_passes=st.target_passes + st.replay_passes,
                        uncertainty=u, tokens=toks)))
        return out

    def _spec_escalate(self, edge_params, cloud_params, reqs, uncs, rng):
        """One BatchedSpecDecoder group over all escalated requests."""
        G = self.batch_size
        d_slots = stack_slot_caches(self.edge_model, G, self._slot_len)
        t_slots = stack_slot_caches(self.cloud_model, G, self._slot_len)
        last = jnp.zeros((G, 1, 1), jnp.int32)
        dcs, tcs = [], []
        for i, r in enumerate(reqs):
            dcs.append(self.edge.prefill(edge_params, r.prompt,
                                         self._slot_len)[1])
            tcs.append(self.cloud.prefill(cloud_params, r.prompt,
                                          self._slot_len)[1])
            last = last.at[i, 0, 0].set(int(r.prompt[-1]))
        d_slots = write_slots(d_slots, list(range(len(reqs))), dcs)
        t_slots = write_slots(t_slots, list(range(len(reqs))), tcs)
        max_news = [r.max_new for r in reqs] + [0] * (G - len(reqs))
        outs, stats = self.spec.generate_group(
            edge_params, cloud_params, d_slots, t_slots, last, max_news, rng)
        res = []
        for i, (r, u) in enumerate(zip(reqs, uncs)):
            st = stats[i]
            res.append((r, RequestTrace(
                "speculative",
                edge_calls=r.max_new + st["rounds"] * (self.gamma + 1),
                cloud_passes=st["rounds"], uncertainty=u, tokens=outs[i])))
        return res

    # ------------------------------------------------------------ stats
    def stats(self) -> Dict[str, Any]:
        return {"cache_hit_rate": self.cache.hit_rate if self.cache else 0.0}
