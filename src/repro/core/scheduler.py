"""Batched continuous-batching serving scheduler (survey §2.3 at throughput).

The original ``CollaborativeEngine`` serves one request at a time with a
host round-trip per decoded token — fine for tracing the taxonomy, hopeless
for the ROADMAP's "heavy traffic" north star.  ``BatchedEngine`` keeps the
same per-request semantics (cache -> edge -> escalation, identical greedy
tokens) but executes them slot-based and batched:

  * SLOTS — ``batch_size`` slots, each holding one in-flight request.  All
    per-slot device state is a stacked pytree with a leading slot axis and
    a per-slot scalar ``pos``.
  * SEQUENCE STATE — every per-family cache layout lives behind the
    ``SequenceState`` adapter protocol in ``core/seq_state.py``: dense KV
    slabs (the parity oracle), the paged block pool + per-slot block tables
    (``kv_layout="paged"``, the default where both families allow), and
    fixed-size recurrent state (ssm / xlstm / hybrid).  The scheduler calls
    ``admit / flush / prepare_tick / retire`` and reads ``peak_bytes``; it
    never branches on layout or family itself.
  * PREFILL on admission is LENGTH-BUCKETED and, past a threshold,
    CHUNKED.  Short prompts prefill in one shot, their token count padded
    to a pow2 bucket (bit-exact on KV layouts — masked scores are exactly
    zero — and jit-cached per bucket, not per distinct length); prompts
    longer than ``prefill_chunk`` entries (default: ``tick_tokens``)
    prefill ONE chunk per tick into a detached cache
    (``Lane.start_prefill`` / ``advance_prefill``) interleaved with the
    batch's decode ticks, then land through ``SequenceState.finalize`` —
    a long prompt no longer stalls every in-flight slot behind a
    monolithic prefill.  Either way the finished cache reaches the slot
    in one batched scatter per wave.
  * OPEN-LOOP TRAFFIC + LATENCY (``core/traffic.py``): ``submit(at=...)``
    gives every request an arrival time; admission only considers arrived
    requests, and the engine's clock (virtual by default — deterministic
    modeled ms; ``WallClock`` for real time) advances with decode steps
    and prefill chunks.  Per-request lifecycle events (submit / admit /
    first-token / retire, swap + defer counts) are stamped tick-granular
    and rolled into p50/p99 TTFT/TPOT, SLO attainment and
    goodput-under-SLO in ``stats()``.
  * DECODE — one jitted ``lax.scan`` of up to ``tick_tokens`` steps over
    the whole batch, with per-slot uncertainty accumulated ON DEVICE
    (``uncertainty.get_batched_estimator``).  One host sync per tick, not
    per token.  Slots that run out of budget mid-tick keep decoding
    garbage behind an ``active`` mask; their emissions are dropped, and on
    the paged layout those masked writes land in the reserved TRAP block
    so freed blocks can be re-allocated immediately.
  * POLICY — every collaboration decision flows through the ``CollabPolicy``
    hooks (``core/policy.py``); the scheduler contains no escalation-mode
    branching of its own.  ``policy.assign(features)`` runs at admission
    (task assignment: an ``"edge"``-assigned request force-accepts its edge
    output, a ``"cloud"``-assigned one skips the edge entirely and is served
    by a grouped batched cloud generation, ``"collab"`` takes the edge-first
    path below); ``policy.decide(unc, steps, budget)`` runs once per
    retirement wave, vectorized, naming each retiring request's action
    (accept / cloud / skeleton / speculative — one wave can mix them);
    ``policy.feedback(action, quality, cost, features)`` fires per
    completion with the realized quality proxy and cloud-token cost,
    closing the loop for bandit/budget policies.
  * RETIRE / ADMIT each tick: finished slots are grouped by their decided
    action and freed; queued requests are admitted into the freed slots.  Identical prompts admitted in the same
    tick (or while a matching request is still in flight) are COALESCED:
    one leader decodes, the rest are served from its result through the
    semantic cache — restoring the sequential engine's behavior.  On the
    paged layout, admission also consults the ``PagedKV`` prefix-block
    index: requests sharing a block-aligned prompt prefix (twins included,
    whatever tick they arrive in) map the shared blocks physically, with
    copy-on-write at first divergence.
  * PREEMPTION-BY-SWAP (paged layout): when the block pool cannot back a
    waiting request, the scheduler swaps out a victim slot — its KV blocks
    staged to a host buffer (``PagedKV.swap_out``) — admits the waiter,
    and resumes the victim later (``swap_in``, bit-identical content, so
    resumed decodes emit the same tokens).  ANTI-STARVATION POLICY:
    admission stays strict-arrival-order (swapped victims, which predate
    everything queued, resume before new admissions); the victim is the
    occupied slot with the MOST remaining decode steps (tie: youngest
    rid), i.e. the one that would hold its reservation longest; slots
    admitted or resumed in the current wave are never victims (no
    same-tick thrash), nor are slots whose swap-in restore could not fit
    the pool; and a request too large for even an empty pool (its live
    shareable prefix counted) fails fast instead of preempting the whole
    batch.  Every preemption
    admits the head waiter, the queue is finite per drain, and a swapped
    victim re-enters at the head of admission order — so no request can
    starve and no permanent deferral exists (the old defer-forever path is
    gone).
  * ESCALATION runs GROUPED: all slots retired into the same action in a
    tick share one batched cloud decode ("cloud"), one batched skeleton +
    batched edge completion ("skeleton"), or one ``BatchedSpecDecoder``
    group ("speculative").  Groups are padded to ``batch_size`` so every jitted
    shape is compiled once.  Speculative rewind is a ``pos`` write on KV
    layouts and a batched accepted-prefix replay (``Model.replay_step``) on
    recurrent layouts — EVERY family pair, mixed ones included (e.g. mamba2
    draft -> granite verify), runs the same grouped batched escalation.

Serving invariants here are pinned mechanically by ``repro-lint``
(``scripts/repro_lint.py``): the tick loop and escalation groups are
``@hot_path`` — ONE batched ``jax.device_get`` per tick/wave is the
only host readback (rule R1, enforced at runtime by the transfer-guard
tier-1 test); steady-state ticks never retrace (rule R2 + the
``compile_stability`` bench arm); and the scheduler knows nothing about
concrete KV layouts or model families — zero ``isinstance``/attribute
probes against them (rule R4: layout queries go through the
``SequenceState`` protocol, e.g. ``owned_blocks``, and layout dispatch
through ``Lane``, e.g. ``dense_side``).

Remaining gaps (see ROADMAP "Serving architecture"): scheduling is
single-host/single-device.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import hot_path
from repro.core.cache import SemanticCache, embed_tokens_mean
from repro.core.policy import (ACTIONS, LANES, cloud_tokens, resolve_policy,
                               trace_quality)
from repro.core.seq_state import (Lane, layout_for,  # noqa: F401 (re-export)
                                  pow2_steps, resolve_kv_layout,
                                  stack_slot_caches, write_slot, write_slots)
from repro.core.speculative import BatchedSpecDecoder
from repro.core.traffic import VirtualClock, latency_rollup


@dataclasses.dataclass
class RequestTrace:
    path: str                       # cache | edge | speculative | cloud | skeleton
    edge_calls: int = 0
    cloud_passes: int = 0
    uncertainty: float = 0.0
    tokens: Optional[List[int]] = None
    # cloud top-k teacher logits for the emitted tokens, when the wave's
    # cloud pass already paid for them: (values, indices) arrays of shape
    # (len(tokens), k) — serve-time distillation supervision
    teacher_topk: Optional[Tuple[np.ndarray, np.ndarray]] = None


# ---------------------------------------------------------------- requests
@dataclasses.dataclass
class _Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    key: Optional[np.ndarray] = None    # semantic-cache key (set at admit)
    lane: Optional[str] = None          # policy.assign outcome (once per req)
    at: Optional[float] = None          # arrival time, clock ms (None = now)
    spent: int = 0                      # edge decode steps actually consumed
    domain: Optional[int] = None        # workload tag for adaptation slicing
    draft: Optional[List[int]] = None   # discarded edge draft (escalations)


@dataclasses.dataclass
class _Slot:
    req: Optional[_Request] = None
    tokens: List[int] = dataclasses.field(default_factory=list)


class BatchedEngine:
    """Slot-based collaborative serving engine (see module docstring).

    Collaboration decisions are delegated to ``policy`` (a
    ``core/policy.py::CollabPolicy``): task assignment at admission,
    per-wave escalation actions at retirement, completion feedback.  The
    default ``SpeculativePolicy(threshold=0.6)`` mirrors
    ``CollaborativeEngine``'s historical decision semantics exactly — same
    estimator, threshold, escalation grouping, semantic cache — so greedy
    traces match the per-request engine token for token, on every KV
    layout and model family.  The legacy ``escalation=`` /
    ``escalate_threshold=`` kwargs still work for one release
    (``DeprecationWarning``) and construct the matching policy.

    Policy feature dicts: ``assign`` sees ``{rid, prompt, prompt_len,
    max_new, queue_depth, free_slots, inflight, at_ms, now_ms, wait_ms,
    slo_ms}`` (prompt features + live load stats + REAL deadline state —
    ``wait_ms`` is how long the request has already queued against
    ``slo_ms``); ``feedback`` sees ``{rid, unc, steps, budget, lane,
    ttft_ms, e2e_ms, slo_ms, slo_met, prompt, tokens, draft,
    teacher_topk, domain}`` — ``steps``/``budget`` matching the aligned
    arrays ``decide`` saw for that request (``steps`` is what it actually
    consumed; a stop-token hit makes it < ``budget``), ``lane``
    distinguishing decided actions from lane-assigned completions that
    never reached ``decide``, the latency fields closing the loop for
    SLA/budget policies, and the supervision tape — the served
    ``tokens``, the discarded edge ``draft`` (escalations), the cloud
    ``teacher_topk`` logits when an adaptation loop requested them — all
    host-side already (they rode the wave's single batched device pull).

    Serving knobs: ``clock`` (a ``core/traffic.py`` clock; default
    ``VirtualClock()`` — deterministic modeled ms), ``slo_ms`` (TTFT SLO
    for goodput/attainment in ``stats()`` and the policy features),
    ``prefill_chunk`` (entries above which admission prefills chunked
    across ticks; None = ``tick_tokens``, 0 = always whole-prompt),
    ``stop_token`` (token id that ends a request's edge decode early;
    None = decode the full budget).

    KV layout knobs:
      * ``kv_layout``: "auto" (paged where both models' cache families
        support it, else dense), "paged", or "dense".  Recurrent-state
        families always keep dense (stacked) storage — their state has no
        sequence axis to page.
      * ``kv_block_size``: tokens per block (paged).
      * ``kv_blocks``: total pool blocks incl. the trap (paged).  Default
        sizes the pool to the dense worst case; give a smaller pool to cap
        KV memory — admission is deferred when it runs full.
    """

    def __init__(self, edge_model, cloud_model, *, batch_size: int = 8,
                 gamma: int = 4, temperature: float = 0.0,
                 escalate_threshold: Optional[float] = None,
                 estimator: str = "entropy",
                 escalation: Optional[str] = None, policy=None,
                 use_cache: bool = True,
                 cache_threshold: float = 0.95, skeleton_len: int = 8,
                 tick_tokens: int = 16, seed: int = 0,
                 kv_layout: str = "auto", kv_block_size: int = 32,
                 kv_blocks: Optional[int] = None, clock=None,
                 slo_ms: Optional[float] = None,
                 prefill_chunk: Optional[int] = None,
                 stop_token: Optional[int] = None,
                 spec_mode: Optional[str] = None,
                 spec_tree_width: Optional[int] = None,
                 spec_exit_layer: Optional[int] = None,
                 mesh=None, adaptation=None):
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        if tick_tokens < 1:
            raise ValueError(f"tick_tokens must be >= 1, got {tick_tokens}")
        if kv_block_size < 1:
            raise ValueError(f"kv_block_size must be >= 1, got "
                             f"{kv_block_size}")
        if prefill_chunk is not None and prefill_chunk < 0:
            raise ValueError(f"prefill_chunk must be >= 0 (0 = whole-prompt "
                             f"prefill), got {prefill_chunk}")
        self.policy = resolve_policy(policy, escalation, escalate_threshold)
        self.kv_layout = resolve_kv_layout(edge_model, cloud_model, kv_layout)
        self.kv_block_size = kv_block_size
        self.kv_blocks = kv_blocks
        self.edge_model = edge_model
        self.cloud_model = cloud_model
        self.batch_size = batch_size
        self.gamma = gamma
        self.temperature = temperature
        self.skeleton_len = skeleton_len
        self.tick_tokens = tick_tokens
        self.seed = seed
        self.clock = clock if clock is not None else VirtualClock()
        self.slo_ms = slo_ms
        self.stop_token = stop_token
        # prompts with more than this many ENTRIES prefill chunked across
        # ticks; None = auto (tick_tokens, so prefill work per tick is
        # bounded by decode work per tick); 0 = always whole-prompt
        self.prefill_chunk = tick_tokens if prefill_chunk is None \
            else prefill_chunk
        self._esc_fns = {"cloud": self._cloud_escalate,
                         "skeleton": self._skeleton_escalate,
                         "speculative": self._spec_escalate}
        # mesh serving: edge drafts are DATA-parallel (batch slots split
        # over the data axes, params replicated); the cloud verifier is
        # TENSOR-parallel over 'model' (params sharded by the launch/
        # sharding.py rules).  Escalation groups are replicated over the
        # data axes (gather_wave hands every data shard the full wave), so
        # the cloud lane never data-shards its pools.
        self.mesh = mesh
        if mesh is not None:
            dp = 1
            for a in mesh.axis_names:
                if a != "model":
                    dp *= mesh.shape[a]
            self._data_shards = dp if batch_size % dp == 0 else 1
        else:
            self._data_shards = 1
        self.edge = Lane(edge_model, estimator, temperature,
                         layout=layout_for(edge_model, self.kv_layout),
                         block_size=kv_block_size, mesh=mesh,
                         data_shards=self._data_shards)
        self.cloud = Lane(cloud_model, estimator, temperature,
                          layout=layout_for(cloud_model, self.kv_layout),
                          block_size=kv_block_size, mesh=mesh)
        self.cache = SemanticCache(threshold=cache_threshold) if use_cache \
            else None
        # online adaptation (core/adaptation.py AdaptationLoop or None):
        # completions feed its FeedbackStore from _finish, and the drain
        # loop offers it a hot-swap point between ticks.  adaptation=None
        # keeps every path byte-identical to the pre-adaptation engine.
        self.adaptation = adaptation
        if adaptation is not None:
            adaptation.bind(edge_model)
        # speculation lane: engine kwarg > policy attribute > linear.  A
        # model family the requested lane cannot serve falls back to the
        # linear tape; the EFFECTIVE mode is what stats()["spec_mode"]
        # reports, so callers can detect the downgrade.
        mode = spec_mode if spec_mode is not None \
            else getattr(self.policy, "spec_mode", None) or "linear"
        if mode not in ("linear", "tree", "self"):
            raise ValueError(f"unknown spec_mode {mode!r}; "
                             "known: linear | tree | self")
        width = spec_tree_width if spec_tree_width is not None \
            else getattr(self.policy, "spec_tree_width", None) or 2
        exit_layer = spec_exit_layer if spec_exit_layer is not None \
            else getattr(self.policy, "spec_exit_layer", None)
        if mode == "tree" and not BatchedSpecDecoder.tree_supported(
                edge_model, cloud_model):
            mode = "linear"
        if mode == "self" and not BatchedSpecDecoder.self_supported(
                edge_model):
            mode = "linear"
        self.spec_mode = mode
        if mode == "tree":
            from repro.core.tree_speculation import branching_for
            self.spec = BatchedSpecDecoder(
                edge_model, cloud_model, gamma=gamma,
                temperature=temperature, mode="tree",
                branching=branching_for(width, gamma))
        elif mode == "self":
            self.spec = BatchedSpecDecoder(
                edge_model, edge_model, gamma=gamma,
                temperature=temperature, mode="self",
                exit_layer=exit_layer)
        else:
            self.spec = BatchedSpecDecoder(edge_model, cloud_model,
                                           gamma=gamma,
                                           temperature=temperature,
                                           kv_layout=self.kv_layout)
        # tree/self SpecOps always run dense per-slot caches (block-masked
        # extends are a dense-layout feature), so their escalation groups
        # build DENSE side states even when the serving lanes are paged.
        # Linear groups keep using the serving lanes — byte-identical.
        # Lane.dense_side() owns the layout decision (rule R4: the
        # scheduler never compares `.layout`); it is identity on lanes
        # that are already dense.
        self._spec_edge = self.edge if mode == "linear" \
            else self.edge.dense_side()
        self._spec_cloud = self.cloud.dense_side() if mode == "tree" \
            else self.cloud
        self._queue: collections.deque = collections.deque()
        self._next_rid = 0
        # intra-batch dedup: in-flight leaders and their coalesced followers
        self._leaders: List[Tuple[np.ndarray, int]] = []
        self._followers: Dict[int, List[_Request]] = {}
        self._kv_stats: Dict[str, Any] = {}
        self._swapped: Dict[int, dict] = {}
        self._preempts = 0
        self._prefill_jobs: Dict[int, dict] = {}    # slot -> chunked job
        self._events: Dict[int, dict] = {}          # rid -> lifecycle stamps

    # ------------------------------------------------------------ submit
    def submit(self, prompt, max_new: int, at: Optional[float] = None,
               domain: Optional[int] = None) -> int:
        """Queue a request.  ``at`` is an OPEN-LOOP arrival time in clock
        milliseconds (``core/traffic.py`` generators produce them): the
        request is invisible to admission until the engine's clock reaches
        it.  ``at=None`` (closed-loop) means "already arrived".
        ``domain`` is an optional workload tag carried through to the
        adaptation feedback record (never affects serving)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        assert prompt.size >= 2, "scheduler needs >= 2 prompt tokens"
        assert max_new >= 1
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(_Request(rid, prompt, max_new, at=at,
                                    domain=domain))
        return rid

    def _note_group(self, *states):
        live = sum(s.peak_bytes for s in states)
        self._kv_stats["kv_group_peak_bytes"] = max(
            self._kv_stats.get("kv_group_peak_bytes", 0), live)

    # ------------------------------------------------------------ dedup
    def _match_leader(self, key: np.ndarray) -> Optional[int]:
        """rid of an in-flight request whose cache key matches ``key`` at
        the semantic-cache threshold (cosine), else None."""
        if not self._leaders:
            return None
        u = SemanticCache._norm(key)
        for lk, rid in self._leaders:
            if float(u @ lk) >= self.cache.threshold:
                return rid
        return None

    # ------------------------------------------------------------ run
    def run(self, edge_params, cloud_params) -> Dict[int, RequestTrace]:
        """Drain the queue; returns {rid: RequestTrace} for this drain.
        Open-loop: requests with a future ``at`` stay invisible until the
        engine's clock reaches them (idle gaps are jumped/slept over).

        With ``mesh=...`` the drain runs inside a ``runtime.mesh_context``:
        edge params are pinned replicated, cloud params tensor-parallel per
        ``launch/sharding.py``, and every jit traced during the drain picks
        up the mesh (activation constraints, ``gather_wave`` collectives).
        ``mesh=None`` takes the exact pre-mesh path — no context, no
        placement, no constraint ops in any trace."""
        if self.mesh is None:
            return self._run_impl(edge_params, cloud_params)
        from repro import runtime
        from repro.launch.sharding import (params_shardings,
                                           replicated_shardings)
        edge_params = jax.device_put(
            edge_params, replicated_shardings(edge_params, self.mesh))
        cloud_params = jax.device_put(
            cloud_params, params_shardings(cloud_params, self.mesh,
                                           self.cloud_model.cfg))
        with runtime.mesh_context(self.mesh):
            res = self._run_impl(edge_params, cloud_params)
        self._kv_stats["mesh_devices"] = self.mesh.size
        self._kv_stats["mesh_shape"] = {k: int(v)
                                        for k, v in self.mesh.shape.items()}
        return res

    @hot_path
    def _run_impl(self, edge_params, cloud_params) -> Dict[int, RequestTrace]:
        if not self._queue:
            return {}
        # adaptation persists ACROSS drains: pick up from the last
        # hot-swapped edge weights, not the caller's baseline
        if self.adaptation is not None:
            edge_params = self.adaptation.current(edge_params)
        clock = self.clock
        t0 = clock.now()
        for r in self._queue:
            if r.at is None:
                r.at = t0
        # strict ARRIVAL order (ties by rid) — closed-loop batches all
        # "arrive" at run start, so their submission order is unchanged
        self._queue = collections.deque(
            sorted(self._queue, key=lambda r: (r.at, r.rid)))
        B = self.batch_size
        # slot capacity: prompt + generation + speculative overdraft margin
        # (matches SpecDecoder's max_seq so escalation reuses the same
        # pads; a tree lane overdrafts a full padded tree per round)
        ovr = self.spec.plan.n_pad if self.spec_mode == "tree" else self.gamma
        self._slot_len = max(r.prompt.size + r.max_new for r in self._queue) \
            + 2 * max(ovr, 16) + 8
        self._kv_stats = {"kv_layout": self.kv_layout}
        state = self.edge.make_state(edge_params, B, self._slot_len,
                                     num_blocks=self.kv_blocks)
        tok = jnp.zeros((B, 1, 1), jnp.int32)
        steps = jnp.zeros((B,), jnp.int32)
        unc = jnp.zeros((B,), jnp.float32)
        # host mirrors of tok/steps/unc, exact between ticks: every device
        # update is either host-originated (admit/finalize/swap, mirrored
        # below) or covered by the ONE batched device_get after each tick —
        # so admission, victim picking and swap-out never touch the device
        # (rule R1: zero per-slot host syncs on the hot path)
        tok_h = np.zeros((B,), np.int32)
        steps_h = np.zeros((B,), np.int32)
        unc_h = np.zeros((B,), np.float32)
        slots = [_Slot() for _ in range(B)]
        rng = jax.random.PRNGKey(self.seed)
        results: Dict[int, RequestTrace] = {}
        self._leaders, self._followers = [], {}
        self._swapped: Dict[int, dict] = {}     # rid -> host swap handle
        self._preempts = 0
        self._prefill_jobs = {}                 # slot -> detached chunk job
        self._events = {r.rid: {"submit_ms": float(r.at),
                                "swaps": 0, "defers": 0}
                        for r in self._queue}
        stop = jnp.int32(-1 if self.stop_token is None else self.stop_token)

        while self._queue or self._swapped or any(s.req is not None
                                                  for s in slots):
            # ---- online-adaptation hot-swap point: BETWEEN ticks, the
            # loop offers the current edge weights for replacement.  The
            # swap is a pure pytree rebind — same treedef/shapes/dtypes —
            # so in-flight caches stay valid and no jitted function sees a
            # new cache key (steady_state_recompiles == 0 across a swap)
            if self.adaptation is not None:
                swapped_p = self.adaptation.maybe_update(edge_params)
                if swapped_p is not None:
                    edge_params = swapped_p
                    state.rebind(edge_params)
            free = [b for b in range(B) if slots[b].req is None]
            wave: set = set()       # slots admitted/resumed this wave
            stalled = False
            # ---- resume swapped-out victims first: every victim predates
            # everything still queued, so strict arrival order = swapped
            # before queue (see the anti-starvation policy above)
            while self._swapped and free:
                rid0 = min(self._swapped)
                b = free[0]
                if not state.swap_in(b, self._swapped[rid0]["kv"]):
                    stalled = True  # pool still tight; retry next tick
                    break
                h = self._swapped.pop(rid0)
                free.pop(0)
                wave.add(b)
                slots[b] = h["slot"]
                tok = tok.at[b, 0, 0].set(h["tok"])
                steps = steps.at[b].set(h["steps"])
                unc = unc.at[b].set(h["unc"])
                tok_h[b], steps_h[b], unc_h[b] = \
                    h["tok"], h["steps"], h["unc"]
            # ---- admit queued requests into free slots (batched cache
            # probe).  A stalled swap-in blocks NEW admissions entirely:
            # the victim predates every queued request, so letting
            # newcomers consume the blocks it is waiting for would break
            # strict arrival order (it resumes within a bounded number of
            # ticks as in-flight slots retire).
            # ---- admission probe: pop every ARRIVED request in a bounded
            # window (free slots + one batch) INDEPENDENTLY of free edge
            # slots.  Cache hits, coalesced followers and cloud-lane
            # requests are served without ever occupying a slot, so a full
            # edge batch no longer head-of-line-blocks them behind slot
            # availability; slot-needing requests that find none simply go
            # back to the queue head.  A stalled swap-in still blocks new
            # admissions entirely: the victim predates every queued
            # request, so letting newcomers consume the blocks it waits
            # for would break strict arrival order.
            deferred = False
            cloud_wave: List[_Request] = []
            now = clock.now()
            if self._queue and not stalled:
                cands: List[_Request] = []
                while self._queue and len(cands) < len(free) + B \
                        and self._queue[0].at <= now:
                    cands.append(self._queue.popleft())
                hits: List[Optional[Any]] = [None] * len(cands)
                if self.cache is not None and cands:
                    for r in cands:
                        if r.key is None:
                            r.key = embed_tokens_mean(self.edge_model,
                                                      edge_params, r.prompt)
                    hits = self.cache.lookup_batch(
                        np.stack([r.key for r in cands]))
                putback: List[_Request] = []
                pend_keys: List[np.ndarray] = []
                share = state.share_hints([r.prompt for r in cands])

                def stay(r):
                    # r stays queued; any matching request probed later
                    # this wave must stay BEHIND it (pend_keys), or the
                    # sequential cache/coalesce semantics would serve a
                    # younger twin ahead of its would-be leader
                    putback.append(r)
                    if r.key is not None:
                        pend_keys.append(SemanticCache._norm(r.key))

                bs, lasts, news = [], [], []
                for r, hit, sharable in zip(cands, hits, share):
                    if deferred:
                        putback.append(r)   # pool pressure aborts the wave
                        continue
                    if pend_keys and r.key is not None and any(
                            float(SemanticCache._norm(r.key) @ k)
                            >= self.cache.threshold for k in pend_keys):
                        stay(r)
                        continue
                    if hit is not None:
                        ev = self._events[r.rid]
                        ev["first_token_ms"] = ev["retire_ms"] = now
                        ev["path"], ev["tokens"] = "cache", len(hit)
                        results[r.rid] = RequestTrace("cache",
                                                      tokens=list(hit))
                        continue
                    if self.cache is not None:
                        # coalesce with an identical in-flight request: the
                        # sequential engine's later twin would hit the
                        # semantic cache the leader is about to warm
                        lid = self._match_leader(r.key)
                        if lid is not None:
                            self._followers.setdefault(lid, []).append(r)
                            self.cache.hits += 1
                            continue
                    # task assignment: the policy picks this request's lane
                    # from prompt features + live load + REAL deadline
                    # state — ONCE per request (a deferred request keeps
                    # its lane, so stateful policies never see phantom
                    # duplicates)
                    if r.lane is None:
                        r.lane = self.policy.assign({
                            "rid": r.rid, "prompt": r.prompt,
                            "prompt_len": int(r.prompt.size),
                            "max_new": int(r.max_new),
                            "queue_depth": len(self._queue),
                            "free_slots": len(free),
                            "inflight": sum(s.req is not None
                                            for s in slots),
                            "at_ms": float(r.at), "now_ms": now,
                            "wait_ms": now - float(r.at),
                            "slo_ms": self.slo_ms})
                        if r.lane not in LANES:
                            raise ValueError(
                                f"policy {self.policy.name!r} assigned "
                                f"unknown lane {r.lane!r}; known: "
                                f"{' | '.join(LANES)}")
                    if r.lane == "cloud":
                        # cloud-only: no edge slot needed — one grouped
                        # batched cloud generation below (grouped shapes
                        # pad to batch_size, so a wave takes at most B).
                        # Register as a leader so identical prompts later
                        # in this wave coalesce instead of paying a second
                        # cloud generation (resolved in _finish this wave)
                        if len(cloud_wave) < B:
                            if self.cache is not None:
                                self._leaders.append(
                                    (SemanticCache._norm(r.key), r.rid))
                            cloud_wave.append(r)
                        else:
                            stay(r)
                        continue
                    if not free:
                        stay(r)             # collab/edge: needs a slot
                        continue
                    b = free.pop(0)
                    need = r.prompt.size - 1 + r.max_new
                    # long prompts reserve now and prefill DETACHED, one
                    # chunk per tick, landing via finalize — never stalling
                    # the in-flight batch behind a monolithic prefill.
                    # Prompts the layout flags as sharable take the
                    # monolithic path: a chunked begin defers the prefix
                    # index registration until finalize, which would cost
                    # same-wave twins their block sharing
                    chunked = (0 < self.prefill_chunk < r.prompt.size - 1
                               and not sharable)
                    admit = state.begin if chunked else state.admit
                    ok = admit(b, r.prompt, need)
                    if not ok and not state.fits_empty(need):
                        # private footprint exceeds the whole pool: only
                        # live prefix sharing can admit this request, and
                        # preemption could evict the very blocks that
                        # sharing needs — defer instead of swapping, and
                        # fail fast once even sharing cannot cover it
                        if not state.fits_empty(need, r.prompt):
                            raise RuntimeError(
                                f"request {r.rid} needs more KV blocks "
                                "than the whole pool; raise kv_blocks")
                    else:
                        while not ok:
                            # pool full: preempt-by-swap — swap out the
                            # victim holding its reservation longest,
                            # retry until admitted or out of victims
                            v = self._pick_victim(state, slots, steps_h,
                                                  wave)
                            if v is None:
                                break
                            vreq = slots[v].req
                            # the victim's decode scalars come from the
                            # host mirrors — swap-out costs zero extra
                            # device syncs (the blocks themselves move
                            # via state.swap_out's one batched pull)
                            self._swapped[vreq.rid] = {
                                "kv": state.swap_out(v),
                                "slot": slots[v],
                                "tok": int(tok_h[v]),
                                "steps": int(steps_h[v]),
                                "unc": float(unc_h[v]),
                            }
                            self._events[vreq.rid]["swaps"] += 1
                            slots[v] = _Slot()
                            steps = steps.at[v].set(0)
                            steps_h[v] = 0
                            free.append(v)
                            self._preempts += 1
                            ok = admit(b, r.prompt, need)
                    if not ok:
                        # every preemptable victim is out and the pool is
                        # still too tight: defer this and the rest, keep
                        # arrival order (in-flight retirements will free
                        # blocks within a bounded number of ticks)
                        free.insert(0, b)
                        self._events[r.rid]["defers"] += 1
                        putback.append(r)
                        deferred = True
                        continue
                    slots[b] = _Slot(req=r)
                    wave.add(b)
                    self._events[r.rid]["admit_ms"] = now
                    if chunked:
                        self._prefill_jobs[b] = self.edge.start_prefill(
                            edge_params, r.prompt,
                            state.detached_len(r.prompt.size - 1),
                            self.prefill_chunk)
                    else:
                        clock.on_prefill(r.prompt.size - 1)
                        bs.append(b)
                        lasts.append([[int(r.prompt[-1])]])
                        news.append(r.max_new)
                    if self.cache is not None:
                        self._leaders.append((SemanticCache._norm(r.key),
                                              r.rid))
                for r in reversed(putback):
                    self._queue.appendleft(r)
                if bs:
                    idx = jnp.asarray(bs, jnp.int32)
                    tok = tok.at[idx].set(jnp.asarray(lasts, jnp.int32))
                    steps = steps.at[idx].set(jnp.asarray(news, jnp.int32))
                    unc = unc.at[idx].set(0.0)
                    tok_h[bs] = [l[0][0] for l in lasts]
                    steps_h[bs] = news
                    unc_h[bs] = 0.0

            if cloud_wave:
                # cloud-assigned lane: one grouped batched cloud generation
                # for the wave (task assignment at admission).  First-token
                # time is the generation's own first step, not the (later)
                # group completion
                rng, r_ = jax.random.split(rng)
                t_cw = clock.now()
                tk = self.adaptation.capture_topk \
                    if self.adaptation is not None else 0
                toks = self._group_generate(
                    self.cloud, cloud_params,
                    [q.prompt for q in cloud_wave],
                    [q.max_new for q in cloud_wave], r_, topk=tk)
                teach = [None] * len(cloud_wave)
                if tk:
                    toks, teach = toks
                for q, t, th in zip(cloud_wave, toks, teach):
                    self._finish(results, q, RequestTrace(
                        "cloud", cloud_passes=q.max_new, tokens=t,
                        teacher_topk=th),
                        t_first=t_cw + clock.step_ms)

            # ---- advance chunked prefills: one detached chunk per job per
            # tick, interleaved with the batch's decode; a finished job
            # lands its cache (finalize) and arms the slot for decode
            for b in list(self._prefill_jobs):
                job = self._prefill_jobs[b]
                before = job["done"]
                finished = self.edge.advance_prefill(edge_params, job)
                clock.on_prefill(job["done"] - before)
                if finished:
                    state.finalize(b, job["cache"])
                    del self._prefill_jobs[b]
                    r = slots[b].req
                    tok = tok.at[b, 0, 0].set(int(r.prompt[-1]))
                    steps = steps.at[b].set(r.max_new)
                    unc = unc.at[b].set(0.0)
                    tok_h[b] = int(r.prompt[-1])
                    steps_h[b] = r.max_new
                    unc_h[b] = 0.0

            occupied = [b for b in range(B) if slots[b].req is not None]
            if not occupied:
                if deferred:
                    raise RuntimeError(
                        "paged KV pool too small for the queued request "
                        "even with an empty batch; raise kv_blocks")
                if stalled:
                    rid0 = min(self._swapped)
                    raise RuntimeError(
                        f"paged KV pool cannot restore swapped-out request "
                        f"{rid0} even with an empty batch (its blocks + "
                        "outstanding reservation exceed the pool); raise "
                        "kv_blocks")
                if self._queue:
                    # nothing in flight and every queued arrival is in the
                    # future: jump/sleep the clock to the next arrival
                    clock.wait_until(float(self._queue[0].at))
                continue            # all cache hits / cloud completions
            state.flush()

            # ---- one batched decode tick (pow2-bucketed step count: the
            # scan recompiles per static n_steps, so bucketing bounds the
            # compile set; overshoot decodes masked garbage).  The live
            # step budget comes from the HOST MIRROR — no pre-tick sync
            # repro-lint: ok(R1, steps_h is the numpy host mirror - no device pull)
            live = int(steps_h[occupied].max())
            if live <= 0:
                continue            # every occupied slot is mid-prefill
            n = pow2_steps(min(self.tick_tokens, live), self.tick_tokens)
            state.prepare_tick(occupied, steps_h, n)
            rng, r = jax.random.split(rng)
            state.caches, tok, steps, unc, toks, actives = self.edge._chunk(
                edge_params, state.caches, tok, steps, unc, r, stop,
                n_steps=n)
            clock.on_steps(n)
            t_tick = clock.now()
            # THE host readback: one batched explicit pull per tick covers
            # retirement (steps/unc), the emitted streams (toks/actives)
            # and the carry mirror (tok == last scan emission)
            steps_d, unc_d, toks_h, act_h = jax.device_get(  # repro-lint: ok(R1, the single batched per-tick device pull)
                (steps, unc, toks, actives))
            steps_h = np.array(steps_d)     # device_get views are
            unc_h = np.array(unc_d)         # read-only; mirrors mutate
            tok_h = np.array(toks_h[-1])
            for b in occupied:
                new = [int(t) for t, a in zip(toks_h[:, b], act_h[:, b])
                       if a]
                if new and not slots[b].tokens:
                    # tick-granular first-token stamp (end of the emitting
                    # tick); escalated requests are re-stamped in _finish
                    self._events[slots[b].req.rid]["first_token_ms"] = t_tick
                slots[b].tokens.extend(new)

            # ---- retire finished slots; the policy names each one's action
            # (steps_h/unc_h are this tick's batched pull — already host)
            retiring: List[Tuple[_Request, float, List[int]]] = []
            for b in occupied:
                if steps_h[b] > 0 or b in self._prefill_jobs:
                    continue
                req = slots[b].req
                # steps actually spent: every ACTIVE emission appended one
                # token, and a stop-token hit zeroes the budget early — so
                # spent < max_new is a real state decide/feedback must see
                req.spent = min(len(slots[b].tokens), req.max_new)
                u = float(unc_h[b]) / max(req.spent, 1)
                retiring.append((req, u, slots[b].tokens[:req.spent]))
                slots[b] = _Slot()
                state.retire(b)

            if retiring:
                # one vectorized decide over the wave's collaborative
                # requests; edge-assigned ones force-accept their output.
                # steps = what each request actually consumed (early stop
                # makes it < budget); budget = its max_new grant — distinct
                # arrays, no aliasing
                actions = ["accept"] * len(retiring)
                decided = [i for i, (rq, _, _) in enumerate(retiring)
                           if rq.lane != "edge"]
                if decided:
                    acts = list(self.policy.decide(
                        np.array([retiring[i][1] for i in decided],
                                 np.float32),
                        np.array([retiring[i][0].spent
                                  for i in decided], np.int32),
                        np.array([retiring[i][0].max_new
                                  for i in decided], np.int32)))
                    if len(acts) != len(decided):
                        raise ValueError(
                            f"policy {self.policy.name!r} decided "
                            f"{len(acts)} actions for a wave of "
                            f"{len(decided)}")
                    for i, a in zip(decided, acts):
                        a = str(a)
                        if a not in ACTIONS:
                            raise ValueError(
                                f"policy {self.policy.name!r} decided "
                                f"unknown action {a!r}; known: "
                                f"{' | '.join(ACTIONS)}")
                        actions[i] = a
                groups: Dict[str, List[Tuple[_Request, float]]] = {}
                for (req, u, toks), a in zip(retiring, actions):
                    if a == "accept":
                        self._finish(results, req, RequestTrace(
                            "edge", edge_calls=req.spent, uncertainty=u,
                            tokens=toks))
                    else:
                        # edge tokens are discarded from the CLIENT stream
                        # — escalation regenerates with cloud involvement
                        # (same as the reference engine) — but kept on the
                        # request as the rejected draft: with the cloud's
                        # corrected continuation it completes the
                        # (prompt, draft, correction) supervision triple
                        req.draft = toks
                        groups.setdefault(a, []).append((req, u))
                # one batched group per decided action (a wave can mix).
                # The escalation's own first step is the client-visible
                # first token (the edge stream it replaces was discarded)
                for a, grp in groups.items():
                    rng, r = jax.random.split(rng)
                    t_esc = clock.now()
                    for req, tr in self._esc_fns[a](
                            edge_params, cloud_params,
                            [g[0] for g in grp], [g[1] for g in grp], r):
                        self._finish(results, req, tr,
                                     t_first=t_esc + clock.step_ms)

        self._kv_stats["kv_peak_bytes"] = state.peak_bytes
        self._kv_stats["kv_capacity_bytes"] = state.capacity_bytes
        self._kv_stats["preemptions"] = self._preempts
        self._kv_stats.update(state.stats())
        return results

    @hot_path
    def _pick_victim(self, state, slots, steps_h, wave) -> Optional[int]:
        """Preemption victim by a cost model: score each candidate by the
        decode steps its eviction frees (remaining budget — how long it
        would hold its block reservation) per block of KV it has staged
        (``steps / (1 + owned_blocks)`` — swap-out checkpoints those bytes
        to host and swap-in restores them, so a fat slot is an expensive
        victim even when it has far to go).  Layouts without a block pool
        report ``owned_blocks == 0`` (the ``SequenceState`` protocol
        query — rule R4 forbids probing pool internals here), so the
        score degrades to raw remaining steps — the historic most-steps
        ordering — and ties still break toward the youngest request.
        ``steps_h`` is the run loop's HOST mirror, so scoring costs no
        device sync (rule R1).  Slots admitted or resumed in the current
        wave are exempt — their staged device writes have not flushed
        yet, and exempting them prevents same-tick swap thrash.  Slots
        whose swap-in restore could never fit the pool (admitted over a
        prefix larger than their private footprint allows) are exempt too
        — swapping them would strand their completed work.  So are slots
        mid-chunked-prefill: their device blocks hold garbage until
        finalize, and swapping would checkpoint that garbage."""
        best = None
        for b, s in enumerate(slots):
            if s.req is None or b in wave or b in self._prefill_jobs \
                    or not state.swappable(b):
                continue
            key = (float(steps_h[b]) / (1.0 + state.owned_blocks(b)),
                   int(steps_h[b]), s.req.rid)
            if best is None or key > best[0]:
                best = (key, b)
        return None if best is None else best[1]

    def serve_batch(self, edge_params, cloud_params, prompts,
                    max_new, domains=None) -> List[RequestTrace]:
        """Convenience: submit ``prompts``, drain, return traces in order.
        ``max_new`` may be an int or a per-request sequence; ``domains``
        an optional per-request workload-tag sequence (adaptation)."""
        if isinstance(max_new, int):
            max_new = [max_new] * len(prompts)
        if len(max_new) != len(prompts):
            raise ValueError(f"{len(prompts)} prompts but {len(max_new)} "
                             "max_new budgets")
        if domains is None:
            domains = [None] * len(prompts)
        if len(domains) != len(prompts):
            raise ValueError(f"{len(prompts)} prompts but {len(domains)} "
                             "domain tags")
        rids = [self.submit(p, m, domain=d)
                for p, m, d in zip(prompts, max_new, domains)]
        results = self.run(edge_params, cloud_params)
        return [results[rid] for rid in rids]

    # ------------------------------------------------------------ internals
    def _finish(self, results, req: _Request, tr: RequestTrace, *,
                t_first: Optional[float] = None):
        """Complete ``req``: stamp lifecycle events, fire policy feedback,
        warm the cache, resolve followers.  ``t_first`` overrides the
        first-token stamp (escalations/cloud lanes — their client stream
        starts with the regeneration, not the discarded edge decode)."""
        now = self.clock.now()
        ev = self._events.setdefault(
            req.rid, {"submit_ms": now, "swaps": 0, "defers": 0})
        if t_first is not None:
            ev["first_token_ms"] = t_first
        elif "first_token_ms" not in ev:
            ev["first_token_ms"] = now
        ev["retire_ms"] = now
        ev["path"] = tr.path
        ev["tokens"] = len(tr.tokens) if tr.tokens else 0
        if tr.path != "cache":
            # completion feedback: realized quality proxy + cloud-token
            # cost close the loop for learning (bandit/budget) policies.
            # features carry the request's lane so policies can tell a
            # decided action from a lane-assigned completion (which never
            # went through decide), plus the realized deadline outcome so
            # SLA policies reconcile against REAL latencies, not proxies
            ttft = ev["first_token_ms"] - ev["submit_ms"]
            slo_met = self.slo_ms is None or ttft <= self.slo_ms
            # the corrected token tape (and teacher top-k, when the wave
            # already paid for the cloud pass) rides the feedback payload:
            # policies used to see only the scalar quality proxy while the
            # continuation itself was dropped on the floor.  Everything
            # here is already host-side — it came off the wave's single
            # batched device_get — so threading it costs zero extra syncs
            self.policy.feedback(
                "accept" if tr.path == "edge" else tr.path,
                trace_quality(tr, req.max_new),
                cloud_tokens(tr, self.gamma),
                {"rid": req.rid, "unc": tr.uncertainty,
                 "steps": req.spent if req.spent else req.max_new,
                 "budget": req.max_new, "lane": req.lane,
                 "ttft_ms": ttft, "e2e_ms": now - ev["submit_ms"],
                 "slo_ms": self.slo_ms, "slo_met": slo_met,
                 "prompt": req.prompt, "tokens": tr.tokens,
                 "draft": req.draft, "teacher_topk": tr.teacher_topk,
                 "domain": req.domain})
            if self.adaptation is not None and tr.tokens:
                self.adaptation.observe(
                    prompt=req.prompt, tokens=tr.tokens, draft=req.draft,
                    teacher_topk=tr.teacher_topk, domain=req.domain,
                    sla="none" if self.slo_ms is None
                    else ("met" if slo_met else "missed"),
                    path=tr.path)
        if self.cache is not None and tr.tokens is not None \
                and req.key is not None:
            self.cache.insert(req.key, tr.tokens)
        results[req.rid] = tr
        # resolve coalesced followers from the leader's result (the
        # sequential engine would serve them from the just-warmed cache)
        self._leaders = [(k, rid) for k, rid in self._leaders
                         if rid != req.rid]
        for f in self._followers.pop(req.rid, []):
            fev = self._events.setdefault(
                f.rid, {"submit_ms": now, "swaps": 0, "defers": 0})
            fev.setdefault("first_token_ms", now)
            fev["retire_ms"] = now
            fev["path"], fev["tokens"] = "cache", ev["tokens"]
            results[f.rid] = RequestTrace(
                "cache", tokens=list(tr.tokens) if tr.tokens else None)

    @hot_path
    def _group_generate(self, lane: Lane, params, prompts,
                        max_news: List[int], rng, topk: int = 0):
        """Batched greedy/sampled generation for an escalation group: per-
        request prefill, then ONE decode scan over the padded group.  The
        initial tok/steps state is host-built and uploaded once; the only
        readback is the single batched pull of the emitted tape (rule
        R1).  Returns the per-request token lists; with ``topk > 0`` the
        scan additionally emits top-k teacher logits and the return
        becomes ``(tokens, teachers)`` where ``teachers[i]`` is a
        ``(values, indices)`` pair trimmed to request ``i``'s emitted
        length — capture extends the SAME batched pull, never adds one."""
        if max(max_news) == 0:
            empty = [[] for _ in prompts]
            return (empty, [None] * len(prompts)) if topk else empty
        n = pow2_steps(max(max_news), 1 << 30)      # bound scan compiles
        G = self.batch_size                         # pad: stable jit shapes
        need = [len(p) - 1 + m for p, m in zip(prompts, max_news) if m > 0]
        state = lane.make_state(params, G, self._slot_len, need_tokens=need)
        tok_h = np.zeros((G, 1, 1), np.int32)
        steps_h = np.zeros((G,), np.int32)
        members = []
        for i, (p, m) in enumerate(zip(prompts, max_news)):
            if m <= 0:
                continue
            state.admit(i, p, len(p) - 1 + m)
            self.clock.on_prefill(len(p) - 1)
            members.append(i)
            tok_h[i, 0, 0] = int(p[-1])
            steps_h[i] = m
        state.flush()
        state.prepare_tick(members, steps_h, n)
        # escalation/cloud groups never stop early: their budgets come
        # from the retirement wave, so stop stays disarmed (-1)
        outs = lane._chunk(
            params, state.caches, jnp.asarray(tok_h), jnp.asarray(steps_h),
            jnp.zeros((G,), jnp.float32), rng, jnp.int32(-1), n_steps=n,
            topk=topk)
        self.clock.on_steps(n)
        self._note_group(state)
        if topk:
            toks, actives, tvals, tidx = outs[4:]
            toks_h, act_h, tv_h, ti_h = jax.device_get(  # repro-lint: ok(R1, the single batched per-group device pull)
                (toks, actives, tvals, tidx))
            tokens = [[int(t) for t, a in zip(toks_h[:, i], act_h[:, i])
                       if a] for i in range(len(prompts))]
            # emissions are a True-prefix of the scan (budgets only count
            # down), so request i's teacher rows are its first len(tokens)
            teachers = [(np.array(tv_h[:len(t), i]),
                         np.array(ti_h[:len(t), i]))
                        for i, t in enumerate(tokens)]
            return tokens, teachers
        toks, actives = outs[4:]
        toks_h, act_h = jax.device_get((toks, actives))  # repro-lint: ok(R1, the single batched per-group device pull)
        return [[int(t) for t, a in zip(toks_h[:, i], act_h[:, i]) if a]
                for i in range(len(prompts))]

    def _cloud_escalate(self, edge_params, cloud_params, reqs, uncs, rng):
        """Grouped full-cloud regeneration (task assignment).  When an
        adaptation loop is attached, the SAME cloud pass also emits top-k
        teacher logits (already paid for — the capture rides the group's
        one batched pull) so the rejected edge draft gets distillation
        supervision."""
        out: List[Tuple[_Request, RequestTrace]] = []
        tk = self.adaptation.capture_topk \
            if self.adaptation is not None else 0
        toks = self._group_generate(self.cloud, cloud_params,
                                    [r.prompt for r in reqs],
                                    [r.max_new for r in reqs], rng,
                                    topk=tk)
        teach = [None] * len(reqs)
        if tk:
            toks, teach = toks
        for r, u, t, th in zip(reqs, uncs, toks, teach):
            out.append((r, RequestTrace(
                "cloud", edge_calls=r.max_new, cloud_passes=r.max_new,
                uncertainty=u, tokens=t, teacher_topk=th)))
        return out

    def _skeleton_escalate(self, edge_params, cloud_params, reqs, uncs, rng):
        """Grouped skeleton division: one batched cloud skeleton pass plus
        one batched edge completion pass for the whole group."""
        out: List[Tuple[_Request, RequestTrace]] = []
        r1, r2 = jax.random.split(rng)
        ks = [min(self.skeleton_len, r.max_new) for r in reqs]
        skels = self._group_generate(self.cloud, cloud_params,
                                     [r.prompt for r in reqs], ks, r1)
        exts = [np.concatenate([r.prompt, np.asarray(s, np.int32)])
                for r, s in zip(reqs, skels)]
        rests = self._group_generate(
            self.edge, edge_params, exts,
            [r.max_new - k for r, k in zip(reqs, ks)], r2)
        for r, u, k, s, rest in zip(reqs, uncs, ks, skels, rests):
            out.append((r, RequestTrace(
                "skeleton", edge_calls=r.max_new + (r.max_new - k),
                cloud_passes=k, uncertainty=u, tokens=s + rest)))
        return out

    @hot_path
    def _spec_escalate(self, edge_params, cloud_params, reqs, uncs, rng):
        """One BatchedSpecDecoder group over all escalated requests.  Paged
        groups pre-grow each slot to prompt + budget + one round of draft
        overdraft — spec rewinds only move ``pos``, never reallocate.
        A tree lane overdrafts a full padded tree per round and runs on the
        dense side lanes; the self lane builds ONE edge-side state (draft
        and verify share cache and params — no cloud involvement, so its
        traces carry ``cloud_passes=0``)."""
        G = self.batch_size
        mode = self.spec_mode
        ovr = (self.spec.plan.n_pad if mode == "tree" else self.gamma) + 2
        need = [r.prompt.size - 1 + r.max_new + ovr for r in reqs]
        d_state = self._spec_edge.make_state(edge_params, G, self._slot_len,
                                             need_tokens=need)
        states = [d_state]
        if mode != "self":
            t_state = self._spec_cloud.make_state(
                cloud_params, G, self._slot_len, need_tokens=need)
            states.append(t_state)
        last_h = np.zeros((G, 1, 1), np.int32)
        for i, (r, nd) in enumerate(zip(reqs, need)):
            for st in states:
                st.admit(i, r.prompt, nd)
            last_h[i, 0, 0] = int(r.prompt[-1])
        last = jnp.asarray(last_h)
        overdraft = np.zeros((G,), np.int32)
        overdraft[:len(reqs)] = [n - (r.prompt.size - 1)
                                 for n, r in zip(need, reqs)]
        for st in states:
            st.flush()
            st.prepare_tick(list(range(len(reqs))), overdraft, 1 << 30)
        max_news = [r.max_new for r in reqs] + [0] * (G - len(reqs))
        for r in reqs:
            self.clock.on_prefill(r.prompt.size - 1)
        if mode == "self":
            outs, stats = self.spec.generate_group_self(
                edge_params, d_state.caches, last, max_news, rng)
        else:
            outs, stats = self.spec.generate_group(
                edge_params, cloud_params, d_state.caches, t_state.caches,
                last, max_news, rng)
        # modeled cost: the group runs the slowest member's rounds, each a
        # draft chunk (gamma steps, or the tree's depth levels) + one
        # verify + one commit step
        draft_steps = self.spec.plan.depth if mode == "tree" else self.gamma
        self.clock.on_steps(max(st["rounds"] for st in stats[:len(reqs)])
                            * (draft_steps + 2))
        self._note_group(*states)
        res = []
        for i, (r, u) in enumerate(zip(reqs, uncs)):
            st = stats[i]
            res.append((r, RequestTrace(
                "speculative",
                edge_calls=r.max_new + st["rounds"] * (draft_steps + 1),
                cloud_passes=0 if mode == "self" else st["rounds"],
                uncertainty=u, tokens=outs[i])))
        return res

    # ------------------------------------------------------------ stats
    @property
    def events(self) -> Dict[int, dict]:
        """Per-request lifecycle events of the last ``run`` (rid ->
        submit/admit/first-token/retire stamps in clock ms, swap + defer
        counts, path, token count)."""
        return self._events

    def stats(self) -> Dict[str, Any]:
        c = self.spec.counters
        return {"cache_hit_rate": self.cache.hit_rate if self.cache else 0.0,
                "policy": self.policy.name,
                "spec_mode": self.spec_mode,
                # acceptance over candidates DRAFTED; emitted per verify
                # pass (>1 is the whole point of the speculation lanes)
                "spec_accept_rate": c["accepted_tokens"] / c["draft_tokens"]
                if c["draft_tokens"] else 0.0,
                "accepted_tokens_per_step":
                c["emitted_tokens"] / c["member_rounds"]
                if c["member_rounds"] else 0.0,
                "spec_lanes": {self.spec_mode: dict(c)},
                **self.policy.stats(), **self._kv_stats,
                **({"adaptation": self.adaptation.stats()}
                   if self.adaptation is not None else {}),
                **latency_rollup(self._events, self.slo_ms)}
