"""Batched continuous-batching serving scheduler (survey §2.3 at throughput).

The original ``CollaborativeEngine`` serves one request at a time with a
host round-trip per decoded token — fine for tracing the taxonomy, hopeless
for the ROADMAP's "heavy traffic" north star.  ``BatchedEngine`` keeps the
same per-request semantics (cache -> edge -> escalation, identical greedy
tokens) but executes them slot-based and batched:

  * SLOTS — ``batch_size`` slots, each holding one in-flight request.  All
    per-slot device state is a stacked pytree with a leading slot axis and
    a per-slot scalar ``pos``.
  * KV LAYOUT — ``kv_layout="paged"`` (default where the families allow)
    backs the slots with ONE shared pool of fixed-size token blocks plus
    per-slot int32 block tables (``core/paged_cache.py``): blocks are
    allocated at admission, grown on demand each decode tick, and freed at
    retirement, so slot capacity follows each request instead of the batch
    maximum and admission is deferred (not over-reserved) when the pool is
    full.  ``kv_layout="dense"`` keeps the original common-``slot_len``
    padded slabs and serves as the parity oracle.
  * PREFILL on admission: the exact-length prompt is prefilled once
    (jit-cached per prompt length) and written into the slot — dense: one
    stacked-slab scatter per admission wave; paged: one block scatter per
    prompt plus a block-table row write.
  * DECODE — one jitted ``lax.scan`` of up to ``tick_tokens`` steps over
    the whole batch, with per-slot uncertainty accumulated ON DEVICE
    (``uncertainty.get_batched_estimator``).  One host sync per tick, not
    per token.  Slots that run out of budget mid-tick keep decoding
    garbage behind an ``active`` mask; their emissions are dropped, and on
    the paged layout those masked writes land in the reserved TRAP block
    so freed blocks can be re-allocated immediately.
  * RETIRE / ADMIT each tick: finished slots are classified by mean
    uncertainty (edge-confident vs escalate) and freed; queued requests are
    admitted into the freed slots.  Identical prompts admitted in the same
    tick (or while a matching request is still in flight) are COALESCED:
    one leader decodes, the rest are served from its result through the
    semantic cache — restoring the sequential engine's behavior.
  * ESCALATION runs GROUPED: all slots retired-uncertain in a tick share
    one batched cloud decode ("cloud"), one batched skeleton + batched edge
    completion ("skeleton"), or one ``BatchedSpecDecoder`` group
    ("speculative").  Groups are padded to ``batch_size`` so every jitted
    shape is compiled once; on the paged layout each group brings its own
    exactly-sized block pool and the speculative rewind is still a ``pos``
    write against the group's block tables.

Remaining gaps (see ROADMAP "Serving architecture"): scheduling is
single-host/single-device, and recurrent-family (ssm/hybrid) speculation
still falls back to per-request snapshot+replay.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import SemanticCache, embed_tokens_mean
from repro.core.paged_cache import (BlockPool, blocks_for,
                                    prompt_cache_to_blocks, write_pool_blocks)
from repro.core.speculative import BatchedSpecDecoder, SpecDecoder
from repro.core.uncertainty import get_batched_estimator


@dataclasses.dataclass
class RequestTrace:
    path: str                       # cache | edge | speculative | cloud | skeleton
    edge_calls: int = 0
    cloud_passes: int = 0
    uncertainty: float = 0.0
    tokens: Optional[List[int]] = None


# ---------------------------------------------------------------- slot utils
def stack_slot_caches(model, batch: int, slot_len: int):
    """Zero-initialized stacked per-slot caches: each leaf of the model's
    single-sequence cache gains a leading slot axis."""
    one = model.init_cache(1, slot_len)
    return jax.tree.map(lambda x: jnp.zeros((batch,) + x.shape, x.dtype), one)


def write_slots(slots, bs: List[int], caches: List):
    """Overwrite slots ``bs`` with freshly prefilled single-sequence caches
    in ONE scatter per leaf (k separate ``.at[b].set`` writes would copy the
    whole stacked cache k times).  Also wipes any garbage a retired occupant
    decoded past its budget."""
    idx = jnp.asarray(bs, jnp.int32)
    return jax.tree.map(
        lambda big, *smalls: big.at[idx].set(jnp.stack(smalls)),
        slots, *caches)


def write_slot(slots, b: int, cache):
    """Single-slot convenience wrapper over ``write_slots``."""
    return write_slots(slots, [b], [cache])


def _pow2_steps(n: int, cap: int) -> int:
    """Round a residual step count up to a power of two (capped): the decode
    scan is jit-compiled per static ``n_steps``, so bucketing keeps the
    compile set at O(log cap) while the active mask absorbs the overshoot."""
    p = 1
    while p < n:
        p *= 2
    return min(p, cap)


class _Lane:
    """Jitted batched machinery for ONE model: a batched decode step (dense:
    vmapped per-slot ``decode_step``; paged: the natively batched
    ``paged_decode_step``), a per-prompt-length prefill, and the multi-token
    decode scan shared by both layouts."""

    def __init__(self, model, estimator: str, temperature: float,
                 kv_layout: str = "dense"):
        self.model = model
        self.kv_layout = kv_layout
        est = get_batched_estimator(estimator)
        if kv_layout == "paged":
            # tok rides through the scan as (B,1,1); the paged step is
            # batched over the leading axis and returns (B, V) logits.
            step = lambda p, t, c: model.paged_decode_step(p, t[:, :, 0], c)
        else:
            step = jax.vmap(lambda p, t, c: model.decode_step(p, t, c),
                            in_axes=(None, 0, 0))
        self._jit_prefill = jax.jit(
            lambda p, toks, max_seq: model.prefill(
                p, {"tokens": toks}, max_seq=max_seq),
            static_argnames=("max_seq",))

        def chunk(params, caches, tok, steps_left, unc_sum, rng,
                  n_steps: int):
            """n_steps decode steps over all slots in one scan.  Returns the
            advanced state plus per-step (token, active) for the host."""
            def body(carry, r):
                caches, tok, steps_left, unc_sum = carry
                lg, caches = step(params, tok, caches)   # (B,1,V) | (B,V)
                lg = lg.reshape(lg.shape[0], -1)
                active = steps_left > 0
                if temperature == 0.0:
                    nxt = jnp.argmax(lg, -1).astype(jnp.int32)
                else:
                    nxt = jax.random.categorical(
                        r, lg / temperature, axis=-1).astype(jnp.int32)
                unc_sum = unc_sum + jnp.where(active, est(lg), 0.0)
                steps_left = steps_left - active.astype(jnp.int32)
                return (caches, nxt[:, None, None], steps_left, unc_sum), \
                    (nxt, active)

            (caches, tok, steps_left, unc_sum), (toks, actives) = \
                jax.lax.scan(body, (caches, tok, steps_left, unc_sum),
                             jax.random.split(rng, n_steps))
            return caches, tok, steps_left, unc_sum, toks, actives

        self._chunk = jax.jit(chunk, static_argnames=("n_steps",))

    def prefill(self, params, prompt, max_seq: int):
        """Prefill ``prompt[:-1]`` into a fresh cache padded to ``max_seq``.
        Recompiles per distinct prompt length; the jit cache makes repeats
        free."""
        toks = jnp.asarray(np.asarray(prompt, np.int32)[None, :-1])
        return self._jit_prefill(params, toks, max_seq=max_seq)


# ---------------------------------------------------------------- kv states
class _DenseKV:
    """Dense stacked slot caches: every slot padded to a common
    ``slot_len`` (the original layout, kept as the parity oracle)."""

    def __init__(self, lane: _Lane, params, batch: int, slot_len: int):
        self.lane = lane
        self.params = params
        self.slot_len = slot_len
        self.caches = stack_slot_caches(lane.model, batch, slot_len)
        self._pend_bs: List[int] = []
        self._pend_caches: List[Any] = []

    def admit(self, b: int, prompt, need_tokens: int) -> bool:
        _, c1 = self.lane.prefill(self.params, prompt, self.slot_len)
        self._pend_bs.append(b)
        self._pend_caches.append(c1)
        return True

    def flush(self):
        if self._pend_bs:   # one scatter for the whole admission wave
            self.caches = write_slots(self.caches, self._pend_bs,
                                      self._pend_caches)
            self._pend_bs, self._pend_caches = [], []

    def prepare_tick(self, occupied, steps_h, n: int):
        pass                # every slot already owns slot_len entries

    def retire(self, b: int):
        pass                # slab is overwritten wholesale on re-admission

    @property
    def capacity_bytes(self) -> int:
        return sum(x.nbytes for x in jax.tree.leaves(self.caches))

    peak_bytes = capacity_bytes


class _PagedKV:
    """Paged slot caches: one shared block pool + per-slot block tables.

    Host side this owns a ``BlockPool`` (block ids only) and mirrors each
    slot's real content length; device side it owns the cache pytree
    ``{k, v, table, pos}``.  Writes are batched: admissions/retirements
    accumulate and land in ``flush`` (block scatters + ONE table-row/pos
    scatter), per-tick growth lands in ``prepare_tick`` (one table-entry
    scatter).  Retired slots' rows are redirected to the trap block so
    their masked garbage decode cannot corrupt re-allocated blocks.
    """

    def __init__(self, lane: _Lane, params, batch: int, slot_len: int,
                 block_size: int, num_blocks: Optional[int] = None):
        self.lane = lane
        self.params = params
        self.block_size = block_size
        self.max_blocks = blocks_for(slot_len, block_size)
        if num_blocks is None:      # worst-case-safe default: dense capacity
            num_blocks = batch * self.max_blocks + 1
        num_blocks = max(num_blocks, 2)
        self.pool = BlockPool(num_blocks, block_size)
        self.caches = lane.model.init_paged_cache(
            num_blocks, block_size, batch, self.max_blocks)
        self._block_bytes = (self.caches["k"].nbytes +
                             self.caches["v"].nbytes) // num_blocks
        self._len = [0] * batch     # real cache entries written per slot
        self._commit = [0] * batch  # blocks reserved for future growth
        self._stale: set = set()    # retired slots awaiting a trap row
        self._pend: List[Tuple[int, np.ndarray, int]] = []  # (b, row, pos)

    def admit(self, b: int, prompt, need_tokens: int) -> bool:
        """Allocate the prompt's blocks and stage the prefill; returns
        False (admission deferred) when the pool cannot back the request.

        Admission is reservation-based: the request's WORST-CASE block need
        (``need_tokens`` = prompt + budget [+ overdraft]) is committed up
        front so on-demand growth can never fail mid-flight, but blocks are
        only physically allocated as decode reaches them — the reservation
        is per-request, not the batch maximum, which is where the paged
        layout beats the dense slabs."""
        S = int(np.asarray(prompt).size)
        nb = self.pool.blocks_for(S - 1)
        total = self.pool.blocks_for(need_tokens)
        if not self.pool.can_alloc(total + sum(self._commit)):
            return False
        blocks = self.pool.alloc(b, nb)
        self._commit[b] = total - nb
        _, c1 = self.lane.prefill(self.params, prompt, nb * self.block_size)
        kb, vb = prompt_cache_to_blocks(c1, self.block_size)
        self.caches["k"], self.caches["v"] = write_pool_blocks(
            self.caches["k"], self.caches["v"],
            jnp.asarray(blocks, jnp.int32), kb, vb)
        row = np.zeros((self.max_blocks,), np.int32)    # pad = trap block
        row[:nb] = blocks
        self._pend.append((b, row, S - 1))
        self._len[b] = S - 1
        self._stale.discard(b)
        return True

    def flush(self):
        if not (self._pend or self._stale):
            return
        idx, rows, poss = [], [], []
        for b, row, p in self._pend:
            idx.append(b)
            rows.append(row)
            poss.append(p)
        for b in self._stale:       # retired, not re-admitted: trap row
            idx.append(b)
            rows.append(np.zeros((self.max_blocks,), np.int32))
            poss.append(0)
        ii = jnp.asarray(idx, jnp.int32)
        self.caches["table"] = self.caches["table"].at[ii].set(
            jnp.asarray(np.stack(rows)))
        self.caches["pos"] = self.caches["pos"].at[ii].set(
            jnp.asarray(poss, jnp.int32))
        self._pend, self._stale = [], set()

    def prepare_tick(self, occupied, steps_h, n: int):
        """Grow every occupied slot to cover this tick's REAL decode steps
        (``min(steps_left, n)``); the masked garbage tail past a slot's
        budget clamps into the trap.  Growth draws down the slot's
        admission-time reservation, so it cannot fail."""
        upd_b, upd_i, upd_blk = [], [], []
        for b in occupied:
            target = self._len[b] + min(int(steps_h[b]), n)
            new = self.pool.grow_to(b, target)
            self._commit[b] = max(self._commit[b] - len(new), 0)
            base = len(self.pool.owned(b)) - len(new)
            for j, blk in enumerate(new):
                upd_b.append(b)
                upd_i.append(base + j)
                upd_blk.append(blk)
            self._len[b] = target
        if upd_b:
            self.caches["table"] = self.caches["table"].at[
                jnp.asarray(upd_b, jnp.int32),
                jnp.asarray(upd_i, jnp.int32)].set(
                jnp.asarray(upd_blk, jnp.int32))

    def retire(self, b: int):
        self.pool.free(b)
        self._len[b] = 0
        self._commit[b] = 0
        self._stale.add(b)

    @property
    def peak_bytes(self) -> int:
        """High-water mark of LIVE block bytes — what a right-sized pool
        would have to hold (the benchmark's headline number)."""
        return self.pool.peak_used * self._block_bytes

    @property
    def capacity_bytes(self) -> int:
        return self.caches["k"].nbytes + self.caches["v"].nbytes


# ---------------------------------------------------------------- requests
@dataclasses.dataclass
class _Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    key: Optional[np.ndarray] = None    # semantic-cache key (set at admit)


@dataclasses.dataclass
class _Slot:
    req: Optional[_Request] = None
    tokens: List[int] = dataclasses.field(default_factory=list)


class BatchedEngine:
    """Slot-based collaborative serving engine (see module docstring).

    Mirrors ``CollaborativeEngine``'s decision semantics exactly — same
    estimator, threshold, escalation modes, semantic cache — so greedy
    traces match the per-request engine token for token, on BOTH KV
    layouts.

    KV layout knobs:
      * ``kv_layout``: "auto" (paged where both models' cache families
        support it, else dense), "paged", or "dense".
      * ``kv_block_size``: tokens per block (paged).
      * ``kv_blocks``: total pool blocks incl. the trap (paged).  Default
        sizes the pool to the dense worst case; give a smaller pool to cap
        KV memory — admission is deferred when it runs full.
    """

    def __init__(self, edge_model, cloud_model, *, batch_size: int = 8,
                 gamma: int = 4, temperature: float = 0.0,
                 escalate_threshold: float = 0.6, estimator: str = "entropy",
                 escalation: str = "speculative", use_cache: bool = True,
                 cache_threshold: float = 0.95, skeleton_len: int = 8,
                 tick_tokens: int = 16, seed: int = 0,
                 kv_layout: str = "auto", kv_block_size: int = 32,
                 kv_blocks: Optional[int] = None):
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        if tick_tokens < 1:
            raise ValueError(f"tick_tokens must be >= 1, got {tick_tokens}")
        if escalation not in ("speculative", "cloud", "skeleton"):
            raise ValueError(f"unknown escalation mode {escalation!r}; "
                             "known: speculative | cloud | skeleton")
        if kv_layout not in ("auto", "paged", "dense"):
            raise ValueError(f"unknown kv_layout {kv_layout!r}; "
                             "known: auto | paged | dense")
        if kv_block_size < 1:
            raise ValueError(f"kv_block_size must be >= 1, got "
                             f"{kv_block_size}")
        paged_ok = edge_model.paged_kv and cloud_model.paged_kv
        if kv_layout == "paged" and not paged_ok:
            raise ValueError(
                "kv_layout='paged' needs KV-cache transformer families on "
                f"both models, got {edge_model.cfg.family!r} / "
                f"{cloud_model.cfg.family!r}")
        self.kv_layout = ("paged" if paged_ok else "dense") \
            if kv_layout == "auto" else kv_layout
        self.kv_block_size = kv_block_size
        self.kv_blocks = kv_blocks
        self.edge_model = edge_model
        self.cloud_model = cloud_model
        self.batch_size = batch_size
        self.gamma = gamma
        self.temperature = temperature
        self.threshold = escalate_threshold
        self.escalation = escalation
        self.skeleton_len = skeleton_len
        self.tick_tokens = tick_tokens
        self.seed = seed
        self.edge = _Lane(edge_model, estimator, temperature,
                          kv_layout=self.kv_layout)
        self.cloud = _Lane(cloud_model, estimator, temperature,
                           kv_layout=self.kv_layout)
        self.cache = SemanticCache(threshold=cache_threshold) if use_cache \
            else None
        if edge_model.rewindable_cache and cloud_model.rewindable_cache:
            self.spec: Optional[BatchedSpecDecoder] = BatchedSpecDecoder(
                edge_model, cloud_model, gamma=gamma, temperature=temperature,
                kv_layout=self.kv_layout)
            self._spec_fallback = None
        else:       # recurrent-state caches: per-request snapshot/replay
            self.spec = None
            self._spec_fallback = SpecDecoder(edge_model, cloud_model,
                                              gamma=gamma,
                                              temperature=temperature)
        self._queue: collections.deque = collections.deque()
        self._next_rid = 0
        # intra-batch dedup: in-flight leaders and their coalesced followers
        self._leaders: List[Tuple[np.ndarray, int]] = []
        self._followers: Dict[int, List[_Request]] = {}
        self._kv_stats: Dict[str, Any] = {}

    # ------------------------------------------------------------ submit
    def submit(self, prompt, max_new: int) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        assert prompt.size >= 2, "scheduler needs >= 2 prompt tokens"
        assert max_new >= 1
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(_Request(rid, prompt, max_new))
        return rid

    # ------------------------------------------------------------ kv state
    def _make_kv(self, lane: _Lane, params, batch: int,
                 need_tokens: Optional[Sequence[int]] = None,
                 num_blocks: Optional[int] = None):
        """Build the decode-cache owner for ``lane`` in the engine's
        layout.  ``need_tokens`` (escalation groups) sizes a paged pool to
        exactly the group's residency instead of the worst case."""
        if self.kv_layout == "dense":
            return _DenseKV(lane, params, batch, self._slot_len)
        if num_blocks is None and need_tokens is not None:
            needed = sum(blocks_for(t, self.kv_block_size)
                         for t in need_tokens)
            # pow2-bucket the pool so escalation groups with different
            # residencies reuse one compiled scan/spec-round shape (the
            # peak-bytes stat tracks LIVE blocks, not this capacity)
            num_blocks = 1 + _pow2_steps(needed, 1 << 30)
        return _PagedKV(lane, params, batch, self._slot_len,
                        self.kv_block_size, num_blocks)

    def _note_group(self, *states):
        live = sum(s.peak_bytes for s in states)
        self._kv_stats["kv_group_peak_bytes"] = max(
            self._kv_stats.get("kv_group_peak_bytes", 0), live)

    # ------------------------------------------------------------ dedup
    def _match_leader(self, key: np.ndarray) -> Optional[int]:
        """rid of an in-flight request whose cache key matches ``key`` at
        the semantic-cache threshold (cosine), else None."""
        if not self._leaders:
            return None
        u = SemanticCache._norm(key)
        for lk, rid in self._leaders:
            if float(u @ lk) >= self.cache.threshold:
                return rid
        return None

    # ------------------------------------------------------------ run
    def run(self, edge_params, cloud_params) -> Dict[int, RequestTrace]:
        """Drain the queue; returns {rid: RequestTrace} for this drain."""
        if not self._queue:
            return {}
        B = self.batch_size
        # slot capacity: prompt + generation + speculative overdraft margin
        # (matches SpecDecoder's max_seq so escalation reuses the same pads)
        self._slot_len = max(r.prompt.size + r.max_new for r in self._queue) \
            + 2 * max(self.gamma, 16) + 8
        self._kv_stats = {"kv_layout": self.kv_layout}
        state = self._make_kv(self.edge, edge_params, B,
                              num_blocks=self.kv_blocks)
        tok = jnp.zeros((B, 1, 1), jnp.int32)
        steps = jnp.zeros((B,), jnp.int32)
        unc = jnp.zeros((B,), jnp.float32)
        slots = [_Slot() for _ in range(B)]
        rng = jax.random.PRNGKey(self.seed)
        results: Dict[int, RequestTrace] = {}
        self._leaders, self._followers = [], {}

        while self._queue or any(s.req is not None for s in slots):
            # ---- admit queued requests into free slots (batched cache probe)
            free = [b for b in range(B) if slots[b].req is None]
            deferred = False
            if free and self._queue:
                cands = [self._queue.popleft()
                         for _ in range(min(len(free), len(self._queue)))]
                hits: List[Optional[Any]] = [None] * len(cands)
                if self.cache is not None:
                    for r in cands:
                        r.key = embed_tokens_mean(self.edge_model,
                                                  edge_params, r.prompt)
                    hits = self.cache.lookup_batch(
                        np.stack([r.key for r in cands]))
                bs, lasts, news = [], [], []
                for i, (r, hit) in enumerate(zip(cands, hits)):
                    if hit is not None:
                        results[r.rid] = RequestTrace("cache",
                                                      tokens=list(hit))
                        continue
                    if self.cache is not None:
                        # coalesce with an identical in-flight request: the
                        # sequential engine's later twin would hit the
                        # semantic cache the leader is about to warm
                        lid = self._match_leader(r.key)
                        if lid is not None:
                            self._followers.setdefault(lid, []).append(r)
                            self.cache.hits += 1
                            continue
                    b = free.pop(0)
                    if not state.admit(b, r.prompt,
                                       r.prompt.size - 1 + r.max_new):
                        # pool full: defer this and the rest, keep order
                        free.insert(0, b)
                        for rr in reversed(cands[i:]):
                            self._queue.appendleft(rr)
                        deferred = True
                        break
                    slots[b] = _Slot(req=r)
                    bs.append(b)
                    lasts.append([[int(r.prompt[-1])]])
                    news.append(r.max_new)
                    if self.cache is not None:
                        self._leaders.append((SemanticCache._norm(r.key),
                                              r.rid))
                if bs:
                    idx = jnp.asarray(bs, jnp.int32)
                    tok = tok.at[idx].set(jnp.asarray(lasts, jnp.int32))
                    steps = steps.at[idx].set(jnp.asarray(news, jnp.int32))
                    unc = unc.at[idx].set(0.0)

            occupied = [b for b in range(B) if slots[b].req is not None]
            if not occupied:
                if deferred:
                    raise RuntimeError(
                        "paged KV pool too small for the queued request "
                        "even with an empty batch; raise kv_blocks")
                continue            # this round was all cache hits
            state.flush()

            # ---- one batched decode tick (pow2-bucketed step count: the
            # scan recompiles per static n_steps, so bucketing bounds the
            # compile set; overshoot decodes masked garbage)
            steps_h = np.asarray(steps)
            n = _pow2_steps(int(min(self.tick_tokens,
                                    steps_h[occupied].max())),
                            self.tick_tokens)
            state.prepare_tick(occupied, steps_h, n)
            rng, r = jax.random.split(rng)
            state.caches, tok, steps, unc, toks, actives = self.edge._chunk(
                edge_params, state.caches, tok, steps, unc, r, n_steps=n)
            toks_h, act_h = np.asarray(toks), np.asarray(actives)
            for b in occupied:
                slots[b].tokens.extend(
                    int(t) for t, a in zip(toks_h[:, b], act_h[:, b]) if a)

            # ---- retire finished slots; group the uncertain ones
            steps_h, unc_h = np.asarray(steps), np.asarray(unc)
            group: List[Tuple[_Request, float]] = []
            for b in occupied:
                if steps_h[b] > 0:
                    continue
                req = slots[b].req
                u = float(unc_h[b]) / req.max_new
                if u <= self.threshold:
                    self._finish(results, req, RequestTrace(
                        "edge", edge_calls=req.max_new, uncertainty=u,
                        tokens=slots[b].tokens[:req.max_new]))
                else:
                    # edge tokens are discarded — escalation regenerates
                    # with cloud involvement (same as the reference engine)
                    group.append((req, u))
                slots[b] = _Slot()
                state.retire(b)

            if group:
                rng, r = jax.random.split(rng)
                for req, tr in self._escalate(edge_params, cloud_params,
                                              group, r):
                    self._finish(results, req, tr)

        self._kv_stats["kv_peak_bytes"] = state.peak_bytes
        self._kv_stats["kv_capacity_bytes"] = state.capacity_bytes
        if isinstance(state, _PagedKV):
            self._kv_stats["kv_blocks_peak"] = state.pool.peak_used
            self._kv_stats["kv_block_size"] = state.block_size
        return results

    def serve_batch(self, edge_params, cloud_params, prompts,
                    max_new) -> List[RequestTrace]:
        """Convenience: submit ``prompts``, drain, return traces in order.
        ``max_new`` may be an int or a per-request sequence."""
        if isinstance(max_new, int):
            max_new = [max_new] * len(prompts)
        if len(max_new) != len(prompts):
            raise ValueError(f"{len(prompts)} prompts but {len(max_new)} "
                             "max_new budgets")
        rids = [self.submit(p, m) for p, m in zip(prompts, max_new)]
        results = self.run(edge_params, cloud_params)
        return [results[rid] for rid in rids]

    # ------------------------------------------------------------ internals
    def _finish(self, results, req: _Request, tr: RequestTrace):
        if self.cache is not None and tr.tokens is not None \
                and req.key is not None:
            self.cache.insert(req.key, tr.tokens)
        results[req.rid] = tr
        # resolve coalesced followers from the leader's result (the
        # sequential engine would serve them from the just-warmed cache)
        self._leaders = [(k, rid) for k, rid in self._leaders
                         if rid != req.rid]
        for f in self._followers.pop(req.rid, []):
            results[f.rid] = RequestTrace(
                "cache", tokens=list(tr.tokens) if tr.tokens else None)

    def _group_generate(self, lane: _Lane, params, prompts,
                        max_news: List[int], rng) -> List[List[int]]:
        """Batched greedy/sampled generation for an escalation group: per-
        request prefill, then ONE decode scan over the padded group."""
        if max(max_news) == 0:
            return [[] for _ in prompts]
        n = _pow2_steps(max(max_news), 1 << 30)     # bound scan compiles
        G = self.batch_size                         # pad: stable jit shapes
        need = [len(p) - 1 + m for p, m in zip(prompts, max_news) if m > 0]
        state = self._make_kv(lane, params, G, need_tokens=need)
        tok = jnp.zeros((G, 1, 1), jnp.int32)
        steps = jnp.zeros((G,), jnp.int32)
        members = []
        for i, (p, m) in enumerate(zip(prompts, max_news)):
            if m <= 0:
                continue
            state.admit(i, p, len(p) - 1 + m)
            members.append(i)
            tok = tok.at[i, 0, 0].set(int(p[-1]))
            steps = steps.at[i].set(m)
        state.flush()
        state.prepare_tick(members, np.asarray(steps), n)
        _, _, _, _, toks, actives = lane._chunk(
            params, state.caches, tok, steps, jnp.zeros((G,), jnp.float32),
            rng, n_steps=n)
        self._note_group(state)
        toks_h, act_h = np.asarray(toks), np.asarray(actives)
        return [[int(t) for t, a in zip(toks_h[:, i], act_h[:, i]) if a]
                for i in range(len(prompts))]

    def _escalate(self, edge_params, cloud_params, group, rng):
        """Batched escalation of the slots retired-uncertain this tick.
        group: list of (request, mean uncertainty)."""
        reqs = [g[0] for g in group]
        uncs = [g[1] for g in group]
        out: List[Tuple[_Request, RequestTrace]] = []

        if self.escalation == "cloud":
            toks = self._group_generate(self.cloud, cloud_params,
                                        [r.prompt for r in reqs],
                                        [r.max_new for r in reqs], rng)
            for r, u, t in zip(reqs, uncs, toks):
                out.append((r, RequestTrace(
                    "cloud", edge_calls=r.max_new, cloud_passes=r.max_new,
                    uncertainty=u, tokens=t)))

        elif self.escalation == "skeleton":
            r1, r2 = jax.random.split(rng)
            ks = [min(self.skeleton_len, r.max_new) for r in reqs]
            skels = self._group_generate(self.cloud, cloud_params,
                                         [r.prompt for r in reqs], ks, r1)
            exts = [np.concatenate([r.prompt, np.asarray(s, np.int32)])
                    for r, s in zip(reqs, skels)]
            rests = self._group_generate(
                self.edge, edge_params, exts,
                [r.max_new - k for r, k in zip(reqs, ks)], r2)
            for r, u, k, s, rest in zip(reqs, uncs, ks, skels, rests):
                out.append((r, RequestTrace(
                    "skeleton", edge_calls=r.max_new + (r.max_new - k),
                    cloud_passes=k, uncertainty=u, tokens=s + rest)))

        else:   # speculative
            if self.spec is not None:
                out.extend(self._spec_escalate(edge_params, cloud_params,
                                               reqs, uncs, rng))
            else:   # recurrent caches: per-request snapshot/replay path
                for r, u in zip(reqs, uncs):
                    toks, st = self._spec_fallback.generate(
                        edge_params, cloud_params, r.prompt, r.max_new)
                    out.append((r, RequestTrace(
                        "speculative",
                        edge_calls=r.max_new + st.draft_calls,
                        cloud_passes=st.target_passes + st.replay_passes,
                        uncertainty=u, tokens=toks)))
        return out

    def _spec_escalate(self, edge_params, cloud_params, reqs, uncs, rng):
        """One BatchedSpecDecoder group over all escalated requests.  Paged
        groups pre-grow each slot to prompt + budget + one round of draft
        overdraft — spec rewinds only move ``pos``, never reallocate."""
        G = self.batch_size
        need = [r.prompt.size - 1 + r.max_new + self.gamma + 2 for r in reqs]
        d_state = self._make_kv(self.edge, edge_params, G, need_tokens=need)
        t_state = self._make_kv(self.cloud, cloud_params, G, need_tokens=need)
        last = jnp.zeros((G, 1, 1), jnp.int32)
        for i, (r, nd) in enumerate(zip(reqs, need)):
            d_state.admit(i, r.prompt, nd)
            t_state.admit(i, r.prompt, nd)
            last = last.at[i, 0, 0].set(int(r.prompt[-1]))
        overdraft = np.zeros((G,), np.int32)
        overdraft[:len(reqs)] = [n - (r.prompt.size - 1)
                                 for n, r in zip(need, reqs)]
        for st in (d_state, t_state):
            st.flush()
            st.prepare_tick(list(range(len(reqs))), overdraft, 1 << 30)
        max_news = [r.max_new for r in reqs] + [0] * (G - len(reqs))
        outs, stats = self.spec.generate_group(
            edge_params, cloud_params, d_state.caches, t_state.caches, last,
            max_news, rng)
        self._note_group(d_state, t_state)
        res = []
        for i, (r, u) in enumerate(zip(reqs, uncs)):
            st = stats[i]
            res.append((r, RequestTrace(
                "speculative",
                edge_calls=r.max_new + st["rounds"] * (self.gamma + 1),
                cloud_passes=st["rounds"], uncertainty=u, tokens=outs[i])))
        return res

    # ------------------------------------------------------------ stats
    def stats(self) -> Dict[str, Any]:
        return {"cache_hit_rate": self.cache.hit_rate if self.cache else 0.0,
                **self._kv_stats}
