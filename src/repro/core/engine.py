"""Task-level mixture orchestration (survey §2.3): the collaborative serving
engine that composes the taxonomy's mechanisms per request:

    1. semantic cache lookup (VELO)                     -> free
    2. edge-only generation + uncertainty check          -> cheap
    3. escalation:
       a. "speculative"  — token-level mixture (§2.4)
       b. "cloud"        — full cloud generation (task assignment)
       c. "skeleton"     — cloud drafts a skeleton prefix, edge completes
                           (cloud-to-edge skeleton, §2.4.3/PICE)

All of step 2-3's decision logic is pluggable: pass a
``core/policy.py::CollabPolicy`` (``policy=``) to choose lanes at
admission, per-wave escalation actions, and online learning from
completion feedback.  The legacy ``escalation=``/``escalate_threshold=``
kwargs construct the matching threshold-family policy (deprecated).

Serving architecture
--------------------
The serving path is the batched continuous-batching scheduler in
``core/scheduler.py``: slot-based admission into per-slot KV caches — by
default PAGED (a shared block pool plus per-slot block tables,
``core/paged_cache.py``; ``kv_layout="dense"`` keeps the padded-slab
parity oracle) — one jitted multi-token ``lax.scan`` per tick over the
whole batch (with uncertainty accumulated on device — no per-token host
sync), and grouped batched escalation.  Cache layouts and families hide
behind the ``SequenceState`` adapters (``core/seq_state.py``), so every
edge/cloud family pair — recurrent-state models included — takes the same
batched path.  ``CollaborativeEngine`` keeps the
original single-request API as a thin wrapper over a ``batch_size=1``
``BatchedEngine``; multi-request callers should construct ``BatchedEngine``
directly (or via ``launch/serve.py --scheduler batched``).

``serve_reference`` preserves the original host-side Python loop (one jitted
model step per decoded token).  It is the executable spec: parity tests in
``tests/test_scheduler.py`` check the scheduler against it token for token,
and ``benchmarks/bench_serving.py`` uses it as the per-request baseline.
"""
from __future__ import annotations

import warnings
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import embed_tokens_mean
from repro.core.policy import ThresholdPolicy, resolve_policy
from repro.core.scheduler import BatchedEngine, RequestTrace  # noqa: F401
from repro.core.speculative import SpecDecoder, autoregressive_baseline
from repro.core.uncertainty import get_estimator


class CollaborativeEngine:
    """Single-request facade over the batched scheduler.

    ``serve`` routes through a one-slot ``BatchedEngine`` (same decision
    semantics, same jitted decode path as production batched serving);
    ``serve_reference`` is the legacy per-token host loop kept as the
    reference implementation.
    """

    def __init__(self, edge_model, cloud_model, *, gamma: int = 4,
                 temperature: float = 0.0, escalate_threshold=None,
                 estimator: str = "entropy", escalation=None, policy=None,
                 use_cache: bool = True, cache_threshold: float = 0.95,
                 skeleton_len: int = 8, kv_layout: str = "auto",
                 kv_block_size: int = 32, kv_blocks=None):
        self.edge = edge_model
        self.cloud = cloud_model
        self.temperature = temperature
        self.policy = resolve_policy(policy, escalation, escalate_threshold)
        # serve_reference is the legacy per-token oracle: it understands
        # only the threshold-family policies' fixed (threshold, action)
        # pair; any other policy (bandit, budget, cascade — whose hooks the
        # per-token loop cannot honor) keeps the historical defaults there,
        # while serve()/the batched path runs the real policy
        if isinstance(self.policy, ThresholdPolicy):
            self.threshold = self.policy.threshold
            self.escalation = self.policy.action
        else:
            self.threshold, self.escalation = 0.6, "speculative"
        self.est = get_estimator(estimator)
        self.skeleton_len = skeleton_len
        self.spec = SpecDecoder(edge_model, cloud_model, gamma=gamma,
                                temperature=temperature)
        self.batched = BatchedEngine(
            edge_model, cloud_model, batch_size=1, gamma=gamma,
            temperature=temperature, policy=self.policy,
            estimator=estimator, use_cache=use_cache,
            cache_threshold=cache_threshold, skeleton_len=skeleton_len,
            kv_layout=kv_layout, kv_block_size=kv_block_size,
            kv_blocks=kv_blocks)
        # single shared semantic cache: reference and scheduler paths hit
        # (and warm) the same entries
        self.cache = self.batched.cache
        self._edge_step = jax.jit(lambda p, t, c: edge_model.decode_step(p, t, c))

    # ----------------------------------------------------------------
    def serve(self, edge_params, cloud_params, prompt, max_new: int
              ) -> RequestTrace:
        return self.batched.serve_batch(edge_params, cloud_params, [prompt],
                                        max_new)[0]

    # ----------------------------------------------------------------
    def _edge_generate(self, params, prompt, max_new):
        """Edge-only generation; returns (tokens, mean uncertainty, calls)."""
        prompt = jnp.atleast_2d(jnp.asarray(prompt, jnp.int32))
        _, cache = self.edge.prefill(params, {"tokens": prompt[:, :-1]},
                                     max_seq=prompt.shape[1] + max_new + 4)
        tok = prompt[:, -1:]
        out, us = [], []
        rng = jax.random.PRNGKey(0)
        for _ in range(max_new):
            lg, cache = self._edge_step(params, tok, cache)
            us.append(float(np.asarray(self.est(lg)).mean()))
            if self.temperature == 0.0:
                nxt = jnp.argmax(lg, -1).astype(jnp.int32)
            else:
                rng, rr = jax.random.split(rng)
                nxt = jax.random.categorical(rr, lg / self.temperature, -1
                                             ).astype(jnp.int32)
            out.append(int(nxt[0]))
            tok = nxt[:, None]
        return out, float(np.mean(us)), max_new

    # ----------------------------------------------------------------
    def serve_reference(self, edge_params, cloud_params, prompt, max_new: int
                        ) -> RequestTrace:
        """Legacy per-request loop (host round-trip per token) — the
        reference the batched scheduler is tested against.  Only honors
        the threshold-family policies; anything else is served with the
        historical speculative@0.6 decisions (with a warning)."""
        if not isinstance(self.policy, ThresholdPolicy):
            warnings.warn(
                f"serve_reference cannot honor policy {self.policy.name!r} "
                "(its assign/decide/feedback hooks never fire here); "
                "serving with the historical speculative@0.6 decisions — "
                "use serve() / BatchedEngine for the real policy",
                RuntimeWarning, stacklevel=2)
        prompt = np.asarray(prompt, np.int32).reshape(-1)

        if self.cache is not None:
            key = embed_tokens_mean(self.edge, edge_params, prompt)
            hit = self.cache.lookup(key)
            if hit is not None:
                return RequestTrace("cache", tokens=list(hit))

        tokens, u, calls = self._edge_generate(edge_params, prompt, max_new)
        if u <= self.threshold:
            trace = RequestTrace("edge", edge_calls=calls, uncertainty=u,
                                 tokens=tokens)
        elif self.escalation == "speculative":
            toks, st = self.spec.generate(edge_params, cloud_params, prompt,
                                          max_new)
            trace = RequestTrace("speculative",
                                 edge_calls=calls + st.draft_calls,
                                 cloud_passes=st.target_passes + st.replay_passes,
                                 uncertainty=u, tokens=toks)
        elif self.escalation == "skeleton":
            toks, ec, cp = self._skeleton_completion(edge_params, cloud_params,
                                                     prompt, max_new)
            trace = RequestTrace("skeleton", edge_calls=calls + ec,
                                 cloud_passes=cp, uncertainty=u, tokens=toks)
        else:   # plain cloud fallback (task assignment)
            toks = autoregressive_baseline(self.cloud, cloud_params, prompt,
                                           max_new, temperature=self.temperature)
            trace = RequestTrace("cloud", edge_calls=calls,
                                 cloud_passes=max_new, uncertainty=u,
                                 tokens=toks)

        if self.cache is not None and trace.tokens is not None:
            self.cache.insert(key, trace.tokens)
        return trace

    # ----------------------------------------------------------------
    def _skeleton_completion(self, edge_params, cloud_params, prompt,
                             max_new: int):
        """Cloud-to-edge skeleton (PICE/CoGenesis): the cloud generates the
        first ``skeleton_len`` tokens (the semantic plan); the edge completes
        the remainder conditioned on them."""
        k = min(self.skeleton_len, max_new)
        skel = autoregressive_baseline(self.cloud, cloud_params, prompt, k,
                                       temperature=self.temperature)
        ext = np.concatenate([np.asarray(prompt, np.int32),
                              np.asarray(skel, np.int32)])
        rest, _, ec = self._edge_generate(edge_params, ext, max_new - k)
        return skel + rest, ec, k

    # ----------------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        return {"cache_hit_rate": self.cache.hit_rate if self.cache else 0.0,
                "policy": self.policy.name, **self.policy.stats()}
