"""Computation offloading / split inference (survey §2.2.2).

Structural model partitioning: the edge device runs layers [0, k) and ships
the (optionally compressed) boundary activation to the cloud, which runs
layers [k, L).  Includes the survey's hybrid cost model for choosing the
branch point (Stammler et al. / Yang et al. style) and INT8 boundary
quantization (Li et al.).

Works for the scan-stacked transformer families (dense/moe/vlm); the split
point for zamba2 keeps the shared attention block cloud-side.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import Identity
from repro.models import layers as L
from repro.models.transformer import _block


def _split_blocks(params, k: int):
    lower = jax.tree.map(lambda x: x[:k], params["blocks"])
    upper = jax.tree.map(lambda x: x[k:], params["blocks"])
    return lower, upper


def edge_forward(params, tokens, cfg, k: int, *, embeds=None):
    """Run embedding + blocks [0, k). Returns boundary activation (B,S,d)."""
    h = L.embed(params["embed"], tokens).astype(jnp.dtype(cfg.activ_dtype))
    prefix_len = 0
    if embeds is not None:
        h = jnp.concatenate([embeds.astype(h.dtype), h], axis=1)
        prefix_len = embeds.shape[1]
    S = h.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    mask = (cfg.sliding_window, prefix_len)
    lower, _ = _split_blocks(params, k)

    def body(hh, p):
        hh, _aux, _ = _block(p, hh, positions, cfg, mask)
        return hh, None

    h, _ = jax.lax.scan(body, h, lower)
    return h


def cloud_forward(params, boundary_h, cfg, k: int, *, prefix_len: int = 0):
    """Run blocks [k, L) + head on a (possibly decompressed) boundary act."""
    S = boundary_h.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    mask = (cfg.sliding_window, prefix_len)
    _, upper = _split_blocks(params, k)

    def body(hh, p):
        hh, _aux, _ = _block(p, hh, positions, cfg, mask)
        return hh, None

    h, _ = jax.lax.scan(body, boundary_h.astype(jnp.dtype(cfg.activ_dtype)),
                        upper)
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return L.unembed(params.get("lm_head", params["embed"]), h)


def split_inference(model, params, batch, k: int, compressor=None
                    ) -> Tuple[jnp.ndarray, int]:
    """Full split pass. Returns (logits, wire_bytes across the boundary)."""
    cfg = model.cfg
    assert cfg.family in ("dense", "moe", "vlm"), \
        "split inference implemented for scan-stacked decoder families"
    compressor = compressor or Identity()
    embeds = batch.get("embeds")
    h = edge_forward(params, batch["tokens"], cfg, k, embeds=embeds)
    c = compressor.compress(h)
    h2 = compressor.decompress(c)
    prefix_len = embeds.shape[1] if embeds is not None else 0
    logits = cloud_forward(params, h2, cfg, k, prefix_len=prefix_len)
    return logits, c.wire_bytes


@dataclasses.dataclass
class SplitCostModel:
    """Survey §2.2.2 hybrid cost function: pick the branch point k minimizing
        T(k) = edge_flops(k)/edge_speed + wire_bytes(k)/bandwidth
             + cloud_flops(k)/cloud_speed
    """
    edge_flops_per_s: float = 2e12        # phone-class NPU
    cloud_flops_per_s: float = 197e12     # one TPU v5e chip
    bandwidth_bytes_per_s: float = 12.5e6 # 100 Mb/s uplink
    bytes_per_act: float = 1.0            # int8 boundary

    def layer_flops(self, cfg, tokens: int) -> float:
        d, f = cfg.d_model, max(cfg.d_ff, cfg.d_model * 4)
        attn = 4 * d * d + 2 * tokens * d   # proj + score/value (amortized)
        mlp = (3 if cfg.mlp_activation in ("silu", "geglu") else 2) * d * f
        return 2.0 * tokens * (attn + mlp)

    def total_time(self, cfg, tokens: int, k: int) -> float:
        lf = self.layer_flops(cfg, tokens)
        wire = tokens * cfg.d_model * self.bytes_per_act
        return (k * lf / self.edge_flops_per_s
                + wire / self.bandwidth_bytes_per_s
                + (cfg.num_layers - k) * lf / self.cloud_flops_per_s)

    def best_split(self, cfg, tokens: int) -> Tuple[int, np.ndarray]:
        ts = np.array([self.total_time(cfg, tokens, k)
                       for k in range(cfg.num_layers + 1)])
        return int(np.argmin(ts)), ts
