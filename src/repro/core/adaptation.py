"""Online adaptation: background distillation/LoRA over serve-time
feedback, hot-swapped into live serving between scheduler ticks.

This is the subsystem that makes the repo's serving and training halves
meet (survey §3: collaborative *inference and learning*).  The flow:

1. **Capture** — ``BatchedEngine._finish`` calls ``observe`` once per
   completion with the supervision triple (prompt, discarded edge draft,
   cloud-corrected continuation) plus the cloud's top-k teacher logits
   when the wave already paid for the cloud pass (``capture_topk`` tells
   the scheduler how many to keep; the capture rides each wave's single
   designated ``jax.device_get`` — never a new sync).  Records land in a
   bounded ``data/feedback_store.FeedbackStore`` with domain/SLA tags.

2. **Train** — every ``interval`` observations, ``maybe_update`` (called
   by the drain loop BETWEEN ticks) assembles a fixed-shape padded batch
   from the store and takes jitted steps built on
   ``training/trainer.make_train_step`` + ``training/optimizer.AdamW``:

   * ``mode="distill"`` — forward KD on the full edge params
     (``training/distillation.kd_loss`` from the stored sparse teacher
     top-k, ``kd_mask`` confining the KL to captured positions).
   * ``mode="lora"`` — adapter-only updates
     (``training/lora.lora_loss_fn``) against the FROZEN base params
     snapshotted at the first update; the swap value is
     ``merge_lora(base, adapters)``.

   Fixed batch/seq shapes + sampling with replacement mean the train
   step compiles exactly ONCE; metrics stay device-side until ``stats``.

3. **Swap** — the new weights go back as a PURE pytree swap: same
   treedef, shapes and dtypes as the serving params (AdamW and
   ``merge_lora`` both cast back to the input dtype), so no jitted
   function's cache key changes and the PR 9 ``CompileCounter`` oracle
   reads ``steady_state_recompiles == 0`` straight across a swap.

``interval=0`` is capture-only: the store fills (e.g. for offline
harvesting, ``benchmarks/bench_collab_training.py``) but ``maybe_update``
never fires.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.data.feedback_store import FeedbackStore

MODES = ("distill", "lora")


class AdaptationLoop:
    """Serve-time adaptation driver (see the module docstring).

    Args:
        store: the ``FeedbackStore`` to fill/train from (fresh if None).
        mode: ``"distill"`` (full-param forward KD) or ``"lora"``
            (adapter-only on frozen base params).
        interval: take an update every this many observations (0 =
            capture-only, never update).
        batch_size / seq_len: fixed training-batch shape (one compile).
        topk: teacher logits kept per captured cloud position; also what
            the scheduler reads as ``capture_topk``.  ``topk=0`` disables
            teacher capture (lora mode trains on CE alone).
        steps_per_update: jitted steps taken per due update.
        opt: ``training/optimizer.AdamW`` (default lr=1e-3 instance).
        lora_rank: adapter rank (lora mode).
        alpha / kd_temperature: ``kd_loss`` mixing knobs (distill mode).
        min_records: updates are skipped until the store holds this many.
    """

    def __init__(self, store: Optional[FeedbackStore] = None, *,
                 mode: str = "distill", interval: int = 64,
                 batch_size: int = 8, seq_len: int = 64, topk: int = 8,
                 steps_per_update: int = 1, opt=None, lora_rank: int = 8,
                 alpha: float = 0.5, kd_temperature: float = 2.0,
                 min_records: int = 1, seed: int = 0):
        if mode not in MODES:
            raise ValueError(f"unknown adaptation mode {mode!r}; "
                             f"known: {' | '.join(MODES)}")
        if interval < 0:
            raise ValueError(f"interval must be >= 0, got {interval}")
        self.store = store if store is not None else FeedbackStore()
        self.mode = mode
        self.interval = interval
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.topk = topk
        self.steps_per_update = steps_per_update
        self.lora_rank = lora_rank
        self.alpha = alpha
        self.kd_temperature = kd_temperature
        self.min_records = min_records
        self.seed = seed
        self._rng = np.random.default_rng(seed)
        if opt is None:
            from repro.training.optimizer import AdamW
            opt = AdamW(lr=1e-3)
        self.opt = opt
        self.model = None
        self._train_step = None
        self._opt_state = None
        self._base = None           # frozen base params (lora mode)
        self.adapters = None        # live adapter pytree (lora mode)
        self._pending = False
        self.observed = 0
        self.updates = 0
        self.steps = 0
        self.swaps = 0
        self.latest = None          # most recent hot-swapped edge weights
        self._last_loss = None      # device scalar; float()ed in stats()

    # ------------------------------------------------------------ capture
    @property
    def capture_topk(self) -> int:
        """Top-k teacher logits the scheduler should emit on cloud passes
        (0 = none).  Distill mode needs them; lora mode trains on the
        corrected tokens alone, so capture stays free there."""
        return self.topk if self.mode == "distill" else 0

    def bind(self, model) -> None:
        """Attach the edge model whose params the loop trains (the engine
        calls this at construction)."""
        self.model = model

    def current(self, params):
        """The latest adapted edge weights, or ``params`` unchanged when
        no update has landed yet.  The scheduler starts every drain from
        this, so adaptation PERSISTS across drains instead of resetting
        to the caller's baseline each ``run``."""
        return params if self.latest is None else self.latest

    def observe(self, *, prompt, tokens, draft=None, teacher_topk=None,
                domain=None, sla="none", path="edge") -> None:
        """Record one completion (host-side data only — the scheduler
        hands over what the wave's batched pull already fetched) and mark
        an update pending every ``interval`` observations."""
        self.store.add(prompt, tokens, draft=draft,
                       teacher_topk=teacher_topk, domain=domain, sla=sla,
                       path=path)
        self.observed += 1
        if self.interval and self.observed % self.interval == 0:
            self._pending = True

    # ------------------------------------------------------------ training
    def _build(self, params):
        from repro.training.trainer import make_train_step
        if self.mode == "lora":
            from repro.training.lora import init_lora, lora_loss_fn
            # freeze the CURRENT serving params as the base: adapters are
            # the only thing that trains, and B's zero init makes the
            # first merge the identity
            self._base = jax.tree.map(lambda x: x, params)
            self.adapters = init_lora(jax.random.PRNGKey(self.seed),
                                      self._base, rank=self.lora_rank)
            loss = lora_loss_fn(self.model, self._base)
        else:
            model, alpha, temp = self.model, self.alpha, self.kd_temperature
            from repro.training.distillation import kd_loss

            def loss(p, b):
                return kd_loss(model, p, b, b["teacher_logits"],
                               alpha=alpha, temperature=temp,
                               kd_mask=b["kd_mask"])
        # donate=False: the donated buffers would be the LIVE serving
        # params — serving still reads them until the swap lands
        self._train_step = make_train_step(self.model, self.opt,
                                           loss_fn=loss, donate=False)
        self._opt_state = self.opt.init(
            self.adapters if self.mode == "lora" else params)

    def maybe_update(self, params):
        """Offered the live edge params between ticks; returns the
        hot-swap replacement (same treedef/shapes/dtypes) when an update
        is due, else None.  All work here is enqueue-only — batches
        upload, the jitted step runs async, metrics stay device-side."""
        if not self._pending or self.model is None:
            return None
        self._pending = False
        if len(self.store) < max(self.min_records, 1):
            return None
        if self._train_step is None:
            self._build(params)
        tk = self.capture_topk
        target = self.adapters if self.mode == "lora" else params
        for _ in range(self.steps_per_update):
            batch = self.store.sample_batch(
                self._rng, self.batch_size, self.seq_len,
                self.model.cfg.vocab_size, topk=tk)
            target, self._opt_state, metrics = self._train_step(
                target, self._opt_state, batch)
            self.steps += 1
            self._last_loss = metrics["loss"]
        self.updates += 1
        self.swaps += 1
        if self.mode == "lora":
            from repro.training.lora import merge_lora
            self.adapters = target
            self.latest = merge_lora(self._base, self.adapters)
        else:
            self.latest = target
        return self.latest

    # ------------------------------------------------------------ stats
    def stats(self) -> Dict[str, Any]:
        return {"mode": self.mode, "interval": self.interval,
                "observed": self.observed, "updates": self.updates,
                "train_steps": self.steps, "swaps": self.swaps,
                "last_loss": None if self._last_loss is None
                else float(self._last_loss),
                **{f"store_{k}": v for k, v in self.store.stats().items()}}
