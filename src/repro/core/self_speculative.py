"""Self-speculative decoding (survey §2.4.2 — Kangaroo / LayerSkip / SWIFT).

No auxiliary draft model: the target's own shallow sub-network (first k
blocks + shared LM head) drafts, the full network verifies.  The draft
shares the target's KV cache — drafting writes layers [0,k) at the draft
positions and verification overwrites all layers, so no extra memory and no
separate-model resync.

Only meaningful for the scan-stacked attention families (the shallow prefix
of an SSM has its own state to carry — supported via a separate cache copy).
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.core.speculative import SpecStats, speculative_sample
from repro.models import layers as L
from repro.models import moe as MOE


def partial_extend_step(params, tokens, cache, cfg, k: int, *, window: int = 0):
    """Run the first k blocks + final norm + head, updating cache layers
    [0, k) at [pos, pos+T). Returns (logits (B,T,V), cache)."""
    h = L.embed(params["embed"], tokens).astype(jnp.dtype(cfg.activ_dtype))
    pos = cache["pos"]
    lower = jax.tree.map(lambda x: x[:k], params["blocks"])
    ck, cv = cache["k"][:k], cache["v"][:k]

    def body(hh, xs):
        p, ck_l, cv_l = xs
        hn = L.rmsnorm(hh, p["attn_norm"], cfg.norm_eps)
        a, ck_l, cv_l = L.extend_attention(p["attn"], hn, ck_l, cv_l, pos, cfg,
                                           window=window or cfg.sliding_window)
        hh = hh + a
        hn = L.rmsnorm(hh, p["mlp_norm"], cfg.norm_eps)
        if cfg.family == "moe":
            m, _ = MOE.moe_block(p["moe"], hn, cfg)
        else:
            m = L.mlp_block(p["mlp"], hn, cfg.mlp_activation)
        return hh + m, (ck_l, cv_l)

    h, (nk, nv) = jax.lax.scan(body, h, (lower, ck, cv))
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params.get("lm_head", params["embed"]), h)
    new_k = jnp.concatenate([nk, cache["k"][k:]], axis=0)
    new_v = jnp.concatenate([nv, cache["v"][k:]], axis=0)
    # note: pos is NOT advanced here; the caller manages it (draft positions
    # are provisional until verification).
    return logits, {**cache, "k": new_k, "v": new_v}


class SelfSpecDecoder:
    """Draft with the first ``exit_layer`` blocks, verify with all blocks."""

    def __init__(self, model, *, exit_layer: int, gamma: int = 4,
                 temperature: float = 1.0):
        assert model.cfg.family in ("dense", "moe", "vlm"), \
            "self-speculation implemented for scan-stacked decoders"
        assert 0 < exit_layer < model.cfg.num_layers
        self.model = model
        self.k = exit_layer
        self.gamma = gamma
        self.temperature = temperature
        cfg = model.cfg
        self._draft = jax.jit(lambda p, t, c, pos: partial_extend_step(
            p, t, {**c, "pos": pos}, cfg, self.k))
        self._verify = jax.jit(lambda p, t, c: model.extend_step(p, t, c))

    def generate(self, params, prompt, max_new: int, rng=None):
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        prompt = jnp.atleast_2d(jnp.asarray(prompt, jnp.int32))
        max_seq = prompt.shape[1] + max_new + self.gamma + 8
        _, cache = self.model.prefill(params, {"tokens": prompt[:, :-1]},
                                      max_seq=max_seq)
        stats = SpecStats()
        out: List[int] = []
        last = prompt[:, -1:]
        while len(out) < max_new:
            rng, r_d, r_v = jax.random.split(rng, 3)
            pos0 = cache["pos"]

            # ---- shallow drafting (sequential, one token at a time)
            draft_tokens, draft_logits = [], []
            tok, pos = last, pos0
            for _ in range(self.gamma):
                lg, cache = self._draft(params, tok, cache, pos)
                stats.draft_calls += 1
                lg = lg[:, -1]
                if self.temperature == 0.0:
                    nxt = jnp.argmax(lg, -1).astype(jnp.int32)
                else:
                    r_d, rr = jax.random.split(r_d)
                    nxt = jax.random.categorical(
                        rr, lg / self.temperature, -1).astype(jnp.int32)
                draft_logits.append(lg[0])
                draft_tokens.append(int(nxt[0]))
                tok = nxt[:, None]
                pos = pos + 1

            # ---- full-depth verification (overwrites all layers at pos0..)
            cache = {**cache, "pos": pos0}   # drafting advanced pos provisionally
            ver_in = jnp.concatenate(
                [last, jnp.asarray(draft_tokens, jnp.int32)[None, :]], axis=1)
            t_logits, cache = self._verify(params, ver_in, cache)
            stats.target_passes += 1
            n_acc, next_tok = speculative_sample(
                r_v, t_logits[0], jnp.stack(draft_logits),
                jnp.asarray(draft_tokens, jnp.int32),
                temperature=self.temperature)
            n_acc, next_tok = int(n_acc), int(next_tok)
            out.extend(draft_tokens[:n_acc] + [next_tok])
            stats.rounds += 1
            stats.accepted.append(n_acc)
            cache = self.model.rewind(cache, int(pos0) + n_acc + 1)
            last = jnp.asarray([[next_tok]], jnp.int32)
        stats.tokens_out = len(out)
        return out[:max_new], stats
