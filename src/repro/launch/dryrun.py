import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, WITHOUT allocating any real tensors
(ShapeDtypeStruct lowering).

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Per run it records: memory_analysis (proves fit), cost_analysis (FLOPs /
bytes for the roofline), and the collective-byte breakdown parsed from the
optimized HLO — written incrementally to experiments/dryrun/*.json.
"""
import argparse
import json
import re
import time
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import runtime
from repro.configs import LONG_DECODE_WINDOW, SHAPES, get_config, list_archs
from repro.launch import sharding as SH
from repro.launch.mesh import make_production_mesh
from repro.models import Model
from repro.training.optimizer import AdamW

RESULTS_DIR = os.environ.get(
    "REPRO_DRYRUN_DIR",
    os.path.join(os.path.dirname(__file__), "..", "..", "..",
                 "experiments", "dryrun"))

# (arch, shape) pairs that are skipped by design — see DESIGN.md.
SKIPS = {
    ("whisper-small", "long_500k"):
        "encoder-decoder with full cross-attention; no 512k decode use-case "
        "and no sliding-window variant implemented (DESIGN.md)",
}


def decode_window(cfg, shape_name: str) -> int:
    if shape_name == "long_500k" and cfg.family in ("dense", "moe", "vlm"):
        return LONG_DECODE_WINDOW
    if shape_name == "long_500k" and cfg.family == "hybrid":
        return LONG_DECODE_WINDOW     # windowed shared-attention block
    return 0


def input_specs(arch: str, shape_name: str) -> Dict:
    """ShapeDtypeStruct stand-ins for every input of the lowered step."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = Model(cfg)
    B, S = shape.global_batch, shape.seq_len
    f = jnp.dtype(cfg.activ_dtype)
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct

    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    out = {"cfg": cfg, "model": model, "params": params, "kind": shape.kind}

    if shape.kind == "train":
        s_text = S - cfg.num_image_tokens if cfg.family == "vlm" else S
        batch = {"tokens": sds((B, s_text), i32), "labels": sds((B, s_text), i32)}
        if cfg.family == "vlm":
            batch["embeds"] = sds((B, cfg.num_image_tokens, cfg.d_model), f)
        if cfg.family == "encdec":
            batch["frames"] = sds((B, cfg.encoder_seq, cfg.d_model), f)
        opt = AdamW()
        out["opt"] = opt
        out["opt_state"] = jax.eval_shape(opt.init, params)
        out["batch"] = batch
    elif shape.kind == "prefill":
        s_text = S - cfg.num_image_tokens if cfg.family == "vlm" else S
        batch = {"tokens": sds((B, s_text), i32)}
        if cfg.family == "vlm":
            batch["embeds"] = sds((B, cfg.num_image_tokens, cfg.d_model), f)
        if cfg.family == "encdec":
            batch["frames"] = sds((B, cfg.encoder_seq, cfg.d_model), f)
        out["batch"] = batch
    else:   # decode
        out["token"] = sds((B, 1), i32)
        out["cache"] = jax.eval_shape(lambda: model.init_cache(B, S))
    return out


def build_step(spec: Dict, shape_name: str):
    model, cfg = spec["model"], spec["cfg"]
    window = decode_window(cfg, shape_name)
    if spec["kind"] == "train":
        opt = spec["opt"]

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: model.loss(p, batch, remat=True))(params)
            params, opt_state, gnorm = opt.update(grads, opt_state, params)
            return params, opt_state, loss
        return step, "train_step"
    if spec["kind"] == "prefill":
        S = SHAPES[shape_name].seq_len

        def step(params, batch):
            return model.prefill(params, batch, max_seq=S)
        return step, "prefill_step"

    def step(params, token, cache):
        return model.decode_step(params, token, cache, window=window)
    return step, "serve_step"


def make_shardings(spec: Dict, mesh, shape_name: str):
    params_sh = SH.params_shardings(spec["params"], mesh, spec["cfg"])
    if spec["kind"] == "train":
        from repro.training.optimizer import AdamWState
        opt_sh = AdamWState(
            m=jax.tree.map(lambda s: s, params_sh),
            v=jax.tree.map(lambda s: s, params_sh),
            step=NamedSharding(mesh, P()))
        return (params_sh, opt_sh, SH.batch_shardings(spec["batch"], mesh))
    if spec["kind"] == "prefill":
        return (params_sh, SH.batch_shardings(spec["batch"], mesh))
    B = SHAPES[shape_name].global_batch
    cache_sh = SH.cache_shardings(spec["cache"], mesh, spec["cfg"], B)
    tok_sh = jax.tree.map(
        lambda x: NamedSharding(mesh, SH.batch_spec(x.shape, mesh)),
        spec["token"])
    return (params_sh, tok_sh, cache_sh)


# ------------------------------------------------------------- HLO parsing
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes of every collective op in the (per-device) HLO."""
    out = {c: 0 for c in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)", ls)
        if not m:
            continue
        rhs = m.group(1)
        op = None
        for c in _COLLECTIVES:
            if re.search(rf"\b{c}(-start|-done)?\(", rhs):
                op = c
                break
        if op is None:
            continue
        if re.search(rf"\b{op}-done\(", rhs):
            continue   # avoid double counting start/done pairs
        # operand types appear inside the call parens in optimized HLO
        paren = rhs.split("(", 1)
        operands = paren[1] if len(paren) > 1 else ""
        shapes = _SHAPE_RE.findall(operands)
        if not shapes:    # fall back to result type (before the op name)
            shapes = _SHAPE_RE.findall(paren[0])
        out[op] += sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        out["count"] += 1
    return out


# ------------------------------------------------------------- main driver
def run_one(arch: str, shape_name: str, mesh_kind: str, verbose: bool = True
            ) -> Dict:
    if (arch, shape_name) in SKIPS:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "status": "skipped", "reason": SKIPS[(arch, shape_name)]}
        _write(rec)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    spec = input_specs(arch, shape_name)
    step, step_name = build_step(spec, shape_name)
    shardings = make_shardings(spec, mesh, shape_name)
    if spec["kind"] == "train":
        args = (spec["params"], spec["opt_state"], spec["batch"])
    elif spec["kind"] == "prefill":
        args = (spec["params"], spec["batch"])
    else:
        args = (spec["params"], spec["token"], spec["cache"])

    with runtime.mesh_context(mesh):
        jitted = jax.jit(step, in_shardings=shardings)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    hlo_text = compiled.as_text()
    coll = collective_bytes(hlo_text)
    # trip-count-aware re-analysis (XLA counts while bodies once; our models
    # are scan-over-layers, so this correction is essential — see hlo_cost.py)
    from repro.launch.hlo_cost import analyze_hlo, cost_analysis_dict
    cost = cost_analysis_dict(compiled)
    hc = analyze_hlo(hlo_text)

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "step": step_name, "status": "ok",
        "devices": int(np_prod(mesh.devices.shape)),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_device": cost.get("flops", 0.0),
        "bytes_per_device": cost.get("bytes accessed", 0.0),
        "hlo_cost": hc,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes",
                                            None),
        },
        "collectives": coll,
    }
    _write(rec)
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: OK "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s, "
              f"{rec['flops_per_device']:.3g} flops/dev, "
              f"coll {sum(v for k, v in coll.items() if k != 'count'):.3g} B/dev)")
    return rec


def np_prod(t):
    n = 1
    for x in t:
        n *= x
    return n


def _write(rec: Dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}.json"
    with open(os.path.join(RESULTS_DIR, name), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for mk in meshes:
                out = os.path.join(RESULTS_DIR, f"{arch}_{shape_name}_{mk}.json")
                if args.skip_existing and os.path.exists(out):
                    print(f"[dryrun] skip existing {arch} {shape_name} {mk}")
                    continue
                try:
                    run_one(arch, shape_name, mk)
                except Exception as e:  # noqa: BLE001 — report, keep sweeping
                    failures.append((arch, shape_name, mk, repr(e)[:300]))
                    print(f"[dryrun] FAIL {arch} x {shape_name} x {mk}: "
                          f"{repr(e)[:300]}")
                    _write({"arch": arch, "shape": shape_name, "mesh": mk,
                            "status": "fail", "error": repr(e)[:1000]})
    if failures:
        print(f"\n{len(failures)} failures:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nALL DRY-RUNS OK")


if __name__ == "__main__":
    main()
