"""Sharding rules: params / optimizer / batches / caches -> PartitionSpecs.

Policy (single pod, axes (data, model); multi-pod prepends "pod" to the
batch axes):
  * params: FSDP over "data" on the d_model-ish dim + tensor parallel over
    "model" on heads/d_ff/vocab; MoE experts over "model" (expert
    parallelism, matching the shard_map in moe.py); tiny leaves replicated.
  * batches: leading batch dim over ("pod","data") when divisible.
  * KV caches: batch over data axes; kv-heads over "model" when divisible,
    else the sequence dim; recurrent states shard their head dim.

Every rule checks divisibility and falls back to replication — a sharding
that does not divide is a silent correctness/perf bug, so the fallback is
logged via the returned spec itself (visible in the dry-run report).
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# leaf-name classes
_DOWN = ("wo", "w_down", "out_proj")
_UP = ("wq", "wk", "wv", "w_gate", "w_up", "in_proj", "w_q", "w_k", "w_v",
       "w_gates", "w_i", "w_f")
_EMBED = ("embed", "lm_head")
_REPLICATE = ("router", "g_bias", "f_bias", "A_log", "dt_bias", "D",
              "alpha", "enc_pos", "dec_pos", "out_norm", "r_gates")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _div(n: int, mesh, axis: str) -> bool:
    return axis in mesh.axis_names and n % mesh.shape[axis] == 0


def param_spec(path, leaf, mesh, cfg=None) -> P:
    name = _path_str(path).split("/")[-1]
    shape = leaf.shape
    nd = len(shape)
    if nd <= 1 or name in _REPLICATE:
        return P()
    # Head-aware TP (perf iteration #2, EXPERIMENTS.md §Perf): sharding an
    # attention projection over 'model' is only clean when the head count
    # divides the axis; otherwise the (B,S,H,hd) reshape crosses shard
    # boundaries and XLA replicates the attention compute.  Fall back to
    # FSDP-only for misaligned head counts.
    if cfg is not None and name in ("wq", "wk", "wv", "wo"):
        heads = cfg.num_heads if name in ("wq", "wo") else cfg.num_kv_heads
        if heads % mesh.shape.get("model", 1) != 0:
            spec = [None] * nd
            d_dim = nd - 2 if name in ("wq", "wk", "wv") else nd - 1
            if _div(shape[d_dim], mesh, "data"):
                spec[d_dim] = "data"
            return P(*spec)
    if name in _EMBED:
        return P(*( ["model" if _div(shape[0], mesh, "model") else None]
                   + [None] * (nd - 1)))
    # expert weights (..., E, d, f) detected by moe path
    if "moe" in _path_str(path) and nd >= 3 and name in ("w_gate", "w_up", "w_down"):
        spec = [None] * nd
        e_dim = nd - 3
        if _div(shape[e_dim], mesh, "model"):
            spec[e_dim] = "model"
        return P(*spec)
    if name in _DOWN:
        spec = [None] * nd
        if _div(shape[-2], mesh, "model"):
            spec[-2] = "model"
        if _div(shape[-1], mesh, "data"):
            spec[-1] = "data"
        return P(*spec)
    if name in _UP or nd >= 2:
        spec = [None] * nd
        if _div(shape[-2], mesh, "data"):
            spec[-2] = "data"
        if _div(shape[-1], mesh, "model"):
            spec[-1] = "model"
        return P(*spec)
    return P()


def params_shardings(params, mesh, cfg=None):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh,
                                         param_spec(path, leaf, mesh, cfg)),
        params)


def params_specs(params, mesh, cfg=None):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(path, leaf, mesh, cfg), params)


# ---------------------------------------------------------------- batches
def batch_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def _dp_size(mesh) -> int:
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return n


def batch_spec(shape, mesh) -> P:
    dp = batch_axes(mesh)
    if shape and shape[0] % _dp_size(mesh) == 0:
        return P(dp, *([None] * (len(shape) - 1)))
    return P(*([None] * len(shape)))


def batch_shardings(batch, mesh):
    return jax.tree.map(
        lambda x: NamedSharding(mesh, batch_spec(x.shape, mesh)), batch)


# ---------------------------------------------------------------- caches
def cache_spec(path, leaf, mesh, cfg) -> P:
    """KV caches / recurrent states (see module docstring)."""
    name = _path_str(path).split("/")[-1]
    shape = leaf.shape
    nd = len(shape)
    dp = batch_axes(mesh)
    if nd == 0 or name == "pos":
        return P()
    spec = [None] * nd

    if cfg.family in ("dense", "moe", "vlm", "encdec") or name in ("k", "v", "ck", "cv"):
        # (L|G, B, S, Kv, hd).  Preference: kv-heads over 'model'; else the
        # HEAD DIM (perf iteration #3, EXPERIMENTS.md §Perf: sequence-dim
        # sharding makes the per-step dynamic-update-slice a cross-shard op
        # and XLA falls back to full rematerialization of the cache).
        if nd == 5:
            if shape[1] % _dp_size(mesh) == 0:
                spec[1] = dp
            if _div(shape[3], mesh, "model"):
                spec[3] = "model"
            elif _div(shape[4], mesh, "model"):
                spec[4] = "model"
            return P(*spec)

    # recurrent states: find the batch dim (matches known B) then shard the
    # largest remaining dim over "model" if divisible.
    b_dim = None
    for i, s in enumerate(shape):
        if s == getattr(cfg, "_runtime_batch", -1):
            b_dim = i
            break
    if b_dim is not None and shape[b_dim] % _dp_size(mesh) == 0:
        spec[b_dim] = dp
    rest = [(s, i) for i, s in enumerate(shape) if i != b_dim and spec[i] is None]
    rest.sort(reverse=True)
    for s, i in rest:
        if _div(s, mesh, "model"):
            spec[i] = "model"
            break
    return P(*spec)


def cache_shardings(cache, mesh, cfg, batch_size: int):
    object.__setattr__(cfg, "_runtime_batch", batch_size)
    out = jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, cache_spec(path, leaf, mesh, cfg)),
        cache)
    return out


# ---------------------------------------------------------------- paged pools
def kv_shard_ways(mesh, cfg) -> int:
    """How many ways the paged KV pool's per-block BYTES divide over the
    'model' axis: kv-heads when divisible, else the head dim, else 1
    (replication fallback, mirroring ``cache_spec``'s preference order).
    ``PagedKV`` multiplies its default pool capacity by this — more blocks
    at the same per-device byte budget is the whole point of sharding the
    pool."""
    m = mesh.shape.get("model", 1)
    if m <= 1:
        return 1
    if cfg.num_kv_heads % m == 0 or cfg.head_dim % m == 0:
        return m
    return 1


def paged_cache_spec(path, leaf, mesh, cfg, data_shards: int = 1) -> P:
    """Specs for the PAGED cache pytree ``{k, v, table, pos}``.

    ``cache_spec`` assumes dense stacked ``(L, B, S, Kv, hd)`` slabs; the
    paged pool is ``(L, num_blocks, block_size, Kv, hd)`` — dim 1 is the
    BLOCK dim, not batch, so it must never take the dp axes unless the
    host-side allocator is actually per-shard (``data_shards`` matches the
    dp size and each shard owns a contiguous id range; see
    ``paged_cache.ShardedBlockPool``).  kv-heads shard over 'model' when
    divisible, falling back to the head dim, else replication — the same
    logged policy as ``cache_spec``."""
    name = _path_str(path).split("/")[-1]
    shape = leaf.shape
    nd = len(shape)
    dp = batch_axes(mesh)
    spec = [None] * nd
    if nd == 0 or name == "pos":
        return P()
    if name == "table":            # (B, max_blocks): slot rows over dp
        if shape[0] % _dp_size(mesh) == 0:
            spec[0] = dp
        return P(*spec)
    if nd == 5:                    # k/v pool (L, NB, bs, Kv, hd)
        if data_shards == _dp_size(mesh) > 1 and shape[1] % data_shards == 0:
            spec[1] = dp           # per-shard block ranges (ShardedBlockPool)
        if _div(shape[3], mesh, "model"):
            spec[3] = "model"
        elif _div(shape[4], mesh, "model"):
            spec[4] = "model"
        return P(*spec)
    return P(*spec)


def paged_cache_shardings(cache, mesh, cfg, data_shards: int = 1):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, paged_cache_spec(path, leaf, mesh, cfg, data_shards)),
        cache)


def replicated_shardings(tree, mesh):
    """Fully-replicated placement (the data-parallel edge's params)."""
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
