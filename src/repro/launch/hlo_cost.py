"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts ``while`` bodies ONCE, so any
scan-over-layers model under-reports flops/bytes/collectives by ~num_layers.
This module re-derives the three roofline inputs from the optimized HLO text
with call-graph multiplicities:

    * flops        — 2 * prod(result dims) * prod(contracting dims) per dot
                     (dots dominate; elementwise flops are ignored)
    * bytes        — operand + result bytes of every non-fused top-level op
                     (fusion internals don't touch HBM; approximate upper
                     bound on unique-buffer traffic)
    * collectives  — operand bytes per collective op, by type

Multiplicities: ENTRY x1; while body/cond x known_trip_count; fusion/call/
to_apply computations inherit the caller's multiplicity (flop-counted, not
byte-counted for fusion internals).
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

def cost_analysis_dict(compiled) -> Dict[str, float]:
    """Version-compatible ``compiled.cost_analysis()``: older JAX returns a
    one-element list of per-device dicts, newer returns the dict directly."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_CALLED_SINGLE_RE = re.compile(
    r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_CALLED_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _called_computations(rest: str):
    out = list(_CALLED_SINGLE_RE.findall(rest))
    for grp in _CALLED_BRANCH_RE.findall(rest):
        out.extend(re.findall(r"%?([\w.\-]+)", grp))
    return out
_TRIP_RE = re.compile(r'known_trip_count["\s:{]+n["\s:]+"?(\d+)')
_DOT_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_elems_bytes(dtype: str, dims: str) -> Tuple[int, int]:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n, n * _DTYPE_BYTES.get(dtype, 4)


def _result_bytes(result_part: str) -> int:
    return sum(_shape_elems_bytes(dt, dims)[1]
               for dt, dims in _SHAPE_RE.findall(result_part))


class Instruction:
    __slots__ = ("name", "op", "result_part", "rest", "operands")

    def __init__(self, name, op, result_part, rest, operands):
        self.name = name
        self.op = op
        self.result_part = result_part
        self.rest = rest
        self.operands = operands


def parse_module(text: str):
    """Returns (computations: name -> [Instruction], entry_name)."""
    comps: Dict[str, List[Instruction]] = {}
    entry = None
    current = None
    for raw in text.splitlines():
        s = raw.strip()
        if s.endswith("{") and ("->" in s or s.startswith("ENTRY")):
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)", s)
            if m:
                current = m.group(1)
                comps[current] = []
                if s.startswith("ENTRY"):
                    entry = current
            continue
        if s.startswith("}"):
            current = None
            continue
        if current is None or "=" not in s:
            continue
        m = _INSTR_RE.match(s)
        if not m:
            continue
        name, rhs = m.groups()
        # split result type part from op call:  "f32[2,3]{1,0} dot(...)"
        call = re.search(r"\b([\w\-]+)\(", rhs)
        if not call:
            continue
        op = call.group(1)
        result_part = rhs[:call.start()]
        rest = rhs[call.start():]
        inner = rest[rest.index("(") + 1:]
        depth = 1
        end = 0
        for i, ch in enumerate(inner):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_str = inner[:end]
        operands = re.findall(r"%([\w.\-]+)", operand_str)
        comps[current].append(Instruction(name, op, result_part,
                                          rest, operands))
    return comps, entry


def _multiplicities(comps, entry) -> Tuple[Dict[str, float], set]:
    """Computation -> execution count; plus the set of fusion-internal
    computations (their ops don't touch HBM)."""
    mult: Dict[str, float] = {}
    fusion_internal = set()
    stack = [(entry, 1.0)]
    while stack:
        comp, m = stack.pop()
        if comp not in comps:
            continue
        mult[comp] = mult.get(comp, 0.0) + m
        for ins in comps[comp]:
            called = _called_computations(ins.rest)
            if not called:
                continue
            if ins.op == "while":
                t = _TRIP_RE.search(ins.rest)
                trip = float(t.group(1)) if t else 1.0
                for c in called:
                    stack.append((c, m * trip))
            elif ins.op == "fusion":
                for c in called:
                    fusion_internal.add(c)
                    stack.append((c, m))
            else:   # call / conditional / reduce to_apply / sort comparator
                for c in called:
                    fusion_internal.add(c) if ins.op in ("reduce", "sort",
                                                         "scatter",
                                                         "reduce-window") \
                        else None
                    stack.append((c, m))
    return mult, fusion_internal


def _symbol_table(instrs) -> Dict[str, str]:
    return {i.name: i.result_part for i in instrs}


def _dot_flops(ins: Instruction, sym: Dict[str, str]) -> float:
    res = _SHAPE_RE.findall(ins.result_part)
    if not res:
        return 0.0
    out_elems = 1
    for dt, dims in res[:1]:
        out_elems, _ = _shape_elems_bytes(dt, dims)
    m = _DOT_CONTRACT_RE.search(ins.rest)
    contract = 1
    if m and ins.operands:
        lhs = ins.operands[0]
        lhs_part = sym.get(lhs, "")
        shp = _SHAPE_RE.findall(lhs_part)
        # inline operand types take precedence if present in the call
        inline = _SHAPE_RE.findall(ins.rest.split("(", 1)[1].split(")")[0])
        if inline:
            shp = inline[:1]
        if shp:
            dims = [int(d) for d in shp[0][1].split(",") if d]
            for ci in (int(x) for x in m.group(1).split(",") if x):
                if ci < len(dims):
                    contract *= dims[ci]
    return 2.0 * out_elems * contract


def analyze_hlo(text: str) -> Dict[str, float]:
    comps, entry = parse_module(text)
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0}
    mult, fusion_internal = _multiplicities(comps, entry)

    flops = 0.0
    bytes_ = 0.0
    coll: Dict[str, float] = {c: 0.0 for c in COLLECTIVES}
    skip_bytes_ops = {"parameter", "constant", "tuple", "get-tuple-element",
                      "bitcast", "after-all", "partition-id", "replica-id",
                      "iota"}

    for comp, instrs in comps.items():
        m = mult.get(comp, 0.0)
        if m == 0.0:
            continue
        sym = _symbol_table(instrs)
        for ins in instrs:
            if ins.op in ("dot", "dot-general"):
                flops += m * _dot_flops(ins, sym)
            if ins.op.rstrip("-start") in COLLECTIVES or any(
                    ins.op == c or ins.op == c + "-start" for c in COLLECTIVES):
                base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
                ob = sum(_result_bytes(sym.get(o, "")) for o in ins.operands)
                if ob == 0:
                    ob = _result_bytes(ins.result_part)
                coll[base] = coll.get(base, 0.0) + m * ob
            if comp in fusion_internal or ins.op in skip_bytes_ops \
                    or ins.op.endswith("-done"):
                continue
            if ins.op == "dynamic-update-slice":
                # XLA updates in place (buffer aliasing): traffic is the
                # update operand read + slice write, NOT the full buffer.
                upd = _result_bytes(sym.get(ins.operands[1], "")) \
                    if len(ins.operands) > 1 else 0
                bytes_ += m * 2 * upd
                continue
            if ins.op in ("dynamic-slice", "slice", "gather"):
                # reads only the slice (= result), writes it once.
                bytes_ += m * 2 * _result_bytes(ins.result_part)
                continue
            ob = sum(_result_bytes(sym.get(o, "")) for o in ins.operands)
            bytes_ += m * (_result_bytes(ins.result_part) + ob)

    out = {"flops": flops, "bytes": bytes_,
           "collective_bytes": sum(coll.values())}
    out.update({f"coll_{k}": v for k, v in coll.items()})
    return out
