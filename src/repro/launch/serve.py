"""Collaborative serving launcher: edge SLM + cloud LLM behind the batched
continuous-batching scheduler, with the collaboration decision surface
picked by ``--policy`` (a ``core/policy.py::CollabPolicy``).

    PYTHONPATH=src python -m repro.launch.serve --edge smollm-135m \
        --cloud granite-8b --requests 32 --reduced \
        --scheduler batched --batch-size 8 --policy cascade

Shipped policies: ``threshold`` (confidence gate -> cloud regen),
``speculative`` / ``skeleton`` (same gate into token-level mixture / task
division), ``cascade`` (cost-ordered multi-tier cascade), ``bandit``
(UCB/LinUCB online routing learned from completion feedback), ``budget``
(per-request cloud-token budget with SLA classes, degrading to edge-accept
when spent).  ``--escalation`` survives as a deprecated alias mapping onto
the matching policy.

``--scheduler per-request`` runs the legacy one-at-a-time reference loop
(useful for tracing and as the baseline the batched numbers are quoted
against).

Any edge/cloud family pair works — mixed ones included, e.g.::

    PYTHONPATH=src python -m repro.launch.serve --edge mamba2-370m \
        --cloud granite-8b --reduced --threshold -1

Recurrent-state edges (mamba2 "ssm", zamba2 "hybrid", "xlstm") ride the
same batched scheduler and grouped speculative escalation as the KV
families: their rewinds are batched accepted-prefix replays behind the
``SequenceState`` adapters in ``core/seq_state.py``.

KV layout (batched scheduler): ``--kv-layout paged`` (the default via
``auto`` on KV-cache transformer families) backs the slots with a shared
pool of ``--kv-block-size``-token blocks and per-slot block tables
(``core/paged_cache.py``) — per-request cache capacity instead of padding
every slot to the longest request.  Requests sharing a block-aligned
prompt prefix (identical system prompts, retried requests) map the shared
blocks physically — refcounts plus copy-on-write at first divergence — and
``--kv-blocks`` caps the pool: when it runs full the scheduler preempts
the slot holding its reservation longest (KV swapped to a host buffer,
restored bit-for-bit later) instead of deferring forever, so an
overcommitted pool still completes every request.  The default sizes the
pool to the dense worst case.  ``--kv-layout dense`` keeps the padded-slab
layout as the parity oracle.

Speculation lanes (batched scheduler): ``--spec-mode`` picks how a
grouped speculative escalation drafts and verifies —

* ``linear`` (default): the classic gamma-token draft tape; any edge/cloud
  family pair, dense or paged group states.
* ``tree``: each slot drafts a packed token TREE (``--spec-tree-width``
  first-level branches, depth ``--gamma``) expanded top-k level-by-level,
  and the cloud verifies ALL candidate branches in ONE tree-masked pass
  (the Pallas tree-attention kernel on TPU) — the longest target-
  consistent root path is accepted, so one verify can commit several
  tokens down the most probable branch.  Dense-attention families only;
  other families fall back to linear (see ``spec_mode`` in the stats).
* ``self``: self-speculative — the EDGE model's early-exit prefix
  (``--spec-exit-layer`` blocks, default half depth) drafts for its own
  full-depth verify through the shared cache.  No second model, no cloud
  verifier: traces carry ``cloud_passes=0``.

All three lanes are lossless against their verifier (greedy outputs are
bit-identical to decoding the verifier alone); the stats line reports
``spec_accept_rate`` and ``accepted_tokens_per_step`` so the lanes can be
compared on acceptance, and ``benchmarks/bench_serving.py --arm
tree_spec`` quotes req/s across them.

Open-loop traffic (batched scheduler): ``--arrival poisson|bursty`` stops
pretending every request is already waiting at t=0 and instead submits
them at sampled arrival times (``--arrival-rate`` req/s long-run average;
bursty adds on/off bursts at a peak rate) against a deterministic virtual
clock (``core/traffic.py``), so the headline numbers become the
latency-honest ones: p50/p99 TTFT measured from SUBMIT (queueing delay
included), p50/p99 TPOT, and — with ``--slo-ms`` — SLO attainment and
goodput-under-SLO.  ``--prefill-chunk`` caps how many prompt tokens a
single tick may prefill, so a long prompt no longer blocks every decoding
request for its whole prefill (chunked prefill interleaves with decode).

Serve-time adaptation (batched scheduler): ``--adapt distill|lora``
closes the inference/learning loop — every completion's supervision
triple (prompt, discarded edge draft, cloud-corrected continuation, plus
``--adapt-topk`` teacher logits when the wave already paid for the cloud
pass) retires into a bounded ``data/feedback_store.FeedbackStore``, and
every ``--adapt-interval`` completions a ``core/adaptation.py``
``AdaptationLoop`` takes jitted background train steps (forward KD on
the full edge params, or LoRA adapter-only updates on the frozen base)
and hot-swaps the result into live serving between scheduler ticks.  The
swap is a pure pytree swap — same treedef/shapes — so the steady state
stays recompile-free across it (the ``bench_serving.py``
``online_adaptation`` arm asserts this with the compile counter, along
with cloud-token share falling as the edge model improves).
``--adapt-checkpoint PATH`` persists the learned artifact on exit (the
LoRA adapter pytree, or the distilled edge params): restore it with
``training/checkpoint.restore``.  Omitting ``--adapt`` keeps serving
byte-identical to the adaptation-free engine.

Running on a mesh: ``--mesh data,model`` shards the batched scheduler over
the local devices — the cloud verifier runs TENSOR-PARALLEL over the
``model`` axis (params partitioned by ``launch/sharding.py``'s rules),
edge drafts stay DATA-parallel over ``data`` (params replicated, batch
slots and the paged block pool split per data shard), and each grouped
escalation wave crosses the mesh as one all-gather of the draft tape
before the TP verify.  Axis sizes are inferred (near-balanced factors of
``jax.device_count()``, larger trailing: 8 devices -> (2, 4)) or pinned
explicitly: ``--mesh data=2,model=4``.  Per-shard KV pools keep the
single-device per-device byte budget, so total ``kv_capacity_blocks``
scales with the shard count (reported in the stats line).  No
accelerators handy? Simulate: set the flag BEFORE the process starts jax::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m repro.launch.serve --reduced \\
        --scheduler batched --mesh data,model

Omitting ``--mesh`` takes the exact single-device code path (no mesh
context, no collectives in any trace).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.engine import CollaborativeEngine
from repro.core.policy import (POLICIES, ThresholdPolicy, make_policy,
                               policy_from_legacy)
from repro.core.scheduler import BatchedEngine
from repro.core.traffic import (bursty_arrivals, poisson_arrivals, replay)
from repro.data import SyntheticLM
from repro.models import Model


def build_policy(args):
    """Construct the ``CollabPolicy`` named by ``--policy`` (or by the
    deprecated ``--escalation`` alias) from its CLI kwargs."""
    if args.escalation is not None:
        if args.policy is not None:
            raise SystemExit("pass --policy or --escalation, not both")
        pol = policy_from_legacy(args.escalation, args.threshold)
        print(f"--escalation is deprecated; use --policy {pol.name}")
        return pol
    name = args.policy or "speculative"
    if name in ("threshold", "speculative", "skeleton"):
        return make_policy(name, threshold=args.threshold)
    if name == "cascade":
        ts = tuple(float(t) for t in args.cascade_thresholds.split(","))
        return make_policy(name, thresholds=ts)
    if name == "bandit":
        return make_policy(name, kind=args.bandit_kind,
                           cost_weight=args.bandit_cost_weight)
    return make_policy(name, threshold=args.threshold,   # budget
                       tokens_per_request=args.budget_tokens)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--edge", default="smollm-135m")
    ap.add_argument("--cloud", default="granite-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--gamma", type=int, default=4)
    ap.add_argument("--policy", default=None, choices=sorted(POLICIES),
                    help="collaboration policy (CollabPolicy); default: "
                         "speculative")
    ap.add_argument("--threshold", type=float, default=0.6,
                    help="uncertainty gate (threshold-family and budget "
                         "policies)")
    ap.add_argument("--cascade-thresholds", default="0.45,0.25",
                    help="comma-separated per-tier acceptance thresholds "
                         "(cascade policy)")
    ap.add_argument("--bandit-kind", default="ucb",
                    choices=["ucb", "linucb"])
    ap.add_argument("--bandit-cost-weight", type=float, default=0.3,
                    help="reward = quality - w * cloud-token share")
    ap.add_argument("--budget-tokens", type=float, default=8.0,
                    help="cloud tokens accrued per admitted request "
                         "(budget policy)")
    ap.add_argument("--spec-mode", default=None,
                    choices=["linear", "tree", "self"],
                    help="speculation lane for grouped speculative "
                         "escalations (batched scheduler): linear draft "
                         "tape, packed token-tree verify, or "
                         "self-speculative early-exit drafting; default: "
                         "linear")
    ap.add_argument("--spec-tree-width", type=int, default=None,
                    help="first-level branches of the draft tree "
                         "(--spec-mode tree); default 2")
    ap.add_argument("--spec-exit-layer", type=int, default=None,
                    help="draft exit layer (--spec-mode self); default: "
                         "half the edge model's depth")
    ap.add_argument("--escalation", default=None,
                    choices=["speculative", "cloud", "skeleton"],
                    help="DEPRECATED: legacy mode name; use --policy")
    ap.add_argument("--scheduler", default="batched",
                    choices=["batched", "per-request"],
                    help="batched continuous-batching scheduler vs the "
                         "legacy one-request-at-a-time reference loop")
    ap.add_argument("--batch-size", type=int, default=8,
                    help="scheduler slots (batched scheduler only)")
    ap.add_argument("--tick-tokens", type=int, default=16,
                    help="decode steps per jitted scheduler tick")
    ap.add_argument("--kv-layout", default="auto",
                    choices=["auto", "paged", "dense"],
                    help="KV cache layout (batched scheduler): paged = "
                         "shared block pool + per-slot block tables; dense "
                         "= slots padded to a common slot_len (the parity "
                         "oracle); auto = paged where the model families "
                         "support it")
    ap.add_argument("--kv-block-size", type=int, default=32,
                    help="tokens per KV block (paged layout)")
    ap.add_argument("--kv-blocks", type=int, default=None,
                    help="total KV pool blocks incl. the trap block (paged "
                         "layout); when the pool runs full the scheduler "
                         "preempts-by-swap (host-staged KV) so every "
                         "request still completes. Default: sized to the "
                         "dense worst case")
    ap.add_argument("--arrival", default="none",
                    choices=["none", "poisson", "bursty"],
                    help="open-loop arrival process (batched scheduler): "
                         "submit requests at sampled times against a "
                         "virtual clock instead of all-at-t=0")
    ap.add_argument("--arrival-rate", type=float, default=50.0,
                    help="long-run average arrival rate, requests/second "
                         "of virtual time")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="TTFT SLO in (virtual) ms; enables SLO "
                         "attainment + goodput-under-SLO reporting and "
                         "feeds deadline-aware policies")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="max prompt tokens prefilled per scheduler tick "
                         "(chunked prefill); 0 disables chunking, default "
                         "= --tick-tokens")
    ap.add_argument("--adapt", default=None, choices=["distill", "lora"],
                    help="serve-time adaptation (batched scheduler): "
                         "capture completion triples into a FeedbackStore "
                         "and hot-swap background-trained edge weights "
                         "(distill = forward KD on full params, lora = "
                         "adapter-only on the frozen base)")
    ap.add_argument("--adapt-interval", type=int, default=16,
                    help="take an adaptation update every this many "
                         "completions (0 = capture-only)")
    ap.add_argument("--adapt-topk", type=int, default=8,
                    help="teacher logits kept per cloud-generated token "
                         "(distill mode; rides the wave's existing "
                         "device pull)")
    ap.add_argument("--adapt-checkpoint", default=None, metavar="PATH",
                    help="persist the learned artifact on exit: the LoRA "
                         "adapter pytree (--adapt lora) or the distilled "
                         "edge params (--adapt distill)")
    ap.add_argument("--mesh", default=None, metavar="AXES",
                    help="shard the batched scheduler over the local "
                         "devices: comma-separated axis names, e.g. "
                         "'data,model' (sizes inferred) or "
                         "'data=2,model=4' (pinned); see the module "
                         "docstring's 'Running on a mesh' section")
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    e_cfg = get_config(args.edge)
    c_cfg = get_config(args.cloud)
    if args.reduced:
        e_cfg, c_cfg = e_cfg.reduced(), c_cfg.reduced()
    # shared vocab required for token-level collaboration
    v = min(e_cfg.vocab_size, c_cfg.vocab_size)
    e_cfg, c_cfg = e_cfg.replace(vocab_size=v), c_cfg.replace(vocab_size=v)

    edge, cloud = Model(e_cfg), Model(c_cfg)
    ep = edge.init(jax.random.PRNGKey(0))
    cp = cloud.init(jax.random.PRNGKey(1))

    synth = SyntheticLM(v)
    rng = np.random.default_rng(0)
    prompts = [synth.sample(rng, i % synth.n_domains, args.prompt_len)
               for i in range(args.requests)]
    paths = {}

    policy = build_policy(args)
    if args.scheduler == "per-request" and not isinstance(policy,
                                                          ThresholdPolicy):
        # serve_reference is the legacy per-token oracle: it cannot honor
        # the assign/decide/feedback hooks, so running it would silently
        # serve speculative@0.6 while reporting this policy's name
        raise SystemExit(
            f"--scheduler per-request only honors the threshold-family "
            f"policies; run --policy {policy.name} on --scheduler batched")
    if args.arrival != "none" and args.scheduler != "batched":
        raise SystemExit("--arrival needs --scheduler batched (the "
                         "per-request loop has no admission queue)")
    if args.mesh is not None and args.scheduler != "batched":
        raise SystemExit("--mesh needs --scheduler batched (the "
                         "per-request loop is single-device)")
    if args.spec_mode not in (None, "linear") \
            and args.scheduler != "batched":
        raise SystemExit("--spec-mode tree/self needs --scheduler batched "
                         "(the per-request loop only drafts linear tapes)")
    if args.adapt is not None and args.scheduler != "batched":
        raise SystemExit("--adapt needs --scheduler batched (capture rides "
                         "the batched scheduler's retirement path)")
    adaptation = None
    if args.adapt is not None:
        from repro.core.adaptation import AdaptationLoop
        adaptation = AdaptationLoop(mode=args.adapt,
                                    interval=args.adapt_interval,
                                    topk=args.adapt_topk)
    mesh = None
    if args.mesh is not None:
        from repro.launch.mesh import parse_mesh_arg
        mesh = parse_mesh_arg(args.mesh)
        print(f"mesh: {dict(mesh.shape)} over {jax.device_count()} devices")
    if args.scheduler == "batched":
        eng = BatchedEngine(edge, cloud, batch_size=args.batch_size,
                            gamma=args.gamma, temperature=0.0,
                            policy=policy,
                            tick_tokens=args.tick_tokens,
                            kv_layout=args.kv_layout,
                            kv_block_size=args.kv_block_size,
                            kv_blocks=args.kv_blocks,
                            slo_ms=args.slo_ms,
                            prefill_chunk=args.prefill_chunk,
                            spec_mode=args.spec_mode,
                            spec_tree_width=args.spec_tree_width,
                            spec_exit_layer=args.spec_exit_layer,
                            mesh=mesh, adaptation=adaptation)
        t0 = time.perf_counter()
        if args.arrival != "none":
            gen = (poisson_arrivals if args.arrival == "poisson"
                   else bursty_arrivals)
            at = gen(args.arrival_rate, args.requests, seed=0)
            traces = replay(eng, ep, cp, prompts, args.max_new, at)
        else:
            traces = eng.serve_batch(
                ep, cp, prompts, args.max_new,
                domains=[i % synth.n_domains
                         for i in range(args.requests)])
        dt = time.perf_counter() - t0
        for i, tr in enumerate(traces):
            paths[tr.path] = paths.get(tr.path, 0) + 1
            print(f"req {i:3d} path={tr.path:12s} unc={tr.uncertainty:.3f} "
                  f"edge_calls={tr.edge_calls} cloud_passes={tr.cloud_passes}")
        stats = eng.stats()
    else:
        eng = CollaborativeEngine(edge, cloud, gamma=args.gamma,
                                  temperature=0.0, policy=policy)
        t0 = time.perf_counter()
        for i, prompt in enumerate(prompts):
            tr = eng.serve_reference(ep, cp, prompt, args.max_new)
            paths[tr.path] = paths.get(tr.path, 0) + 1
            print(f"req {i:3d} path={tr.path:12s} unc={tr.uncertainty:.3f} "
                  f"edge_calls={tr.edge_calls} cloud_passes={tr.cloud_passes}")
        dt = time.perf_counter() - t0
        stats = eng.stats()

    toks = args.requests * args.max_new
    print(f"\n{args.requests} requests in {dt:.1f}s "
          f"({args.requests / dt:.2f} req/s, {toks / dt:.1f} tok/s); "
          f"paths: {paths}; cache hit rate {stats['cache_hit_rate']:.2f}")
    print(f"policy: {stats['policy']} "
          + " ".join(f"{k.removeprefix('policy_')}={v}"
                     for k, v in stats.items() if k.startswith("policy_")))
    if stats.get("spec_lanes") and any(
            c["member_rounds"] for c in stats["spec_lanes"].values()):
        print(f"spec: mode={stats['spec_mode']} "
              f"accept_rate={stats['spec_accept_rate']:.2f} "
              f"accepted_tokens_per_step="
              f"{stats['accepted_tokens_per_step']:.2f} "
              + " ".join(f"{m}[draft={c['draft_tokens']} "
                         f"verify={c['verify_tokens']} "
                         f"accepted={c['accepted_tokens']} "
                         f"emitted={c['emitted_tokens']} "
                         f"rounds={c['member_rounds']}]"
                         for m, c in stats["spec_lanes"].items()))
    if "kv_peak_bytes" in stats:
        print(f"kv: layout={stats['kv_layout']} "
              f"peak={stats['kv_peak_bytes'] / 1e6:.2f}MB "
              f"capacity={stats['kv_capacity_bytes'] / 1e6:.2f}MB"
              + (f" blocks_peak={stats['kv_blocks_peak']}"
                 if "kv_blocks_peak" in stats else "")
              + (f" shards={stats['kv_shards']} "
                 f"capacity_blocks={stats['kv_capacity_blocks']}"
                 if stats.get("kv_shards", 1) > 1 else ""))
        if stats.get("kv_prefix_hits") or stats.get("preemptions"):
            print(f"kv: prefix_hits={stats.get('kv_prefix_hits', 0)} "
                  f"shared_blocks={stats.get('kv_shared_blocks', 0)} "
                  f"cow_forks={stats.get('kv_cow_forks', 0)} "
                  f"preemptions={stats.get('preemptions', 0)} "
                  f"swaps={stats.get('kv_swaps', 0)}")
    if "ttft_p50_ms" in stats:
        unit = "virtual ms" if args.arrival != "none" else "ms"
        print(f"latency ({unit}): "
              f"ttft p50={stats['ttft_p50_ms']:.1f} "
              f"p99={stats['ttft_p99_ms']:.1f} "
              f"tpot p50={stats['tpot_p50_ms']:.2f} "
              f"p99={stats['tpot_p99_ms']:.2f} "
              f"makespan={stats['makespan_ms']:.0f} "
              f"(swapped={stats['swapped_requests']} "
              f"deferred={stats['deferred_admissions']})")
        if args.slo_ms is not None:
            print(f"slo: ttft<={args.slo_ms:.0f}ms "
                  f"attainment={stats['slo_attainment']:.2f} "
                  f"goodput={stats['goodput_slo']:.2f} req/s")
    if "adaptation" in stats:
        a = stats["adaptation"]
        loss = "n/a" if a["last_loss"] is None else f"{a['last_loss']:.4f}"
        print(f"adapt: mode={a['mode']} observed={a['observed']} "
              f"updates={a['updates']} steps={a['train_steps']} "
              f"swaps={a['swaps']} loss={loss} "
              f"store={a['store_size']}/{a['store_capacity']} "
              f"(evicted={a['store_evicted']})")
    if args.adapt_checkpoint is not None and adaptation is not None:
        from repro.training import checkpoint
        artifact = adaptation.adapters if args.adapt == "lora" \
            else adaptation.latest
        if artifact is None:
            print(f"adapt: nothing learned yet — skipping checkpoint "
                  f"{args.adapt_checkpoint}")
        else:
            checkpoint.save(args.adapt_checkpoint, artifact,
                            step=adaptation.steps)
            print(f"adapt: saved {args.adapt} artifact to "
                  f"{args.adapt_checkpoint} "
                  f"(restore via training/checkpoint.restore)")


if __name__ == "__main__":
    main()
