"""Collaborative serving launcher: edge SLM + cloud LLM behind the
CollaborativeEngine (task-level mixture) with speculative escalation.

    PYTHONPATH=src python -m repro.launch.serve --edge smollm-135m \
        --cloud granite-8b --requests 16 --reduced
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.engine import CollaborativeEngine
from repro.data import SyntheticLM
from repro.models import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--edge", default="smollm-135m")
    ap.add_argument("--cloud", default="granite-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--gamma", type=int, default=4)
    ap.add_argument("--threshold", type=float, default=0.6)
    ap.add_argument("--escalation", default="speculative",
                    choices=["speculative", "cloud", "skeleton"])
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    e_cfg = get_config(args.edge)
    c_cfg = get_config(args.cloud)
    if args.reduced:
        e_cfg, c_cfg = e_cfg.reduced(), c_cfg.reduced()
    # shared vocab required for token-level collaboration
    v = min(e_cfg.vocab_size, c_cfg.vocab_size)
    e_cfg, c_cfg = e_cfg.replace(vocab_size=v), c_cfg.replace(vocab_size=v)

    edge, cloud = Model(e_cfg), Model(c_cfg)
    ep = edge.init(jax.random.PRNGKey(0))
    cp = cloud.init(jax.random.PRNGKey(1))
    eng = CollaborativeEngine(edge, cloud, gamma=args.gamma, temperature=0.0,
                              escalate_threshold=args.threshold,
                              escalation=args.escalation)

    synth = SyntheticLM(v)
    rng = np.random.default_rng(0)
    paths = {}
    t0 = time.time()
    for i in range(args.requests):
        prompt = synth.sample(rng, i % synth.n_domains, args.prompt_len)
        tr = eng.serve(ep, cp, prompt, args.max_new)
        paths[tr.path] = paths.get(tr.path, 0) + 1
        print(f"req {i:3d} path={tr.path:12s} unc={tr.uncertainty:.3f} "
              f"edge_calls={tr.edge_calls} cloud_passes={tr.cloud_passes}")
    print(f"\n{args.requests} requests in {time.time()-t0:.1f}s; "
          f"paths: {paths}; cache hit rate "
          f"{eng.stats()['cache_hit_rate']:.2f}")


if __name__ == "__main__":
    main()
