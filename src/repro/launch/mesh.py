"""Production mesh construction.

Single pod: (16, 16) = 256 v5e chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — "pod" is
extra data parallelism across the DCI/ICI boundary (and models the survey's
cloud/edge pool boundary for the collaborative engine).

Functions, not module constants: importing this module must not touch jax
device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 2):
    """Tiny mesh for CPU tests (requires xla_force_host_platform_device_count
    >= data*model in the test process)."""
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a != "model")


def mesh_devices(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
