"""Production mesh construction.

Single pod: (16, 16) = 256 v5e chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — "pod" is
extra data parallelism across the DCI/ICI boundary (and models the survey's
cloud/edge pool boundary for the collaborative engine).

Functions, not module constants: importing this module must not touch jax
device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 2):
    """Tiny mesh for CPU tests (requires xla_force_host_platform_device_count
    >= data*model in the test process)."""
    return jax.make_mesh((data, model), ("data", "model"))


def _balanced_factor(rem: int, k: int) -> int:
    """Smallest divisor of ``rem`` >= rem**(1/k) — peeling these off from
    the TRAILING axis backward splits ``rem`` into k near-balanced factors
    with the larger shares on later axes (the 'model' axis sits last in
    serving specs, and tensor parallelism wants the bigger/faster slice)."""
    if k <= 1:
        return rem
    t = rem ** (1.0 / k)
    for f in range(max(2, math.ceil(t)), rem + 1):
        if rem % f == 0:
            return f
    return rem


def parse_mesh_arg(spec: str):
    """Mesh from a CLI axis spec over the LOCAL devices.

    ``"data,model"`` sizes the axes automatically (near-balanced factors of
    ``jax.device_count()``, larger factors trailing: 8 devices -> (2, 4));
    ``"data=2,model=4"`` pins sizes explicitly (mixes allowed — pinned
    axes are honored, the rest split the remaining devices)."""
    names, sizes = [], []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        name, _, size = part.partition("=")
        names.append(name)
        sizes.append(int(size) if size else 0)
    if not names:
        raise ValueError(f"empty mesh spec {spec!r}")
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate axis in mesh spec {spec!r}")
    ndev = jax.device_count()
    fixed = math.prod(s for s in sizes if s)
    if fixed == 0 or ndev % fixed != 0:
        raise ValueError(f"mesh spec {spec!r} needs a divisor of the "
                         f"{ndev} local devices, got fixed product {fixed}")
    rem = ndev // fixed
    free = [i for i, s in enumerate(sizes) if s == 0]
    for j, i in enumerate(reversed(free)):
        f = _balanced_factor(rem, len(free) - j)
        sizes[i] = f
        rem //= f
    if rem != 1:
        raise ValueError(f"mesh spec {spec!r} does not use all {ndev} "
                         f"local devices (shape {tuple(sizes)})")
    return jax.make_mesh(tuple(sizes), tuple(names))


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a != "model")


def mesh_devices(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
