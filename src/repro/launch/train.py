"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 100 --batch 8 --seq 128 --reduced

On real hardware drop --reduced and pass --mesh to train the full config on
the production mesh (the dry-run validates those graphs in this container).
"""
from __future__ import annotations

import argparse

import jax

from repro import runtime
from repro.configs import get_config
from repro.data import batches
from repro.launch import sharding as SH
from repro.launch.mesh import make_production_mesh
from repro.models import Model
from repro.training import AdamW, cosine_schedule, train
from repro.training.checkpoint import save


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-scale variant (CPU-friendly)")
    ap.add_argument("--mesh", choices=["none", "single", "multi"],
                    default="none")
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--save", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params ({'reduced' if args.reduced else 'full'})")

    opt = AdamW(lr=args.lr, schedule=cosine_schedule(args.steps // 10, args.steps))
    it = batches(cfg, args.batch, args.seq)

    ctx = None
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
        ctx = runtime.mesh_context(mesh)
        ctx.__enter__()
        params = jax.device_put(params, SH.params_shardings(params, mesh))

    res = train(model, params, it, steps=args.steps, opt=opt, remat=args.remat)
    if ctx is not None:
        ctx.__exit__(None, None, None)
    if args.save:
        save(args.save, res["params"], step=args.steps)
        print(f"saved to {args.save}")


if __name__ == "__main__":
    main()
