#!/usr/bin/env bash
# Tier-1 verify (see ROADMAP.md). Extra pytest args pass through, e.g.:
#   scripts/tier1.sh -m "not slow"
#   scripts/tier1.sh -m "not slow" --junitxml=test-report.xml
# Set TIER1_NO_FAILFAST=1 to drop the default -x so report files cover the
# whole suite (CI artifact mode).
set -euo pipefail
cd "$(dirname "$0")/.."
args=(-q)
[[ -n "${TIER1_NO_FAILFAST:-}" ]] || args+=(-x)
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest "${args[@]}" "$@"
