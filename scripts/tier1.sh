#!/usr/bin/env bash
# Tier-1 verify (see ROADMAP.md). Extra pytest args pass through, e.g.:
#   scripts/tier1.sh -m "not slow"
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -x -q "$@"
