#!/usr/bin/env python
"""repro-lint: static analysis for the repo's serving invariants.

Walks the given files/directories and reports violations of:

  R0  suppression hygiene — markers must carry a reason
  R1  host syncs inside @hot_path functions
  R2  recompile hazards in jitted code
  R3  Pallas kernel hygiene (pure index maps, no side effects,
      ref.py oracle + interpret dispatch)
  R4  protocol conformance + scheduler layout/family purity

Exit status: 0 when clean, 1 when any unsuppressed finding remains,
2 on usage errors.

Suppression syntax
------------------
A finding is suppressed by a marker on the SAME line or the LINE ABOVE:

    x = int(np.asarray(v))  # repro-lint: ok(R1, one batched pull per wave)

    # repro-lint: ok(R2, branch is on a static config flag)
    if mode == "fast":
        ...

The reason is REQUIRED: ``# repro-lint: ok(R1)`` suppresses nothing and
is itself reported (rule R0), so every shipped suppression documents why
the construct is deliberate.
"""
import argparse
import json
import sys
from pathlib import Path

_SRC = Path(__file__).resolve().parents[1] / "src"
if str(_SRC) not in sys.path:
    sys.path.insert(0, str(_SRC))

from repro.analysis import RULE_DOCS, RULES, analyze_paths  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro_lint",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("paths", nargs="*", default=["src", "tests",
                                                 "benchmarks"],
                    help="files or directories to analyze "
                         "(default: src tests benchmarks)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run (default: all), "
                         "e.g. --rules R1,R3")
    ap.add_argument("--format", choices=("human", "json"), default="human",
                    help="report format on stdout")
    ap.add_argument("--json-out", default=None, metavar="PATH",
                    help="additionally write the JSON report to PATH")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule ids + one-line docs and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid in sorted(RULES):
            print(f"{rid}  {RULE_DOCS[rid]}")
        return 0

    rules = None
    if args.rules:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]
        unknown = [r for r in rules if r not in RULES]
        if unknown:
            print(f"unknown rule(s): {', '.join(unknown)}; "
                  f"known: {', '.join(sorted(RULES))}", file=sys.stderr)
            return 2

    findings = analyze_paths(args.paths or ["src", "tests", "benchmarks"],
                             rules)
    report = {"findings": [f.to_dict() for f in findings],
              "count": len(findings),
              "rules": sorted(rules or RULES)}
    if args.json_out:
        Path(args.json_out).write_text(json.dumps(report, indent=2))
    if args.format == "json":
        print(json.dumps(report, indent=2))
    else:
        for f in findings:
            print(f.format())
        print(f"repro-lint: {len(findings)} finding(s) over rules "
              f"{','.join(report['rules'])}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
