#!/usr/bin/env python
"""Assert the serving benchmark artifact (``BENCH_serving.json``) is sane.

CI's bench-smoke job runs this right after ``benchmarks/bench_serving.py``;
the unit test (``tests/test_check_bench.py``) runs it over synthetic JSON
so an assert regression fails locally, not just in Actions.

    python scripts/check_bench.py [--path BENCH_serving.json]
        [--require-multi-device]

Exit code 0 = every arm present and within bounds; any failed check raises
(non-zero exit) with the offending row in the message.
"""
from __future__ import annotations

import argparse
import json
import sys


def check(rows: dict, *, require_multi_device: bool = False, out=print) -> None:
    """Validate a loaded BENCH_serving.json result set.  Raises
    ``AssertionError``/``KeyError`` on the first violated bound."""
    arm = rows["paged_vs_dense"]
    assert arm["paged"]["kv_peak_bytes"] < arm["dense"]["kv_peak_bytes"]
    assert arm["kv_savings_x"] > 1.0
    out(f"paged KV savings: {arm['kv_savings_x']:.2f}x")

    sp = rows["shared_prefix"]
    assert sp["kv_savings_x"] > 1.5, sp
    assert sp["prefix_hits"] > 0 and sp["shared_blocks"] > 0, sp
    sp_x, sp_n = sp["kv_savings_x"], sp["shared_blocks"]
    out(f"shared-prefix KV savings: {sp_x:.2f}x over {sp_n} shared blocks")

    oc = rows["overcommit"]
    assert oc["deferred_forever"] == 0, oc
    assert oc["completed"] == rows["config"]["requests"], oc
    assert oc["preemptions"] > 0, oc
    out(f"overcommit: all {oc['completed']} requests served,")
    out(f"  {oc['preemptions']} preemptions, {oc['deferred_forever']} deferred")

    ol = rows["open_loop"]
    for arm_name in ("poisson", "bursty_2x"):
        a = ol[arm_name]
        keys = ("ttft_p50_ms", "ttft_p99_ms", "tpot_p50_ms", "goodput_slo")
        for k in keys + ("slo_attainment",):
            assert k in a, (arm_name, k)
        assert a["completed"] == a["requests"], (arm_name, a)
        assert a["ttft_p99_ms"] > 0, (arm_name, a)
        ttft = f"{a['ttft_p50_ms']:.1f}/{a['ttft_p99_ms']:.1f}"
        out(f"open-loop {arm_name} ttft p50/p99: {ttft} ms,")
        out(f"  tpot p50: {a['tpot_p50_ms']:.2f} ms,")
        out(f"  goodput: {a['goodput_slo']:.2f} req/s")
    assert ol["poisson"]["goodput_slo"] > 0, ol["poisson"]
    assert ol["bursty_2x"]["deferred_admissions"] >= 0

    rec = rows["serving_recurrent"]
    assert {r["family"] for r in rec.values()} == {"ssm", "hybrid"}
    for arch, r in rec.items():
        out(f"{arch} batched speculation speedup: {r['speedup']:.2f}x")

    pol = rows["policy"]
    for name in ("threshold", "cascade", "bandit"):
        p = pol[name]
        assert p["req_s"] > 0, (name, p)
        # cost ratio, not a fraction: speculative verification scores
        # gamma+1 tokens per pass, bounding it by 5 (gamma 4)
        assert 0.0 <= p["cloud_token_share"] <= 5.0, (name, p)
        assert 0.0 <= p["quality_proxy"] <= 1.0, (name, p)
        out(f"policy {name} req/s: {p['req_s']:.2f},")
        out(f"  cloud share: {p['cloud_token_share']:.3f},")
        out(f"  quality: {p['quality_proxy']:.3f}")
    ad = pol["bandit_adaptation"]
    assert ad["share_last"] < ad["share_first"], ad
    first, last = ad["share_first"], ad["share_last"]
    out(f"bandit cloud-token share adapted: {first:.3f} -> {last:.3f}")

    ts = rows["tree_spec"]
    lanes = ts["lanes"]
    for name in ("chain", "tree", "chain_depth4", "self"):
        lane = lanes[name]
        assert lane["req_s"] > 0, (name, lane)
        assert lane["accepted_tokens_per_step"] > 0, (name, lane)
    # multi-token acceptance: the tree lane must retire >1 token per
    # verify pass, and must not lose to the matched-budget chain
    assert lanes["tree"]["accepted_tokens_per_step"] > 1.0, lanes["tree"]
    assert lanes["tree"]["rounds"] <= lanes["chain"]["rounds"], lanes
    assert ts["tree_vs_chain_speedup"] >= 1.0, ts
    out(f"tree speculation: {lanes['tree']['accepted_tokens_per_step']:.2f} "
        f"tokens/step, x{ts['tree_vs_chain_speedup']:.2f} vs matched-budget "
        f"chain ({lanes['tree']['rounds']} vs {lanes['chain']['rounds']} "
        "rounds)")

    cs = rows["compile_stability"]
    # the cold drain must have compiled SOMETHING (a zero here means the
    # log_compiles counter never saw the decode path — a broken probe, not
    # a fast one) and the warmed identical-shape drain must compile NOTHING
    assert cs["decode_compiles"] > 0, cs
    assert cs["steady_state_recompiles"] == 0, cs
    out(f"compile stability: {cs['decode_compiles']} cold compiles, "
        f"{cs['steady_state_recompiles']} steady-state recompiles")

    oa = rows["online_adaptation"]
    # the serve->train->serve loop must pay off on its own traffic: cloud
    # share falls, acceptance rises, and the hot-swap is compile-free —
    # steady_swaps >= 1 proves the recompile counter actually bracketed a
    # swap rather than measuring an idle window
    assert oa["cloud_share_last_third"] < oa["cloud_share_first_third"], oa
    assert oa["accept_last_third"] > oa["accept_first_third"], oa
    assert oa["swaps"] >= 1 and oa["train_steps"] >= 1, oa
    assert oa["steady_swaps"] >= 1, oa
    assert oa["steady_state_recompiles"] == 0, oa
    out(f"online adaptation: cloud share "
        f"{oa['cloud_share_first_third']:.3f} -> "
        f"{oa['cloud_share_last_third']:.3f}, accept "
        f"{oa['accept_first_third']:.2f} -> {oa['accept_last_third']:.2f}, "
        f"{oa['swaps']} swaps, {oa['steady_state_recompiles']} recompiles")

    md = rows["multi_device"]
    if "skipped" in md:
        msg = f"multi_device arm was skipped: {md['skipped']}"
        assert not require_multi_device, msg
        out(f"multi-device arm skipped: {md['skipped']}")
        return
    assert md["token_parity"] is True, md
    assert md["kv_shards"] > 1, md
    assert md["kv_capacity_scale_x"] > 1.0, md
    assert md["mesh_kv_capacity_blocks"] > md["single_kv_capacity_blocks"], md
    assert md["single_req_s"] > 0 and md["mesh_req_s"] > 0, md
    out(f"multi-device: {md['mesh_shape']} mesh, {md['kv_shards']} kv shards,")
    out(f"  kv capacity x{md['kv_capacity_scale_x']:.2f},")
    out(f"  req/s {md['mesh_req_s']:.2f} (single {md['single_req_s']:.2f})")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--path",
        default="BENCH_serving.json",
        help="benchmark artifact to validate",
    )
    ap.add_argument(
        "--require-multi-device",
        action="store_true",
        help="fail if the multi_device arm was skipped (CI runs the bench "
        "under XLA_FLAGS=--xla_force_host_platform_device_count=8, so a "
        "skip there means the mesh never ran)",
    )
    args = ap.parse_args(argv)
    with open(args.path) as f:
        rows = json.load(f)
    check(rows, require_multi_device=args.require_multi_device)
    print("BENCH_serving.json: all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
