"""Unit tests for launch/sharding.py partition rules and launch/mesh.py.

The *_spec functions only touch ``mesh.axis_names`` / ``mesh.shape`` and
``leaf.shape``, so most tests run device-free against duck-typed fakes —
the divisibility-fallback rules are pure functions of shapes.  Tests that
build a real mesh are marked ``mesh`` and need 8 simulated devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""
from __future__ import annotations

from types import SimpleNamespace

import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.sharding import (batch_spec, cache_spec, kv_shard_ways,
                                   paged_cache_spec, param_spec)


class FakeMesh:
    """Duck-types the two attributes the spec rules read."""

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)


class Leaf:
    def __init__(self, *shape):
        self.shape = shape


MESH = FakeMesh(data=2, model=4)


def _cfg(heads=8, kv=8, hd=64, family="dense"):
    return SimpleNamespace(num_heads=heads, num_kv_heads=kv, head_dim=hd,
                           family=family)


# ------------------------------------------------------------ param_spec
def test_param_tiny_and_1d_replicated():
    assert param_spec(("norm",), Leaf(128), MESH) == P()
    assert param_spec(("alpha",), Leaf(4, 4), MESH) == P()  # _REPLICATE


def test_param_up_proj_tp():
    # (L, d, f): d over data, f over model when both divide
    assert param_spec(("blocks", "w_up"), Leaf(4, 256, 1024), MESH) == \
        P(None, "data", "model")
    # f not divisible by model=4 -> replicate that dim
    assert param_spec(("blocks", "w_up"), Leaf(4, 256, 1023), MESH) == \
        P(None, "data", None)


def test_param_down_proj_transposed():
    assert param_spec(("blocks", "wo"), Leaf(4, 1024, 256), MESH,
                      _cfg(heads=8)) == P(None, "model", "data")


def test_param_attention_head_fallback():
    # num_heads=6 does not divide model=4: wq falls back to FSDP-only on
    # its d_model dim (nd-2), never "model"
    spec = param_spec(("blocks", "wq"), Leaf(4, 256, 384), MESH, _cfg(heads=6))
    assert spec == P(None, "data", None)
    # kv projections consult num_kv_heads, not num_heads
    spec = param_spec(("blocks", "wk"), Leaf(4, 256, 384), MESH,
                      _cfg(heads=8, kv=2))
    assert spec == P(None, "data", None)
    # aligned heads keep tensor parallelism
    spec = param_spec(("blocks", "wq"), Leaf(4, 256, 512), MESH, _cfg(heads=8))
    assert spec == P(None, "data", "model")


def test_param_embed_vocab_sharding():
    assert param_spec(("embed",), Leaf(32000, 256), MESH) == P("model", None)
    assert param_spec(("lm_head",), Leaf(32002, 256), MESH) == P(None, None)


def test_param_moe_expert_dim():
    spec = param_spec(("moe", "w_up"), Leaf(8, 256, 1024), MESH)
    assert spec == P("model", None, None)
    # expert count not divisible -> replicated expert dim
    spec = param_spec(("moe", "w_up"), Leaf(6, 256, 1024), MESH)
    assert spec == P(None, None, None)


# ------------------------------------------------------------ batch_spec
def test_batch_spec_divisibility():
    assert batch_spec((8, 16), MESH) == P(("data",), None)
    assert batch_spec((3, 16), MESH) == P(None, None)
    assert batch_spec((), MESH) == P()


# ------------------------------------------------------------ cache_spec
def test_cache_kv_head_preference():
    cfg = _cfg(kv=8)
    spec = cache_spec(("k",), Leaf(4, 8, 128, 8, 64), MESH, cfg)
    assert spec == P(None, ("data",), None, "model", None)


def test_cache_head_dim_fallback():
    # kv-heads=2 not divisible by model=4 -> shard the head dim instead
    cfg = _cfg(kv=2)
    spec = cache_spec(("k",), Leaf(4, 8, 128, 2, 64), MESH, cfg)
    assert spec == P(None, ("data",), None, None, "model")


def test_cache_pos_replicated():
    assert cache_spec(("pos",), Leaf(8), MESH, _cfg()) == P()


# ------------------------------------------------------ paged_cache_spec
def test_paged_table_rows_over_data():
    cfg = _cfg()
    assert paged_cache_spec(("table",), Leaf(8, 16), MESH, cfg) == \
        P(("data",), None)
    assert paged_cache_spec(("table",), Leaf(3, 16), MESH, cfg) == \
        P(None, None)
    assert paged_cache_spec(("pos",), Leaf(8), MESH, cfg) == P()


def test_paged_pool_block_dim_needs_sharded_allocator():
    cfg = _cfg(kv=8)
    pool = Leaf(4, 34, 32, 8, 64)
    # data_shards=1 (host allocator is global): block dim must stay
    # replicated even though 34 % 2 == 0
    assert paged_cache_spec(("k",), pool, MESH, cfg, data_shards=1) == \
        P(None, None, None, "model", None)
    # data_shards matching the dp size: block dim (dim 1) takes the dp axes
    assert paged_cache_spec(("k",), pool, MESH, cfg, data_shards=2) == \
        P(None, ("data",), None, "model", None)


def test_paged_pool_head_dim_fallback():
    cfg = _cfg(kv=2, hd=64)
    pool = Leaf(4, 34, 32, 2, 64)
    assert paged_cache_spec(("k",), pool, MESH, cfg) == \
        P(None, None, None, None, "model")


# -------------------------------------------------------- kv_shard_ways
def test_kv_shard_ways_rules():
    assert kv_shard_ways(MESH, _cfg(kv=8)) == 4
    assert kv_shard_ways(MESH, _cfg(kv=2, hd=64)) == 4   # head-dim route
    assert kv_shard_ways(MESH, _cfg(kv=3, hd=63)) == 1   # replication
    assert kv_shard_ways(FakeMesh(data=8), _cfg(kv=8)) == 1  # no model axis


# ------------------------------------------------------------- real mesh
@pytest.mark.mesh
class TestRealMesh:
    @pytest.fixture(autouse=True)
    def _need_devices(self):
        import jax
        if jax.device_count() < 8:
            pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_"
                        "device_count=8")

    def test_make_host_mesh(self):
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(2, 4)
        assert mesh.axis_names == ("data", "model")
        assert dict(mesh.shape) == {"data": 2, "model": 4}
        assert mesh.size == 8

    def test_parse_mesh_arg_auto_sizes(self):
        from repro.launch.mesh import parse_mesh_arg
        mesh = parse_mesh_arg("data,model")
        # balanced factors of 8, larger trailing
        assert dict(mesh.shape) == {"data": 2, "model": 4}

    def test_parse_mesh_arg_pinned(self):
        from repro.launch.mesh import parse_mesh_arg
        mesh = parse_mesh_arg("data=4,model=2")
        assert dict(mesh.shape) == {"data": 4, "model": 2}
        mesh = parse_mesh_arg("data=1,model")
        assert dict(mesh.shape) == {"data": 1, "model": 8}

    def test_parse_mesh_arg_errors(self):
        from repro.launch.mesh import parse_mesh_arg
        with pytest.raises(ValueError, match="duplicate"):
            parse_mesh_arg("data,data")
        with pytest.raises(ValueError, match="divisor"):
            parse_mesh_arg("data=3,model")
        with pytest.raises(ValueError):
            parse_mesh_arg("")
