import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device.  Mesh tests spawn subprocesses with their own
# --xla_force_host_platform_device_count (see test_dryrun_small.py).

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture
def compile_counter():
    """Count XLA compilations inside the test body (``jax.log_compiles``
    listener, ``repro.analysis.compile_guard.CompileCounter``).  Use to
    assert a warmed path stays recompile-free: check ``c.count`` /
    ``c.events`` after driving the code under test."""
    from repro.analysis.compile_guard import CompileCounter
    with CompileCounter() as c:
        yield c
