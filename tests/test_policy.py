"""CollabPolicy API: the pluggable task-assignment / task-division /
mixture-policy surface over the batched scheduler (survey taxonomy as the
policy axis orthogonal to execution).

Covers: the deprecation shim (legacy ``escalation=``/``escalate_threshold=``
kwargs warn and produce byte-identical tokens vs the policy-object
spelling), admission-lane task assignment, per-wave mixed actions (which
the legacy string API could not express), and the routing-layer bandits /
cascade exercised THROUGH the policy hooks rather than in isolation.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.engine import CollaborativeEngine
from repro.core.policy import (ACTIONS, BanditPolicy, BudgetPolicy,
                               CascadePolicy, CollabPolicy, SkeletonPolicy,
                               SpeculativePolicy, ThresholdPolicy,
                               cloud_tokens, make_policy,
                               policy_from_legacy, trace_quality)
from repro.core.scheduler import BatchedEngine
from repro.core.speculative import autoregressive_baseline
from repro.models import Model


@pytest.fixture(scope="module")
def pair():
    e_cfg = get_config("smollm-135m").reduced()
    c_cfg = get_config("granite-8b").reduced().replace(
        vocab_size=e_cfg.vocab_size)
    edge, cloud = Model(e_cfg), Model(c_cfg)
    return (edge, edge.init(jax.random.PRNGKey(0)),
            cloud, cloud.init(jax.random.PRNGKey(1)))


def _prompts(vocab, specs):
    return [((np.arange(n) * 7 + off) % vocab).astype(np.int32)
            for n, off in specs]


# ---------------------------------------------------------------- shim
@pytest.mark.parametrize("esc", ["speculative", "cloud", "skeleton"])
def test_legacy_kwargs_warn_and_match_policy_spelling(pair, esc):
    """``escalation=``/``escalate_threshold=`` still construct the matching
    policy, emit ``DeprecationWarning``, and produce byte-identical tokens
    vs the policy-object spelling."""
    edge, ep, cloud, cp = pair
    prompts = _prompts(edge.cfg.vocab_size, [(8, 0), (6, 3)])
    with pytest.warns(DeprecationWarning):
        old = BatchedEngine(edge, cloud, batch_size=2, temperature=0.0,
                            escalation=esc, escalate_threshold=-1.0,
                            use_cache=False, skeleton_len=4, tick_tokens=4)
    assert type(old.policy) is type(policy_from_legacy(esc, 0.0))
    new = BatchedEngine(edge, cloud, batch_size=2, temperature=0.0,
                        policy=policy_from_legacy(esc, -1.0), use_cache=False,
                        skeleton_len=4, tick_tokens=4)
    ots = old.serve_batch(ep, cp, prompts, 8)
    nts = new.serve_batch(ep, cp, prompts, 8)
    for ot, nt in zip(ots, nts):
        assert ot.path == nt.path == esc
        assert ot.tokens == nt.tokens


def test_legacy_kwargs_and_policy_mutually_exclusive(pair):
    edge, _, cloud, _ = pair
    with pytest.raises(ValueError, match="not both"):
        BatchedEngine(edge, cloud, policy=SpeculativePolicy(0.5),
                      escalate_threshold=0.5)
    with pytest.raises(ValueError, match="unknown escalation mode"):
        with pytest.warns(DeprecationWarning):
            BatchedEngine(edge, cloud, escalation="nope")


def test_collaborative_engine_shim_warns(pair):
    edge, _, cloud, _ = pair
    with pytest.warns(DeprecationWarning):
        eng = CollaborativeEngine(edge, cloud, escalation="skeleton",
                                  escalate_threshold=0.3)
    assert type(eng.policy) is SkeletonPolicy
    assert eng.threshold == 0.3 and eng.escalation == "skeleton"
    # defaults stay warning-free and keep the historical behavior
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        eng2 = CollaborativeEngine(edge, cloud)
    assert type(eng2.policy) is SpeculativePolicy
    assert eng2.policy.threshold == 0.6


def test_make_policy_names():
    assert type(make_policy("threshold", threshold=0.4)) is ThresholdPolicy
    assert type(make_policy("bandit", kind="ucb")) is BanditPolicy
    with pytest.raises(KeyError):
        make_policy("nope")


# ---------------------------------------------------------------- lanes
class _PinnedLane(CollabPolicy):
    """Test policy: pin every request to one admission lane."""

    name = "pinned"

    def __init__(self, lane):
        self.lane = lane
        self.decides = 0

    def assign(self, features):
        return self.lane

    def decide(self, unc, steps, budget):
        self.decides += 1
        # deliberately escalate: an "edge"-assigned request must bypass this
        return ["cloud"] * len(np.reshape(unc, (-1,)))


def test_assign_cloud_lane_skips_edge(pair):
    """Cloud-lane task assignment at admission: no edge decode, output is
    cloud-greedy exactly."""
    edge, ep, cloud, cp = pair
    prompts = _prompts(edge.cfg.vocab_size, [(8, 0), (6, 3), (10, 5)])
    pol = _PinnedLane("cloud")
    be = BatchedEngine(edge, cloud, batch_size=2, temperature=0.0,
                       policy=pol, use_cache=False, tick_tokens=4)
    bts = be.serve_batch(ep, cp, prompts, 6)
    for p, bt in zip(prompts, bts):
        assert bt.path == "cloud" and bt.edge_calls == 0
        assert bt.tokens == autoregressive_baseline(cloud, cp, p, 6,
                                                    temperature=0.0)
    assert pol.decides == 0                 # nothing reached retirement


def test_assign_edge_lane_forces_accept(pair):
    """Edge-lane assignment accepts the SLM output unconditionally — the
    decide hook (which would escalate everything) is bypassed."""
    edge, ep, cloud, cp = pair
    prompts = _prompts(edge.cfg.vocab_size, [(8, 0), (6, 3)])
    ref = CollaborativeEngine(edge, cloud, temperature=0.0,
                              policy=ThresholdPolicy(1.1), use_cache=False)
    be = BatchedEngine(edge, cloud, batch_size=2, temperature=0.0,
                       policy=_PinnedLane("edge"), use_cache=False,
                       tick_tokens=4)
    bts = be.serve_batch(ep, cp, prompts, 8)
    for p, bt in zip(prompts, bts):
        rt = ref.serve_reference(ep, cp, p, 8)
        assert bt.path == "edge"
        assert bt.tokens == rt.tokens


def test_assign_cloud_lane_twins_coalesce(pair):
    """Identical prompts in one admission wave coalesce even on the cloud
    lane: the first is the leader's single grouped cloud generation, the
    twin is served from it (no second cloud pass)."""
    edge, ep, cloud, cp = pair
    p = _prompts(edge.cfg.vocab_size, [(8, 0)])[0]
    be = BatchedEngine(edge, cloud, batch_size=2, temperature=0.0,
                       policy=_PinnedLane("cloud"), cache_threshold=0.99,
                       tick_tokens=4)
    t1, t2 = be.serve_batch(ep, cp, [p, p.copy()], 6)
    assert t1.path == "cloud" and t2.path == "cache"
    assert t2.tokens == t1.tokens


def test_bandit_ignores_lane_assigned_feedback():
    """Feedback for a completion that never went through ``decide`` (a
    lane-assigned request) must not consume a pending pull or move the
    arm estimates."""
    pol = BanditPolicy(arms=("accept", "cloud"), kind="ucb")
    [a] = pol.decide([0.5], [8], [8])
    pol.feedback("accept", 1.0, 0.0, {"budget": 8, "lane": "edge"})
    assert pol.router.n.sum() == 0          # no reward landed
    assert pol._pending.sum() == 1          # the real pull still pending
    pol.feedback(a, 1.0, 0.0, {"budget": 8, "lane": "collab"})
    assert pol.router.n.sum() == 1 and pol._pending.sum() == 0


def test_serve_reference_keeps_defaults_for_non_threshold_policies(pair):
    """The per-token reference loop cannot honor budget/bandit hooks; a
    non-threshold policy must leave it on the historical defaults instead
    of duck-typing the policy's unrelated threshold/action attributes —
    and calling it must WARN rather than silently misattribute."""
    edge, ep, cloud, cp = pair
    eng = CollaborativeEngine(edge, cloud, temperature=0.0,
                              use_cache=False,
                              policy=BudgetPolicy(threshold=-1.0,
                                                  tokens_per_request=0.0))
    assert eng.threshold == 0.6 and eng.escalation == "speculative"
    with pytest.warns(RuntimeWarning, match="cannot honor"):
        eng.serve_reference(ep, cp,
                            _prompts(edge.cfg.vocab_size, [(8, 0)])[0], 4)


class _Alternating(CollabPolicy):
    """Test policy: one wave mixing per-request actions — something the
    legacy single-mode string API could not express."""

    name = "alternating"

    def decide(self, unc, steps, budget):
        n = len(np.reshape(unc, (-1,)))
        return [("cloud" if i % 2 == 0 else "skeleton") for i in range(n)]


def test_mixed_actions_in_one_wave(pair):
    """A single retirement wave splits into per-action groups; each request
    matches the reference engine running that mode alone."""
    edge, ep, cloud, cp = pair
    prompts = _prompts(edge.cfg.vocab_size, [(8, 0), (6, 3), (10, 5), (7, 11)])
    be = BatchedEngine(edge, cloud, batch_size=4, temperature=0.0,
                       policy=_Alternating(), use_cache=False,
                       skeleton_len=4, tick_tokens=16)
    bts = be.serve_batch(ep, cp, prompts, 8)
    for i, (p, bt) in enumerate(zip(prompts, bts)):
        esc = "cloud" if i % 2 == 0 else "skeleton"
        ref = CollaborativeEngine(edge, cloud, temperature=0.0,
                                  policy=policy_from_legacy(esc, -1.0), use_cache=False,
                                  skeleton_len=4)
        rt = ref.serve_reference(ep, cp, p, 8)
        assert bt.path == rt.path == esc
        assert bt.tokens == rt.tokens


def test_assign_called_once_per_request_even_when_deferred(pair):
    """The scheduler invokes ``assign`` exactly once per request — a
    request deferred by pool pressure keeps its lane instead of being
    re-assigned every retry tick (stateful policies must not see phantom
    duplicates)."""
    edge, ep, cloud, cp = pair
    calls = []

    class Counting(CollabPolicy):
        name = "counting"

        def assign(self, features):
            calls.append(features["rid"])
            return "collab"

        def decide(self, unc, steps, budget):
            return ["accept"] * len(np.reshape(unc, (-1,)))

    prompts = _prompts(edge.cfg.vocab_size, [(17, 0), (17, 3)])
    # 4-usable-block pool: request 0 admits (2 blocks + 1 reserve), the
    # same-wave request 1 cannot (its victim is wave-exempt) and defers
    be = BatchedEngine(edge, cloud, batch_size=2, temperature=0.0,
                       policy=Counting(), use_cache=False, tick_tokens=4,
                       kv_layout="paged", kv_block_size=8, kv_blocks=5)
    bts = be.serve_batch(ep, cp, prompts, 8)
    assert all(bt.path == "edge" and len(bt.tokens) == 8 for bt in bts)
    assert sorted(calls) == [0, 1]          # once each, deferral included


def test_unknown_action_rejected(pair):
    edge, ep, cloud, cp = pair

    class Bad(CollabPolicy):
        def decide(self, unc, steps, budget):
            return ["teleport"] * len(np.reshape(unc, (-1,)))

    be = BatchedEngine(edge, cloud, batch_size=1, temperature=0.0,
                       policy=Bad(), use_cache=False, tick_tokens=4)
    with pytest.raises(ValueError, match="unknown action"):
        be.serve_batch(ep, cp, _prompts(edge.cfg.vocab_size, [(8, 0)]), 4)


# ---------------------------------------------------------------- cascade
def test_cascade_respects_cost_ordering():
    """The cascade never takes a costlier tier while a cheaper one is
    confident, pays tier costs cumulatively in cost order, and rejects a
    non-ascending cost vector outright — exercised through
    ``CascadePolicy`` driving ``CascadeRouter.route``."""
    pol = CascadePolicy(thresholds=(0.3, 0.25), costs=(0.0, 1.0, 4.0),
                        relief=0.5)
    acts = pol.decide([0.1, 0.45, 0.6], [8, 8, 8], [8, 8, 8])
    assert acts == ["accept", "speculative", "cloud"]
    # cumulative spend: 0 (tier 0) + 0+1 (tier 1) + 0+1+4 (tier 2)
    assert pol.stats()["policy_cascade_cost"] == 6.0
    assert pol.stats()["policy_tier_counts"] == {"accept": 1,
                                                 "speculative": 1, "cloud": 1}
    # actions are monotone in uncertainty: sweeping u upward never falls
    # back to a cheaper tier
    sweep = CascadePolicy(thresholds=(0.3, 0.25), costs=(0.0, 1.0, 4.0),
                          relief=0.5)
    order = {a: i for i, a in enumerate(sweep.tiers)}
    picked = sweep.decide(np.linspace(0.0, 1.0, 21), [8] * 21, [8] * 21)
    idxs = [order[a] for a in picked]
    assert idxs == sorted(idxs)
    # the DEFAULT configuration keeps every tier reachable on [0, 1]
    dflt = CascadePolicy()
    assert dflt.decide([0.2, 0.6, 0.9], [8] * 3, [8] * 3) == \
        ["accept", "speculative", "cloud"]
    with pytest.raises(ValueError, match="cost-ordered"):
        CascadePolicy(thresholds=(0.3, 0.25), costs=(0.0, 4.0, 1.0))


# ---------------------------------------------------------------- bandits
QUAL = {"accept": 0.9, "speculative": 0.6, "cloud": 0.3}


def test_ucb_regret_shrinks_via_feedback():
    """UCB routing through ``BanditPolicy.decide``/``feedback`` under
    stationary rewards: per-step regret shrinks as the best arm (accept,
    here the highest stationary quality at zero cost) takes over."""
    pol = BanditPolicy(arms=tuple(QUAL), kind="ucb", cost_weight=0.0, c=0.8)
    rng = np.random.default_rng(0)
    chosen = []
    for _ in range(600):
        [a] = pol.decide([0.5], [8], [8])
        pol.feedback(a, QUAL[a] + rng.normal(0.0, 0.05), 0.0, {"budget": 8})
        chosen.append(a)
    assert pol.router.n.sum() == 600        # every pull got its reward
    regret = np.cumsum([QUAL["accept"] - QUAL[a] for a in chosen])
    assert regret[-1] / 600 < 0.5 * (regret[59] / 60)
    assert max(pol.stats()["policy_pulls"],
               key=pol.stats()["policy_pulls"].get) == "accept"


def test_ucb_cold_start_round_robins_within_a_wave():
    """One big wave decided before any feedback lands must spread pulls
    round-robin over the arms (outstanding pulls count), not pile onto
    arm 0."""
    for kind in ("ucb", "linucb"):
        pol = BanditPolicy(arms=("accept", "speculative", "cloud"),
                           kind=kind)
        acts = pol.decide([0.5] * 7, [8] * 7, [8] * 7)
        assert set(acts[:3]) == {"accept", "speculative", "cloud"}, kind
        counts = {a: acts.count(a) for a in pol.arms}
        assert max(counts.values()) - min(counts.values()) <= 1, kind


def test_linucb_routes_on_context_via_feedback():
    """LinUCB learns a context-dependent routing — accept easy (low-unc)
    requests, cloud-escalate hard ones — purely from the feedback loop."""
    pol = BanditPolicy(arms=("accept", "cloud"), kind="linucb",
                       cost_weight=0.0, alpha=0.3)
    rng = np.random.default_rng(0)
    for _ in range(400):
        u = 0.1 if rng.uniform() < 0.5 else 0.9
        [a] = pol.decide([u], [8], [8])
        good = "accept" if u < 0.5 else "cloud"
        pol.feedback(a, 1.0 if a == good else 0.0, 0.0,
                     {"unc": u, "steps": 8, "budget": 8})
    assert pol.decide([0.1], [8], [8]) == ["accept"]
    assert pol.decide([0.9], [8], [8]) == ["cloud"]


def test_bandit_closes_loop_through_engine(pair):
    """End-to-end: ``BanditPolicy`` serves real traffic through the
    scheduler, every completion lands a reward, all arms are real paths."""
    edge, ep, cloud, cp = pair
    pol = BanditPolicy(arms=("accept", "cloud"), kind="ucb",
                       cost_weight=2.0, c=0.05)
    be = BatchedEngine(edge, cloud, batch_size=2, temperature=0.0,
                       policy=pol, use_cache=False, tick_tokens=4)
    prompts = _prompts(edge.cfg.vocab_size, [(8, 0), (6, 3), (10, 5), (7, 11)])
    bts = be.serve_batch(ep, cp, prompts, 6)
    assert all(bt.path in ("edge", "cloud") for bt in bts)
    assert int(pol.router.n.sum()) == len(prompts)
    assert be.stats()["policy"] == "bandit"
    assert sum(be.stats()["policy_pulls"].values()) == len(prompts)


# ---------------------------------------------------------------- budget
def test_budget_policy_degrades_when_spent():
    """Per-request cloud-token budgeting: escalations are granted while the
    accrued pool covers them, then DEGRADE to edge-accept; feedback
    reconciles the reserved estimate against the realized spend."""
    pol = BudgetPolicy(threshold=0.5, tokens_per_request=4.0)
    for rid in range(4):
        assert pol.assign({"rid": rid, "max_new": 8}) == "collab"
    assert pol.stats()["policy_cloud_pool"] == 16.0  # one accrual each
    acts = pol.decide([0.9, 0.9, 0.9, 0.9], [8] * 4, [8] * 4)
    assert acts == ["cloud", "cloud", "accept", "accept"]
    assert pol.stats()["policy_degraded"] == 2
    pol.feedback("cloud", 1.0, 6.0, {"budget": 8, "rid": 0})
    assert pol.stats()["policy_cloud_pool"] == 2.0   # spent less than est
    pol.feedback("cloud", 1.0, 8.0)     # no features: reservation stands
    assert pol.stats()["policy_cloud_pool"] == 2.0   # no double charge
    confident = pol.decide([0.1], [8], [8])          # under threshold
    assert confident == ["accept"] and pol.stats()["policy_degraded"] == 2


def test_budget_policy_sla_classes():
    """SLA classes scale each request's accrual; the classifier sees the
    admission feature dict."""
    pol = BudgetPolicy(threshold=0.5, tokens_per_request=4.0,
                       sla={"premium": 2.0, "batch": 0.0},
                       classify=lambda f: "premium" if f["max_new"] > 8
                       else "batch")
    pol.assign({"rid": 0, "max_new": 16})
    pol.assign({"rid": 1, "max_new": 4})
    s = pol.stats()
    assert s["policy_cloud_pool"] == 8.0
    assert s["policy_sla_classes"] == {"premium": 1, "batch": 1}


def test_deadline_classifier_buckets_by_slo_pressure():
    """``deadline_classifier`` classes a request by the fraction of its
    TTFT SLO already burned queueing, degrading to the first class when
    no SLO / wait feed exists (closed-loop runs)."""
    from repro.core.policy import deadline_classifier
    cls = deadline_classifier({"relaxed": 0.25, "standard": 0.5,
                               "urgent": float("inf")})
    assert cls({"wait_ms": 10.0, "slo_ms": 100.0}) == "relaxed"
    assert cls({"wait_ms": 40.0, "slo_ms": 100.0}) == "standard"
    assert cls({"wait_ms": 90.0, "slo_ms": 100.0}) == "urgent"
    # boundary inclusive; order comes from boundary values, not dict order
    assert cls({"wait_ms": 25.0, "slo_ms": 100.0}) == "relaxed"
    # graceful degradation: no SLO configured or no wait feed
    assert cls({"wait_ms": 0.0, "slo_ms": None}) == "relaxed"
    assert cls({}) == "relaxed"
    with pytest.raises(ValueError):
        deadline_classifier({})
    # plugged into BudgetPolicy: accrual scales by the deadline class
    pol = BudgetPolicy(tokens_per_request=4.0,
                       sla={"relaxed": 1.0, "urgent": 2.0},
                       classify=deadline_classifier(
                           {"relaxed": 0.5, "urgent": float("inf")}))
    pol.assign({"rid": 0, "wait_ms": 5.0, "slo_ms": 100.0})
    pol.assign({"rid": 1, "wait_ms": 95.0, "slo_ms": 100.0})
    s = pol.stats()
    assert s["policy_cloud_pool"] == 12.0
    assert s["policy_sla_classes"] == {"relaxed": 1, "urgent": 1}


# ---------------------------------------------------------------- metrics
def test_trace_metrics_helpers():
    from repro.core.scheduler import RequestTrace
    spec = RequestTrace("speculative", cloud_passes=3, uncertainty=0.4)
    assert cloud_tokens(spec, gamma=4) == 15
    assert trace_quality(spec, 8) == 1.0
    edge = RequestTrace("edge", uncertainty=0.3)
    assert cloud_tokens(edge, gamma=4) == 0
    assert abs(trace_quality(edge, 8) - 0.7) < 1e-9
    skel = RequestTrace("skeleton", cloud_passes=4, uncertainty=0.5)
    assert cloud_tokens(skel, gamma=4) == 4
    assert abs(trace_quality(skel, 8) - (0.5 + 0.5 * 0.5)) < 1e-9
    assert set(ACTIONS) == {"accept", "cloud", "skeleton", "speculative"}
