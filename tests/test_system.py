"""End-to-end system behaviour: the collaborative engine (survey Fig. 1b)
composing cache -> edge -> escalation, plus the small-mesh distributed
dry-run (subprocess with its own fake device count)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.engine import CollaborativeEngine
from repro.core.policy import SkeletonPolicy, SpeculativePolicy
from repro.core.speculative import autoregressive_baseline
from repro.models import Model

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def pair():
    e_cfg = get_config("smollm-135m").reduced()
    c_cfg = get_config("granite-8b").reduced().replace(
        vocab_size=e_cfg.vocab_size)
    edge, cloud = Model(e_cfg), Model(c_cfg)
    return (edge, edge.init(jax.random.PRNGKey(0)),
            cloud, cloud.init(jax.random.PRNGKey(1)))


def test_engine_edge_path(pair):
    edge, ep, cloud, cp = pair
    eng = CollaborativeEngine(edge, cloud, temperature=0.0,
                              policy=SpeculativePolicy(1.1))
    prompt = np.arange(8) % edge.cfg.vocab_size
    tr = eng.serve(ep, cp, prompt, 8)
    assert tr.path == "edge"
    assert tr.cloud_passes == 0


def test_engine_speculative_escalation_lossless(pair):
    edge, ep, cloud, cp = pair
    eng = CollaborativeEngine(edge, cloud, temperature=0.0,
                              policy=SpeculativePolicy(-1.0),
                              use_cache=False)
    prompt = np.arange(8) % edge.cfg.vocab_size
    tr = eng.serve(ep, cp, prompt, 8)
    assert tr.path == "speculative"
    base = autoregressive_baseline(cloud, cp, prompt, 8, temperature=0.0)
    assert tr.tokens == base                     # escalation = cloud quality


def test_engine_cache_hit(pair):
    edge, ep, cloud, cp = pair
    eng = CollaborativeEngine(edge, cloud, temperature=0.0,
                              policy=SpeculativePolicy(1.1), cache_threshold=0.99)
    prompt = np.arange(8) % edge.cfg.vocab_size
    t1 = eng.serve(ep, cp, prompt, 8)
    t2 = eng.serve(ep, cp, prompt, 8)
    assert t2.path == "cache"
    assert t2.tokens == t1.tokens


def test_engine_skeleton_path(pair):
    edge, ep, cloud, cp = pair
    eng = CollaborativeEngine(edge, cloud, temperature=0.0,
                              policy=SkeletonPolicy(-1.0),
                              use_cache=False, skeleton_len=4)
    prompt = np.arange(8) % edge.cfg.vocab_size
    tr = eng.serve(ep, cp, prompt, 8)
    assert tr.path == "skeleton"
    base = autoregressive_baseline(cloud, cp, prompt, 4, temperature=0.0)
    assert tr.tokens[:4] == base                  # cloud skeleton prefix


@pytest.mark.slow
def test_small_mesh_dryrun_subprocess(tmp_path):
    """Sharded lower+compile on a small fake-device mesh — the same code
    path as the production dry-run, in a subprocess so this test session
    keeps its single CPU device."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro import runtime
from repro.configs import get_config
from repro.launch import sharding as SH
from repro.models import Model
from repro.training.optimizer import AdamW, AdamWState
from jax.sharding import NamedSharding, PartitionSpec as P

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_config("olmoe-1b-7b").reduced().replace(num_experts=4, top_k=2)
model = Model(cfg)
params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
opt = AdamW()
opt_state = jax.eval_shape(opt.init, params)
batch = {"tokens": jax.ShapeDtypeStruct((8, 4096), jnp.int32),
         "labels": jax.ShapeDtypeStruct((8, 4096), jnp.int32)}

def step(p, s, b):
    loss, g = jax.value_and_grad(lambda pp: model.loss(pp, b, remat=True))(p)
    p, s, _ = opt.update(g, s, p)
    return p, s, loss

p_sh = SH.params_shardings(params, mesh)
o_sh = AdamWState(m=p_sh, v=p_sh, step=NamedSharding(mesh, P()))
b_sh = SH.batch_shardings(batch, mesh)
with runtime.mesh_context(mesh):
    compiled = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh)).lower(
        params, opt_state, batch).compile()
from repro.launch.hlo_cost import cost_analysis_dict
print("COMPILED_OK", cost_analysis_dict(compiled).get("flops", 0) > 0)
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "COMPILED_OK True" in out.stdout, out.stderr[-2000:]


def test_dryrun_results_recorded():
    """If the production sweep has run in this container, every recorded
    combo must be ok or an explicitly documented skip."""
    d = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
    if not os.path.isdir(d) or not os.listdir(d):
        pytest.skip("production dry-run sweep not executed yet")
    bad = []
    for f in os.listdir(d):
        if not f.endswith(".json"):
            continue
        rec = json.load(open(os.path.join(d, f)))
        if rec["status"] not in ("ok", "skipped"):
            bad.append(f)
    assert not bad, f"failed dry-runs: {bad}"
