"""Open-loop traffic harness + latency-honest scheduler accounting.

Three layers under test:

  * ``core/traffic.py`` in isolation — arrival-process determinism and
    long-run rates, virtual/wall clock semantics, and the
    ``latency_rollup`` math on hand-built event dicts.
  * ``BatchedEngine`` lifecycle events under open-loop arrivals —
    submit <= admit <= first-token <= retire per request, rollup fields
    surfaced through ``stats()``, and (hypothesis) TTFT monotone in
    arrival order under a deterministic trace.
  * The scheduler-bug regressions this PR pins: cloud-lane requests no
    longer head-of-line blocked behind a full edge batch; ``decide()``
    sees steps-actually-spent as a distinct array from the budget;
    queued-request vs swapped-victim-restore stalls raise distinct
    errors; a swapped-out leader still coalesces same-prompt followers;
    ``_pick_victim`` honors its documented tie-break.
"""
import types

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.policy import ThresholdPolicy
from repro.core.scheduler import BatchedEngine
from repro.core.seq_state import PagedKV
from repro.core.traffic import (VirtualClock, WallClock, bursty_arrivals,
                                latency_rollup, poisson_arrivals, replay,
                                trace_arrivals)
from repro.models import Model


@pytest.fixture(scope="module")
def pair():
    e_cfg = get_config("smollm-135m").reduced()
    c_cfg = get_config("granite-8b").reduced().replace(
        vocab_size=e_cfg.vocab_size)
    edge, cloud = Model(e_cfg), Model(c_cfg)
    return (edge, edge.init(jax.random.PRNGKey(0)),
            cloud, cloud.init(jax.random.PRNGKey(1)))


def _prompts(vocab, specs):
    return [((np.arange(n) * 7 + off) % vocab).astype(np.int32)
            for n, off in specs]


# ---------------------------------------------------------------- arrivals
def test_poisson_arrivals_deterministic_sorted():
    a = poisson_arrivals(100.0, 500, seed=3)
    b = poisson_arrivals(100.0, 500, seed=3)
    np.testing.assert_array_equal(a, b)
    assert a.size == 500 and np.all(np.diff(a) >= 0)
    # long-run mean gap ~ 1000/rate ms
    assert 0.8 < np.diff(a).mean() / 10.0 < 1.25
    assert poisson_arrivals(100.0, 0).size == 0
    with pytest.raises(ValueError):
        poisson_arrivals(0.0, 4)


def test_bursty_arrivals_long_run_rate():
    a = bursty_arrivals(100.0, 400, seed=5, burst=8, peak=8.0)
    assert a.size == 400 and np.all(np.diff(a) >= 0)
    # long-run average must stay ~rate even though bursts run at 8x:
    # span ~ n/rate seconds
    span_s = (a[-1] - a[0]) / 1e3
    assert 0.6 < span_s / (400 / 100.0) < 1.4
    # instantaneous burstiness: the median gap (inside a burst) is far
    # below the mean gap (which amortizes the off-periods)
    gaps = np.diff(a)
    assert np.median(gaps) < 0.5 * gaps.mean()
    for bad in [dict(rate=0.0), dict(peak=1.0), dict(burst=0)]:
        with pytest.raises(ValueError):
            bursty_arrivals(**{**dict(rate=50.0, peak=4.0, burst=4),
                               **bad}, n=8)


def test_trace_arrivals_sorts_and_validates():
    np.testing.assert_array_equal(trace_arrivals([5.0, 1.0, 3.0]),
                                  [1.0, 3.0, 5.0])
    with pytest.raises(ValueError):
        trace_arrivals([0.0, np.nan])


# ---------------------------------------------------------------- clocks
def test_virtual_clock_charges_and_jumps():
    c = VirtualClock(step_ms=2.0, prefill_token_ms=0.5)
    assert c.now() == 0.0
    c.on_steps(4)
    assert c.now() == 8.0
    c.on_prefill(6)
    assert c.now() == 11.0
    c.wait_until(100.0)
    assert c.now() == 100.0
    c.wait_until(50.0)                  # never moves backward
    assert c.now() == 100.0
    assert VirtualClock(step_ms=8.0).prefill_token_ms == 1.0  # default /8
    with pytest.raises(ValueError):
        VirtualClock(step_ms=0.0)


def test_wall_clock_monotone_and_sleeps():
    c = WallClock()
    t0 = c.now()
    c.on_steps(1000)                    # modeled costs are no-ops
    c.on_prefill(1000)
    target = c.now() + 15.0
    c.wait_until(target)
    assert c.now() >= target > t0
    assert WallClock.step_ms == 0.0


# ---------------------------------------------------------------- rollup
def test_latency_rollup_math():
    events = {
        0: {"submit_ms": 0.0, "admit_ms": 1.0, "first_token_ms": 10.0,
            "retire_ms": 40.0, "tokens": 4, "swaps": 1, "defers": 2},
        1: {"submit_ms": 5.0, "first_token_ms": 35.0, "retire_ms": 35.0,
            "tokens": 1, "swaps": 0, "defers": 0},
        2: {"submit_ms": 6.0, "swaps": 0, "defers": 1},   # never finished
    }
    r = latency_rollup(events, slo_ms=20.0)
    assert r["requests"] == 3 and r["completed"] == 2
    # ttfts: 10.0 and 30.0
    assert r["ttft_p50_ms"] == pytest.approx(20.0)
    assert r["ttft_p99_ms"] == pytest.approx(29.8)
    # only rid 0 streamed >= 2 tokens: tpot = 30/3
    assert r["tpot_p50_ms"] == pytest.approx(10.0)
    assert r["swapped_requests"] == 1
    assert r["deferred_admissions"] == 3
    assert r["makespan_ms"] == pytest.approx(40.0)
    # rid 0 met the 20ms TTFT SLO, rid 1 missed
    assert r["slo_attainment"] == pytest.approx(0.5)
    assert r["goodput_slo"] == pytest.approx(1 / 0.040)
    # no SLO -> every completion counts
    assert latency_rollup(events)["slo_attainment"] == 1.0
    empty = latency_rollup({})
    assert empty["completed"] == 0 and empty["goodput_slo"] == 0.0


# ---------------------------------------------------------------- open loop
def test_open_loop_event_ordering(pair):
    """Per-request lifecycle timestamps are causally ordered and the
    rollup lands in ``stats()`` with a positive goodput."""
    edge, ep, cloud, cp = pair
    prompts = _prompts(edge.cfg.vocab_size,
                       [(8, 0), (6, 3), (10, 5), (7, 11), (9, 2), (6, 9)])
    be = BatchedEngine(edge, cloud, batch_size=2, temperature=0.0,
                       policy=ThresholdPolicy(1.1), use_cache=False,
                       tick_tokens=4, slo_ms=500.0)
    at = poisson_arrivals(200.0, len(prompts), seed=11)
    traces = replay(be, ep, cp, prompts, 6, at)
    assert len(traces) == len(prompts)
    assert all(t.path == "edge" for t in traces)
    for rid, ev in be.events.items():
        assert ev["submit_ms"] <= ev["admit_ms"] <= ev["first_token_ms"] \
            <= ev["retire_ms"]
        assert ev["tokens"] == 6
    stats = be.stats()
    assert stats["completed"] == stats["requests"] == len(prompts)
    assert stats["ttft_p99_ms"] >= stats["ttft_p50_ms"] > 0
    assert stats["goodput_slo"] > 0 and stats["slo_attainment"] == 1.0


def test_future_arrivals_wait_for_the_clock(pair):
    """A request submitted far in the virtual future is invisible to
    admission until the clock reaches it: its admit stamp can never
    precede its arrival."""
    edge, ep, cloud, cp = pair
    prompts = _prompts(edge.cfg.vocab_size, [(8, 0), (6, 3)])
    be = BatchedEngine(edge, cloud, batch_size=2, temperature=0.0,
                       policy=ThresholdPolicy(1.1), use_cache=False,
                       tick_tokens=4)
    traces = replay(be, ep, cp, prompts, 4, [0.0, 5000.0])
    assert all(t.path == "edge" for t in traces)
    ev = be.events
    assert ev[1]["admit_ms"] >= 5000.0
    assert ev[0]["retire_ms"] < 5000.0  # the idle gap was jumped, not spun


# ------------------------------------------------- head-of-line regression
class _LaneByBudget(ThresholdPolicy):
    """Tiny requests go to the cloud lane, everything else collaborates."""
    name = "lane-by-budget"

    def assign(self, features):
        return "cloud" if features["max_new"] <= 2 else "collab"


def test_cloud_lane_not_blocked_by_full_edge_batch(pair):
    """REGRESSION (head-of-line): with every edge slot occupied by
    long-running collab requests, a cloud-lane request must still be
    probed, generated and retired — before any collab request even
    produces its first token, not one-per-freed-slot ticks later."""
    edge, ep, cloud, cp = pair
    prompts = _prompts(edge.cfg.vocab_size,
                       [(8, 0), (6, 3), (10, 5), (7, 11)])
    budgets = [24, 24, 24, 2]           # [3] -> cloud lane
    be = BatchedEngine(edge, cloud, batch_size=2, temperature=0.0,
                       policy=_LaneByBudget(1.1), use_cache=False,
                       tick_tokens=4)
    traces = be.serve_batch(ep, cp, prompts, budgets)
    assert [t.path for t in traces[:3]] == ["edge"] * 3
    assert traces[3].path == "cloud" and len(traces[3].tokens) == 2
    ev = be.events
    # the batch is full (2 slots, 4 requests) the whole run; the cloud
    # request retires no later than the FIRST decode tick's stamps
    assert ev[3]["retire_ms"] <= min(ev[r]["first_token_ms"]
                                     for r in range(3))


# ------------------------------------------------- steps/budget de-aliasing
class _RecordingPolicy(ThresholdPolicy):
    """Captures the (steps, budget) arrays ``decide`` receives and the
    per-completion feedback features."""
    name = "recording"

    def __init__(self, threshold):
        super().__init__(threshold)
        self.decided = []
        self.feedbacks = []

    def decide(self, unc, steps, budget):
        self.decided.append((np.array(steps), np.array(budget)))
        return super().decide(unc, steps, budget)

    def feedback(self, action, quality, cost, features=None):
        self.feedbacks.append(features)


def test_decide_sees_spent_steps_not_budget(pair):
    """REGRESSION (aliasing): with a stop token ending decode early,
    ``decide``'s steps array reflects tokens actually produced — strictly
    below the budget array — and feedback carries the same spent count.
    The prompt is longer than the chunk so chunked prefill is active."""
    edge, ep, cloud, cp = pair
    prompts = _prompts(edge.cfg.vocab_size, [(12, 0), (12, 3)])
    probe = BatchedEngine(edge, cloud, batch_size=2, temperature=0.0,
                          policy=ThresholdPolicy(1.1), use_cache=False,
                          tick_tokens=4)
    first = probe.serve_batch(ep, cp, [prompts[0]], 8)[0].tokens
    pol = _RecordingPolicy(1.1)
    be = BatchedEngine(edge, cloud, batch_size=2, temperature=0.0,
                       policy=pol, use_cache=False, tick_tokens=4,
                       stop_token=first[2])
    traces = be.serve_batch(ep, cp, [prompts[0]], 8)
    # greedy decode re-emits the probed stream until the stop token
    assert traces[0].tokens == first[:3]
    (steps, budget), = pol.decided
    assert steps.tolist() == [3] and budget.tolist() == [8]
    assert int(steps[0]) < int(budget[0]), "steps aliased to budget"
    fb, = pol.feedbacks
    assert fb["steps"] == 3 and fb["budget"] == 8
    assert traces[0].edge_calls == 3


# ---------------------------------------------------------- stall messages
def test_stall_error_queued_request(pair, monkeypatch):
    """A queued request the pool can never admit (even with sharing) fails
    fast with the raise-kv_blocks message naming the QUEUED case."""
    edge, ep, cloud, cp = pair
    be = BatchedEngine(edge, cloud, batch_size=2, temperature=0.0,
                       policy=ThresholdPolicy(1.1), use_cache=False,
                       kv_layout="paged", kv_block_size=4)
    monkeypatch.setattr(PagedKV, "admit", lambda self, *a, **k: False)
    monkeypatch.setattr(PagedKV, "fits_empty",
                        lambda self, need, prompt=None: prompt is None)
    with pytest.raises(RuntimeError, match="queued request"):
        be.serve_batch(ep, cp, _prompts(edge.cfg.vocab_size, [(8, 0)]), 4)


def test_stall_error_swapped_victim_restore(pair, monkeypatch):
    """A swapped-out victim the pool can never restore raises the
    DISTINCT swapped-victim message (not the queued-request one): the
    overcommitted pool swaps a victim out, then ``swap_in`` is broken so
    the restore can never succeed even after the batch drains."""
    edge, ep, cloud, cp = pair
    prompts = _prompts(edge.cfg.vocab_size, [(9, 0), (9, 3)])
    be = BatchedEngine(edge, cloud, batch_size=2, temperature=0.0,
                       policy=ThresholdPolicy(1.1), use_cache=False,
                       tick_tokens=4, prefill_chunk=0,
                       kv_layout="paged", kv_block_size=4, kv_blocks=6)
    monkeypatch.setattr(PagedKV, "swap_in", lambda self, b, h: False)
    with pytest.raises(RuntimeError,
                       match="cannot restore swapped-out request"):
        # staggered arrivals: the second request preempts the first
        replay(be, ep, cp, prompts, 8, [0.0, 2.0])


# ------------------------------------------------- swapped leader coalesce
def test_swapped_leader_still_coalesces_followers(pair):
    """A preempted (swapped-out) in-flight request keeps its ``_leaders``
    entry, so an identical later prompt coalesces into a follower and is
    served from the leader's eventual result instead of paying a second
    decode."""
    edge, ep, cloud, cp = pair
    pa, pb = _prompts(edge.cfg.vocab_size, [(9, 0), (9, 101)])
    # pool of 6 blocks (1 trap + 5 usable) x 4 tokens: each request needs
    # 4 blocks, so admitting B preempts A; C == A coalesces with swapped A
    be = BatchedEngine(edge, cloud, batch_size=2, temperature=0.0,
                       policy=ThresholdPolicy(1.1), cache_threshold=0.999,
                       tick_tokens=4, prefill_chunk=0,
                       kv_layout="paged", kv_block_size=4, kv_blocks=6)
    ta, tb, tc = replay(be, ep, cp, [pa, pb, pa], 8, [0.0, 2.0, 2.0])
    assert be.stats()["preemptions"] >= 1
    assert be.events[0]["swaps"] >= 1, "expected A to be the swap victim"
    assert ta.path == "edge" and tb.path == "edge"
    assert tc.path == "cache" and tc.tokens == ta.tokens
    assert be.events[2]["retire_ms"] >= be.events[0]["retire_ms"]


# ---------------------------------------------------------- victim picking
def _victim_env(specs, prefill_jobs=()):
    """specs: per-slot (rid or None, steps_left)."""
    slots = [types.SimpleNamespace(
        req=None if rid is None else types.SimpleNamespace(rid=rid))
        for rid, _ in specs]
    steps = np.array([s for _, s in specs], np.int32)
    me = types.SimpleNamespace(_prefill_jobs=dict.fromkeys(prefill_jobs))
    state = types.SimpleNamespace(swappable=lambda b: True,
                                  owned_blocks=lambda b: 0)
    return me, state, slots, steps


def test_pick_victim_most_steps_then_youngest():
    """Tie-break matches the docstring: MOST remaining steps first, then
    the youngest (largest) rid; wave members, empty slots and
    mid-chunked-prefill slots are exempt."""
    pick = BatchedEngine._pick_victim
    me, st, slots, steps = _victim_env([(0, 3), (1, 7), (2, 5)])
    assert pick(me, st, slots, steps, wave=set()) == 1
    # tie on steps -> youngest rid wins
    me, st, slots, steps = _victim_env([(4, 7), (9, 7), (2, 5)])
    assert pick(me, st, slots, steps, wave=set()) == 1
    # wave exemption
    me, st, slots, steps = _victim_env([(0, 3), (1, 7), (2, 5)])
    assert pick(me, st, slots, steps, wave={1}) == 2
    # mid-prefill exemption
    me, st, slots, steps = _victim_env([(0, 3), (1, 7), (2, 5)],
                                       prefill_jobs=[1])
    assert pick(me, st, slots, steps, wave=set()) == 2
    # empty slots / everything exempt -> no victim
    me, st, slots, steps = _victim_env([(None, 0), (7, 4)])
    assert pick(me, st, slots, steps, wave={1}) is None
    # unswappable slots are exempt
    me, st, slots, steps = _victim_env([(0, 3), (1, 7)])
    st.swappable = lambda b: b == 0
    assert pick(me, st, slots, steps, wave=set()) == 0


def test_pick_victim_cost_model_bytes_vs_steps():
    """Paged states expose per-slot staged blocks through the
    ``SequenceState.owned_blocks`` protocol query (repro-lint rule R4
    forbids the scheduler reaching into ``pool`` internals): the victim
    maximizes decode-steps-saved per block staged, so a slot that would
    stage many blocks needs proportionally more remaining steps to be
    picked.  Zero-staging slots and dense states (``owned_blocks == 0``)
    reduce to the raw most-steps ordering pinned above."""
    pick = BatchedEngine._pick_victim
    me, st, slots, steps = _victim_env([(0, 8), (1, 6), (2, 6)])
    owned = {0: [0] * 7, 1: [0], 2: [0]}
    st.owned_blocks = lambda b: len(owned[b])
    # slot 0 leads on steps (8) but stages 7 blocks (score 8/8 = 1.0);
    # slots 1/2 stage one block each (6/2 = 3.0) — the cheap swaps win,
    # and their exact tie falls back to the youngest (largest) rid
    assert pick(me, st, slots, steps, wave=set()) == 2
    # equal staging -> same order as the dense tie-break
    owned = {b: [0] for b in range(3)}
    assert pick(me, st, slots, steps, wave=set()) == 0


# ---------------------------------------------------------------- property
@pytest.fixture(scope="module")
def mono_engine(pair):
    edge, ep, cloud, cp = pair
    be = BatchedEngine(edge, cloud, batch_size=2, temperature=0.0,
                       policy=ThresholdPolicy(1.1), use_cache=False,
                       tick_tokens=4)
    return be, ep, cp, edge.cfg.vocab_size


def _check_ttft_monotone(mono_engine, gaps):
    """PROPERTY: under a deterministic trace (FIFO admission, uniform
    budgets, no cache), first-token times are nondecreasing in arrival
    order — a later arrival can never beat an earlier one to its first
    token."""
    be, ep, cp, vocab = mono_engine
    # the engine's virtual clock persists across runs: offset the trace
    # so arrivals are in this run's future, not its past
    at = be.clock.now() + np.cumsum(np.asarray(gaps, np.float64))
    prompts = _prompts(vocab, [(6 + i % 3, 5 * i) for i in range(len(at))])
    traces = replay(be, ep, cp, prompts, 4, at)
    assert len(traces) == len(at)
    firsts = [be.events[r]["first_token_ms"] for r in sorted(be.events)]
    assert all(a <= b for a, b in zip(firsts, firsts[1:])), firsts


try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # deterministic fallback traces
    @pytest.mark.parametrize("gaps", [
        [0, 0, 0],                       # simultaneous burst
        [0, 40, 0, 40],                  # arrivals straddle ticks
        [7, 1, 0, 23, 2, 11],            # mixed gaps, > batch_size deep
        [40, 40, 40],                    # idle gaps between every arrival
    ])
    def test_ttft_monotone_in_arrival_order(mono_engine, gaps):
        _check_ttft_monotone(mono_engine, gaps)
else:
    @settings(max_examples=8, deadline=None)
    @given(st.lists(st.integers(0, 40), min_size=3, max_size=6))
    def test_ttft_monotone_in_arrival_order(mono_engine, gaps):
        _check_ttft_monotone(mono_engine, gaps)
