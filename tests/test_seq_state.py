"""Family-agnostic SequenceState serving: batched recurrent speculation.

The contract extended to every model family: ``BatchedEngine`` routes
recurrent-state edge/cloud models (ssm = mamba2, hybrid = zamba2, xlstm)
through the SAME slot/tick/grouped-escalation machinery as the KV families,
with speculative rewinds executed as batched accepted-prefix replays
(``Model.replay_step`` behind ``core/seq_state.py``) — token-for-token
equal to ``serve_reference``'s per-request snapshot+replay loop, with ZERO
host-side per-request fallback calls.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import speculative as spec_mod
from repro.configs import get_config
from repro.core.engine import CollaborativeEngine
from repro.core.policy import (SpeculativePolicy, ThresholdPolicy,
                               policy_from_legacy)
from repro.core.scheduler import BatchedEngine
from repro.core.seq_state import PagedKV, layout_for
from repro.core.speculative import autoregressive_baseline
from repro.models import Model

# one edge arch per family named by the acceptance criteria; the shared
# cloud is the dense transformer (mixed family pairs by construction)
EDGE_ARCHS = {
    "dense": "smollm-135m",
    "moe": "granite-moe-1b-a400m",
    "ssm": "mamba2-370m",
    "hybrid": "zamba2-2.7b",
    "xlstm": "xlstm-125m",
}
RECURRENT = ("ssm", "hybrid", "xlstm")


@pytest.fixture(scope="module")
def cloud():
    c_cfg = get_config("granite-8b").reduced().replace(vocab_size=512)
    m = Model(c_cfg)
    return m, m.init(jax.random.PRNGKey(1))


@pytest.fixture(scope="module")
def edges():
    out = {}
    for fam, arch in EDGE_ARCHS.items():
        cfg = get_config(arch).reduced().replace(vocab_size=512)
        m = Model(cfg)
        out[fam] = (m, m.init(jax.random.PRNGKey(0)))
    return out


def _prompts(vocab, specs):
    return [((np.arange(n) * 7 + off) % vocab).astype(np.int32)
            for n, off in specs]


# ---------------------------------------------------------------- routing
def test_recurrent_layouts_resolved(edges, cloud):
    """Recurrent families get the recurrent adapter; the mixed pair's cloud
    lane keeps its KV layout; nobody needs a per-request fallback."""
    cm, _ = cloud
    for fam in RECURRENT:
        em, _ = edges[fam]
        be = BatchedEngine(em, cm, use_cache=False)
        assert be.kv_layout == "dense"          # auto: no paging for state
        assert be.edge.layout == "recurrent"
        assert be.cloud.layout == "dense"
        assert layout_for(em, be.kv_layout) == "recurrent"


# ---------------------------------------------------------------- edge path
@pytest.mark.parametrize("fam", RECURRENT)
def test_recurrent_edge_parity_staggered(fam, edges, cloud):
    """Greedy tokens match serve_reference under staggered prompt lengths
    AND budgets, with a batch smaller than the request count so slots
    admit/retire mid-run."""
    em, ep = edges[fam]
    cm, cp = cloud
    prompts = _prompts(512, [(8, 0), (6, 3), (9, 7), (5, 2)])
    budgets = [3, 9, 6, 8]
    ref = CollaborativeEngine(em, cm, temperature=0.0,
                              policy=ThresholdPolicy(1.1), use_cache=False)
    be = BatchedEngine(em, cm, batch_size=2, temperature=0.0,
                       policy=ThresholdPolicy(1.1), use_cache=False,
                       tick_tokens=4)
    bts = be.serve_batch(ep, cp, prompts, budgets)
    for p, m, bt in zip(prompts, budgets, bts):
        rt = ref.serve_reference(ep, cp, p, m)
        assert bt.path == rt.path == "edge"
        assert bt.tokens == rt.tokens and len(bt.tokens) == m
        assert abs(bt.uncertainty - rt.uncertainty) < 1e-5


# ---------------------------------------------------------------- chunked
@pytest.mark.parametrize("fam", EDGE_ARCHS)
def test_chunked_prefill_parity(fam, edges, cloud):
    """Long prompts admitted via DETACHED CHUNKED PREFILL (prefill_chunk=8
    entries landing across ticks, interleaved with the batch's decode)
    keep exact greedy token parity with ``serve_reference`` on EVERY
    family.  Lengths straddle the chunk size: an exact multiple (33 ->
    32 entries), above/below multiples (21, 16), and one short prompt (9)
    that takes the unchunked whole-prompt path alongside the jobs."""
    em, ep = edges[fam]
    cm, cp = cloud
    prompts = _prompts(512, [(33, 0), (21, 5), (16, 9), (9, 2)])
    budgets = [6, 4, 7, 5]
    ref = CollaborativeEngine(em, cm, temperature=0.0,
                              policy=ThresholdPolicy(1.1), use_cache=False)
    be = BatchedEngine(em, cm, batch_size=2, temperature=0.0,
                       policy=ThresholdPolicy(1.1), use_cache=False,
                       tick_tokens=4, prefill_chunk=8)
    bts = be.serve_batch(ep, cp, prompts, budgets)
    for p, m, bt in zip(prompts, budgets, bts):
        rt = ref.serve_reference(ep, cp, p, m)
        assert bt.path == rt.path == "edge"
        assert bt.tokens == rt.tokens and len(bt.tokens) == m
        assert abs(bt.uncertainty - rt.uncertainty) < 1e-5


def test_share_hints_keep_prefix_sharing_under_chunking(edges, cloud,
                                                        monkeypatch):
    """Shared-prefix prompts keep block-level prefix sharing when chunked
    prefill is on: ``share_hints`` routes them down the monolithic admit
    path (a chunked ``begin`` defers index registration until finalize,
    which would forfeit same-wave sharing), while a prompt with a unique
    first block still chunks.  Token parity with ``serve_reference``
    holds throughout."""
    em, ep = edges["dense"]
    cm, cp = cloud
    pref = ((np.arange(16) * 3) % 512).astype(np.int32)     # 2 full blocks
    prompts = [np.concatenate([pref, ((np.arange(5) * 11 + o) % 512)
                               .astype(np.int32)]) for o in range(3)]
    prompts.append(((np.arange(25) * 13 + 200) % 512).astype(np.int32))
    begin_lens = []
    orig_begin = PagedKV.begin
    monkeypatch.setattr(
        PagedKV, "begin",
        lambda self, b, prompt, need: begin_lens.append(
            int(np.asarray(prompt).size)) or orig_begin(
                self, b, prompt, need))
    ref = CollaborativeEngine(em, cm, temperature=0.0,
                              policy=ThresholdPolicy(1.1), use_cache=False)
    be = BatchedEngine(em, cm, batch_size=4, temperature=0.0,
                       policy=ThresholdPolicy(1.1), use_cache=False,
                       tick_tokens=4, kv_layout="paged", kv_block_size=8,
                       prefill_chunk=8)
    bts = be.serve_batch(ep, cp, prompts, 6)
    for p, bt in zip(prompts, bts):
        rt = ref.serve_reference(ep, cp, p, 6)
        assert bt.path == rt.path == "edge"
        assert bt.tokens == rt.tokens
    st = be.stats()
    # first registrant doesn't count as a hit; its two wave twins do
    assert st["kv_prefix_hits"] == 2 and st["kv_shared_blocks"] > 0
    # only the unique-first-block prompt took the chunked begin path
    assert begin_lens == [25]


# ---------------------------------------------------------------- escalation
@pytest.mark.parametrize("esc", ["speculative", "cloud", "skeleton"])
def test_recurrent_escalation_parity(esc, edges, cloud):
    """Every grouped escalation mode matches the reference for a recurrent
    edge — including speculative, whose rewind is the batched replay."""
    em, ep = edges["ssm"]
    cm, cp = cloud
    prompts = _prompts(512, [(8, 0), (6, 3), (10, 5)])
    ref = CollaborativeEngine(em, cm, temperature=0.0,
                              policy=policy_from_legacy(esc, -1.0),
                              use_cache=False, skeleton_len=4)
    be = BatchedEngine(em, cm, batch_size=2, temperature=0.0,
                       policy=policy_from_legacy(esc, -1.0),
                       use_cache=False, skeleton_len=4, tick_tokens=4)
    rts = [ref.serve_reference(ep, cp, p, 8) for p in prompts]
    bts = be.serve_batch(ep, cp, prompts, 8)
    for rt, bt in zip(rts, bts):
        assert bt.path == rt.path == esc
        assert bt.tokens == rt.tokens


@pytest.mark.parametrize("fam", EDGE_ARCHS)
def test_all_family_speculative_parity(fam, edges, cloud):
    """All five families (dense transformer, moe, ssm, hybrid, xlstm) pass
    batched-vs-serve_reference parity through speculative escalation.
    max_new > gamma forces multiple rounds, so partial accepts exercise
    mid-stream rewinds (pos writes for KV, replays for recurrent state)."""
    em, ep = edges[fam]
    cm, cp = cloud
    prompts = _prompts(512, [(8, 0), (6, 3)])
    ref = CollaborativeEngine(em, cm, gamma=3, temperature=0.0,
                              policy=SpeculativePolicy(-1.0), use_cache=False)
    be = BatchedEngine(em, cm, batch_size=2, gamma=3, temperature=0.0,
                       policy=SpeculativePolicy(-1.0), use_cache=False,
                       tick_tokens=4)
    rts = [ref.serve_reference(ep, cp, p, 8) for p in prompts]
    bts = be.serve_batch(ep, cp, prompts, 8)
    for rt, bt in zip(rts, bts):
        assert bt.path == rt.path == "speculative"
        assert bt.tokens == rt.tokens


@pytest.mark.parametrize("fam", RECURRENT)
def test_recurrent_speculation_lossless(fam, edges, cloud):
    """Greedy speculative escalation with a recurrent draft equals cloud-
    only greedy decoding — losslessness survives the batched replay."""
    em, ep = edges[fam]
    cm, cp = cloud
    prompts = _prompts(512, [(8, 0), (6, 3)])
    be = BatchedEngine(em, cm, batch_size=2, temperature=0.0,
                       policy=SpeculativePolicy(-1.0), use_cache=False)
    bts = be.serve_batch(ep, cp, prompts, 8)
    for p, bt in zip(prompts, bts):
        base = autoregressive_baseline(cm, cp, p, 8, temperature=0.0)
        assert bt.tokens == base


def test_recurrent_cloud_side_replay(edges, cloud):
    """A recurrent CLOUD (dense edge drafting for a hybrid verifier) also
    rides the batched path: the target-side rewind is the replay."""
    em, ep = edges["dense"]
    cm, cp = edges["hybrid"]
    prompts = _prompts(512, [(8, 0), (6, 3)])
    ref = CollaborativeEngine(em, cm, temperature=0.0,
                              policy=SpeculativePolicy(-1.0), use_cache=False)
    be = BatchedEngine(em, cm, batch_size=2, temperature=0.0,
                       policy=SpeculativePolicy(-1.0), use_cache=False)
    rts = [ref.serve_reference(ep, cp, p, 6) for p in prompts]
    bts = be.serve_batch(ep, cp, prompts, 6)
    for rt, bt in zip(rts, bts):
        assert bt.tokens == rt.tokens


def test_no_per_request_snapshot_replay(edges, cloud, monkeypatch):
    """The scheduler NEVER falls back to the host-side per-request
    SpecDecoder loop: poisoning it must not affect a recurrent drain."""
    def _boom(*a, **k):
        raise AssertionError("per-request SpecDecoder.generate called "
                             "from the batched scheduler")
    monkeypatch.setattr(spec_mod.SpecDecoder, "generate", _boom)
    em, ep = edges["ssm"]
    cm, cp = cloud
    prompts = _prompts(512, [(8, 0), (6, 3)])
    be = BatchedEngine(em, cm, batch_size=2, temperature=0.0,
                       policy=SpeculativePolicy(-1.0), use_cache=False)
    bts = be.serve_batch(ep, cp, prompts, 6)
    assert all(bt.path == "speculative" and len(bt.tokens) == 6
               for bt in bts)


# ---------------------------------------------------------------- replay op
def test_replay_step_prefix_equivalence(edges):
    """``replay_step(tokens, count)`` lands exactly on the state reached by
    decoding tokens[:count] one by one — for every recurrent family and
    every count, including 0 (frozen slot keeps its state)."""
    for fam in RECURRENT:
        m, params = edges[fam]
        prompt = _prompts(512, [(6, 1)])[0]
        _, cache = m.prefill(params, {"tokens": jnp.asarray(prompt[None, :])},
                             max_seq=16)
        tape = jnp.asarray([[7, 11, 13, 17]], jnp.int32)
        for count in range(tape.shape[1] + 1):
            got = m.replay_step(params, tape, cache,
                                jnp.asarray(count, jnp.int32))
            want = cache
            for t in range(count):
                _, want = m.decode_step(params, tape[:, t:t + 1], want)
            lg_g, _ = m.decode_step(params, jnp.asarray([[23]], jnp.int32),
                                    got)
            lg_w, _ = m.decode_step(params, jnp.asarray([[23]], jnp.int32),
                                    want)
            np.testing.assert_allclose(np.asarray(lg_g), np.asarray(lg_w),
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=f"{fam} count={count}")


# ---------------------------------------------------------------- cow
@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_cow_shared_prefix_spec_rewind_parity(layout, edges, cloud):
    """CoW correctness end-to-end: slots sharing a prompt prefix (an exact
    twin included) diverge mid-stream, then take speculative rewinds
    (threshold -1 escalates everyone; max_new > gamma forces multi-round
    partial accepts).  Tokens must match the unshared ``serve_reference``
    byte-for-byte on both layouts — on paged, the escalation group's
    draft AND verify pools shared the prefix blocks and forked them at
    first divergence."""
    em, ep = edges["dense"]
    cm, cp = cloud
    pref = ((np.arange(16) * 3) % 512).astype(np.int32)     # 2 full blocks
    prompts = [np.concatenate([pref,
                               ((np.arange(5) * 11 + o) % 512)
                               .astype(np.int32)]) for o in range(2)]
    prompts.append(prompts[0].copy())           # exact twin: partial tail
    ref = CollaborativeEngine(em, cm, gamma=3, temperature=0.0,
                              policy=SpeculativePolicy(-1.0), use_cache=False)
    be = BatchedEngine(em, cm, batch_size=3, gamma=3, temperature=0.0,
                       policy=SpeculativePolicy(-1.0), use_cache=False,
                       tick_tokens=4, kv_layout=layout, kv_block_size=8)
    rts = [ref.serve_reference(ep, cp, p, 8) for p in prompts]
    bts = be.serve_batch(ep, cp, prompts, 8)
    for rt, bt in zip(rts, bts):
        assert bt.path == rt.path == "speculative"
        assert bt.tokens == rt.tokens


# ---------------------------------------------------------------- paged read
def test_paged_decode_backend_dispatch_parity():
    """The dispatched paged decode read (Pallas kernel / jnp oracle) agrees
    with the full-width block-table gather path it replaces."""
    cfg = get_config("smollm-135m").reduced()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    from repro.core.paged_cache import (BlockPool, prompt_cache_to_blocks,
                                        write_pool_blocks)
    bs, nb, mb = 8, 9, 4
    cache = m.init_paged_cache(nb, bs, 3, mb)
    pool = BlockPool(nb, bs)
    rng = np.random.default_rng(0)
    tables, poss = [], []
    for b, S in enumerate([9, 6, 12]):
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, S)), jnp.int32)
        nblk = pool.blocks_for(S)
        blocks = pool.alloc(b, nblk)
        _, c1 = m.prefill(params, {"tokens": toks}, max_seq=nblk * bs)
        kb, vb = prompt_cache_to_blocks(c1, bs)
        cache["k"], cache["v"] = write_pool_blocks(
            cache["k"], cache["v"], jnp.asarray(blocks, jnp.int32), kb, vb)
        row = np.zeros((mb,), np.int32)
        row[:nblk] = blocks
        tables.append(row)
        poss.append(S)
    cache["table"] = jnp.asarray(np.stack(tables))
    cache["pos"] = jnp.asarray(poss, jnp.int32)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (3, 1)), jnp.int32)

    lg_gather, c_gather = m.paged_decode_step(params, tok, cache,
                                              attn_backend="gather")
    lg_ref, c_ref = m.paged_decode_step(params, tok, cache,
                                        attn_backend="ref")
    lg_kern, _ = m.paged_decode_step(params, tok, cache,
                                     attn_backend="kernel")
    np.testing.assert_allclose(np.asarray(lg_ref), np.asarray(lg_gather),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(lg_kern), np.asarray(lg_gather),
                               rtol=1e-4, atol=1e-4)
    for key in ("k", "v", "pos"):
        np.testing.assert_allclose(
            np.asarray(c_ref[key], np.float32),
            np.asarray(c_gather[key], np.float32), rtol=1e-6)
