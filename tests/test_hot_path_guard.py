"""Runtime complement of repro-lint: warmed serving must be pure.

Two teeth, one drain:

* ``CompileCounter`` (``jax.log_compiles`` listener) around a SECOND,
  identical-shape drain through a warmed ``BatchedEngine`` — zero XLA
  compilations allowed.  This is the machine check behind the
  ``steady_state_recompiles == 0`` bench gate, run at tier-1 size.
* ``jax.transfer_guard_device_to_host("disallow")`` around the same
  drain.  Device->host is the hot-path sync direction; host->device
  stays unguarded because admission legitimately uploads prompts and
  host mirrors (``jnp.asarray`` at the tick boundary).  On the CPU
  backend d2h reads are zero-copy and the guard is vacuous, so on CI
  this leg is structural — it pins that the steady-state path runs
  entirely under the guard context, so on a real accelerator (where the
  guard has teeth) the same test fails on any IMPLICIT d2h transfer.
  The scheduler's one-batched-``jax.device_get``-per-wave pulls are
  explicit transfers, which guards allow by design.

Both drains must stay token-identical to the per-request reference —
purity must not buy a different answer.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.engine import CollaborativeEngine
from repro.core.policy import SpeculativePolicy, ThresholdPolicy
from repro.core.scheduler import BatchedEngine
from repro.models import Model


@pytest.fixture(scope="module")
def pair():
    e_cfg = get_config("smollm-135m").reduced()
    c_cfg = get_config("granite-8b").reduced().replace(
        vocab_size=e_cfg.vocab_size)
    edge, cloud = Model(e_cfg), Model(c_cfg)
    return (edge, edge.init(jax.random.PRNGKey(0)),
            cloud, cloud.init(jax.random.PRNGKey(1)))


def _prompts(vocab, n, length=8):
    return [((np.arange(length) * 7 + 3 * i) % vocab).astype(np.int32)
            for i in range(n)]


@pytest.mark.parametrize("policy_cls,threshold", [
    (ThresholdPolicy, 1.1),          # pure edge decode
    (SpeculativePolicy, -1.0),       # every request escalates (group path)
])
def test_steady_state_drain_is_pure(pair, compile_counter, policy_cls,
                                    threshold):
    edge, ep, cloud, cp = pair
    prompts = _prompts(edge.cfg.vocab_size, 4)
    eng = BatchedEngine(edge, cloud, batch_size=2, temperature=0.0,
                        policy=policy_cls(threshold), use_cache=False,
                        tick_tokens=4)
    warm = eng.serve_batch(ep, cp, prompts, 8)          # compiles here
    assert compile_counter.count > 0, \
        "warm-up drain compiled nothing — the counter is not listening"
    compile_counter.reset()
    with jax.transfer_guard_device_to_host("disallow"):
        steady = eng.serve_batch(ep, cp, prompts, 8)
    assert compile_counter.count == 0, (
        "steady-state drain recompiled: " + "; ".join(compile_counter.events))
    ref = CollaborativeEngine(edge, cloud, temperature=0.0,
                              policy=policy_cls(threshold), use_cache=False)
    for p, w, s in zip(prompts, warm, steady):
        rt = ref.serve_reference(ep, cp, p, 8)
        assert w.tokens == s.tokens == rt.tokens
        assert w.path == s.path == rt.path
