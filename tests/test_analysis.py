"""repro-lint (``src/repro/analysis``) — rule fixtures, suppression
semantics, CLI exit codes, and the real-tree-clean regression.

Each rule gets a good/bad source pair driven through ``analyze_source``;
the suppression tests pin the load-bearing property that a marker WITHOUT
a reason suppresses nothing, and the strip test pins that the shipped
suppressions in ``core/scheduler.py`` are actually holding back findings
(so deleting one, or re-seeding a violation, turns the tree non-clean).
"""
import importlib.util
import inspect
import json
import re
import textwrap
from pathlib import Path

import pytest

from repro.analysis import (RULE_DOCS, RULES, analyze_paths, analyze_source,
                            hot_path)
from repro.analysis.protocol import PROTOCOL_SURFACES

REPO = Path(__file__).resolve().parents[1]

_SCRIPT = REPO / "scripts" / "repro_lint.py"
_spec = importlib.util.spec_from_file_location("repro_lint", _SCRIPT)
repro_lint = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(repro_lint)


def _lint(source, path="mod.py", rules=None):
    return analyze_source(path, textwrap.dedent(source), rules)


def _rules(findings):
    return [f.rule for f in findings]


# ------------------------------------------------------------------- R1
HOT_ITEM = """
    from repro.analysis import hot_path

    @hot_path
    def tick(x):
        return x.item()
"""


def test_r1_item_in_hot_function():
    (f,) = _lint(HOT_ITEM)
    assert f.rule == "R1" and ".item()" in f.message and f.line == 6


def test_r1_cold_function_not_flagged():
    assert _lint("def tick(x):\n    return x.item()\n") == []


def test_r1_asarray_flagged_array_not():
    src = """
        @hot_path
        def tick(v):
            a = np.asarray(v)
            b = np.array(v)
            return a, b
    """
    (f,) = _lint(src)
    assert f.rule == "R1" and "asarray" in f.message


def test_r1_device_get_and_blocking():
    src = """
        @hot_path
        def tick(v):
            h = jax.device_get(v)
            v.block_until_ready()
            return h
    """
    assert _rules(_lint(src)) == ["R1", "R1"]


def test_r1_scalar_pull_and_nested_hotness():
    src = """
        @hot_path
        def outer(v):
            def inner(u):
                return float(u.max())
            return inner(v)
    """
    (f,) = _lint(src)
    assert f.rule == "R1" and "device scalar" in f.message


def test_r1_host_int_on_subscript_ok():
    # int() over plain indexing is how the host mirrors are read — legal
    src = """
        @hot_path
        def tick(steps_h, b):
            return int(steps_h[b])
    """
    assert _lint(src) == []


def test_hot_path_marker_is_transparent():
    @hot_path
    def f(x):
        return x + 1

    assert f(1) == 2 and f.__hot_path__ is True


# ---------------------------------------------------------- suppression
def test_suppression_same_line_and_line_above():
    src = """
        @hot_path
        def tick(v):
            a = jax.device_get(v)  # repro-lint: ok(R1, the one batched pull)
            # repro-lint: ok(R1, second batched pull for the group path)
            b = jax.device_get(v)
            return a, b
    """
    assert _lint(src) == []


def test_reasonless_marker_suppresses_nothing_and_is_flagged():
    src = """
        @hot_path
        def tick(v):
            return jax.device_get(v)  # repro-lint: ok(R1)
    """
    assert sorted(_rules(_lint(src))) == ["R0", "R1"]


def test_wrong_rule_suppression_does_not_apply():
    src = """
        @hot_path
        def tick(v):
            return jax.device_get(v)  # repro-lint: ok(R2, wrong rule id)
    """
    assert _rules(_lint(src)) == ["R1"]


def test_malformed_marker_flagged():
    (f,) = _lint("x = 1  # repro-lint: okay(R1, typo)\n")
    assert f.rule == "R0"


def test_docstring_mentioning_marker_is_not_a_marker():
    src = '''
        def doc():
            """Suppress with `# repro-lint: ok(R1)` — reasonless example."""
            return 1
    '''
    assert _lint(src) == []


# ------------------------------------------------------------------- R2
def test_r2_branch_on_traced_param():
    src = """
        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
    """
    (f,) = _lint(src)
    assert f.rule == "R2" and "`if` on traced param `x`" in f.message


def test_r2_static_shapes_and_statics_clean():
    src = """
        @functools.partial(jax.jit, static_argnames=("n",))
        def f(x, n):
            if x.shape[0] > 2:
                pass
            if n > 0:
                pass
            if x is None:
                pass
            for _ in range(n):
                pass
            return x
    """
    assert _lint(src) == []


def test_r2_fstring_and_loop_and_ifexp():
    src = """
        @jax.jit
        def f(x, n):
            s = f"value={x}"
            y = x if x > 0 else -x
            for i in range(n):
                y = y + i
            return y, s
    """
    assert sorted(_rules(_lint(src))) == ["R2", "R2", "R2"]


def test_r2_unhashable_static_at_call_site():
    src = """
        def body(x, n_steps):
            return x

        run = jax.jit(body, static_argnames=("n_steps",))

        def drive(x):
            return run(x, n_steps=[4])
    """
    (f,) = _lint(src)
    assert f.rule == "R2" and "unhashable" in f.message


def test_r2_hashable_static_call_site_clean():
    src = """
        def body(x, n_steps):
            return x

        run = jax.jit(body, static_argnames=("n_steps",))

        def drive(x):
            return run(x, n_steps=4)
    """
    assert _lint(src) == []


# ------------------------------------------------------------------- R3
GOOD_KERNEL = """
    from jax.experimental import pallas as pl

    def _k(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2

    def double(x, *, interpret=False):
        spec = pl.BlockSpec((128,), lambda i: (i,))
        return pl.pallas_call(_k, out_shape=x, in_specs=[spec],
                              out_specs=spec, interpret=interpret)(x)
"""


def _kernel_dir(tmp_path, src, ref="def double_ref(x):\n    return 2 * x\n"):
    d = tmp_path / "kern"
    d.mkdir()
    (d / "op.py").write_text(textwrap.dedent(src))
    if ref is not None:
        (d / "ref.py").write_text(ref)
    return d / "op.py"


def test_r3_good_kernel_clean(tmp_path):
    from repro.analysis import analyze_file
    assert analyze_file(_kernel_dir(tmp_path, GOOD_KERNEL)) == []


def test_r3_missing_ref_and_interpret(tmp_path):
    from repro.analysis import analyze_file
    src = GOOD_KERNEL.replace(", *, interpret=False", "") \
                     .replace("interpret=interpret", "interpret=False")
    findings = analyze_file(_kernel_dir(tmp_path, src, ref=None))
    msgs = " | ".join(f.message for f in findings)
    assert _rules(findings) == ["R3", "R3"]
    assert "interpret" in msgs and "missing" in msgs


def test_r3_impure_index_map_and_print(tmp_path):
    from repro.analysis import analyze_file
    src = """
        from jax.experimental import pallas as pl

        def _k(x_ref, o_ref):
            print("debug")
            o_ref[...] = x_ref[...]

        def double(x, *, interpret=False):
            spec = pl.BlockSpec((128,), lambda i: (np.random.randint(i),))
            return pl.pallas_call(_k, out_shape=x, in_specs=[spec],
                                  out_specs=spec, interpret=interpret)(x)
    """
    findings = analyze_file(_kernel_dir(tmp_path, src))
    assert sorted(_rules(findings)) == ["R3", "R3"]
    msgs = " | ".join(f.message for f in findings)
    assert "pure function" in msgs and "`print`" in msgs


def test_r3_non_pallas_file_skipped():
    assert _lint("def BlockSpec():\n    return open('x')\n") == []


# ------------------------------------------------------------------- R4
def test_r4_missing_method_and_bad_arity():
    src = """
        class Partial(SequenceState):
            def admit(self, b, prompt):
                return True
    """
    findings = _lint(src)
    assert sorted(_rules(findings)) == ["R4", "R4", "R4"]
    msgs = " | ".join(f.message for f in findings)
    assert "finalize" in msgs and "detached_len" in msgs and "admit" in msgs


def test_r4_conforming_subclass_clean():
    src = """
        class Full(SequenceState):
            def admit(self, b, prompt, need_tokens):
                return True

            def finalize(self, b, cache, extra=None):
                pass

            def detached_len(self, entry_count):
                return entry_count
    """
    assert _lint(src) == []


def test_r4_scheduler_purity():
    src = """
        def route(state, lane):
            if isinstance(state, PagedKV):
                pass
            if lane.layout == "paged":
                pass
            return getattr(state, "pool", None)
    """
    findings = _lint(src, path="src/repro/core/scheduler.py")
    assert sorted(_rules(findings)) == ["R4", "R4", "R4"]
    # the same constructs OUTSIDE the scheduler are legal
    assert _lint(src, path="src/repro/core/seq_state.py") == []


def test_protocol_surfaces_match_live_signatures():
    """The baked arity table cannot rot: every entry must equal the live
    protocol method's positional arity (incl. self)."""
    from repro.core.policy import CollabPolicy
    from repro.core.seq_state import SequenceState, SpecOps
    live = {"SequenceState": SequenceState, "CollabPolicy": CollabPolicy,
            "SpecOps": SpecOps}
    assert set(PROTOCOL_SURFACES) == set(live)
    for cls_name, surface in PROTOCOL_SURFACES.items():
        for meth, arity in surface.items():
            sig = inspect.signature(getattr(live[cls_name], meth))
            pos = [p for p in sig.parameters.values()
                   if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)]
            assert len(pos) == arity, (cls_name, meth, sig)


# ------------------------------------------------------------ machinery
def test_syntax_error_reported_not_raised():
    (f,) = _lint("def broken(:\n")
    assert f.rule == "E0"


def test_unknown_rule_raises():
    with pytest.raises(KeyError, match="R9"):
        _lint("x = 1\n", rules=["R9"])


def test_rule_registry_complete():
    assert set(RULES) == {"R0", "R1", "R2", "R3", "R4"}
    assert set(RULE_DOCS) == set(RULES)


def test_rule_selection():
    src = """
        @hot_path
        def tick(v):
            return v.item()

        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
    """
    assert _rules(_lint(src, rules=["R1"])) == ["R1"]
    assert _rules(_lint(src, rules=["R2"])) == ["R2"]


# ----------------------------------------------------------------- tree
def test_real_tree_is_clean():
    """The shipped tree must lint clean — the acceptance gate, inside
    tier-1 so a regression fails locally before CI."""
    findings = analyze_paths([REPO / "src", REPO / "tests",
                              REPO / "benchmarks"])
    assert findings == [], "\n".join(f.format() for f in findings)


def test_shipped_suppressions_are_load_bearing():
    """Stripping the scheduler's suppression markers must re-surface R1
    findings — i.e. each shipped `ok(R1, ...)` is holding back a real
    finding, not decorating clean code."""
    path = REPO / "src" / "repro" / "core" / "scheduler.py"
    src = path.read_text()
    stripped = re.sub(r"#\s*repro-lint:[^\n]*", "", src)
    assert stripped != src, "scheduler.py lost its suppression markers"
    findings = analyze_source(str(path), stripped, rules=["R1"])
    assert len(findings) >= 2
    assert all(f.rule == "R1" for f in findings)


def test_reseeded_violation_turns_tree_dirty(tmp_path):
    """CLI exits non-zero the moment a violation lands in a linted file."""
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent(HOT_ITEM))
    assert repro_lint.main([str(bad)]) == 1


# ------------------------------------------------------------------ CLI
def test_cli_clean_exit_and_json_report(tmp_path, capsys):
    good = tmp_path / "good.py"
    good.write_text("def f(x):\n    return x + 1\n")
    report_path = tmp_path / "report.json"
    rc = repro_lint.main([str(good), "--format", "json",
                          "--json-out", str(report_path)])
    assert rc == 0
    report = json.loads(report_path.read_text())
    assert report["count"] == 0
    assert report["rules"] == sorted(RULES)
    assert json.loads(capsys.readouterr().out)["findings"] == []


def test_cli_findings_exit_one_with_location(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent(HOT_ITEM))
    assert repro_lint.main([str(bad), "--rules", "R1"]) == 1
    out = capsys.readouterr().out
    assert "bad.py:6" in out and "R1" in out


def test_cli_unknown_rule_exit_two(tmp_path):
    good = tmp_path / "good.py"
    good.write_text("x = 1\n")
    assert repro_lint.main([str(good), "--rules", "R7"]) == 2


def test_cli_list_rules(capsys):
    assert repro_lint.main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rid in RULES:
        assert rid in out


def test_cli_help_documents_suppression_syntax():
    assert "repro-lint: ok(" in repro_lint.__doc__
    assert "reason is REQUIRED" in repro_lint.__doc__


# --------------------------------------------------- CompileCounter
def test_compile_counter_counts_and_steady_state(compile_counter):
    import jax
    import jax.numpy as jnp

    @jax.jit
    def f(x):
        return x * 2 + 1

    f(jnp.zeros((4,))).block_until_ready()
    first = compile_counter.count
    assert first >= 1
    assert any("f" in e for e in compile_counter.events)
    f(jnp.ones((4,))).block_until_ready()      # same shape: no recompile
    assert compile_counter.count == first
    f(jnp.zeros((8,))).block_until_ready()     # new shape: recompiles
    assert compile_counter.count > first
    compile_counter.reset()
    assert compile_counter.count == 0 and compile_counter.events == []
