"""Online adaptation subsystem (``core/adaptation.py`` +
``data/feedback_store.py``): feedback capture off the scheduler's
retirement path, background distillation/LoRA updates, and the hot-swap
contract — a pure pytree exchange that must neither change served tokens
(identity adapters) nor trigger a single steady-state recompile."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.adaptation import AdaptationLoop
from repro.core.policy import ThresholdPolicy, cloud_tokens
from repro.core.scheduler import BatchedEngine
from repro.data import SyntheticLM
from repro.data.feedback_store import TOPK_FILL, FeedbackStore
from repro.models import Model
from repro.training import checkpoint
from repro.training.lora import init_lora, merge_lora
from repro.training.optimizer import AdamW


@pytest.fixture(scope="module")
def pair():
    e_cfg = get_config("smollm-135m").reduced()
    c_cfg = get_config("granite-8b").reduced().replace(
        vocab_size=e_cfg.vocab_size)
    edge, cloud = Model(e_cfg), Model(c_cfg)
    return (edge, edge.init(jax.random.PRNGKey(0)),
            cloud, cloud.init(jax.random.PRNGKey(1)))


def _prompts(vocab, n, length=8):
    return [((np.arange(length) * 7 + 3 * i) % vocab).astype(np.int32)
            for i in range(n)]


def _engine(edge, cloud, adapt, threshold=0.0, batch=4):
    return BatchedEngine(edge, cloud, batch_size=batch, temperature=0.0,
                         policy=ThresholdPolicy(threshold), use_cache=False,
                         tick_tokens=4, adaptation=adapt)


# --------------------------------------------------------------- store
def test_store_ring_bounds_and_eviction():
    s = FeedbackStore(capacity=4)
    for i in range(6):
        s.add(np.arange(3), [i], domain=i % 2,
              sla="met" if i % 3 else "missed", path="cloud")
    assert len(s) == 4
    st = s.stats()
    assert st["added"] == 6 and st["evicted"] == 2 and st["capacity"] == 4
    # oldest two fell off the ring; counters still see every add
    assert [r.tokens[0] for r in s.records()] == [2, 3, 4, 5]
    assert st["by_domain"] == {"0": 3, "1": 3}
    assert st["by_sla"] == {"missed": 2, "met": 4}
    assert st["by_path"] == {"cloud": 6}


def test_store_validation():
    with pytest.raises(ValueError):
        FeedbackStore(capacity=0)
    with pytest.raises(ValueError):
        FeedbackStore().sample_batch(np.random.default_rng(0), 2, 8, 16)
    with pytest.raises(ValueError):
        AdaptationLoop(mode="finetune")
    with pytest.raises(ValueError):
        AdaptationLoop(interval=-1)


def test_sample_batch_shapes_and_teacher_scatter():
    vocab, P = 32, 4
    s = FeedbackStore()
    prompt = np.arange(P, dtype=np.int32)
    tokens = np.array([9, 11, 13], np.int32)
    tv = np.array([[2.0, 1.0], [3.0, 0.5], [4.0, 0.25]], np.float32)
    ti = np.array([[9, 1], [11, 2], [13, 3]], np.int32)
    s.add(prompt, tokens, teacher_topk=(tv, ti), domain=1)
    b = s.sample_batch(np.random.default_rng(0), 2, 12, vocab, topk=2)
    assert b["tokens"].shape == (2, 12) and b["labels"].shape == (2, 12)
    assert b["teacher_logits"].shape == (2, 12, vocab)
    lab = np.array(b["labels"][0])
    # only the continuation is supervised; prompt and pad stay -1
    assert (lab[:P] == -1).all() and (lab[P:P + 3] == tokens).all()
    assert (lab[P + 3:] == -1).all()
    km = np.array(b["kd_mask"][0])
    tl = np.array(b["teacher_logits"][0])
    # generated token j scatters at teacher-forced position P-1+j
    for j in range(3):
        pos = P - 1 + j
        assert km[pos]
        assert tl[pos, ti[j, 0]] == tv[j, 0]
        assert tl[pos, ti[j, 1]] == tv[j, 1]
    assert not km[[0, P + 2, 11]].any()
    assert tl[0].max() == TOPK_FILL       # unmasked rows stay at the fill


def test_sample_batch_domain_filter():
    s = FeedbackStore()
    s.add(np.arange(2), [5], domain=0)
    s.add(np.arange(2), [7], domain=1)
    rng = np.random.default_rng(0)
    b = s.sample_batch(rng, 8, 6, 16, domains=[1])
    assert (np.array(b["labels"])[:, 2] == 7).all()
    # empty tagged subset falls back to the whole ring, not an error
    b = s.sample_batch(rng, 8, 6, 16, domains=[9])
    assert set(np.array(b["labels"])[:, 2].tolist()) <= {5, 7}


# ----------------------------------------------------------- capture
def test_scheduler_capture_and_tagging(pair):
    edge, ep, cloud, cp = pair
    adapt = AdaptationLoop(mode="distill", interval=0, topk=4)
    eng = _engine(edge, cloud, adapt)            # threshold 0 -> all cloud
    prompts = _prompts(edge.cfg.vocab_size, 6)
    traces = eng.serve_batch(ep, cp, prompts, 5,
                             domains=[i % 2 for i in range(6)])
    assert all(t.path == "cloud" for t in traces)
    st = adapt.store.stats()
    assert st["size"] == 6 and st["by_path"] == {"cloud": 6}
    assert st["by_domain"] == {"0": 3, "1": 3}
    for r in adapt.store.records():
        assert r.tokens.size == 5 and r.draft is not None
        assert r.teacher_values.shape == (5, 4)      # rode the wave's pull
        assert r.teacher_indices.dtype == np.int32
    assert "adaptation" in eng.stats()
    # capture-only: interval=0 never marks an update pending
    assert adapt.updates == 0 and adapt.maybe_update(ep) is None


def test_capture_topk_gated_by_mode():
    assert AdaptationLoop(mode="distill", topk=8).capture_topk == 8
    assert AdaptationLoop(mode="lora", topk=8).capture_topk == 0


# ------------------------------------------------------------- training
def test_one_cold_compile_then_zero_across_swaps(pair, compile_counter):
    edge, ep, cloud, cp = pair
    adapt = AdaptationLoop(mode="distill", interval=6, batch_size=4,
                           seq_len=16, topk=4, min_records=1)
    eng = _engine(edge, cloud, adapt)
    prompts = _prompts(edge.cfg.vocab_size, 6)
    eng.serve_batch(ep, cp, prompts, 5)          # fill + mark pending
    before = compile_counter.count
    eng.serve_batch(ep, cp, prompts, 5)          # first update: cold compile
    assert adapt.swaps == 1
    cold = compile_counter.count - before
    assert cold > 0, "first train step never compiled?"
    steady_start = compile_counter.count
    eng.serve_batch(ep, cp, prompts, 5)          # second update: warm step
    eng.serve_batch(ep, cp, prompts, 5)
    assert adapt.swaps == 3
    assert compile_counter.count == steady_start, \
        f"train step / swap recompiled: {compile_counter.events}"


def test_lora_zero_init_hot_swap_parity(pair):
    """lr=0 LoRA: every swap installs merge(base, zero adapters) == base,
    so the adapted engine must be token-identical to an adaptation-free
    one — the hot-swap mechanism itself cannot perturb serving."""
    edge, ep, cloud, cp = pair
    adapt = AdaptationLoop(mode="lora", interval=4, batch_size=4,
                           seq_len=16, opt=AdamW(lr=0.0), min_records=1)
    prompts = _prompts(edge.cfg.vocab_size, 8)
    adapted = _engine(edge, cloud, adapt).serve_batch(ep, cp, prompts, 6)
    plain = _engine(edge, cloud, None).serve_batch(ep, cp, prompts, 6)
    assert adapt.swaps >= 1
    assert all(a.tokens == b.tokens for a, b in zip(adapted, plain))


def test_adaptation_persists_across_drains(pair):
    edge, ep, cloud, cp = pair
    adapt = AdaptationLoop(mode="distill", interval=4, batch_size=4,
                           seq_len=16, topk=4, min_records=1)
    eng = _engine(edge, cloud, adapt)
    prompts = _prompts(edge.cfg.vocab_size, 4)
    eng.serve_batch(ep, cp, prompts, 5)
    eng.serve_batch(ep, cp, prompts, 5)
    assert adapt.latest is not None
    # the next drain starts from the adapted weights, not the caller's
    assert adapt.current(ep) is adapt.latest
    some = jax.tree.leaves(adapt.latest)[0]
    assert not np.allclose(np.asarray(some),
                           np.asarray(jax.tree.leaves(ep)[0]))


# ----------------------------------------------------------- checkpoint
def test_checkpoint_slash_keys_roundtrip(tmp_path):
    """The regression the LoRA adapter tree exposed: a dict key that
    itself contains "/" must not collide with the nested spelling of the
    same path in the flat npz namespace."""
    tree = {"a/b": np.full((2,), 1.0, np.float32),
            "a": {"b": np.full((2,), 2.0, np.float32)}}
    checkpoint.save(str(tmp_path / "amb"), tree, step=3)
    back, step = checkpoint.restore(str(tmp_path / "amb"), tree)
    assert step == 3
    assert np.array_equal(np.asarray(back["a/b"]), tree["a/b"])
    assert np.array_equal(np.asarray(back["a"]["b"]), tree["a"]["b"])


def test_adapter_save_swap_restore(pair, tmp_path):
    """Adapters trained at serve time survive a save -> fresh-process
    restore -> merge: the restored merge is bit-identical to the live
    hot-swapped weights."""
    edge, ep, cloud, cp = pair
    adapt = AdaptationLoop(mode="lora", interval=4, batch_size=4,
                           seq_len=16, opt=AdamW(lr=1e-3), min_records=1)
    eng = _engine(edge, cloud, adapt)
    prompts = _prompts(edge.cfg.vocab_size, 4)
    eng.serve_batch(ep, cp, prompts, 5)
    eng.serve_batch(ep, cp, prompts, 5)
    assert adapt.swaps >= 1 and adapt.adapters is not None
    checkpoint.save(str(tmp_path / "adapters"), adapt.adapters,
                    step=adapt.steps)
    like = init_lora(jax.random.PRNGKey(0), ep, rank=adapt.lora_rank)
    restored, step = checkpoint.restore(str(tmp_path / "adapters"), like)
    assert step == adapt.steps
    merged = merge_lora(ep, restored)
    for a, b in zip(jax.tree.leaves(merged), jax.tree.leaves(adapt.latest)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------- integration
def test_edge_improves_on_stationary_stream(pair):
    """The subsystem's reason to exist: distilling on its own served
    traffic must pull the edge below the escalation gate — cloud share
    falls, acceptance rises — on a stationary synthetic stream."""
    edge, ep, cloud, cp = pair
    synth = SyntheticLM(edge.cfg.vocab_size)
    rng = np.random.default_rng(21)
    n, max_new = 8, 6
    prompts = [synth.sample(rng, i % synth.n_domains, 8) for i in range(n)]
    domains = [i % synth.n_domains for i in range(n)]
    probe = _engine(edge, cloud, None, threshold=1.1)
    uncs = [t.uncertainty for t in probe.serve_batch(ep, cp, prompts,
                                                     max_new)]
    thr = float(np.quantile(uncs, 0.25))
    adapt = AdaptationLoop(mode="distill", interval=n, batch_size=8,
                           seq_len=8 + max_new, topk=8, steps_per_update=8,
                           opt=AdamW(lr=1e-3), min_records=4)
    eng = _engine(edge, cloud, adapt, threshold=thr)
    shares, accepts = [], []
    for _ in range(3):
        traces = eng.serve_batch(ep, cp, prompts, max_new, domains=domains)
        shares.append(sum(cloud_tokens(t, 4) for t in traces))
        accepts.append(sum(t.path == "edge" for t in traces) / n)
    assert accepts[0] < 1.0, "gate placed too loose to measure improvement"
    assert adapt.swaps >= 1
    assert shares[-1] < shares[0], shares
    assert accepts[-1] > accepts[0], accepts
