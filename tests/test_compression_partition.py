"""Communication optimization (§2.2.4) and split inference (§2.2.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.compression import (Int4Quantizer, Int8Quantizer, TopKLogits,
                                    TopKSparsifier, entropy_bits_estimate,
                                    relative_error)
from repro.core.partition import SplitCostModel, split_inference
from repro.models import Model, example_batch


@pytest.fixture(scope="module")
def act():
    return jax.random.normal(jax.random.PRNGKey(0), (4, 32, 64))


def test_int8_roundtrip(act):
    c = Int8Quantizer().compress(act)
    out = Int8Quantizer().decompress(c)
    assert relative_error(out, act) < 0.01
    assert c.wire_bytes < act.size * 4 / 3.5      # ~4x smaller


def test_int4_tradeoff(act):
    c8 = Int8Quantizer().compress(act)
    c4 = Int4Quantizer().compress(act)
    assert c4.wire_bytes < c8.wire_bytes
    e8 = relative_error(Int8Quantizer().decompress(c8), act)
    e4 = relative_error(Int4Quantizer().decompress(c4), act)
    assert e4 > e8                                 # fidelity/bytes trade-off


def test_topk_sparsifier(act):
    sp = TopKSparsifier(frac=0.1)
    c = sp.compress(act)
    out = sp.decompress(c)
    nz = int(jnp.sum(out != 0))
    assert nz <= int(act.size * 0.1) + 1
    # keeping the top-10% by magnitude retains the largest energy share
    assert relative_error(act, out) < 0.9


def test_topk_error_feedback_reduces_bias():
    sp_no = TopKSparsifier(frac=0.2, error_feedback=False)
    sp_ef = TopKSparsifier(frac=0.2, error_feedback=True)
    x = jax.random.normal(jax.random.PRNGKey(1), (256,))
    acc_no = np.zeros(256)
    acc_ef = np.zeros(256)
    for _ in range(20):
        acc_no += np.asarray(sp_no.decompress(sp_no.compress(x)))
        acc_ef += np.asarray(sp_ef.decompress(sp_ef.compress(x)))
    # with error feedback, the accumulated signal approaches 20*x
    err_no = np.linalg.norm(acc_no - 20 * np.asarray(x))
    err_ef = np.linalg.norm(acc_ef - 20 * np.asarray(x))
    assert err_ef < err_no


def test_topk_logits_roundtrip():
    logits = jax.random.normal(jax.random.PRNGKey(2), (3, 100))
    tk = TopKLogits(k=10)
    rec = tk.decompress(tk.compress(logits))
    # top-1 is preserved exactly
    assert jnp.array_equal(jnp.argmax(rec, -1), jnp.argmax(logits, -1))


def test_entropy_estimate_bounds(act):
    q = np.round(np.asarray(act) * 10)
    bits = entropy_bits_estimate(q)
    assert 0 < bits <= np.log2(256)


def test_split_inference_identity_exact():
    cfg = get_config("smollm-135m").reduced()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = example_batch(cfg, 2, 12, with_labels=False)
    full, _ = m.forward(params, batch)
    lg, wire = split_inference(m, params, batch, k=1)
    assert float(jnp.max(jnp.abs(lg - full))) < 2e-3
    assert wire > 0


def test_split_int8_close():
    cfg = get_config("smollm-135m").reduced()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = example_batch(cfg, 2, 12, with_labels=False)
    full, _ = m.forward(params, batch)
    lg, wire8 = split_inference(m, params, batch, k=1,
                                compressor=Int8Quantizer())
    _, wire32 = split_inference(m, params, batch, k=1)
    assert relative_error(lg, full) < 0.05
    assert wire8 < wire32 / 3


def test_cost_model_prefers_cloud_for_heavy_models():
    cm = SplitCostModel()
    cfg = get_config("granite-8b")
    k, ts = cm.best_split(cfg, tokens=128)
    assert 0 <= k <= cfg.num_layers
    # a phone should not run all 36 layers of an 8B model
    assert k < cfg.num_layers
