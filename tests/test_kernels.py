"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape)
    return x.astype(dtype)


# ------------------------------------------------------------ flash attn
@pytest.mark.parametrize("B,H,S,hd", [(1, 1, 128, 64), (2, 3, 256, 64),
                                      (1, 2, 512, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, S, hd, dtype):
    q, k, v = (_rand(i, (B, H, S, hd), dtype) for i in range(3))
    o = ops.flash_attention(q, k, v, causal=True, bq=64, bk=64)
    o_ref = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_window(window):
    q, k, v = (_rand(i, (1, 2, 256, 64), jnp.float32) for i in range(3))
    o = ops.flash_attention(q, k, v, causal=True, window=window, bq=64, bk=64)
    o_ref = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=1e-5)


def test_flash_attention_noncausal():
    q, k, v = (_rand(i, (1, 1, 128, 64), jnp.float32) for i in range(3))
    o = ops.flash_attention(q, k, v, causal=False, bq=64, bk=64)
    o_ref = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=1e-5)


# ------------------------------------------------------------ decode attn
@pytest.mark.parametrize("B,Kv,G,S,hd", [(1, 1, 1, 256, 64), (2, 2, 4, 512, 64),
                                         (1, 4, 8, 1024, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, Kv, G, S, hd, dtype):
    q = _rand(0, (B, Kv, G, hd), dtype)
    k = _rand(1, (B, Kv, S, hd), dtype)
    v = _rand(2, (B, Kv, S, hd), dtype)
    length = jnp.asarray(np.random.default_rng(0).integers(1, S + 1, B), jnp.int32)
    o = ops.decode_attention(q, k, v, length, bs=128)
    o_ref = ref.decode_attention_ref(q, k, v, length)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), atol=tol, rtol=tol)


def test_decode_attention_window():
    q = _rand(0, (2, 2, 2, 64), jnp.float32)
    k = _rand(1, (2, 2, 512, 64), jnp.float32)
    v = _rand(2, (2, 2, 512, 64), jnp.float32)
    length = jnp.asarray([100, 512], jnp.int32)
    o = ops.decode_attention(q, k, v, length, window=64, bs=128)
    o_ref = ref.decode_attention_ref(q, k, v, length, window=64)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=1e-5)


# ------------------------------------------------------------ tree verify
def _tree_plan(width, gamma):
    from repro.core.tree_speculation import TreePlan, branching_for
    return TreePlan(branching_for(width, gamma))


@pytest.mark.parametrize("B,Kv,G,S,hd", [(1, 1, 1, 256, 64),
                                         (2, 2, 4, 512, 64),
                                         (1, 4, 2, 160, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tree_verify_attention_sweep(B, Kv, G, S, hd, dtype):
    """Tree-verify kernel vs oracle over a real packed ancestor mask,
    per-sequence lengths, and a non-divisible S (160 forces the wrapper's
    masked tail padding at bs=128)."""
    plan = _tree_plan(2, 4)
    N = plan.n_pad
    q = _rand(0, (B, Kv, G, N, hd), dtype)
    k = _rand(1, (B, Kv, S, hd), dtype)
    v = _rand(2, (B, Kv, S, hd), dtype)
    length = jnp.asarray(
        np.random.default_rng(0).integers(1, S - N + 1, B), jnp.int32)
    mask = jnp.asarray(plan.mask)
    q_pos = length[:, None] + jnp.asarray(plan.depths)[None, :]
    o = ops.tree_verify_attention(q, k, v, length, mask, q_pos, bs=128)
    o_ref = ref.tree_verify_attention_ref(q, k, v, length, mask, q_pos)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               atol=tol, rtol=tol)


def test_tree_verify_attention_windowed():
    """Sliding-window masking is depth-correct: node at depth d sees the
    window a linear decode at position length+d would."""
    plan = _tree_plan(2, 4)
    N = plan.n_pad
    B, Kv, G, S, hd = 2, 2, 2, 512, 64
    q = _rand(0, (B, Kv, G, N, hd), jnp.float32)
    k = _rand(1, (B, Kv, S, hd), jnp.float32)
    v = _rand(2, (B, Kv, S, hd), jnp.float32)
    length = jnp.asarray([100, 480], jnp.int32)
    mask = jnp.asarray(plan.mask)
    q_pos = length[:, None] + jnp.asarray(plan.depths)[None, :]
    o = ops.tree_verify_attention(q, k, v, length, mask, q_pos,
                                  window=64, bs=128)
    o_ref = ref.tree_verify_attention_ref(q, k, v, length, mask, q_pos,
                                          window=64)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=1e-5)


@pytest.mark.parametrize("level", [1, 2, 3])
def test_tree_verify_attention_rectangular_levels(level):
    """Rectangular (T, C) masks — the incremental level-drafting path:
    query only level ``level``'s nodes while the mask's earlier columns
    cover tree rows previous levels already wrote at
    [length-(C-T), length)."""
    plan = _tree_plan(2, 4)
    lo, hi = plan.levels[level]
    T, C = hi - lo, hi
    B, Kv, G, S, hd = 2, 2, 2, 256, 64
    q = _rand(3, (B, Kv, G, T, hd), jnp.float32)
    k = _rand(4, (B, Kv, S, hd), jnp.float32)
    v = _rand(5, (B, Kv, S, hd), jnp.float32)
    base = jnp.asarray([32, 100], jnp.int32)          # tree starts here
    length = base + lo                                # rows [base, base+lo)
    mask = jnp.asarray(plan.mask)[lo:hi, :hi]         # (T, C), C > T
    q_pos = base[:, None] + jnp.asarray(plan.depths)[None, lo:hi]
    o = ops.tree_verify_attention(q, k, v, length, mask, q_pos, bs=128)
    o_ref = ref.tree_verify_attention_ref(q, k, v, length, mask, q_pos)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=1e-5)
    # the square path on the same geometry agrees where rows overlap:
    # a full-tree verify with the square mask yields the same outputs for
    # these nodes once the remaining tree rows are masked garbage
    full_mask = jnp.asarray(plan.mask)
    qf = jnp.zeros((B, Kv, G, plan.n_pad, hd)).at[:, :, :, lo:hi].set(q)
    q_pos_f = base[:, None] + jnp.asarray(plan.depths)[None, :]
    of = ops.tree_verify_attention(qf, k, v, base, full_mask, q_pos_f,
                                   bs=128)
    np.testing.assert_allclose(np.asarray(of[:, :, :, lo:hi]),
                               np.asarray(o), atol=1e-5)


# ------------------------------------------------------------ paged decode
@pytest.mark.parametrize("B,Kv,G,bs,MB,hd", [(1, 1, 1, 16, 4, 64),
                                             (3, 2, 4, 16, 8, 64),
                                             (2, 4, 2, 32, 4, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention_sweep(B, Kv, G, bs, MB, hd, dtype):
    """Paged kernel (block-table scalar-prefetch gather) vs the pure-jnp
    gather-then-dense oracle, with shuffled per-sequence block tables."""
    NB = B * MB + 1
    q = _rand(0, (B, Kv, G, hd), dtype)
    k_pool = _rand(1, (NB, bs, Kv, hd), dtype)
    v_pool = _rand(2, (NB, bs, Kv, hd), dtype)
    rng = np.random.default_rng(0)
    table = jnp.asarray(
        rng.permutation(np.arange(1, NB))[:B * MB].reshape(B, MB), jnp.int32)
    length = jnp.asarray(rng.integers(1, MB * bs + 1, B), jnp.int32)
    o = ops.paged_decode_attention(q, k_pool, v_pool, table, length)
    o_ref = ref.paged_decode_attention_ref(q, k_pool, v_pool, table, length)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [4, 8, 11, 16, 48])
def test_paged_decode_attention_windowed(window):
    """Windowed paged kernel (trailing-window blocks only, scalar-
    prefetched start block) vs the windowed oracle — unaligned windows,
    lengths below the window (early-position clamp), and windows past the
    whole table included."""
    B, Kv, G, bs, MB, hd = 4, 2, 4, 8, 6, 64
    NB = B * MB + 1
    q = _rand(0, (B, Kv, G, hd), jnp.float32)
    k_pool = _rand(1, (NB, bs, Kv, hd), jnp.float32)
    v_pool = _rand(2, (NB, bs, Kv, hd), jnp.float32)
    rng = np.random.default_rng(0)
    table = jnp.asarray(
        rng.permutation(np.arange(1, NB))[:B * MB].reshape(B, MB), jnp.int32)
    length = jnp.asarray([3, 17, 30, MB * bs], jnp.int32)
    o = ops.paged_decode_attention(q, k_pool, v_pool, table, length,
                                   window=window)
    o_ref = ref.paged_decode_attention_ref(q, k_pool, v_pool, table, length,
                                           window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               atol=1e-5, rtol=1e-5)


def test_paged_windowed_matches_full_when_window_covers_length():
    """window >= length degenerates to full attention — the windowed grid
    restriction must not drop any valid block."""
    B, Kv, G, bs, MB, hd = 2, 2, 2, 8, 4, 64
    NB = B * MB + 1
    q = _rand(0, (B, Kv, G, hd), jnp.float32)
    k_pool = _rand(1, (NB, bs, Kv, hd), jnp.float32)
    v_pool = _rand(2, (NB, bs, Kv, hd), jnp.float32)
    table = jnp.asarray(np.arange(1, NB).reshape(B, MB), jnp.int32)
    length = jnp.asarray([7, 29], jnp.int32)
    o_win = ops.paged_decode_attention(q, k_pool, v_pool, table, length,
                                       window=MB * bs)
    o_full = ops.paged_decode_attention(q, k_pool, v_pool, table, length)
    np.testing.assert_allclose(np.asarray(o_win), np.asarray(o_full),
                               atol=1e-5, rtol=1e-5)


def test_paged_matches_dense_on_contiguous_table():
    """With an identity (contiguous) block table the paged kernel computes
    exactly what the dense decode kernel computes over the flat cache."""
    B, Kv, G, bs, MB, hd = 2, 2, 2, 32, 4, 64
    NB = B * MB + 1
    q = _rand(0, (B, Kv, G, hd), jnp.float32)
    k_pool = _rand(1, (NB, bs, Kv, hd), jnp.float32)
    v_pool = _rand(2, (NB, bs, Kv, hd), jnp.float32)
    table = jnp.asarray(np.arange(1, NB).reshape(B, MB), jnp.int32)
    length = jnp.asarray([40, 128], jnp.int32)
    kk = jnp.moveaxis(k_pool[table].reshape(B, -1, Kv, hd), 2, 1)
    vv = jnp.moveaxis(v_pool[table].reshape(B, -1, Kv, hd), 2, 1)
    o_paged = ops.paged_decode_attention(q, k_pool, v_pool, table, length)
    o_dense = ops.decode_attention(q, kk, vv, length, bs=32)
    np.testing.assert_allclose(np.asarray(o_paged), np.asarray(o_dense),
                               atol=1e-5, rtol=1e-5)


# ------------------------------------------------------------ dense window
@pytest.mark.parametrize("window", [3, 4, 7])
@pytest.mark.parametrize("use_rope", [False, True])
def test_dense_decode_window_clamp_vs_full_oracle(window, use_rope):
    """Audit of the dense sliding-window decode path
    (``layers.decode_attention``: ``start = max(pos - (window-1), 0)`` +
    ``dynamic_slice_in_dim``): at every position the windowed read must
    equal full attention masked to the trailing ``window`` keys — and at
    ``pos < window`` (where the slice start clamps to 0) it must equal
    UNRESTRICTED full attention exactly.  Parametrized over early, exact-
    boundary, and deep positions; no off-by-one found, test pins it."""
    from types import SimpleNamespace
    from repro.models import layers as L
    cfg = SimpleNamespace(num_heads=4, num_kv_heads=2, head_dim=16,
                          use_rope=use_rope, rope_theta=10_000.0)
    d = 32
    rng = jax.random.PRNGKey(0)
    p = L.init_attention(rng, SimpleNamespace(d_model=d, head_dim=16,
                                              num_heads=4, num_kv_heads=2),
                         jnp.float32)
    Smax = 2 * window + 4
    xs = jax.random.normal(jax.random.PRNGKey(1), (Smax, 1, 1, d))
    ck_w = jnp.zeros((1, Smax, 2, 16))
    cv_w = jnp.zeros_like(ck_w)
    ck_f, cv_f = ck_w, cv_w
    for pos in range(Smax):
        o_w, ck_w, cv_w = L.decode_attention(p, xs[pos], ck_w, cv_w, pos,
                                             cfg, window=window)
        o_f, ck_f, cv_f = L.decode_attention(p, xs[pos], ck_f, cv_f, pos,
                                             cfg, window=0)
        if pos < window:
            # early positions: window covers the whole prefix -> identical
            # to full attention (the clamp must not drop position 0)
            np.testing.assert_allclose(np.asarray(o_w), np.asarray(o_f),
                                       atol=1e-5, rtol=1e-5,
                                       err_msg=f"pos={pos}")
        else:
            # deep positions: equals full attention over the cache masked
            # to keys (pos - window, pos]
            q = (xs[pos] @ p["wq"]).reshape(1, 1, 4, 16)
            kpos = jnp.arange(Smax)
            if use_rope:
                q = L.apply_rope(q, jnp.asarray([pos], jnp.int32),
                                 cfg.rope_theta)
            mask = ((kpos <= pos) & (kpos > pos - window))[None, None, None,
                                                           None, :]
            o_ref = L.mha(q, ck_f, cv_f, mask=mask).reshape(1, 1, 64) \
                @ p["wo"]
            np.testing.assert_allclose(np.asarray(o_w), np.asarray(o_ref),
                                       atol=1e-5, rtol=1e-5,
                                       err_msg=f"pos={pos}")


# ------------------------------------------------------------ spec verify
@pytest.mark.parametrize("gamma,V", [(1, 64), (4, 1000), (8, 4096)])
@pytest.mark.parametrize("temperature", [0.0, 0.7, 1.0])
def test_spec_verify_matches_ref(gamma, V, temperature):
    rng = jax.random.PRNGKey(42)
    tl = _rand(0, (gamma + 1, V), jnp.float32) * 2
    dl = tl[:gamma] + _rand(1, (gamma, V), jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(2), (gamma,), 0, V)
    n1, t1 = ops.spec_verify(rng, tl, dl, toks, temperature=temperature)
    n2, t2 = ref.spec_verify_ref(rng, tl, dl, toks, temperature=temperature)
    assert int(n1) == int(n2)
    assert int(t1) == int(t2)


def test_spec_verify_all_accept_identical():
    rng = jax.random.PRNGKey(0)
    tl = _rand(0, (5, 128), jnp.float32)
    n, _ = ops.spec_verify(rng, tl, tl[:4],
                           jnp.argmax(tl[:4], -1).astype(jnp.int32),
                           temperature=0.0)
    assert int(n) == 4


@pytest.mark.parametrize("temperature", [0.0, 1.0])
def test_spec_verify_batched_matches_per_row(temperature):
    """The grouped entry point equals per-member spec_verify calls."""
    G, gamma, V = 3, 4, 256
    rngs = jax.random.split(jax.random.PRNGKey(7), G)
    tl = _rand(0, (G, gamma + 1, V), jnp.float32) * 2
    dl = tl[:, :gamma] + _rand(1, (G, gamma, V), jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(2), (G, gamma), 0, V)
    n_b, t_b = ops.spec_verify_batched(rngs, tl, dl, toks,
                                       temperature=temperature)
    for g in range(G):
        n1, t1 = ops.spec_verify(rngs[g], tl[g], dl[g], toks[g],
                                 temperature=temperature)
        assert int(n_b[g]) == int(n1)
        assert int(t_b[g]) == int(t1)


# ------------------------------------------------------------ ssd scan
@pytest.mark.parametrize("B,S,H,N,P,Q", [(1, 128, 2, 16, 32, 32),
                                         (2, 256, 3, 32, 64, 64),
                                         (1, 512, 1, 64, 64, 128)])
def test_ssd_chunk_scan_sweep(B, S, H, N, P, Q):
    q = _rand(0, (B, S, H, N), jnp.float32)
    k = _rand(1, (B, S, H, N), jnp.float32)
    v = _rand(2, (B, S, H, P), jnp.float32)
    la = -jax.nn.softplus(_rand(3, (B, S, H), jnp.float32))
    li = _rand(4, (B, S, H), jnp.float32) * 0.5
    y1, d1, m1 = ops.ssd_chunk_scan(q, k, v, la, li, chunk=Q)
    y2, d2, m2 = ref.ssd_chunk_scan_ref(q, k, v, la, li, chunk=Q)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-5)


def test_ssd_kernel_vs_sequential_step():
    """Chunked kernel == step-by-step recurrence (chunk-size invariance)."""
    from repro.models.ssm import gla_step, init_gla_state
    B, S, H, N, P = 1, 64, 2, 8, 16
    q = _rand(0, (B, S, H, N), jnp.float32)
    k = _rand(1, (B, S, H, N), jnp.float32)
    v = _rand(2, (B, S, H, P), jnp.float32)
    la = -jax.nn.softplus(_rand(3, (B, S, H), jnp.float32))
    li = _rand(4, (B, S, H), jnp.float32)
    y_k, d_k, m_k = ops.ssd_chunk_scan(q, k, v, la, li, chunk=16)
    st = init_gla_state(B, H, N, P)
    for t in range(S):
        y_t, d_t, m_t, st = gla_step(q[:, t], k[:, t], v[:, t],
                                     la[:, t], li[:, t], st)
        # compare un-stabilized outputs (stabilizers m may differ)
        np.testing.assert_allclose(
            np.asarray(y_t * jnp.exp(m_t)[..., None]),
            np.asarray(y_k[:, t] * jnp.exp(m_k[:, t])[..., None]),
            atol=1e-3, rtol=1e-3)
