"""Serving-path consistency: prefill + decode_step + extend_step must agree
with the teacher-forced forward for EVERY architecture family — the
correctness foundation under speculative decoding."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.models import Model, example_batch

ARCHS = list_archs()
TOL = 2e-3


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_extend_match_forward(arch):
    cfg = get_config(arch).reduced()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = example_batch(cfg, 2, 21, with_labels=False)
    full, *_ = m.forward(params, batch)
    off = cfg.num_image_tokens if cfg.family == "vlm" else 0
    T = batch["tokens"].shape[1]           # vlm batches have fewer text tokens
    cut = T - 5

    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :cut]
    lg, cache = m.prefill(params, pre, max_seq=40)
    assert float(jnp.max(jnp.abs(lg - full[:, off + cut - 1]))) < TOL

    lg1, cache = m.decode_step(params, batch["tokens"][:, cut:cut + 1], cache)
    assert float(jnp.max(jnp.abs(lg1 - full[:, off + cut]))) < TOL

    lg4, cache = m.extend_step(params, batch["tokens"][:, cut + 1:], cache)
    assert float(jnp.max(jnp.abs(lg4 - full[:, off + cut + 1:]))) < TOL


@pytest.mark.parametrize("arch", ["smollm-135m", "zamba2-2.7b", "xlstm-125m"])
def test_sliding_window_decode(arch):
    """Window-limited decode equals full decode while pos < window."""
    cfg = get_config(arch).reduced()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = example_batch(cfg, 1, 10, with_labels=False)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :9]
    _, c1 = m.prefill(params, pre, max_seq=16)
    _, c2 = m.prefill(params, pre, max_seq=16)
    l1, _ = m.decode_step(params, batch["tokens"][:, 9:10], c1)
    l2, _ = m.decode_step(params, batch["tokens"][:, 9:10], c2, window=12)
    assert float(jnp.max(jnp.abs(l1 - l2))) < TOL
