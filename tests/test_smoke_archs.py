"""Per-architecture smoke tests (required deliverable f): a REDUCED variant
of each assigned architecture runs one forward + one train step on CPU with
correct shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.models import Model, example_batch
from repro.training import AdamW, make_train_step

ARCHS = list_archs()


@pytest.fixture(scope="module")
def setups():
    out = {}
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        m = Model(cfg)
        out[arch] = (cfg, m, m.init(jax.random.PRNGKey(0)))
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch, setups):
    cfg, m, params = setups[arch]
    B, S = 2, 16
    batch = example_batch(cfg, B, S)
    logits, *_ = m.forward(params, batch)
    assert logits.shape[0] == B
    assert logits.shape[-1] == cfg.vocab_size
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch, setups):
    cfg, m, params = setups[arch]
    batch = example_batch(cfg, 2, 16)
    opt = AdamW(lr=1e-3)
    step = make_train_step(m, opt, donate=False)
    p2, st, metrics = step(params, opt.init(params), batch)
    loss = float(metrics["loss"])
    assert loss == loss, "NaN loss"          # not NaN
    assert 0 < loss < 20
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_fields(arch):
    """The full (non-reduced) config matches the assignment exactly."""
    cfg = get_config(arch)
    expected = {
        "mamba2-370m": (48, 1024, 0, 0, 0, 50288),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected
    if arch == "olmoe-1b-7b":
        assert (cfg.num_experts, cfg.top_k) == (64, 8)
    if arch == "granite-moe-1b-a400m":
        assert (cfg.num_experts, cfg.top_k) == (32, 8)
    if arch == "zamba2-2.7b":
        assert cfg.ssm_state == 64
    if arch == "mamba2-370m":
        assert (cfg.ssm_state, cfg.ssm_head_dim) == (128, 64)
