"""Sharded serving: ShardedBlockPool bookkeeping + mesh engine parity.

The pool tests are pure host-side bookkeeping and run anywhere.  The
engine tests are marked ``mesh`` — CI runs them with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (see ci.yml);
they skip when fewer simulated devices are available because the (2, 4)
host mesh cannot be built.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.paged_cache import ShardedBlockPool


def _pool(shards=2, per_shard=5, block_size=4):
    # slots 0..N map to shards round-robin-by-half: slot // (per-shard
    # slots) — tests use contiguous slot groups like the scheduler does
    return ShardedBlockPool(shards, per_shard, block_size,
                            shard_of=lambda slot: slot // 4)


class TestShardedBlockPool:
    def test_global_ids_are_shard_offset(self):
        p = _pool()
        # slot 0 -> shard 0: local ids 1.. -> global 1..
        assert p.alloc(0, 2) == [1, 2]
        # slot 4 -> shard 1: local ids 1.. -> global per_shard+1..
        assert p.alloc(4, 2) == [6, 7]
        assert p.used == 4

    def test_per_shard_traps(self):
        p = _pool()
        assert p.trap(0) == 0
        assert p.trap(4) == 5   # shard 1's range starts at per_shard

    def test_can_alloc_is_shard_scoped(self):
        p = _pool()            # 4 usable per shard
        p.alloc(0, 4)
        assert not p.can_alloc(1, owner=0)    # shard 0 full
        assert p.can_alloc(4, owner=4)        # shard 1 untouched
        # ownerless query answers for every shard (admission pre-check)
        assert not p.can_alloc(1)

    def test_usable_is_per_shard(self):
        assert _pool(per_shard=5).usable() == 4

    def test_alloc_exhaustion_raises(self):
        p = _pool()
        p.alloc(0, 4)
        with pytest.raises(RuntimeError, match="exhausted"):
            p.alloc(1, 1)      # slot 1 is also shard 0

    def test_share_within_shard_bumps_refcount(self):
        p = _pool()
        blocks = p.alloc(0, 2)
        p.share(1, blocks)     # slot 1 is shard 0 too
        assert p.refcount(blocks[0]) == 2
        assert p.free(0) == []            # still referenced by slot 1
        assert sorted(p.free(1)) == blocks

    def test_cross_shard_share_refused(self):
        p = _pool()
        blocks = p.alloc(0, 1)
        with pytest.raises(RuntimeError, match="cross-shard"):
            p.share(4, blocks)  # slot 4 lives on shard 1

    def test_fork_returns_global_id_in_same_shard(self):
        p = _pool()
        blocks = p.alloc(4, 1)        # shard 1: global id 6
        p.share(5, blocks)
        new = p.fork(5, blocks[0])
        assert new != blocks[0]
        assert new // 5 == 1          # stays in shard 1's range
        assert p.refcount(blocks[0]) == 1

    def test_used_and_peak_aggregate_shards(self):
        p = _pool()
        p.alloc(0, 3)
        p.alloc(4, 2)
        assert p.used == 5
        p.free(0)
        assert p.used == 2
        assert p.peak_used == 5


# ---------------------------------------------------------------- engine
@pytest.mark.mesh
class TestMeshEngine:
    # class-scoped so it orders BEFORE the class-scoped `served` fixture
    # (pytest instantiates higher/equal-scope autouse fixtures first);
    # a function-scoped guard would let `served` build the mesh and
    # error out instead of skipping on single-device runs
    @pytest.fixture(autouse=True, scope="class")
    def _need_devices(self):
        import jax
        if jax.device_count() < 8:
            pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_"
                        "device_count=8")

    @pytest.fixture(scope="class")
    def served(self):
        import jax

        from repro.configs import get_config
        from repro.core.policy import SpeculativePolicy
        from repro.core.scheduler import BatchedEngine
        from repro.data import SyntheticLM
        from repro.launch.mesh import make_host_mesh
        from repro.models import Model

        e_cfg = get_config("smollm-135m").reduced()
        c_cfg = get_config("granite-8b").reduced().replace(
            vocab_size=e_cfg.vocab_size)
        edge, cloud = Model(e_cfg), Model(c_cfg)
        ep = edge.init(jax.random.PRNGKey(0))
        cp = cloud.init(jax.random.PRNGKey(1))
        synth = SyntheticLM(e_cfg.vocab_size)
        rng = np.random.default_rng(0)
        prompts = [synth.sample(rng, i % synth.n_domains, 8)
                   for i in range(8)]

        def serve(mesh):
            eng = BatchedEngine(edge, cloud, batch_size=8, temperature=0.0,
                                use_cache=False,
                                policy=SpeculativePolicy(-1.0),
                                kv_layout="paged", mesh=mesh)
            tr = eng.serve_batch(ep, cp, prompts, 6)
            return [t.tokens for t in tr], eng.stats()

        base, st0 = serve(None)
        mesh_toks, st1 = serve(make_host_mesh(data=2, model=4))
        return base, st0, mesh_toks, st1

    def test_token_parity_with_single_device(self, served):
        base, _, mesh_toks, _ = served
        assert base == mesh_toks

    def test_kv_capacity_scales(self, served):
        _, st0, _, st1 = served
        assert st0["kv_shards"] == 1
        assert st1["kv_shards"] > 1
        assert st1["kv_capacity_blocks"] > st0["kv_capacity_blocks"]

    def test_mesh_stats_reported(self, served):
        _, st0, _, st1 = served
        assert "mesh_devices" not in st0
        assert st1["mesh_devices"] == 8
        assert st1["mesh_shape"] == {"data": 2, "model": 4}

    def test_gather_wave_tiles_dp_dim(self):
        import jax
        import jax.numpy as jnp

        from repro import runtime
        from repro.launch.mesh import make_host_mesh

        x = jnp.arange(8, dtype=jnp.int32).reshape(4, 2)
        # identity off-mesh (single-array calls return the bare array)
        y = runtime.gather_wave(x)
        assert (np.asarray(y) == np.asarray(x)).all()
        mesh = make_host_mesh(data=2, model=4)
        with runtime.mesh_context(mesh):
            xs = jax.device_put(x, jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec("data")))
            y, y2 = runtime.gather_wave(xs, xs + 1)
            # all-gather is a reorder-free concat over the dp axis here
            assert (np.asarray(y) == np.asarray(x)).all()
            assert (np.asarray(y2) == np.asarray(x) + 1).all()
            # odd leading dim: identity fallback (cannot tile over dp=2)
            z = jnp.ones((3, 2))
            w = runtime.gather_wave(z)
            assert w.shape == (3, 2)
