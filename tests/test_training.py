"""Collaborative training (survey §3): optimizer, distillation, LoRA,
quantization, pruning, early-exit training, checkpointing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.early_exit import early_exit_decision, exit_logits, layerskip_loss
from repro.data import SyntheticLM, batches, dirichlet_clients
from repro.data.pipeline import client_divergence
from repro.models import Model, example_batch
from repro.training import AdamW, cosine_schedule, make_train_step, train
from repro.training.checkpoint import restore, save
from repro.training.distillation import (acceptance_estimate, kd_loss,
                                         kl_divergence, logit_delta_guidance,
                                         reverse_kd_loss, teacher_logits_fn)
from repro.training.lora import (hetlora_aggregate, init_lora, lora_loss_fn,
                                 lora_param_count, merge_lora)
from repro.training.pruning import (apply_masks, magnitude_masks,
                                    sparsity_report, structured_ffn_prune)
from repro.training.quantization import (dequantize_params, fake_quant,
                                         quantization_error, quantize_params)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-135m").reduced()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def test_train_loss_decreases(setup):
    cfg, m, params = setup
    it = batches(cfg, 8, 32)
    res = train(m, params, it, steps=25, opt=AdamW(lr=1e-3), log_every=1000,
                log=lambda *_: None)
    hist = res["history"]
    assert hist[-1][1] < hist[0][1] - 0.2


def test_cosine_schedule():
    s = cosine_schedule(10, 100)
    assert float(s(0)) < 0.11
    assert abs(float(s(10)) - 1.0) < 1e-5
    assert float(s(100)) <= 0.11


def test_kd_better_than_far_teacher(setup):
    cfg, m, params = setup
    batch = example_batch(cfg, 2, 16)
    tlf = teacher_logits_fn(m, params)
    tl = tlf(batch)
    # KL(self, self) = 0
    logits, _ = m.forward(params, batch)
    assert float(kl_divergence(tl, logits)) < 1e-4
    loss = kd_loss(m, params, batch, tl, alpha=0.5)
    assert float(loss) > 0


def test_reverse_kd(setup):
    cfg, m, params = setup
    batch = example_batch(cfg, 2, 16)
    tl = teacher_logits_fn(m, params)(batch)
    assert float(reverse_kd_loss(m, params, batch, tl)) < 1e-4


def test_acceptance_estimate_ordering(setup):
    cfg, m, params = setup
    p2 = m.init(jax.random.PRNGKey(5))
    batch = example_batch(cfg, 2, 16)
    t = teacher_logits_fn(m, params)(batch)
    d_same = t
    d_diff = teacher_logits_fn(m, p2)(batch)
    assert float(acceptance_estimate(d_same, t)) > \
        float(acceptance_estimate(d_diff, t))


def test_distillation_raises_acceptance(setup):
    """DistillSpec's premise: KD on target outputs raises 1-TV acceptance."""
    cfg, m, params = setup
    student = m.init(jax.random.PRNGKey(7))
    batch = example_batch(cfg, 8, 24)
    tlf = teacher_logits_fn(m, params)
    before = float(acceptance_estimate(tlf(batch), m.forward(student, batch)[0]))
    opt = AdamW(lr=2e-3)
    step = make_train_step(
        m, opt, loss_fn=lambda p, b: kd_loss(m, p, b, tlf(b), alpha=0.0),
        donate=False)
    st = opt.init(student)
    for _ in range(30):
        student, st, _ = step(student, st, batch)
    after = float(acceptance_estimate(tlf(batch), m.forward(student, batch)[0]))
    assert after > before + 0.02


def test_logit_delta_guidance():
    llm = jnp.zeros((2, 5))
    ft = jnp.array([[1.0, 0, 0, 0, 0]] * 2)
    base = jnp.zeros((2, 5))
    out = logit_delta_guidance(llm, ft, base, beta=2.0)
    assert float(out[0, 0]) == 2.0


def test_lora_zero_init_and_train(setup):
    cfg, m, params = setup
    ad = init_lora(jax.random.PRNGKey(1), params, rank=4)
    batch = example_batch(cfg, 2, 16)
    base, _ = m.forward(params, batch)
    merged, _ = m.forward(merge_lora(params, ad), batch)
    assert float(jnp.max(jnp.abs(base - merged))) == 0.0   # B=0 at init
    # adapters train: loss decreases while base stays frozen
    loss_fn = lora_loss_fn(m, params)
    g = jax.grad(loss_fn)(ad, batch)
    assert any(float(jnp.max(jnp.abs(x))) > 0 for x in jax.tree.leaves(g))
    assert lora_param_count(ad) < sum(x.size for x in jax.tree.leaves(params)) / 50


def test_hetlora_rank_padding(setup):
    cfg, m, params = setup
    clients = [init_lora(jax.random.PRNGKey(i), params, rank=r)
               for i, r in enumerate([2, 4, 8])]
    agg = hetlora_aggregate(clients, max_rank=8)
    first = agg[next(iter(agg))]
    assert first["A"].shape[-2] == 8


def test_quantization(setup):
    cfg, m, params = setup
    qp = quantize_params(params)
    err = quantization_error(params, qp)
    assert err["mean_rel_err"] < 0.01
    batch = example_batch(cfg, 2, 16)
    base, _ = m.forward(params, batch)
    deq, _ = m.forward(dequantize_params(qp), batch)
    rel = float(jnp.linalg.norm(deq - base) / jnp.linalg.norm(base))
    assert rel < 0.1


def test_fake_quant_gradient_passthrough():
    w = jnp.linspace(-1, 1, 32).reshape(4, 8)
    g = jax.grad(lambda w: jnp.sum(fake_quant(w) ** 2))(w)
    assert g.shape == w.shape
    assert not bool(jnp.any(jnp.isnan(g)))


def test_pruning(setup):
    cfg, m, params = setup
    masks = magnitude_masks(params, 0.5)
    rep = sparsity_report(masks)
    assert 0.4 < rep["pruned_frac"] < 0.6
    pruned = apply_masks(params, masks)
    batch = example_batch(cfg, 2, 16)
    logits, _ = m.forward(pruned, batch)
    assert not bool(jnp.any(jnp.isnan(logits)))


def test_structured_prune_runs(setup):
    cfg, m, params = setup
    pruned, keep = structured_ffn_prune(params, cfg, 0.5)
    assert keep <= cfg.d_ff
    batch = example_batch(cfg, 2, 16)
    logits, _ = m.forward(pruned, batch)
    assert logits.shape[-1] == cfg.vocab_size


def test_layerskip_and_exit_decision(setup):
    cfg, m, params = setup
    batch = example_batch(cfg, 2, 16)
    loss, ces = layerskip_loss(m, params, batch, exit_layers=[0])
    assert float(loss) > float(m.loss(params, batch)) - 1e-6
    _, _, hs = m.forward(params, batch, collect_hidden=True)
    ex = exit_logits(m, params, hs, [0, 1])
    idx, chosen = early_exit_decision(ex[:, :, -1, :], threshold=-1.0)
    assert int(idx[0]) == 1                       # impossible threshold -> last
    idx2, _ = early_exit_decision(ex[:, :, -1, :], threshold=2.0)
    assert int(idx2[0]) == 0                      # trivial threshold -> first


def test_checkpoint_roundtrip(tmp_path, setup):
    cfg, m, params = setup
    p = str(tmp_path / "ck.npz")
    save(p, params, step=7)
    restored, step = restore(p, params)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        assert jnp.array_equal(a, b)


def test_dirichlet_clients_skew():
    tight = dirichlet_clients(8, 4, alpha=100.0)
    skewed = dirichlet_clients(8, 4, alpha=0.1)
    assert client_divergence(skewed) > client_divergence(tight)


def test_synthetic_lm_learnable():
    synth = SyntheticLM(128, n_domains=2, order_vocab=32)
    rng = np.random.default_rng(0)
    s = synth.sample(rng, 0, 1000)
    assert s.min() >= 0 and s.max() < 128
    # markov structure: bigram entropy < unigram entropy
    uni, _ = np.histogram(s, bins=128)
    pu = uni / uni.sum()
    hu = -(pu[pu > 0] * np.log(pu[pu > 0])).sum()
    assert hu < np.log(64)                        # concentrated sub-vocab
