"""Task assignment (§2.1): uncertainty estimators and routers."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.routing import (CascadeRouter, ConfidenceRouter, LinUCBRouter,
                                UCBRouter, capability_vector)
from repro.core.uncertainty import (ESTIMATORS, dirichlet_evidence, entropy,
                                    get_estimator, max_prob)

PEAKED = jnp.array([10.0, 0.0, 0.0, 0.0])
FLAT = jnp.zeros(4)


@pytest.mark.parametrize("name", sorted(ESTIMATORS))
def test_estimators_order_peaked_below_flat(name):
    est = get_estimator(name)
    assert float(est(PEAKED)) < float(est(FLAT))


def test_entropy_normalized_range():
    assert abs(float(entropy(FLAT)) - 1.0) < 1e-6
    assert float(entropy(PEAKED)) < 0.01


def test_dirichlet_components():
    d_flat = dirichlet_evidence(FLAT)
    d_peak = dirichlet_evidence(PEAKED)
    # strong single evidence lowers epistemic (more total evidence) AND
    # aleatoric (less conflict)
    assert float(d_peak["epistemic"]) < float(d_flat["epistemic"])
    assert float(d_peak["aleatoric"]) < float(d_flat["aleatoric"])
    # scaled-down logits = weak evidence: epistemic rises vs the peaked case
    d_weak = dirichlet_evidence(PEAKED * 0.01)
    assert float(d_weak["epistemic"]) > float(d_peak["epistemic"])


def test_confidence_router():
    r = ConfidenceRouter(threshold=0.5)
    assert r(PEAKED[None]).model_idx == 0        # confident -> edge
    assert r(FLAT[None]).model_idx == 1          # uncertain -> cloud


def test_cascade_lazy_escalation():
    calls = []

    def mk(logits, i):
        def fn():
            calls.append(i)
            return logits
        return fn

    r = CascadeRouter(costs=[1, 10], thresholds=[0.3, 1.0],
                      estimator="max_prob")
    route = r.run([mk(PEAKED[None], 0), mk(FLAT[None], 1)])
    assert route.model_idx == 0 and calls == [0]   # never calls the cloud
    calls.clear()
    route = r.run([mk(FLAT[None], 0), mk(PEAKED[None], 1)])
    assert route.model_idx == 1 and calls == [0, 1]
    assert route.cost == 11


def test_ucb_converges_to_best_arm():
    rng = np.random.default_rng(0)
    r = UCBRouter(3, cost_weight=0.0)
    means = [0.2, 0.8, 0.5]
    for _ in range(500):
        a = r.select()
        r.update(a, rng.normal(means[a], 0.1))
    assert np.argmax(r.n) == 1                    # pulls the best arm most


def test_linucb_uses_context():
    rng = np.random.default_rng(0)
    r = LinUCBRouter(2, dim=2, alpha=0.3, cost_weight=0.0)
    # context [1,0] -> model 0 good; [0,1] -> model 1 good
    for _ in range(400):
        x = np.array([1.0, 0.0]) if rng.uniform() < 0.5 else np.array([0.0, 1.0])
        a = r.select(x)
        good = 0 if x[0] > 0 else 1
        r.update(a, x, 1.0 if a == good else 0.0)
    assert r.select(np.array([1.0, 0.0])) == 0
    assert r.select(np.array([0.0, 1.0])) == 1


def test_capability_vector_shape():
    ls = [np.random.randn(4, 16) for _ in range(3)]
    v = capability_vector(ls)
    assert v.shape == (4,)
