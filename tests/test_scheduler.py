"""Batched continuous-batching scheduler vs the per-request reference.

The contract: ``BatchedEngine`` changes the EXECUTION (slots, one jitted
scan per tick, grouped escalation) but not the SEMANTICS — greedy traces
must match ``CollaborativeEngine.serve_reference`` token for token, on
every path of the taxonomy (cache / edge / speculative / skeleton / cloud),
under staggered prompt lengths and generation budgets.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.cache import SemanticCache
from repro.core.engine import CollaborativeEngine
from repro.core.policy import (SpeculativePolicy, ThresholdPolicy,
                               policy_from_legacy)
from repro.core.scheduler import BatchedEngine, stack_slot_caches, write_slot
from repro.core.speculative import autoregressive_baseline
from repro.core.uncertainty import get_batched_estimator
from repro.models import Model


@pytest.fixture(scope="module")
def pair():
    e_cfg = get_config("smollm-135m").reduced()
    c_cfg = get_config("granite-8b").reduced().replace(
        vocab_size=e_cfg.vocab_size)
    edge, cloud = Model(e_cfg), Model(c_cfg)
    return (edge, edge.init(jax.random.PRNGKey(0)),
            cloud, cloud.init(jax.random.PRNGKey(1)))


def _prompts(vocab, specs):
    """specs: list of (length, offset) -> deterministic distinct prompts."""
    return [((np.arange(n) * 7 + off) % vocab).astype(np.int32)
            for n, off in specs]


# ---------------------------------------------------------------- edge path
def test_edge_token_parity_with_reference(pair):
    """Greedy tokens AND accumulated uncertainty match the per-request
    reference loop exactly, with a batch smaller than the request count so
    slots admit/retire mid-run."""
    edge, ep, cloud, cp = pair
    prompts = _prompts(edge.cfg.vocab_size, [(8, 0), (6, 3), (10, 5), (7, 11)])
    ref = CollaborativeEngine(edge, cloud, temperature=0.0,
                              policy=ThresholdPolicy(1.1), use_cache=False)
    be = BatchedEngine(edge, cloud, batch_size=2, temperature=0.0,
                       policy=ThresholdPolicy(1.1), use_cache=False,
                       tick_tokens=4)
    rts = [ref.serve_reference(ep, cp, p, 8) for p in prompts]
    bts = be.serve_batch(ep, cp, prompts, 8)
    for rt, bt in zip(rts, bts):
        assert bt.path == rt.path == "edge"
        assert bt.tokens == rt.tokens
        assert bt.edge_calls == rt.edge_calls
        assert abs(bt.uncertainty - rt.uncertainty) < 1e-5


def test_staggered_budgets_admit_retire(pair):
    """Requests with different max_new retire at different ticks; freed
    slots are re-admitted and every request still matches the reference."""
    edge, ep, cloud, cp = pair
    specs = [(8, 0), (6, 3), (9, 7), (5, 2), (10, 9)]
    prompts = _prompts(edge.cfg.vocab_size, specs)
    budgets = [3, 11, 6, 9, 4]
    ref = CollaborativeEngine(edge, cloud, temperature=0.0,
                              policy=ThresholdPolicy(1.1), use_cache=False)
    be = BatchedEngine(edge, cloud, batch_size=2, temperature=0.0,
                       policy=ThresholdPolicy(1.1), use_cache=False,
                       tick_tokens=4)
    bts = be.serve_batch(ep, cp, prompts, budgets)
    for p, m, bt in zip(prompts, budgets, bts):
        rt = ref.serve_reference(ep, cp, p, m)
        assert bt.tokens == rt.tokens
        assert len(bt.tokens) == m


# ---------------------------------------------------------------- escalation
@pytest.mark.parametrize("esc", ["speculative", "cloud", "skeleton"])
def test_escalation_parity_with_reference(pair, esc):
    """Grouped batched escalation selects the same path and emits the same
    greedy tokens as the single-request trace, per mode."""
    edge, ep, cloud, cp = pair
    prompts = _prompts(edge.cfg.vocab_size, [(8, 0), (6, 3), (10, 5)])
    ref = CollaborativeEngine(edge, cloud, temperature=0.0,
                              policy=policy_from_legacy(esc, -1.0),
                              use_cache=False, skeleton_len=4)
    be = BatchedEngine(edge, cloud, batch_size=2, temperature=0.0,
                       policy=policy_from_legacy(esc, -1.0),
                       use_cache=False, skeleton_len=4, tick_tokens=4)
    rts = [ref.serve_reference(ep, cp, p, 8) for p in prompts]
    bts = be.serve_batch(ep, cp, prompts, 8)
    for rt, bt in zip(rts, bts):
        assert bt.path == rt.path == esc
        assert bt.tokens == rt.tokens


def test_speculative_escalation_lossless_batched(pair):
    """Greedy speculative escalation equals cloud-only greedy decoding
    (losslessness survives batching)."""
    edge, ep, cloud, cp = pair
    prompts = _prompts(edge.cfg.vocab_size, [(8, 0), (6, 3)])
    be = BatchedEngine(edge, cloud, batch_size=2, temperature=0.0,
                       policy=SpeculativePolicy(-1.0), use_cache=False)
    bts = be.serve_batch(ep, cp, prompts, 8)
    for p, bt in zip(prompts, bts):
        base = autoregressive_baseline(cloud, cp, p, 8, temperature=0.0)
        assert bt.tokens == base


def test_mixed_paths_one_batch(pair):
    """Path selection is per-request even inside one batch: an engine serving
    requests under a mid threshold classifies each by ITS OWN uncertainty,
    matching the reference decisions."""
    edge, ep, cloud, cp = pair
    prompts = _prompts(edge.cfg.vocab_size, [(8, 0), (6, 3), (10, 5), (7, 11)])
    ref = CollaborativeEngine(edge, cloud, temperature=0.0,
                              policy=SpeculativePolicy(0.9915), use_cache=False)
    be = BatchedEngine(edge, cloud, batch_size=4, temperature=0.0,
                       policy=SpeculativePolicy(0.9915), use_cache=False)
    rts = [ref.serve_reference(ep, cp, p, 8) for p in prompts]
    bts = be.serve_batch(ep, cp, prompts, 8)
    assert [bt.path for bt in bts] == [rt.path for rt in rts]
    for rt, bt in zip(rts, bts):
        assert bt.tokens == rt.tokens


# ---------------------------------------------------------------- cache
def test_cache_hit_path(pair):
    edge, ep, cloud, cp = pair
    be = BatchedEngine(edge, cloud, batch_size=2, temperature=0.0,
                       policy=ThresholdPolicy(1.1), cache_threshold=0.99)
    p = _prompts(edge.cfg.vocab_size, [(8, 0)])[0]
    t1 = be.serve_batch(ep, cp, [p], 8)[0]
    t2 = be.serve_batch(ep, cp, [p], 8)[0]
    assert t1.path == "edge" and t2.path == "cache"
    assert t2.tokens == t1.tokens


def test_semantic_cache_batch_lookup():
    cache = SemanticCache(threshold=0.9)
    rng = np.random.default_rng(0)
    keys = rng.normal(size=(4, 16)).astype(np.float32)
    for i, k in enumerate(keys):
        cache.insert(k, f"v{i}")
    # cosine similarity is scale-invariant: scaled copies must hit; fresh
    # random 16-d keys are (overwhelmingly) below a 0.9 threshold
    queries = np.concatenate([keys[:2] * 3.0,
                              rng.normal(size=(2, 16)).astype(np.float32)])
    batch = cache.lookup_batch(queries)
    assert batch[:2] == ["v0", "v1"]
    assert batch[2:] == [None, None]
    assert cache.lookups == 4 and cache.hits == 2
    # scalar lookup is the N=1 special case of the batched path
    assert cache.lookup(keys[3] * 0.5) == "v3"


# ---------------------------------------------------------------- device API
def test_batched_estimator_per_slot_scalars():
    est = get_batched_estimator("entropy")
    lg = jax.random.normal(jax.random.PRNGKey(0), (5, 1, 33))
    u = est(lg)
    assert u.shape == (5,) and u.dtype == jnp.float32
    ref = get_batched_estimator("entropy")(lg.reshape(5, 33))
    np.testing.assert_allclose(np.asarray(u), np.asarray(ref), rtol=1e-6)


def test_slot_write_isolation(pair):
    """Writing one slot's prefilled cache leaves the other slots' state
    untouched (leading-axis isolation of the stacked pytree)."""
    edge, ep, _, _ = pair
    slots = stack_slot_caches(edge, 3, 32)
    _, c1 = jax.jit(lambda p, t: edge.prefill(p, {"tokens": t}, max_seq=32)
                    )(ep, jnp.arange(8, dtype=jnp.int32)[None, :])
    written = write_slot(slots, 1, c1)
    for leaf_w, leaf_0 in zip(jax.tree.leaves(written),
                              jax.tree.leaves(slots)):
        np.testing.assert_array_equal(np.asarray(leaf_w[0]),
                                      np.asarray(leaf_0[0]))
        np.testing.assert_array_equal(np.asarray(leaf_w[2]),
                                      np.asarray(leaf_0[2]))
