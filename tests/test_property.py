"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; property tests skipped")
from hypothesis import given, settings, strategies as st

from repro.core.compression import Int8Quantizer, relative_error
from repro.core.speculative import acceptance_rate_bound, speculative_sample
from repro.models.moe import capacity
from repro.models.ssm import gla_chunked

_settings = settings(max_examples=25, deadline=None)


@_settings
@given(st.integers(0, 10_000), st.integers(1, 8), st.integers(2, 50))
def test_spec_sample_invariants(seed, gamma, V):
    """n_acc in [0, gamma]; next token always a valid vocab index."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    tl = jax.random.normal(k1, (gamma + 1, V)) * 3
    dl = jax.random.normal(k2, (gamma, V)) * 3
    toks = jax.random.randint(k3, (gamma,), 0, V)
    n, t = speculative_sample(k4, tl, dl, toks, temperature=1.0)
    assert 0 <= int(n) <= gamma
    assert 0 <= int(t) < V


@_settings
@given(st.integers(0, 10_000), st.integers(2, 30))
def test_acceptance_bound_is_probability(seed, V):
    key = jax.random.PRNGKey(seed)
    p = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 0), (V,)))
    q = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (V,)))
    a = float(acceptance_rate_bound(p, q))
    assert 0.0 <= a <= 1.0 + 1e-6
    assert float(acceptance_rate_bound(p, p)) > 0.999


@_settings
@given(st.integers(0, 10_000), st.sampled_from([(2, 1), (2, 3), (2, 4),
                                                (3, 2), (4, 2)]),
       st.integers(4, 24), st.sampled_from([0.0, 0.7, 1.0]))
def test_tree_accept_matches_sequential_oracle(seed, shape, V, temperature):
    """The vectorized packed-tree acceptance walk equals the sequential
    python rejection-sampling oracle (same rng stream) for every tree
    shape, vocab and temperature; the returned path is a root-anchored
    ancestor chain."""
    from repro.core.tree_speculation import (TreePlan, branching_for,
                                             tree_accept, tree_accept_ref)
    plan = TreePlan(branching_for(*shape))
    rng = jax.random.PRNGKey(seed)
    kt, kq, kk = jax.random.split(jax.random.fold_in(rng, 1), 3)
    tl = jax.random.normal(kt, (plan.n_pad, V)) * 2
    ql = jax.random.normal(kq, (plan.n_pad, V)) * 2
    toks = jax.random.randint(kk, (plan.n_pad,), 0, V)
    n, em, path = tree_accept(rng, tl, ql, toks, plan,
                              temperature=temperature)
    n_ref, em_ref = tree_accept_ref(rng, tl, ql, toks, plan,
                                    temperature=temperature)
    assert int(n) == n_ref
    assert [int(x) for x in em[: int(n) + 1]] == em_ref
    assert 0 <= int(n) <= plan.depth
    assert int(path[0]) == 0
    for d in range(1, int(n) + 1):
        assert int(plan.parent[int(path[d])]) == int(path[d - 1])


@_settings
@given(st.integers(0, 1000), st.sampled_from([1, 2, 4, 8, 16]))
def test_gla_chunk_size_invariance(seed, chunk):
    """The chunked GLA recurrence gives identical (un-stabilized) outputs
    for ANY chunk size — the core numerical invariant under Mamba2/mLSTM."""
    B, S, H, N, P = 1, 16, 1, 4, 4
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, S, H, N))
    k = jax.random.normal(ks[1], (B, S, H, N))
    v = jax.random.normal(ks[2], (B, S, H, P))
    la = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    li = jax.random.normal(ks[4], (B, S, H))
    y1, d1, m1, _ = gla_chunked(q, k, v, la, li, chunk=chunk)
    y2, d2, m2, _ = gla_chunked(q, k, v, la, li, chunk=S)
    np.testing.assert_allclose(np.asarray(y1 * jnp.exp(m1)[..., None]),
                               np.asarray(y2 * jnp.exp(m2)[..., None]),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(d1 * jnp.exp(m1)),
                               np.asarray(d2 * jnp.exp(m2)),
                               atol=1e-4, rtol=1e-4)


@_settings
@given(st.integers(0, 1000))
def test_int8_roundtrip_error_bounded(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (16, 64)) * \
        (1 + 10 * jax.random.uniform(jax.random.PRNGKey(seed + 1), ()))
    q = Int8Quantizer()
    err = relative_error(q.decompress(q.compress(x)), x)
    assert err < 0.02      # 1/127 per-channel worst case is ~0.8%


@_settings
@given(st.integers(1, 4096), st.integers(1, 8))
def test_moe_capacity_dropless_small(tokens, k):
    # top-k experts are distinct per token, so an expert receives at most
    # `tokens` assignments — capacity >= tokens is the dropless bound.
    from repro.configs import get_config
    cfg = get_config("olmoe-1b-7b").replace(top_k=k)
    assert capacity(tokens, cfg) >= tokens
