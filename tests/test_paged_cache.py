"""Paged KV-cache allocation: allocator invariants, paged-vs-dense token
parity through ``BatchedEngine`` (every escalation path incl. the
speculative rewind), deferred admission under a capped pool, and the
intra-batch semantic-cache dedup regression.

The dense layout is the parity oracle: ``kv_layout="paged"`` changes WHERE
K/V live (shared block pool + block tables) but not a single emitted token.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.engine import CollaborativeEngine
from repro.core.policy import SpeculativePolicy, policy_from_legacy
from repro.core.paged_cache import TRAP_BLOCK, BlockPool, blocks_for
from repro.core.scheduler import BatchedEngine
from repro.models import Model


@pytest.fixture(scope="module")
def pair():
    e_cfg = get_config("smollm-135m").reduced()
    c_cfg = get_config("granite-8b").reduced().replace(
        vocab_size=e_cfg.vocab_size)
    edge, cloud = Model(e_cfg), Model(c_cfg)
    return (edge, edge.init(jax.random.PRNGKey(0)),
            cloud, cloud.init(jax.random.PRNGKey(1)))


def _prompts(vocab, specs):
    return [((np.arange(n) * 7 + off) % vocab).astype(np.int32)
            for n, off in specs]


def _engine(edge, cloud, layout, **kw):
    kw.setdefault("batch_size", 2)
    kw.setdefault("temperature", 0.0)
    kw.setdefault("use_cache", False)
    kw.setdefault("tick_tokens", 4)
    return BatchedEngine(edge, cloud, kv_layout=layout, kv_block_size=8,
                         **kw)


# ---------------------------------------------------------------- allocator
def test_block_pool_alloc_free_invariants():
    pool = BlockPool(num_blocks=9, block_size=4)
    assert pool.used == 0 and pool.can_alloc(8) and not pool.can_alloc(9)
    a = pool.alloc("a", 3)
    b = pool.alloc("b", 2)
    assert TRAP_BLOCK not in a + b          # trap never handed out
    assert len(set(a + b)) == 5 == pool.used
    pool.free("a")
    assert pool.used == 2 and sorted(pool.owned("a")) == []
    c = pool.alloc("c", 6)                  # reuses a's blocks
    assert pool.used == 8 and len(set(b + c)) == 8
    with pytest.raises(RuntimeError):
        pool.alloc("d", 1)
    assert pool.peak_used == 8
    pool.free("b")
    pool.free("b")                          # idempotent
    assert pool.used == 6


def test_block_pool_growth():
    pool = BlockPool(num_blocks=8, block_size=4)
    first = pool.alloc("s", pool.blocks_for(5))         # ceil(5/4) = 2
    assert len(first) == 2
    assert pool.grow_to("s", 8) == []                   # already covered
    grown = pool.grow_to("s", 9)                        # needs a third
    assert len(grown) == 1 and pool.owned("s") == first + grown
    assert pool.peak_used == 3


def test_blocks_for_rounding():
    assert blocks_for(0, 8) == 0
    assert blocks_for(1, 8) == 1
    assert blocks_for(8, 8) == 1
    assert blocks_for(9, 8) == 2


def test_block_pool_free_order_determinism():
    """Regression: freed ids must re-enter the LOW-IDS-FIRST discipline.
    The old list-append free broke it after any retire/admit churn (the
    most recently freed block came back first)."""
    pool = BlockPool(num_blocks=10, block_size=4)
    assert pool.alloc("a", 3) == [1, 2, 3]
    assert pool.alloc("b", 3) == [4, 5, 6]
    pool.free("a")
    # after churn, the lowest free ids still come first
    assert pool.alloc("c", 2) == [1, 2]
    assert pool.alloc("d", 3) == [3, 7, 8]
    pool.free("b")
    pool.free("c")
    assert pool.alloc("e", 4) == [1, 2, 4, 5]


def test_block_pool_refcounts_share_fork():
    """share bumps refcounts without allocating; fork (copy-on-write)
    splits a shared block in place; free releases references and only
    returns DEAD ids."""
    pool = BlockPool(num_blocks=10, block_size=4)
    a = pool.alloc("a", 3)                      # [1, 2, 3]
    pool.share("b", a[:2])                      # b maps a's first 2 blocks
    assert pool.owned("b") == [1, 2]
    assert pool.used == 3                       # no physical allocation
    assert pool.refcount(1) == 2 and pool.refcount(3) == 1
    new = pool.fork("b", 2)                     # CoW split of block 2
    assert new not in a and pool.owned("b") == [1, new]
    assert pool.refcount(2) == 1 and pool.refcount(new) == 1
    assert pool.fork("b", new) == new           # private: no-op
    dead = pool.free("a")                       # 1 survives via b
    assert sorted(dead) == [2, 3] and pool.refcount(1) == 1
    assert sorted(pool.free("b")) == [1, new]
    assert pool.used == 0
    with pytest.raises(RuntimeError):
        pool.share("c", [3])                    # dead blocks can't be shared


# ---------------------------------------------------------------- parity
def test_paged_edge_parity_staggered(pair):
    """Greedy tokens, paths, and uncertainties match the dense layout under
    staggered prompt lengths and budgets (slots admit/retire mid-run)."""
    edge, ep, cloud, cp = pair
    prompts = _prompts(edge.cfg.vocab_size,
                       [(8, 0), (6, 3), (10, 5), (7, 11), (5, 2)])
    budgets = [3, 11, 6, 9, 4]
    dense = _engine(edge, cloud, "dense", policy=SpeculativePolicy(1.1))
    paged = _engine(edge, cloud, "paged", policy=SpeculativePolicy(1.1))
    dts = dense.serve_batch(ep, cp, prompts, budgets)
    pts = paged.serve_batch(ep, cp, prompts, budgets)
    for dt, pt in zip(dts, pts):
        assert pt.path == dt.path == "edge"
        assert pt.tokens == dt.tokens
        assert abs(pt.uncertainty - dt.uncertainty) < 1e-5
    assert paged.stats()["kv_layout"] == "paged"


@pytest.mark.parametrize("esc", ["speculative", "cloud", "skeleton"])
def test_paged_escalation_parity(pair, esc):
    """Every grouped escalation mode emits identical greedy tokens on the
    paged layout — including the speculative path, whose per-slot rewind
    becomes a ``pos`` write against block tables."""
    edge, ep, cloud, cp = pair
    prompts = _prompts(edge.cfg.vocab_size, [(8, 0), (6, 3), (10, 5)])
    dense = _engine(edge, cloud, "dense", policy=policy_from_legacy(esc, -1.0),
                    skeleton_len=4)
    paged = _engine(edge, cloud, "paged", policy=policy_from_legacy(esc, -1.0),
                    skeleton_len=4)
    dts = dense.serve_batch(ep, cp, prompts, 8)
    pts = paged.serve_batch(ep, cp, prompts, 8)
    for dt, pt in zip(dts, pts):
        assert pt.path == dt.path == esc
        assert pt.tokens == dt.tokens


def test_paged_mixed_paths_match_reference(pair):
    """Per-request path selection under a mid threshold matches the
    sequential reference engine on the paged layout."""
    edge, ep, cloud, cp = pair
    prompts = _prompts(edge.cfg.vocab_size, [(8, 0), (6, 3), (10, 5), (7, 11)])
    ref = CollaborativeEngine(edge, cloud, temperature=0.0,
                              policy=SpeculativePolicy(0.9915), use_cache=False,
                              kv_layout="dense")
    paged = _engine(edge, cloud, "paged", batch_size=4,
                    policy=SpeculativePolicy(0.9915), tick_tokens=16)
    rts = [ref.serve_reference(ep, cp, p, 8) for p in prompts]
    pts = paged.serve_batch(ep, cp, prompts, 8)
    assert [pt.path for pt in pts] == [rt.path for rt in rts]
    for rt, pt in zip(rts, pts):
        assert pt.tokens == rt.tokens


def test_paged_deferred_admission_under_small_pool(pair):
    """A pool far below the dense worst case forces admission deferral;
    every request still completes with dense-identical tokens."""
    edge, ep, cloud, cp = pair
    prompts = _prompts(edge.cfg.vocab_size, [(24, 0), (6, 3), (6, 9), (8, 5)])
    dense = _engine(edge, cloud, "dense", policy=SpeculativePolicy(1.1),
                    batch_size=3)
    # enough for the long prompt + one short neighbour, not three slots
    paged = _engine(edge, cloud, "paged", policy=SpeculativePolicy(1.1),
                    batch_size=3, kv_blocks=8)
    dts = dense.serve_batch(ep, cp, prompts, 6)
    pts = paged.serve_batch(ep, cp, prompts, 6)
    for dt, pt in zip(dts, pts):
        assert pt.tokens == dt.tokens
    stats = paged.stats()
    assert stats["kv_blocks_peak"] <= 7     # never exceeded the cap


def test_paged_pool_too_small_raises(pair):
    edge, ep, cloud, cp = pair
    (p,) = _prompts(edge.cfg.vocab_size, [(33, 0)])
    paged = _engine(edge, cloud, "paged", policy=SpeculativePolicy(1.1),
                    batch_size=1, kv_blocks=3)
    with pytest.raises(RuntimeError, match="kv_blocks|pool"):
        paged.serve_batch(ep, cp, [p], 4)


def test_paged_rejects_recurrent_families():
    cfg = get_config("xlstm-125m").reduced()
    ssm = Model(cfg)
    dense_cfg = get_config("smollm-135m").reduced().replace(
        vocab_size=cfg.vocab_size)
    dense = Model(dense_cfg)
    with pytest.raises(ValueError, match="paged"):
        BatchedEngine(ssm, dense, kv_layout="paged")
    eng = BatchedEngine(ssm, dense, kv_layout="auto", use_cache=False)
    assert eng.kv_layout == "dense"         # auto falls back


def test_paged_sliding_window_parity():
    """``cfg.sliding_window`` survives the paged layout: the block-table
    read applies the same window mask the dense decode path does."""
    e_cfg = get_config("smollm-135m").reduced().replace(sliding_window=4)
    c_cfg = get_config("granite-8b").reduced().replace(
        vocab_size=e_cfg.vocab_size, sliding_window=4)
    edge, cloud = Model(e_cfg), Model(c_cfg)
    ep = edge.init(jax.random.PRNGKey(0))
    cp = cloud.init(jax.random.PRNGKey(1))
    prompts = _prompts(e_cfg.vocab_size, [(10, 0), (6, 3)])
    dense = _engine(edge, cloud, "dense", policy=SpeculativePolicy(1.1))
    paged = _engine(edge, cloud, "paged", policy=SpeculativePolicy(1.1))
    dts = dense.serve_batch(ep, cp, prompts, 8)
    pts = paged.serve_batch(ep, cp, prompts, 8)
    for dt, pt in zip(dts, pts):
        assert pt.tokens == dt.tokens
        assert abs(pt.uncertainty - dt.uncertainty) < 1e-5


def test_paged_sliding_window_uses_kernel_path(monkeypatch):
    """Sliding-window configs now ride the windowed Pallas/ref decode
    kernel: the masked full-width block-table gather
    (``paged_extend_attention``) must never fire on the T=1 decode hot
    path.  (Escalation-free run: the gather legitimately remains the T>1
    speculative-verify read.)"""
    from repro.models import layers as L
    e_cfg = get_config("smollm-135m").reduced().replace(sliding_window=4)
    c_cfg = get_config("granite-8b").reduced().replace(
        vocab_size=e_cfg.vocab_size, sliding_window=4)
    edge, cloud = Model(e_cfg), Model(c_cfg)
    ep = edge.init(jax.random.PRNGKey(0))
    cp = cloud.init(jax.random.PRNGKey(1))

    def _boom(*a, **k):
        raise AssertionError("masked gather used on the T=1 decode path")
    monkeypatch.setattr(L, "paged_extend_attention", _boom)
    prompts = _prompts(e_cfg.vocab_size, [(10, 0), (6, 3)])
    paged = _engine(edge, cloud, "paged", policy=SpeculativePolicy(1.1))
    pts = paged.serve_batch(ep, cp, prompts, 8)
    assert all(pt.path == "edge" and len(pt.tokens) == 8 for pt in pts)


# ---------------------------------------------------------------- sharing
def test_prefix_sharing_across_ticks(pair):
    """Requests sharing a block-aligned prompt prefix map the shared
    blocks physically (refcounts, not copies) — including ones admitted in
    LATER ticks, past the same-tick dedup window — at exact token parity
    with the dense oracle."""
    edge, ep, cloud, cp = pair
    v = edge.cfg.vocab_size
    pref = ((np.arange(16) * 7) % v).astype(np.int32)       # 2 full blocks
    prompts = [np.concatenate([pref,
                               ((np.arange(6) * 5 + o) % v).astype(np.int32)])
               for o in range(5)]
    # the long-budget leader keeps the prefix blocks live while the other
    # four rotate through the second slot across later ticks
    budgets = [16, 4, 4, 4, 4]
    dense = _engine(edge, cloud, "dense", policy=SpeculativePolicy(1.1))
    paged = _engine(edge, cloud, "paged", policy=SpeculativePolicy(1.1))
    dts = dense.serve_batch(ep, cp, prompts, budgets)
    pts = paged.serve_batch(ep, cp, prompts, budgets)
    for dt, pt in zip(dts, pts):
        assert pt.tokens == dt.tokens
    s = paged.stats()
    # batch_size=2: requests 2..4 admit in later ticks and still share
    assert s["kv_prefix_hits"] == 4
    assert s["kv_shared_blocks"] == 4 * 2       # 2 full prefix blocks each


def test_twin_prompts_cow_on_divergent_write(pair):
    """Exact twin prompts (semantic cache off) share EVERY prompt block,
    including the partial tail; the first decode write forks a private
    copy (copy-on-write), so both twins still emit dense-identical
    tokens."""
    edge, ep, cloud, cp = pair
    (p,) = _prompts(edge.cfg.vocab_size, [(10, 0)])         # 9 entries: partial tail
    dense = _engine(edge, cloud, "dense", policy=SpeculativePolicy(1.1))
    paged = _engine(edge, cloud, "paged", policy=SpeculativePolicy(1.1))
    dts = dense.serve_batch(ep, cp, [p, p.copy()], 6)
    pts = paged.serve_batch(ep, cp, [p, p.copy()], 6)
    for dt, pt in zip(dts, pts):
        assert pt.tokens == dt.tokens
    s = paged.stats()
    assert s["kv_prefix_hits"] == 1 and s["kv_cow_forks"] == 1


def test_shared_prefix_peak_below_unshared(pair):
    """The point of sharing: an 80%-shared-prefix mix keeps one physical
    copy of the prefix, so peak live blocks sit well below dense."""
    edge, ep, cloud, cp = pair
    v = edge.cfg.vocab_size
    pref = ((np.arange(24) * 7) % v).astype(np.int32)       # 3 full blocks
    prompts = [np.concatenate([pref,
                               ((np.arange(6) * 5 + o) % v).astype(np.int32)])
               for o in range(6)]
    dense = _engine(edge, cloud, "dense", policy=SpeculativePolicy(1.1),
                    batch_size=3)
    paged = _engine(edge, cloud, "paged", policy=SpeculativePolicy(1.1),
                    batch_size=3)
    dts = dense.serve_batch(ep, cp, prompts, 6)
    pts = paged.serve_batch(ep, cp, prompts, 6)
    for dt, pt in zip(dts, pts):
        assert pt.tokens == dt.tokens
    d, p = dense.stats(), paged.stats()
    assert p["kv_peak_bytes"] * 2 < d["kv_peak_bytes"]


# ---------------------------------------------------------------- preemption
def test_preemption_under_overcommitted_pool(pair):
    """A pool holding HALF the batch's reservations forces
    preemption-by-swap: victims' blocks are staged to host and restored
    bit-for-bit, every request completes (zero permanent deferrals), and
    tokens match the dense oracle exactly."""
    edge, ep, cloud, cp = pair
    prompts = _prompts(edge.cfg.vocab_size,
                       [(16, 0), (16, 3), (16, 6), (16, 9), (16, 12)])
    per_req = blocks_for(15 + 8, 8)             # blocks per request
    dense = _engine(edge, cloud, "dense", policy=SpeculativePolicy(1.1),
                    batch_size=2)
    paged = _engine(edge, cloud, "paged", policy=SpeculativePolicy(1.1),
                    batch_size=2, kv_blocks=per_req + per_req // 2 + 1)
    dts = dense.serve_batch(ep, cp, prompts, 8)
    pts = paged.serve_batch(ep, cp, prompts, 8)
    assert len(pts) == len(prompts)             # nobody starved
    for dt, pt in zip(dts, pts):
        assert pt.tokens == dt.tokens
    s = paged.stats()
    assert s["preemptions"] > 0 and s["kv_swaps"] == s["preemptions"]


def test_swap_in_reshares_prompt_blocks(pair):
    """ROADMAP paged polish: ``swap_in`` re-consults the prefix-block
    index, so a swapped twin re-SHARES its full prompt blocks (refcount
    bumps against the resident twin) instead of paying private copies on
    resume.  Pins the refcounts and the physical block count."""
    edge, ep, _, _ = pair
    from repro.core.seq_state import Lane
    lane = Lane(edge, "entropy", 0.0, layout="paged", block_size=8)
    st = lane.make_state(ep, 2, 64, num_blocks=16)
    v = edge.cfg.vocab_size
    prompt = ((np.arange(17) * 7) % v).astype(np.int32)   # 16 entries: 2 full blocks
    assert st.admit(0, prompt, 24)
    assert st.admit(1, prompt, 24)          # twin: shares both prompt blocks
    st.flush()
    shared = st.pool.owned(0)[:2]
    assert st.pool.owned(1)[:2] == shared
    assert all(st.pool.refcount(blk) == 2 for blk in shared)
    used_before = st.pool.used
    handle = st.swap_out(1)
    assert all(st.pool.refcount(blk) == 1 for blk in shared)
    assert st.swap_in(1, handle)
    st.flush()
    # the resumed twin maps the SAME physical prompt blocks again
    assert st.pool.owned(1)[:2] == shared
    assert all(st.pool.refcount(blk) == 2 for blk in shared)
    assert st.pool.used == used_before      # no private copies paid
    assert st.stats()["kv_shared_blocks"] == 4      # 2 at admit + 2 at resume
    # grow past the prompt, swap again: the generated-token block restores
    # privately and must stay OUT of the prefix index (O(1) purge path)
    st.prepare_tick([1], np.asarray([0, 8]), 8)
    h2 = st.swap_out(1)
    assert st.swap_in(1, h2)
    st.flush()
    assert st.pool.owned(1)[:2] == shared
    gen = st.pool.owned(1)[2]
    assert gen not in st._indexed


def test_cow_reservation_survives_twin_retirement(pair):
    """Regression: the CoW fork block must be charged to the SHARER's
    reservation, not the forking slot's.  Here the registrant (long
    budget) forks first, the twin (short budget) retires, and a third
    request is admitted into the gap — under the old accounting the
    registrant's growth reservation had been silently consumed and its
    next ``grow_to`` raised "KV block pool exhausted" mid-flight."""
    edge, ep, cloud, cp = pair
    v = edge.cfg.vocab_size
    twin = ((np.arange(10) * 7) % v).astype(np.int32)   # 9 entries: partial
    other = ((np.arange(17) * 5 + 3) % v).astype(np.int32)
    prompts = [twin, twin.copy(), other]
    budgets = [10, 2, 6]
    dense = _engine(edge, cloud, "dense", policy=SpeculativePolicy(1.1),
                    batch_size=3, tick_tokens=2)
    paged = _engine(edge, cloud, "paged", policy=SpeculativePolicy(1.1),
                    batch_size=3, tick_tokens=2, kv_blocks=6)
    dts = dense.serve_batch(ep, cp, prompts, budgets)
    pts = paged.serve_batch(ep, cp, prompts, budgets)
    for dt, pt in zip(dts, pts):
        assert pt.tokens == dt.tokens
    assert paged.stats()["kv_cow_forks"] == 1


def test_giant_prompt_cannot_starve(pair):
    """Anti-starvation regression (strict arrival order + preemption): a
    giant request that needs most of the pool is admitted by swapping out
    in-flight victims instead of deferring forever, and the victims resume
    and finish with dense-identical tokens."""
    edge, ep, cloud, cp = pair
    v = edge.cfg.vocab_size
    prompts = _prompts(v, [(8, 0), (8, 3), (40, 5), (8, 9)])
    budgets = [12, 12, 4, 6]
    dense = _engine(edge, cloud, "dense", policy=SpeculativePolicy(1.1),
                    batch_size=3)
    # pool fits the giant + one small neighbour, not the giant + two
    paged = _engine(edge, cloud, "paged", policy=SpeculativePolicy(1.1),
                    batch_size=3, kv_blocks=blocks_for(39 + 4, 8) + 4)
    dts = dense.serve_batch(ep, cp, prompts, budgets)
    pts = paged.serve_batch(ep, cp, prompts, budgets)
    for dt, pt in zip(dts, pts):
        assert pt.tokens == dt.tokens
    assert paged.stats()["preemptions"] > 0


# ---------------------------------------------------------------- memory
def test_paged_peak_bytes_below_dense_on_skewed_mix(pair):
    """The point of paging: with one 4x-length outlier, dense pads every
    slot to the outlier while the paged pool only backs what each request
    actually uses — peak KV bytes strictly below dense."""
    edge, ep, cloud, cp = pair
    v = edge.cfg.vocab_size
    prompts = _prompts(v, [(8, 0), (8, 3), (8, 6), (32, 1), (8, 9), (8, 4)])
    dense = _engine(edge, cloud, "dense", policy=SpeculativePolicy(1.1),
                    batch_size=3)
    paged = _engine(edge, cloud, "paged", policy=SpeculativePolicy(1.1),
                    batch_size=3)
    dts = dense.serve_batch(ep, cp, prompts, 6)
    pts = paged.serve_batch(ep, cp, prompts, 6)
    for dt, pt in zip(dts, pts):
        assert pt.tokens == dt.tokens
    d, p = dense.stats(), paged.stats()
    assert p["kv_peak_bytes"] < d["kv_peak_bytes"]


# ---------------------------------------------------------------- dedup
def test_intra_batch_dedup_regression(pair):
    """Identical prompts admitted in the same tick are coalesced: one
    leader decodes, the twin is served from its result as a cache hit —
    the sequential engine's behavior (its second request hits the cache
    the first just warmed)."""
    edge, ep, cloud, cp = pair
    (p,) = _prompts(edge.cfg.vocab_size, [(8, 0)])
    be = BatchedEngine(edge, cloud, batch_size=4, temperature=0.0,
                       policy=SpeculativePolicy(1.1), cache_threshold=0.99,
                       tick_tokens=4)
    t1, t2, t3 = be.serve_batch(ep, cp, [p, p.copy(), p.copy()], 8)
    assert t1.path == "edge"
    assert t2.path == "cache" and t3.path == "cache"
    assert t2.tokens == t1.tokens and t3.tokens == t1.tokens
    # the twins count as cache hits, exactly like the sequential engine
    assert be.cache.hits == 2 and be.cache.lookups == 3


def test_dedup_distinct_prompts_not_coalesced(pair):
    edge, ep, cloud, cp = pair
    prompts = _prompts(edge.cfg.vocab_size, [(8, 0), (8, 11)])
    be = BatchedEngine(edge, cloud, batch_size=2, temperature=0.0,
                       policy=SpeculativePolicy(1.1), cache_threshold=0.999,
                       tick_tokens=4)
    t1, t2 = be.serve_batch(ep, cp, prompts, 8)
    assert t1.path == "edge" and t2.path == "edge"


def test_dedup_follower_waits_for_inflight_leader(pair):
    """A duplicate admitted in a LATER tick, while its leader is still
    decoding (leader budget outlasts its neighbour's), also coalesces —
    it gets the leader's full result once the leader finishes."""
    edge, ep, cloud, cp = pair
    (p,) = _prompts(edge.cfg.vocab_size, [(8, 0)])
    q = _prompts(edge.cfg.vocab_size, [(6, 5)])[0]
    be = BatchedEngine(edge, cloud, batch_size=2, temperature=0.0,
                       policy=SpeculativePolicy(1.1), cache_threshold=0.99,
                       tick_tokens=2)
    t1, t2, t3 = be.serve_batch(ep, cp, [p, q, p.copy()], [12, 2, 4])
    assert t1.path == "edge" and t2.path == "edge"
    assert t3.path == "cache" and t3.tokens == t1.tokens
