"""Token-tree speculation (§2.4.4) and self-speculative decoding (§2.4.2)."""
import jax
import pytest

from repro.configs import get_config
from repro.core.self_speculative import SelfSpecDecoder
from repro.core.speculative import autoregressive_baseline
from repro.core.tree_speculation import TokenTree, TreeSpecDecoder
import numpy as np

from repro.models import Model


@pytest.fixture(scope="module")
def small():
    cfg = get_config("smollm-135m").reduced()
    m = Model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def test_token_tree_structure():
    #      0
    #    1   2
    #   3
    t = TokenTree(np.array([5, 6, 7, 8], np.int32),
                  np.array([-1, 0, 0, 1], np.int32),
                  np.zeros((4, 10), np.float32))
    assert t.ancestors(3) == [0, 1, 3]
    m = t.attention_mask()
    assert m[3, 1] and m[3, 0] and not m[3, 2]
    assert list(t.depths()) == [0, 1, 1, 2]


def test_tree_spec_greedy_lossless(small):
    cfg, m, params = small
    prompt = jax.random.randint(jax.random.PRNGKey(1), (8,), 0, cfg.vocab_size)
    base = autoregressive_baseline(m, params, prompt, 12, temperature=0.0)
    dec = TreeSpecDecoder(m, m, branching=(2, 2), temperature=0.0)
    toks, stats = dec.generate(params, params, prompt, 12)
    assert toks == base
    # identical draft: the greedy path is always accepted to the leaf
    assert all(a == 2 for a in stats["accepted_per_round"])


def test_tree_spec_rejects_ssm_target():
    cfg = get_config("xlstm-125m").reduced()
    m = Model(cfg)
    with pytest.raises(ValueError):
        TreeSpecDecoder(m, m)


@pytest.mark.parametrize("gamma", [1, 3])
def test_self_spec_greedy_lossless(small, gamma):
    cfg, m, params = small
    prompt = jax.random.randint(jax.random.PRNGKey(1), (8,), 0, cfg.vocab_size)
    base = autoregressive_baseline(m, params, prompt, 12, temperature=0.0)
    dec = SelfSpecDecoder(m, exit_layer=1, gamma=gamma, temperature=0.0)
    toks, stats = dec.generate(params, prompt, 12)
    assert toks == base
    assert stats.target_passes == stats.rounds
