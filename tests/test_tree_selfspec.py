"""Token-tree speculation (§2.4.4) and self-speculative decoding (§2.4.2):
the per-request seed decoders, the packed ``TreePlan`` the batched lanes
run on, and the ``BatchedEngine`` speculation-lane wiring
(``SpeculativePolicy(mode=...)`` -> ``BatchedSpecDecoder`` mode)."""
import jax
import pytest

from repro.configs import get_config
from repro.core.policy import SpeculativePolicy
from repro.core.scheduler import BatchedEngine
from repro.core.self_speculative import SelfSpecDecoder
from repro.core.speculative import BatchedSpecDecoder, autoregressive_baseline
from repro.core.tree_speculation import (TokenTree, TreePlan, TreeSpecDecoder,
                                         branching_for, tree_accept,
                                         tree_accept_ref)
import numpy as np

from repro.models import Model


@pytest.fixture(scope="module")
def small():
    cfg = get_config("smollm-135m").reduced()
    m = Model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def test_token_tree_structure():
    #      0
    #    1   2
    #   3
    t = TokenTree(np.array([5, 6, 7, 8], np.int32),
                  np.array([-1, 0, 0, 1], np.int32),
                  np.zeros((4, 10), np.float32))
    assert t.ancestors(3) == [0, 1, 3]
    m = t.attention_mask()
    assert m[3, 1] and m[3, 0] and not m[3, 2]
    assert list(t.depths()) == [0, 1, 1, 2]


def test_tree_spec_greedy_lossless(small):
    cfg, m, params = small
    prompt = jax.random.randint(jax.random.PRNGKey(1), (8,), 0, cfg.vocab_size)
    base = autoregressive_baseline(m, params, prompt, 12, temperature=0.0)
    dec = TreeSpecDecoder(m, m, branching=(2, 2), temperature=0.0)
    toks, stats = dec.generate(params, params, prompt, 12)
    assert toks == base
    # identical draft: the greedy path is always accepted to the leaf
    assert all(a == 2 for a in stats["accepted_per_round"])


def test_tree_spec_rejects_ssm_target():
    cfg = get_config("xlstm-125m").reduced()
    m = Model(cfg)
    with pytest.raises(ValueError):
        TreeSpecDecoder(m, m)


@pytest.mark.parametrize("gamma", [1, 3])
def test_self_spec_greedy_lossless(small, gamma):
    cfg, m, params = small
    prompt = jax.random.randint(jax.random.PRNGKey(1), (8,), 0, cfg.vocab_size)
    base = autoregressive_baseline(m, params, prompt, 12, temperature=0.0)
    dec = SelfSpecDecoder(m, exit_layer=1, gamma=gamma, temperature=0.0)
    toks, stats = dec.generate(params, prompt, 12)
    assert toks == base
    assert stats.target_passes == stats.rounds


# ---------------------------------------------------------------- TreePlan
@pytest.mark.parametrize("width,gamma", [(2, 1), (2, 4), (3, 3), (4, 2)])
def test_tree_plan_matches_seed_token_tree(width, gamma):
    """The packed static plan reproduces the seed ``TokenTree``'s ancestor
    mask and depths exactly over the real (un-padded) nodes."""
    plan = TreePlan(branching_for(width, gamma))
    tree = TokenTree(np.zeros(plan.n, np.int32),
                     np.asarray(plan.parent[:plan.n], np.int32),
                     np.zeros((plan.n, 4), np.float32))
    assert np.array_equal(np.asarray(plan.mask)[:plan.n, :plan.n],
                          tree.attention_mask())
    assert np.array_equal(np.asarray(plan.depths)[:plan.n], tree.depths())


@pytest.mark.parametrize("width,gamma", [(2, 1), (2, 4), (3, 3), (4, 2)])
def test_tree_plan_invariants(width, gamma):
    plan = TreePlan(branching_for(width, gamma))
    assert plan.depth == gamma
    assert plan.n_pad >= plan.n and plan.n_pad & (plan.n_pad - 1) == 0
    # levels are contiguous and every child's parent sits one level up
    prev = (0, 1)
    for lo, hi in plan.levels:
        assert lo == prev[1]
        for c in range(lo, hi):
            assert prev[0] <= plan.parent[c] < prev[1]
        prev = (lo, hi)
    assert prev[1] == plan.n
    # pad nodes: parentless, depth 0, self-only mask rows (never attended)
    for i in range(plan.n, plan.n_pad):
        assert plan.parent[i] == -1 and plan.depths[i] == 0
        row = np.asarray(plan.mask)[i]
        assert row[i] and row.sum() == 1


@pytest.mark.parametrize("temperature", [0.0, 1.0])
def test_tree_accept_matches_sequential_oracle(temperature):
    plan = TreePlan(branching_for(2, 4))
    V = 12
    for seed in range(8):
        rng = jax.random.PRNGKey(seed)
        kt, kq, kk = jax.random.split(jax.random.fold_in(rng, 1), 3)
        tl = jax.random.normal(kt, (plan.n_pad, V)) * 2
        ql = jax.random.normal(kq, (plan.n_pad, V)) * 2
        toks = jax.random.randint(kk, (plan.n_pad,), 0, V)
        n, em, path = tree_accept(rng, tl, ql, toks, plan,
                                  temperature=temperature)
        n_ref, em_ref = tree_accept_ref(rng, tl, ql, toks, plan,
                                        temperature=temperature)
        assert int(n) == n_ref
        assert [int(x) for x in em[:int(n) + 1]] == em_ref
        # path is a root-anchored ancestor chain over real nodes
        assert int(path[0]) == 0
        for d in range(1, int(n) + 1):
            assert int(plan.parent[int(path[d])]) == int(path[d - 1])


# ------------------------------------------------------- batched engine lanes
@pytest.fixture(scope="module")
def pair():
    e_cfg = get_config("smollm-135m").reduced()
    c_cfg = get_config("granite-8b").reduced().replace(
        vocab_size=e_cfg.vocab_size)
    edge, cloud = Model(e_cfg), Model(c_cfg)
    return (edge, edge.init(jax.random.PRNGKey(0)),
            cloud, cloud.init(jax.random.PRNGKey(1)))


def _prompts(vocab, specs):
    return [((np.arange(n) * 7 + off) % vocab).astype(np.int32)
            for n, off in specs]


@pytest.mark.slow
def test_batched_tree_lane_matches_linear_and_baseline(pair):
    """The tree lane is exact: greedy output token-identical to both the
    linear lane and cloud-only greedy decode, with multi-token acceptance
    visible in the stats."""
    edge, ep, cloud, cp = pair
    prompts = _prompts(edge.cfg.vocab_size, [(8, 0), (6, 3), (10, 5)])
    outs = {}
    for mode in ("linear", "tree"):
        be = BatchedEngine(edge, cloud, batch_size=2, temperature=0.0,
                           policy=SpeculativePolicy(-1.0, mode=mode),
                           use_cache=False)
        outs[mode] = be.serve_batch(ep, cp, prompts, 8)
        st = be.stats()
        assert st["spec_mode"] == mode
        assert st["accepted_tokens_per_step"] > 0
        assert set(st["spec_lanes"][mode]) == {
            "member_rounds", "draft_tokens", "verify_tokens",
            "accepted_tokens", "emitted_tokens"}
    for p, lt, tt in zip(prompts, outs["linear"], outs["tree"]):
        base = autoregressive_baseline(cloud, cp, p, 8, temperature=0.0)
        assert tt.tokens == lt.tokens == base
        assert tt.path == "speculative"


@pytest.mark.slow
def test_batched_self_lane_zero_second_model(pair):
    """mode="self": the edge's own early-exit head drafts, its full depth
    verifies — zero second-model params, zero cloud passes, and the
    output equals plain edge greedy decode."""
    edge, ep, cloud, cp = pair
    prompts = _prompts(edge.cfg.vocab_size, [(8, 0), (6, 3)])
    be = BatchedEngine(edge, cloud, batch_size=2, temperature=0.0,
                       policy=SpeculativePolicy(-1.0, mode="self"),
                       use_cache=False)
    assert be.spec.second_model_params == 0
    bts = be.serve_batch(ep, cp, prompts, 8)
    for p, bt in zip(prompts, bts):
        base = autoregressive_baseline(edge, ep, p, 8, temperature=0.0)
        assert bt.tokens == base
        assert bt.cloud_passes == 0
    assert be.stats()["spec_mode"] == "self"


def test_mode_fallback_and_validation(pair):
    """Unknown modes raise everywhere the mode enters; unsupported
    families downgrade to the linear lane and report it."""
    edge, ep, cloud, cp = pair
    with pytest.raises(ValueError, match="speculation mode"):
        SpeculativePolicy(-1.0, mode="bogus")
    with pytest.raises(ValueError, match="speculation mode"):
        BatchedSpecDecoder(edge, cloud, mode="bogus")
    with pytest.raises(ValueError, match="mode"):
        BatchedEngine(edge, cloud, batch_size=2, spec_mode="bogus")
    with pytest.raises(ValueError, match="exit_layer"):
        BatchedSpecDecoder(edge, edge, mode="self", exit_layer=99)
    # recurrent drafts can't run block-masked tree extends -> linear
    r_cfg = get_config("mamba2-370m").reduced().replace(
        vocab_size=edge.cfg.vocab_size)
    rec = Model(r_cfg)
    be = BatchedEngine(rec, cloud, batch_size=2, temperature=0.0,
                       policy=SpeculativePolicy(-1.0, mode="tree"),
                       use_cache=False)
    assert be.spec_mode == "linear"
    assert be.stats()["spec_mode"] == "linear"
