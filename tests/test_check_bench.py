"""scripts/check_bench.py over synthetic BENCH_serving.json payloads —
assert regressions in the CI bench gate fail here, not just in Actions."""
import copy
import importlib.util
import json
from pathlib import Path

import pytest

_SCRIPT = Path(__file__).resolve().parents[1] / "scripts" / "check_bench.py"
_spec = importlib.util.spec_from_file_location("check_bench", _SCRIPT)
check_bench = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_bench)


def _rows():
    """A minimal result set that satisfies every check."""
    ol_arm = {"requests": 8, "completed": 8, "ttft_p50_ms": 10.0,
              "ttft_p99_ms": 40.0, "tpot_p50_ms": 2.0, "tpot_p99_ms": 4.0,
              "goodput_slo": 5.0, "slo_attainment": 0.9,
              "deferred_admissions": 1}
    return {
        "config": {"requests": 8, "prompt_len": 16, "max_new": 8,
                   "batch": 4, "smoke": True},
        "paged_vs_dense": {
            "dense": {"req_s": 2.0, "kv_peak_bytes": 1000},
            "paged": {"req_s": 2.0, "kv_peak_bytes": 400},
            "kv_savings_x": 2.5},
        "shared_prefix": {"kv_savings_x": 3.0, "prefix_hits": 7,
                          "shared_blocks": 21, "cow_forks": 2},
        "overcommit": {"deferred_forever": 0, "completed": 8,
                       "preemptions": 3},
        "open_loop": {"poisson": dict(ol_arm), "bursty_2x": dict(ol_arm)},
        "serving_recurrent": {
            "mamba2-370m": {"family": "ssm", "speedup": 3.0},
            "zamba2-1b": {"family": "hybrid", "speedup": 2.0}},
        "policy": {
            "threshold": {"req_s": 2.0, "cloud_token_share": 0.4,
                          "quality_proxy": 0.8},
            "cascade": {"req_s": 2.0, "cloud_token_share": 0.3,
                        "quality_proxy": 0.8},
            "bandit": {"req_s": 2.0, "cloud_token_share": 0.5,
                       "quality_proxy": 0.7},
            "bandit_adaptation": {"share_first": 0.9, "share_last": 0.2}},
        "tree_spec": {
            "noise_scale": 1e-3, "verify_budget": 16,
            "tree_vs_chain_speedup": 1.4,
            "lanes": {
                "chain": {"req_s": 2.0, "accepted_tokens_per_step": 3.0,
                          "accept_rate": 0.2, "rounds": 60,
                          "spec_mode": "linear"},
                "tree": {"req_s": 2.8, "accepted_tokens_per_step": 3.6,
                         "accept_rate": 0.2, "rounds": 50,
                         "spec_mode": "tree"},
                "chain_depth4": {"req_s": 3.0,
                                 "accepted_tokens_per_step": 2.7,
                                 "accept_rate": 0.5, "rounds": 70,
                                 "spec_mode": "linear"},
                "self": {"req_s": 3.2, "accepted_tokens_per_step": 1.4,
                         "accept_rate": 0.1, "rounds": 90,
                         "spec_mode": "self"}}},
        "compile_stability": {
            "decode_compiles": 12, "steady_state_recompiles": 0,
            "recompile_events": []},
        "online_adaptation": {
            "threshold": 0.99, "segments": 9, "req_s": 8.0,
            "cloud_share_first_third": 0.75,
            "cloud_share_last_third": 0.0,
            "accept_first_third": 0.25, "accept_last_third": 1.0,
            "swaps": 8, "train_steps": 64, "last_loss": 7.5,
            "store_size": 72, "steady_state_recompiles": 0,
            "steady_swaps": 1},
        "multi_device": {
            "mesh_shape": {"data": 2, "model": 4}, "mesh_devices": 8,
            "single_req_s": 2.0, "mesh_req_s": 1.5, "kv_shards": 8,
            "single_kv_capacity_blocks": 16,
            "mesh_kv_capacity_blocks": 134,
            "kv_capacity_scale_x": 8.4, "token_parity": True},
    }


def _quiet(*a, **k):
    pass


def test_good_rows_pass():
    check_bench.check(_rows(), out=_quiet)
    check_bench.check(_rows(), require_multi_device=True, out=_quiet)


def test_multi_device_skip_tolerated_without_flag():
    rows = _rows()
    rows["multi_device"] = {"skipped": "needs 8 devices, have 1"}
    check_bench.check(rows, out=_quiet)


def test_multi_device_skip_fails_when_required():
    rows = _rows()
    rows["multi_device"] = {"skipped": "needs 8 devices, have 1"}
    with pytest.raises(AssertionError, match="skipped"):
        check_bench.check(rows, require_multi_device=True, out=_quiet)


@pytest.mark.parametrize("mutate", [
    lambda r: r["paged_vs_dense"].__setitem__("kv_savings_x", 0.9),
    lambda r: r["paged_vs_dense"]["paged"].__setitem__(
        "kv_peak_bytes", 2000),
    lambda r: r["shared_prefix"].__setitem__("prefix_hits", 0),
    lambda r: r["overcommit"].__setitem__("deferred_forever", 2),
    lambda r: r["overcommit"].__setitem__("completed", 5),
    lambda r: r["open_loop"]["poisson"].__setitem__("goodput_slo", 0.0),
    lambda r: r["open_loop"]["bursty_2x"].pop("ttft_p99_ms"),
    lambda r: r["serving_recurrent"]["mamba2-370m"].__setitem__(
        "family", "dense"),
    lambda r: r["policy"]["cascade"].__setitem__("cloud_token_share", 9.0),
    lambda r: r["policy"]["bandit_adaptation"].__setitem__(
        "share_last", 0.95),
    lambda r: r["tree_spec"]["lanes"]["tree"].__setitem__(
        "accepted_tokens_per_step", 0.9),
    lambda r: r["tree_spec"].__setitem__("tree_vs_chain_speedup", 0.8),
    lambda r: r["tree_spec"]["lanes"]["tree"].__setitem__("rounds", 99),
    lambda r: r["tree_spec"]["lanes"]["self"].pop("req_s"),
    lambda r: r["tree_spec"]["lanes"].pop("chain"),
    lambda r: r.pop("tree_spec"),
    lambda r: r["compile_stability"].__setitem__(
        "steady_state_recompiles", 1),
    lambda r: r["compile_stability"].__setitem__("decode_compiles", 0),
    lambda r: r.pop("compile_stability"),
    lambda r: r["online_adaptation"].__setitem__(
        "cloud_share_last_third", 0.8),
    lambda r: r["online_adaptation"].__setitem__("accept_last_third", 0.1),
    lambda r: r["online_adaptation"].__setitem__(
        "steady_state_recompiles", 2),
    lambda r: r["online_adaptation"].__setitem__("steady_swaps", 0),
    lambda r: r["online_adaptation"].__setitem__("swaps", 0),
    lambda r: r.pop("online_adaptation"),
    lambda r: r["multi_device"].__setitem__("token_parity", False),
    lambda r: r["multi_device"].__setitem__("kv_capacity_scale_x", 1.0),
    lambda r: r["multi_device"].__setitem__("kv_shards", 1),
    lambda r: r.pop("multi_device"),
])
def test_regressions_fail(mutate):
    rows = copy.deepcopy(_rows())
    mutate(rows)
    with pytest.raises((AssertionError, KeyError)):
        check_bench.check(rows, out=_quiet)


def test_cli_roundtrip(tmp_path, capsys):
    p = tmp_path / "BENCH_serving.json"
    p.write_text(json.dumps(_rows()))
    assert check_bench.main(["--path", str(p),
                             "--require-multi-device"]) == 0
    assert "all checks passed" in capsys.readouterr().out
