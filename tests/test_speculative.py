"""Speculative decoding (survey §2.4): losslessness, stats accounting, and
the distribution-preservation theorem."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.speculative import (AdaptiveGamma, SpecDecoder,
                                    acceptance_rate_bound,
                                    autoregressive_baseline,
                                    speculative_sample)
from repro.models import Model


@pytest.fixture(scope="module")
def small():
    cfg = get_config("smollm-135m").reduced()
    m = Model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def _prompt(cfg, n=8, seed=1):
    return jax.random.randint(jax.random.PRNGKey(seed), (n,), 0, cfg.vocab_size)


def test_greedy_lossless_same_draft(small):
    cfg, m, params = small
    prompt = _prompt(cfg)
    base = autoregressive_baseline(m, params, prompt, 16, temperature=0.0)
    dec = SpecDecoder(m, m, gamma=4, temperature=0.0)
    toks, stats = dec.generate(params, params, prompt, 16)
    assert toks == base
    assert stats.mean_accepted == 4.0            # identical draft: all accepted
    assert stats.tokens_per_target_pass > 4.0


def test_greedy_lossless_different_draft(small):
    cfg, m, params = small
    p2 = m.init(jax.random.PRNGKey(9))
    prompt = _prompt(cfg)
    base = autoregressive_baseline(m, params, prompt, 16, temperature=0.0)
    dec = SpecDecoder(m, m, gamma=4, temperature=0.0)
    toks, _ = dec.generate(p2, params, prompt, 16)
    assert toks == base                          # greedy spec decode is exact


@pytest.mark.parametrize("arch", ["mamba2-370m", "xlstm-125m", "zamba2-2.7b",
                                  "whisper-small", "olmoe-1b-7b"])
def test_greedy_lossless_all_families(arch):
    cfg = get_config(arch).reduced()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    prompt = _prompt(cfg, 6)
    if cfg.family == "encdec":
        pytest.skip("enc-dec needs frames plumbing in SpecDecoder prompts")
    base = autoregressive_baseline(m, params, prompt, 10, temperature=0.0)
    dec = SpecDecoder(m, m, gamma=3, temperature=0.0)
    toks, stats = dec.generate(params, params, prompt, 10)
    assert toks == base
    if cfg.family in ("ssm", "xlstm", "hybrid"):
        assert stats.replay_passes > 0           # recurrent replay accounted


def test_speculative_sample_all_accept_when_equal():
    V, gamma = 50, 5
    logits = jax.random.normal(jax.random.PRNGKey(0), (gamma + 1, V))
    toks = jax.random.randint(jax.random.PRNGKey(1), (gamma,), 0, V)
    n, _ = speculative_sample(jax.random.PRNGKey(2), logits, logits[:gamma],
                              toks, temperature=1.0)
    assert int(n) == gamma                        # p==q -> ratio 1 -> accept


def test_distribution_preservation():
    """Theorem (Leviathan et al.): when the draft token is SAMPLED from q,
    the emitted token is distributed exactly as p.  Empirical check on a
    5-token vocab."""
    V = 5
    key = jax.random.PRNGKey(0)
    t_logits = jnp.array([[2.0, 1.0, 0.0, -1.0, 0.5],
                          [0.3, 0.1, -0.5, 1.0, 0.0]])
    d_logits = jnp.array([[0.0, 1.5, 0.2, -0.5, 0.1]])

    def trial(k):
        k_draft, k_ver = jax.random.split(k)
        tok = jax.random.categorical(k_draft, d_logits[0])[None]
        n, t = speculative_sample(k_ver, t_logits, d_logits,
                                  tok.astype(jnp.int32), temperature=1.0)
        return jnp.where(n >= 1, tok[0], t)

    trials = 8000
    firsts = jax.vmap(trial)(jax.random.split(key, trials))
    emp = np.bincount(np.asarray(firsts), minlength=V) / trials
    target = np.asarray(jax.nn.softmax(t_logits[0]))
    assert np.max(np.abs(emp - target)) < 0.025   # ~4.5 sigma at 8000 trials


def test_acceptance_bound():
    p = jnp.array([0.5, 0.3, 0.2])
    q = jnp.array([0.2, 0.5, 0.3])
    assert abs(float(acceptance_rate_bound(p, q)) - (0.2 + 0.3 + 0.2)) < 1e-6


def test_adaptive_gamma():
    g = AdaptiveGamma(gamma=4, lo=1, hi=8)
    assert g.update(4, 4) == 5                   # high acceptance -> longer
    assert g.update(0, 5) == 4                   # rejections -> shorter
