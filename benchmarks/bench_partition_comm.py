"""Split inference + communication optimization benchmark (survey §2.2.2 and
§2.2.4 / Table 4): wire bytes vs output fidelity per boundary compressor,
and the hybrid cost model's optimal branch points per architecture."""
from __future__ import annotations

import jax

from repro.configs import get_config
from repro.core.compression import (Identity, Int4Quantizer, Int8Quantizer,
                                    TopKSparsifier, entropy_bits_estimate,
                                    relative_error)
from repro.core.partition import SplitCostModel, split_inference
from repro.models import Model, example_batch


def run(csv=print):
    cfg = get_config("smollm-135m").reduced()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = example_batch(cfg, 2, 24, with_labels=False)
    full, _ = m.forward(params, batch)

    for comp in (Identity(), Int8Quantizer(), Int4Quantizer(),
                 TopKSparsifier(frac=0.1)):
        lg, wire = split_inference(m, params, batch, k=1, compressor=comp)
        err = relative_error(full, lg)
        csv(f"split_wire_bytes,{comp.name},{wire}")
        csv(f"split_logit_rel_err,{comp.name},{err:.5f}")

    # entropy bound for the int8 boundary (survey's entropy-coding headroom)
    from repro.core.partition import edge_forward
    h = edge_forward(params, batch["tokens"], cfg, 1)
    q = Int8Quantizer().compress(h)
    bits = entropy_bits_estimate(q.payload["q"])
    csv(f"split_boundary_entropy_bits_per_elem,int8,{bits:.3f}")

    cm = SplitCostModel()
    for arch in ("smollm-135m", "granite-8b", "granite-20b"):
        k, _ = cm.best_split(get_config(arch), tokens=128)
        csv(f"split_best_branch_layer,{arch},{k}")


if __name__ == "__main__":
    run()
