"""Benchmark harness — one module per survey table/claim (see DESIGN.md §7).

Prints ``name,case,value`` CSV rows.  Run:

    PYTHONPATH=src python -m benchmarks.run [--only speculative]
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (bench_collab_training, bench_early_exit,
                        bench_partition_comm, bench_routing,
                        bench_serving, bench_speculative, roofline)

SUITES = {
    "serving": bench_serving.run,                # survey §2.3 at throughput
    "speculative": bench_speculative.run,        # survey §2.4 / Table 2
    "routing": bench_routing.run,                # survey §2.1 / Table 4
    "early_exit": bench_early_exit.run,          # survey §2.2.3 / Table 4
    "partition_comm": bench_partition_comm.run,  # survey §2.2.2/.4 / Table 4
    "collab_training": bench_collab_training.run,  # survey §3 / Table 6
    "roofline": lambda csv=print: roofline.main(),  # deliverable (g)
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(SUITES))
    args = ap.parse_args()
    suites = {args.only: SUITES[args.only]} if args.only else SUITES
    print("name,case,value")
    for name, fn in suites.items():
        t0 = time.time()
        print(f"# === {name} ===", file=sys.stderr)
        fn()
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
