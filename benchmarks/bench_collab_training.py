"""Collaborative training benchmark (survey §3 / Table 6): distillation
uplift, LoRA communication savings, HETLoRA aggregation, quantization and
pruning deployment costs.

The distillation arm runs through the SERVING stack, not an oracle
``teacher_logits_fn``: a capture-only ``AdaptationLoop`` behind an
escalate-everything ``BatchedEngine`` harvests the supervision corpus —
(prompt, discarded student draft, cloud continuation, teacher top-k)
triples riding each wave's single device pull into the
``FeedbackStore`` — and the student then distills from the STORED sparse
top-k via ``FeedbackStore.sample_batch``, exactly the tensors the online
``AdaptationLoop`` trains on (``core/adaptation.py``).  The from-scratch
baseline trains on the same served corpus with CE alone, so the delta
isolates what the teacher's logits add at equal steps and equal data.

Emits ``name,case,value`` CSV rows and merges a ``collab_training`` row
set into ``BENCH_serving.json`` (pass ``rows=`` to merge in-process, or
``out=`` to read-modify-write the artifact).
"""
from __future__ import annotations

import json

import jax
import numpy as np

from repro.configs import get_config
from repro.core.adaptation import AdaptationLoop
from repro.core.policy import ThresholdPolicy
from repro.core.scheduler import BatchedEngine
from repro.data import FeedbackStore, SyntheticLM, batches, dirichlet_clients
from repro.models import Model, cross_entropy
from repro.training import AdamW, make_train_step, train
from repro.training.distillation import kd_loss
from repro.training.lora import (hetlora_aggregate, init_lora,
                                 lora_param_count)
from repro.training.pruning import magnitude_masks, sparsity_report
from repro.training.quantization import (quantization_error,
                                         quantize_params, quantized_bytes)

REQUESTS = 16
PROMPT_LEN = 12
MAX_NEW = 24


def run(csv=print, rows=None, out="BENCH_serving.json"):
    row = {}
    cfg = get_config("smollm-135m").reduced()
    teacher_m = Model(cfg)
    teacher = train(teacher_m, teacher_m.init(jax.random.PRNGKey(0)),
                    batches(cfg, 8, 48), steps=60, opt=AdamW(lr=2e-3),
                    log_every=10_000, log=lambda *_: None)["params"]

    # ---- serve-time harvest: every request escalates, so each completion
    # lands in the store as (prompt, student draft, cloud continuation,
    # teacher top-8) — the same capture path online adaptation uses
    s_cfg = cfg.replace(num_layers=1)
    s_m = Model(s_cfg)
    sp0 = s_m.init(jax.random.PRNGKey(1))
    store = FeedbackStore(capacity=4 * REQUESTS)
    harvest = AdaptationLoop(store=store, mode="distill", interval=0, topk=8)
    eng = BatchedEngine(s_m, teacher_m, batch_size=8, temperature=0.0,
                        policy=ThresholdPolicy(0.0), use_cache=False,
                        adaptation=harvest)
    synth = SyntheticLM(cfg.vocab_size)
    rng = np.random.default_rng(0)
    prompts = [synth.sample(rng, i % synth.n_domains, PROMPT_LEN)
               for i in range(REQUESTS)]
    eng.serve_batch(sp0, teacher, prompts, MAX_NEW,
                    domains=[i % synth.n_domains for i in range(REQUESTS)])
    st = store.stats()
    assert st["size"] == REQUESTS and st["by_path"].get("cloud") == REQUESTS
    csv(f"collab_harvest,records,{st['size']}")
    row["harvested_records"] = st["size"]

    # ---- distillation vs from-scratch at equal steps on the SAME served
    # corpus (Table 6 row 1): KD reads the stored sparse teacher top-k
    evalb = next(batches(cfg, 8, 48, seed=50))

    def final_ce(loss_fn, topk):
        opt = AdamW(lr=2e-3)
        p = s_m.init(jax.random.PRNGKey(1))
        stt = opt.init(p)
        step = make_train_step(s_m, opt, loss_fn=loss_fn, donate=False)
        r = np.random.default_rng(3)
        for _ in range(40):
            b = store.sample_batch(r, 8, PROMPT_LEN + MAX_NEW,
                                   cfg.vocab_size, topk=topk)
            p, stt, _ = step(p, stt, b)
        lg, _ = s_m.forward(p, evalb)
        return float(cross_entropy(lg[:, :-1], evalb["labels"][:, 1:]))

    ce_scratch = final_ce(None, 0)
    ce_kd = final_ce(
        lambda p, b: kd_loss(s_m, p, b, b["teacher_logits"], alpha=0.5,
                             kd_mask=b["kd_mask"]), 8)
    csv(f"distill_student_ce,scratch,{ce_scratch:.4f}")
    csv(f"distill_student_ce,kd,{ce_kd:.4f}")
    row["student_ce_scratch"] = ce_scratch
    row["student_ce_kd"] = ce_kd

    # ---- LoRA: trainable/communicated params vs full fine-tune (§3.4)
    ad = init_lora(jax.random.PRNGKey(2), teacher, rank=4)
    full_params = sum(x.size for x in jax.tree.leaves(teacher))
    lora_ratio = lora_param_count(ad) / full_params
    csv(f"lora_comm_ratio,rank4,{lora_ratio:.5f}")
    row["lora_comm_ratio_rank4"] = lora_ratio
    clients = [init_lora(jax.random.PRNGKey(10 + i), teacher, rank=r)
               for i, r in enumerate((2, 4, 8))]
    agg = hetlora_aggregate(clients, max_rank=8)
    agg_rank = int(agg[next(iter(agg))]["A"].shape[-2])
    csv(f"hetlora_agg_rank,max,{agg_rank}")
    row["hetlora_agg_rank"] = agg_rank

    # ---- deployment costs (§3.1)
    qp = quantize_params(teacher)
    err = quantization_error(teacher, qp)["mean_rel_err"]
    bytes_ratio = quantized_bytes(qp) / (full_params * 4)
    csv(f"quant_int8_rel_err,mean,{err:.5f}")
    csv(f"quant_bytes_ratio,int8,{bytes_ratio:.3f}")
    rep = sparsity_report(magnitude_masks(teacher, 0.5))
    csv(f"prune_kept_frac,sparsity0.5,{rep['kept_frac']:.3f}")
    row["quant_int8_rel_err"] = float(err)
    row["quant_bytes_ratio"] = float(bytes_ratio)
    row["prune_kept_frac"] = float(rep["kept_frac"])

    # ---- non-IID heterogeneity measure (§4 datasets)
    from repro.data.pipeline import client_divergence
    row["fed_client_divergence"] = {}
    for alpha in (0.1, 1.0, 10.0):
        w = dirichlet_clients(8, 4, alpha=alpha)
        div = float(client_divergence(w))
        csv(f"fed_client_divergence,alpha={alpha},{div:.3f}")
        row["fed_client_divergence"][str(alpha)] = div

    if rows is not None:
        rows["collab_training"] = row
    elif out:
        try:
            with open(out) as f:
                existing = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            existing = {}
        existing["collab_training"] = row
        with open(out, "w") as f:
            json.dump(existing, f, indent=2)
    return row


if __name__ == "__main__":
    run()
