"""Collaborative training benchmark (survey §3 / Table 6): distillation
uplift, LoRA communication savings, HETLoRA aggregation, quantization and
pruning deployment costs."""
from __future__ import annotations

import jax

from repro.configs import get_config
from repro.data import batches, dirichlet_clients
from repro.models import Model, cross_entropy
from repro.training import AdamW, make_train_step, train
from repro.training.distillation import kd_loss, teacher_logits_fn
from repro.training.lora import (hetlora_aggregate, init_lora,
                                 lora_param_count)
from repro.training.pruning import magnitude_masks, sparsity_report
from repro.training.quantization import (quantization_error,
                                         quantize_params, quantized_bytes)


def run(csv=print):
    cfg = get_config("smollm-135m").reduced()
    teacher_m = Model(cfg)
    teacher = train(teacher_m, teacher_m.init(jax.random.PRNGKey(0)),
                    batches(cfg, 8, 48), steps=60, opt=AdamW(lr=2e-3),
                    log_every=10_000, log=lambda *_: None)["params"]
    tlf = teacher_logits_fn(teacher_m, teacher)

    # ---- distillation vs from-scratch at equal steps (Table 6 row 1)
    s_cfg = cfg.replace(num_layers=1)
    s_m = Model(s_cfg)
    evalb = next(batches(cfg, 8, 48, seed=50))

    def final_ce(loss_fn):
        opt = AdamW(lr=2e-3)
        p = s_m.init(jax.random.PRNGKey(1))
        st = opt.init(p)
        step = make_train_step(s_m, opt, loss_fn=loss_fn, donate=False)
        it = batches(cfg, 8, 48)
        for _ in range(40):
            p, st, _ = step(p, st, next(it))
        lg, _ = s_m.forward(p, evalb)
        return float(cross_entropy(lg[:, :-1], evalb["labels"][:, 1:]))

    ce_scratch = final_ce(None)
    ce_kd = final_ce(lambda p, b: kd_loss(s_m, p, b, tlf(b), alpha=0.5))
    csv(f"distill_student_ce,scratch,{ce_scratch:.4f}")
    csv(f"distill_student_ce,kd,{ce_kd:.4f}")

    # ---- LoRA: trainable/communicated params vs full fine-tune (§3.4)
    ad = init_lora(jax.random.PRNGKey(2), teacher, rank=4)
    full_params = sum(x.size for x in jax.tree.leaves(teacher))
    csv(f"lora_comm_ratio,rank4,{lora_param_count(ad)/full_params:.5f}")
    clients = [init_lora(jax.random.PRNGKey(10 + i), teacher, rank=r)
               for i, r in enumerate((2, 4, 8))]
    agg = hetlora_aggregate(clients, max_rank=8)
    csv(f"hetlora_agg_rank,max,{agg[next(iter(agg))]['A'].shape[-2]}")

    # ---- deployment costs (§3.1)
    qp = quantize_params(teacher)
    err = quantization_error(teacher, qp)["mean_rel_err"]
    csv(f"quant_int8_rel_err,mean,{err:.5f}")
    csv(f"quant_bytes_ratio,int8,{quantized_bytes(qp)/(full_params*4):.3f}")
    rep = sparsity_report(magnitude_masks(teacher, 0.5))
    csv(f"prune_kept_frac,sparsity0.5,{rep['kept_frac']:.3f}")

    # ---- non-IID heterogeneity measure (§4 datasets)
    from repro.data.pipeline import client_divergence
    for alpha in (0.1, 1.0, 10.0):
        w = dirichlet_clients(8, 4, alpha=alpha)
        csv(f"fed_client_divergence,alpha={alpha},{client_divergence(w):.3f}")


if __name__ == "__main__":
    run()
