"""Serving-scheduler benchmark: per-request vs batched continuous batching.

The ROADMAP's throughput claim lives or dies on the serving loop, not the
kernels: the per-request engine pays a host round-trip per decoded token,
the batched scheduler pays one per ``tick_tokens`` x ``batch_size`` tokens.
This bench measures requests/sec and tokens/sec for both schedulers over
mixed-uncertainty traffic on reduced configs, across three regimes:

  * edge        — every request confident (escalation never fires)
  * mixed       — threshold at the median request uncertainty (~half the
                  slots retire into a grouped escalation each drain)
  * escalate    — every request escalates (speculative)

Emits ``serving_<regime>,<scheduler>,<req/s>`` rows plus a
``serving_speedup_<regime>`` row (batched / per-request).  Acceptance
target: >= 3x req/s for the batched scheduler at batch size 16 on the edge
regime.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.engine import CollaborativeEngine
from repro.core.scheduler import BatchedEngine
from repro.data import SyntheticLM
from repro.models import Model

REQUESTS = 32
PROMPT_LEN = 16
MAX_NEW = 24
BATCH = 16


def _setup():
    e_cfg = get_config("smollm-135m").reduced()
    c_cfg = get_config("granite-8b").reduced().replace(
        vocab_size=e_cfg.vocab_size)
    edge, cloud = Model(e_cfg), Model(c_cfg)
    ep = edge.init(jax.random.PRNGKey(0))
    cp = cloud.init(jax.random.PRNGKey(1))
    synth = SyntheticLM(e_cfg.vocab_size)
    rng = np.random.default_rng(0)
    prompts = [synth.sample(rng, i % synth.n_domains, PROMPT_LEN)
               for i in range(REQUESTS)]
    return edge, ep, cloud, cp, prompts


def _per_request(edge, cloud, ep, cp, prompts, threshold):
    eng = CollaborativeEngine(edge, cloud, temperature=0.0,
                              escalate_threshold=threshold, use_cache=False)
    eng.serve_reference(ep, cp, prompts[0], MAX_NEW)      # warm the jits
    t0 = time.time()
    traces = [eng.serve_reference(ep, cp, p, MAX_NEW) for p in prompts]
    return time.time() - t0, traces


def _batched(edge, cloud, ep, cp, prompts, threshold):
    eng = BatchedEngine(edge, cloud, batch_size=BATCH, temperature=0.0,
                        escalate_threshold=threshold, use_cache=False)
    eng.serve_batch(ep, cp, prompts[:BATCH], MAX_NEW)     # warm the jits
    t0 = time.time()
    traces = eng.serve_batch(ep, cp, prompts, MAX_NEW)
    return time.time() - t0, traces


def run(csv=print):
    edge, ep, cloud, cp, prompts = _setup()

    # probe per-request uncertainties once to place the mixed threshold
    probe = CollaborativeEngine(edge, cloud, temperature=0.0,
                                escalate_threshold=1.1, use_cache=False)
    uncs = [probe.serve_reference(ep, cp, p, MAX_NEW).uncertainty
            for p in prompts]
    regimes = {
        "edge": 1.1,
        "mixed": float(np.median(uncs)),
        "escalate": -1.0,
    }

    for regime, threshold in regimes.items():
        dt_ref, tr_ref = _per_request(edge, cloud, ep, cp, prompts, threshold)
        dt_bat, tr_bat = _batched(edge, cloud, ep, cp, prompts, threshold)
        esc = sum(t.path != "edge" for t in tr_bat)
        assert [t.path for t in tr_bat] == [t.path for t in tr_ref]
        csv(f"serving_{regime},per_request_req_s,{REQUESTS / dt_ref:.3f}")
        csv(f"serving_{regime},batched{BATCH}_req_s,{REQUESTS / dt_bat:.3f}")
        csv(f"serving_{regime},per_request_tok_s,"
            f"{REQUESTS * MAX_NEW / dt_ref:.1f}")
        csv(f"serving_{regime},batched{BATCH}_tok_s,"
            f"{REQUESTS * MAX_NEW / dt_bat:.1f}")
        csv(f"serving_speedup_{regime},batched{BATCH}_vs_per_request,"
            f"{dt_ref / dt_bat:.2f}")
        csv(f"serving_{regime},escalated,{esc}")


if __name__ == "__main__":
    print("name,case,value")
    run()
