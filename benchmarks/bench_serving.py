"""Serving-scheduler benchmark: per-request vs batched continuous batching,
dense vs paged KV layout, and recurrent/mixed-family batched speculation.

The ROADMAP's throughput claim lives or dies on the serving loop, not the
kernels: the per-request engine pays a host round-trip per decoded token,
the batched scheduler pays one per ``tick_tokens`` x ``batch_size`` tokens.
This bench measures requests/sec and tokens/sec for both schedulers over
mixed-uncertainty traffic on reduced configs, across three regimes:

  * edge        — every request confident (escalation never fires)
  * mixed       — threshold at the median request uncertainty (~half the
                  slots retire into a grouped escalation each drain)
  * escalate    — every request escalates (speculative)

The PAGED-vs-DENSE arm runs the batched scheduler over a skewed
prompt-length mix (one 4x-length outlier per batch): dense pads every slot
to the outlier, the paged layout (``core/paged_cache.py``) backs each
request with exactly the blocks it touches.  It reports req/s and PEAK KV
CACHE BYTES for both layouts, asserts token-for-token parity, and asserts
the paged peak is strictly below dense.

The SHARED-PREFIX arm serves a mix whose prompts share an 80% common
prefix (the agentic/system-prompt regime): the paged layout's prefix-block
index maps the shared blocks physically (refcounts + copy-on-write,
``core/paged_cache.py``), so peak live KV sits several times below dense.
Reports ``kv_savings_x`` (>= 3x target) plus sharing/CoW counters, and
asserts token parity.

The OVERCOMMIT arm caps the block pool at HALF the batch's reservations
(2x overcommit): admission proceeds by preemption-by-swap (victim blocks
staged to a host buffer and restored bit-for-bit) instead of deferring, so
every request completes — ``deferred_forever`` must be 0 — at dense token
parity.

The POLICY arm compares the shipped ``CollabPolicy`` implementations
(threshold vs cascade vs bandit, ``core/policy.py``) at fixed traffic —
per-policy req/s, cloud-token share, quality proxy.  ``cloud_token_share``
counts tokens the cloud SCORES over the tokens requested: speculative
verification scores gamma+1 per pass, so it is a cost RATIO that can
exceed 1.0, not a fraction of output.  The arm then checks the ONLINE
ADAPTATION the policy API unlocks: a UCB ``BanditPolicy`` served an
easy-prompt stream in segments must learn to stop escalating (its
cloud-token share strictly decreases from the first segment to the last).

The OPEN-LOOP arm stops pretending every request is already queued at
t=0: requests are submitted at sampled arrival times (Poisson, and an
on/off bursty trace whose bursts overcommit a half-sized paged pool 2x)
against the deterministic virtual clock in ``core/traffic.py``, with
chunked prefill interleaving prompt processing and decode.  It reports
the latency-honest serving numbers — p50/p99 TTFT measured from SUBMIT
(queueing delay counts), p50/p99 TPOT, SLO attainment and
goodput-under-SLO — and asserts the bursty overcommitted trace still
completes every request (zero permanent deferrals) at a bounded p99
TTFT.  Virtual-clock determinism is what makes those latency asserts
CI-stable.

The TREE-SPECULATION arm races the batched speculation lanes at MATCHED
VERIFY BUDGET (both lanes verify 16 positions per target pass): a packed
token tree (branching (2,2,1,1), ``BatchedSpecDecoder`` mode="tree")
against a depth-15 linear chain, plus an equal-depth gamma=4 chain as an
informational reference and the self-speculative lane (the drafter's own
early-exit head, zero second-model params).  All lanes must be
token-identical to the greedy non-speculative baseline; the tree must
retire the stream in no more verify rounds — and at least the req/s — of
the matched-budget chain, with accepted-tokens-per-step > 1.5.

The COMPILE-STABILITY arm re-serves an identical drain through a warmed
engine under ``jax.log_compiles``: the cold drain's compile count is
reported as ``decode_compiles``, and the steady-state drain must trigger
ZERO further compilations (``steady_state_recompiles == 0``) — the
runtime complement of repro-lint's static recompile-hazard rule (R2),
gated by ``scripts/check_bench.py``.

The ONLINE-ADAPTATION arm closes the serve->train->serve loop
(``core/adaptation.py``): a stationary ``SyntheticLM`` stream behind a
``ThresholdPolicy`` placed so the random-init edge escalates ~3/4 of the
first segment; ``_finish`` captures each escalation's (prompt, discarded
edge draft, cloud continuation, teacher top-k) triple into the
``FeedbackStore``, and every segment's worth of observations triggers a
distillation update whose result is hot-swapped into the live engine
between ticks.  Asserts cloud-token share in the last third of the run
is below the first third, edge acceptance rises, and — under
``CompileCounter`` with at least one hot-swap inside the counted
window — ``steady_state_recompiles == 0``.  Gated by
``scripts/check_bench.py``.

The RECURRENT arm runs mixed-family speculative escalation — mamba2 (ssm)
and zamba2 (hybrid) drafts against a granite (transformer) cloud — where
the batched scheduler's rewind is a replayed state select
(``Model.replay_step`` via ``core/seq_state.py``) instead of the reference
engine's per-request snapshot+replay.  It asserts token parity against
``serve_reference`` and reports the batched-vs-per-request speedup per
draft family.

Emits ``name,case,value`` CSV rows on stdout and writes the full result
set as JSON (``--out``, default ``BENCH_serving.json``) — the artifact the
CI ``bench-smoke`` job uploads per-commit so the perf trajectory is
trackable.  ``--smoke`` shrinks the workload to a CI-sized config and
skips the slow per-request baseline regimes (the paged-vs-dense arm always
runs).

Acceptance targets: >= 3x req/s for the batched scheduler at batch 16 on
the edge regime (full mode); paged peak KV bytes strictly below dense with
req/s within 10% on the skewed mix.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.engine import CollaborativeEngine
from repro.core.policy import (BanditPolicy, CascadePolicy,
                               SpeculativePolicy, ThresholdPolicy,
                               cloud_tokens, trace_quality)
from repro.core.scheduler import BatchedEngine
from repro.core.traffic import (VirtualClock, bursty_arrivals,
                                poisson_arrivals, replay)
from repro.data import SyntheticLM
from repro.models import Model

REQUESTS = 32
PROMPT_LEN = 16
MAX_NEW = 24
BATCH = 16


def _setup():
    e_cfg = get_config("smollm-135m").reduced()
    c_cfg = get_config("granite-8b").reduced().replace(
        vocab_size=e_cfg.vocab_size)
    edge, cloud = Model(e_cfg), Model(c_cfg)
    ep = edge.init(jax.random.PRNGKey(0))
    cp = cloud.init(jax.random.PRNGKey(1))
    synth = SyntheticLM(e_cfg.vocab_size)
    rng = np.random.default_rng(0)
    prompts = [synth.sample(rng, i % synth.n_domains, PROMPT_LEN)
               for i in range(REQUESTS)]
    return edge, ep, cloud, cp, prompts


def _per_request(edge, cloud, ep, cp, prompts, threshold):
    eng = CollaborativeEngine(edge, cloud, temperature=0.0,
                              policy=SpeculativePolicy(threshold),
                              use_cache=False)
    eng.serve_reference(ep, cp, prompts[0], MAX_NEW)      # warm the jits
    t0 = time.perf_counter()
    traces = [eng.serve_reference(ep, cp, p, MAX_NEW) for p in prompts]
    jax.block_until_ready(traces[-1].tokens)
    return time.perf_counter() - t0, traces


def _batched(edge, cloud, ep, cp, prompts, threshold, **kw):
    kw.setdefault("policy", SpeculativePolicy(threshold))
    eng = BatchedEngine(edge, cloud, batch_size=BATCH, temperature=0.0,
                        use_cache=False, **kw)
    eng.serve_batch(ep, cp, prompts[:BATCH], MAX_NEW)     # warm the jits
    t0 = time.perf_counter()
    traces = eng.serve_batch(ep, cp, prompts, MAX_NEW)
    jax.block_until_ready(traces[-1].tokens)
    return time.perf_counter() - t0, traces, eng.stats()


def _scheduler_regimes(edge, ep, cloud, cp, prompts, csv, rows):
    """Per-request vs batched req/s across the three uncertainty regimes."""
    # probe per-request uncertainties once to place the mixed threshold
    probe = CollaborativeEngine(edge, cloud, temperature=0.0,
                                policy=SpeculativePolicy(1.1),
                                use_cache=False)
    uncs = [probe.serve_reference(ep, cp, p, MAX_NEW).uncertainty
            for p in prompts]
    regimes = {
        "edge": 1.1,
        "mixed": float(np.median(uncs)),
        "escalate": -1.0,
    }

    for regime, threshold in regimes.items():
        dt_ref, tr_ref = _per_request(edge, cloud, ep, cp, prompts, threshold)
        dt_bat, tr_bat, _ = _batched(edge, cloud, ep, cp, prompts, threshold)
        esc = sum(t.path != "edge" for t in tr_bat)
        assert [t.path for t in tr_bat] == [t.path for t in tr_ref]
        n = len(prompts)
        rows[f"serving_{regime}"] = {
            "per_request_req_s": n / dt_ref,
            f"batched{BATCH}_req_s": n / dt_bat,
            "speedup": dt_ref / dt_bat,
            "escalated": esc,
        }
        csv(f"serving_{regime},per_request_req_s,{n / dt_ref:.3f}")
        csv(f"serving_{regime},batched{BATCH}_req_s,{n / dt_bat:.3f}")
        csv(f"serving_{regime},per_request_tok_s,{n * MAX_NEW / dt_ref:.1f}")
        csv(f"serving_{regime},batched{BATCH}_tok_s,{n * MAX_NEW / dt_bat:.1f}")
        csv(f"serving_speedup_{regime},batched{BATCH}_vs_per_request,"
            f"{dt_ref / dt_bat:.2f}")
        csv(f"serving_{regime},escalated,{esc}")


def _paged_vs_dense(edge, ep, cloud, cp, csv, rows):
    """Skewed prompt-length mix (one 4x outlier per batch): paged must
    match dense token-for-token at a strictly smaller peak KV footprint."""
    synth = SyntheticLM(edge.cfg.vocab_size)
    rng = np.random.default_rng(1)
    prompts = [synth.sample(rng, i % synth.n_domains,
                            4 * PROMPT_LEN if i % BATCH == 0 else PROMPT_LEN)
               for i in range(REQUESTS)]
    arms = {}
    for layout in ("dense", "paged"):
        dt, traces, stats = _batched(edge, cloud, ep, cp, prompts, 1.1,
                                     kv_layout=layout)
        arms[layout] = (traces, stats)
        rows.setdefault("paged_vs_dense", {})[layout] = {
            "req_s": len(prompts) / dt,
            "kv_peak_bytes": stats["kv_peak_bytes"],
            "kv_capacity_bytes": stats["kv_capacity_bytes"],
        }
        csv(f"serving_skewed,{layout}_req_s,{len(prompts) / dt:.3f}")
        csv(f"serving_skewed,{layout}_kv_peak_mb,"
            f"{stats['kv_peak_bytes'] / 1e6:.3f}")
    (d_tr, d_stats), (p_tr, p_stats) = arms["dense"], arms["paged"]
    assert all(dt.tokens == pt.tokens for dt, pt in zip(d_tr, p_tr)), \
        "paged layout diverged from the dense parity oracle"
    assert p_stats["kv_peak_bytes"] < d_stats["kv_peak_bytes"], \
        (p_stats["kv_peak_bytes"], d_stats["kv_peak_bytes"])
    ratio = d_stats["kv_peak_bytes"] / p_stats["kv_peak_bytes"]
    rows["paged_vs_dense"]["kv_savings_x"] = ratio
    csv(f"serving_skewed,paged_kv_savings_x,{ratio:.2f}")


def _shared_prefix(edge, ep, cloud, cp, csv, rows):
    """80%-shared-prefix mix: every request carries the same long prefix
    (block-aligned) plus a short distinct tail.  The paged prefix-block
    index keeps ONE physical copy of the prefix per pool; dense pays it
    per slot.  Target: kv_savings_x >= 3 at exact token parity."""
    v = edge.cfg.vocab_size
    rng = np.random.default_rng(3)
    plen = 5 * PROMPT_LEN                       # 80% shared, 20% distinct
    pref = rng.integers(0, v, (4 * plen) // 5).astype(np.int32)
    prompts = [np.concatenate([pref,
                               rng.integers(0, v, plen - pref.size)
                               .astype(np.int32)])
               for _ in range(REQUESTS)]
    arms = {}
    for layout in ("dense", "paged"):
        dt, traces, stats = _batched(edge, cloud, ep, cp, prompts, 1.1,
                                     kv_layout=layout, kv_block_size=8)
        arms[layout] = (traces, stats)
        rows.setdefault("shared_prefix", {})[layout] = {
            "req_s": len(prompts) / dt,
            "kv_peak_bytes": stats["kv_peak_bytes"],
        }
        csv(f"serving_shared_prefix,{layout}_req_s,{len(prompts) / dt:.3f}")
        csv(f"serving_shared_prefix,{layout}_kv_peak_mb,"
            f"{stats['kv_peak_bytes'] / 1e6:.3f}")
    (d_tr, d_stats), (p_tr, p_stats) = arms["dense"], arms["paged"]
    assert all(dt.tokens == pt.tokens for dt, pt in zip(d_tr, p_tr)), \
        "prefix sharing diverged from the dense parity oracle"
    ratio = d_stats["kv_peak_bytes"] / p_stats["kv_peak_bytes"]
    rows["shared_prefix"]["kv_savings_x"] = ratio
    rows["shared_prefix"]["prefix_hits"] = p_stats["kv_prefix_hits"]
    rows["shared_prefix"]["shared_blocks"] = p_stats["kv_shared_blocks"]
    rows["shared_prefix"]["cow_forks"] = p_stats["kv_cow_forks"]
    csv(f"serving_shared_prefix,kv_savings_x,{ratio:.2f}")
    csv(f"serving_shared_prefix,shared_blocks,{p_stats['kv_shared_blocks']}")


def _overcommit(edge, ep, cloud, cp, csv, rows):
    """2x-overcommitted pool: kv_blocks holds HALF the batch's worst-case
    reservations.  Preemption-by-swap must complete every request (zero
    permanent deferrals) at dense token parity."""
    v = edge.cfg.vocab_size
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, v, PROMPT_LEN).astype(np.int32)
               for _ in range(REQUESTS)]
    bs = 8
    per_req = -(-(PROMPT_LEN - 1 + MAX_NEW) // bs)
    kv_blocks = (BATCH * per_req) // 2 + 1      # half the full residency
    dt_d, d_tr, _ = _batched(edge, cloud, ep, cp, prompts, 1.1,
                             kv_layout="dense")
    # short ticks keep several part-done requests resident, so admission
    # pressure manifests as preemption rather than same-tick turnover
    dt_p, p_tr, stats = _batched(edge, cloud, ep, cp, prompts, 1.1,
                                 kv_layout="paged", kv_block_size=bs,
                                 kv_blocks=kv_blocks, tick_tokens=4)
    assert all(dt.tokens == pt.tokens for dt, pt in zip(d_tr, p_tr)), \
        "preemption-by-swap diverged from the dense parity oracle"
    deferred_forever = len(prompts) - len(p_tr)
    rows["overcommit"] = {
        "kv_blocks": kv_blocks,
        "full_residency_blocks": BATCH * per_req,
        "completed": len(p_tr),
        "deferred_forever": deferred_forever,
        "preemptions": stats["preemptions"],
        "swaps": stats["kv_swaps"],
        "kv_blocks_peak": stats["kv_blocks_peak"],
        "req_s": len(prompts) / dt_p,
        "dense_req_s": len(prompts) / dt_d,
    }
    assert deferred_forever == 0
    assert stats["preemptions"] > 0, \
        "overcommit arm exerted no pool pressure (preemption never fired)"
    csv(f"serving_overcommit,deferred_forever,{deferred_forever}")
    csv(f"serving_overcommit,preemptions,{stats['preemptions']}")
    csv(f"serving_overcommit,paged_req_s,{len(prompts) / dt_p:.3f}")


def _open_loop(edge, ep, cloud, cp, csv, rows):
    """OPEN-LOOP arm: serving latency under arrivals instead of a drain.

    Both sub-arms run the batched scheduler against a ``VirtualClock`` —
    deterministic simulated milliseconds, so every percentile below is
    reproducible bit-for-bit and safe to assert on in CI:

      * poisson    — memoryless arrivals at ~half the batch's decode
                     capacity: moderate queueing, every request should
                     clear the (generous) TTFT SLO.
      * bursty_2x  — on/off bursts at 8x the mean rate into a paged pool
                     capped at HALF the full residency, with chunked
                     prefill (``prefill_chunk = tick_tokens = 4``): the
                     burst head fills the pool, the tail is admitted by
                     preemption-by-swap and chunk-interleaved prefill.
                     Every request must still complete — zero permanent
                     deferrals — with p99 TTFT bounded.
    """
    slo = 250.0
    # bound asserted on the bursty arm's p99 TTFT (virtual ms).  The
    # workload is deterministic (seeded arrivals, virtual clock), so this
    # is a regression tripwire an order of magnitude above the observed
    # smoke (~64ms) and full values, not a guess.
    ttft_bound = 2000.0
    rows["open_loop"] = {}

    def serve(name, at, **kw):
        synth = SyntheticLM(edge.cfg.vocab_size)
        rng = np.random.default_rng(6)
        prompts = [synth.sample(rng, i % synth.n_domains, PROMPT_LEN)
                   for i in range(len(at))]
        eng = BatchedEngine(edge, cloud, batch_size=BATCH, temperature=0.0,
                            policy=ThresholdPolicy(1.1), use_cache=False,
                            clock=VirtualClock(), slo_ms=slo, **kw)
        traces = replay(eng, ep, cp, prompts, MAX_NEW, at)
        stats = eng.stats()
        row = {k: stats[k] for k in (
            "requests", "completed", "ttft_p50_ms", "ttft_p99_ms",
            "ttft_mean_ms", "tpot_p50_ms", "tpot_p99_ms", "slo_ms",
            "slo_attainment", "goodput_slo", "makespan_ms",
            "swapped_requests", "deferred_admissions")}
        row["preemptions"] = stats.get("preemptions", 0)
        rows["open_loop"][name] = row
        for k in ("ttft_p50_ms", "ttft_p99_ms", "tpot_p50_ms",
                  "slo_attainment", "goodput_slo"):
            csv(f"open_loop_{name},{k},{row[k]:.3f}")
        assert len(traces) == len(prompts) == row["completed"], \
            f"open-loop {name}: {len(traces)}/{len(prompts)} completed"
        return row

    # half the batch's decode capacity: BATCH slots retire one request
    # per MAX_NEW decode-scan steps (step_ms = 1ms each)
    rate = 1e3 * BATCH / MAX_NEW / 2
    # short ticks resolve TPOT (a whole MAX_NEW decode inside one tick
    # would stamp first-token and retire at the same tick end)
    p = serve("poisson", poisson_arrivals(rate, REQUESTS, seed=7),
              tick_tokens=4)
    assert p["goodput_slo"] > 0, "poisson arm: nothing met the TTFT SLO"
    assert p["tpot_p50_ms"] > 0, "poisson arm: TPOT unresolved"

    bs = 8
    per_req = -(-(PROMPT_LEN - 1 + MAX_NEW) // bs)
    b = serve("bursty_2x",
              bursty_arrivals(rate, REQUESTS, seed=8, peak=8.0),
              kv_layout="paged", kv_block_size=bs,
              kv_blocks=(BATCH * per_req) // 2 + 1,
              tick_tokens=4, prefill_chunk=4)
    # transient deferrals (retried next tick) are expected under the burst;
    # permanent ones are not — serve() asserted completed == requests
    assert b["ttft_p99_ms"] <= ttft_bound, \
        f"bursty_2x p99 TTFT unbounded: {b['ttft_p99_ms']:.1f}ms"


def _recurrent_mix(cloud, cp, csv, rows):
    """Mixed-family batched speculation: recurrent drafts (mamba2 ssm +
    zamba2 hybrid) against the transformer cloud, every request escalating
    (threshold -1).  Batched rewinds are pure state selects; the
    per-request baseline pays host-side snapshot+replay per round."""
    n_req = max(REQUESTS // 4, 4)
    for arch in ("mamba2-370m", "zamba2-2.7b"):
        e_cfg = get_config(arch).reduced().replace(
            vocab_size=cloud.cfg.vocab_size)
        edge = Model(e_cfg)
        ep = edge.init(jax.random.PRNGKey(2))
        synth = SyntheticLM(e_cfg.vocab_size)
        rng = np.random.default_rng(2)
        prompts = [synth.sample(rng, i % synth.n_domains, PROMPT_LEN)
                   for i in range(n_req)]
        ref = CollaborativeEngine(edge, cloud, temperature=0.0,
                                  policy=SpeculativePolicy(-1.0),
                                  use_cache=False)
        ref.serve_reference(ep, cp, prompts[0], MAX_NEW)      # warm the jits
        t0 = time.perf_counter()
        tr_ref = [ref.serve_reference(ep, cp, p, MAX_NEW) for p in prompts]
        jax.block_until_ready(tr_ref[-1].tokens)
        dt_ref = time.perf_counter() - t0
        dt_bat, tr_bat, _ = _batched(edge, cloud, ep, cp, prompts, -1.0)
        assert all(bt.path == rt.path == "speculative"
                   for bt, rt in zip(tr_bat, tr_ref))
        assert all(bt.tokens == rt.tokens
                   for bt, rt in zip(tr_bat, tr_ref)), \
            f"batched recurrent speculation diverged from reference ({arch})"
        fam = edge.cfg.family
        rows.setdefault("serving_recurrent", {})[arch] = {
            "family": fam,
            "per_request_req_s": n_req / dt_ref,
            f"batched{BATCH}_req_s": n_req / dt_bat,
            "speedup": dt_ref / dt_bat,
        }
        csv(f"serving_recurrent_{fam},per_request_req_s,{n_req / dt_ref:.3f}")
        csv(f"serving_recurrent_{fam},batched{BATCH}_req_s,"
            f"{n_req / dt_bat:.3f}")
        csv(f"serving_recurrent_{fam},speedup,{dt_ref / dt_bat:.2f}")


def _policies(edge, ep, cloud, cp, csv, rows):
    """POLICY-COMPARISON arm: ThresholdPolicy vs CascadePolicy vs
    BanditPolicy over the same fixed mixed-uncertainty stream, each served
    cold (compile included for all three, so req/s stays comparable).
    Emits per-policy req/s, cloud-token share, and the quality proxy.

    The ADAPTATION sub-arm then drives a fresh UCB ``BanditPolicy`` over an
    easy-prompt stream (the below-median-uncertainty half) in repeated
    segments through ONE engine: completion feedback accrues across
    segments, so the learned cloud-token share must measurably DECREASE
    from the first segment to the last (the acceptance criterion the old
    string API could not even express)."""
    gamma = 4
    synth = SyntheticLM(edge.cfg.vocab_size)
    rng = np.random.default_rng(5)
    base = [synth.sample(rng, i % synth.n_domains, PROMPT_LEN)
            for i in range(REQUESTS)]
    # probe the stream's uncertainty profile through a never-escalate drain
    probe_eng = BatchedEngine(edge, cloud, batch_size=BATCH,
                              temperature=0.0, policy=ThresholdPolicy(1.1),
                              use_cache=False)
    probe = probe_eng.serve_batch(ep, cp, base, MAX_NEW)
    uncs = np.array([t.uncertainty for t in probe])
    med = float(np.median(uncs))

    policies = {
        "threshold": ThresholdPolicy(threshold=med),
        "cascade": CascadePolicy(thresholds=(med, med), relief=0.5),
        "bandit": BanditPolicy(arms=("accept", "cloud"), kind="ucb",
                               cost_weight=med + 0.25, c=0.05),
    }
    rows["policy"] = {}
    for name, pol in policies.items():
        eng = BatchedEngine(edge, cloud, batch_size=BATCH, temperature=0.0,
                            gamma=gamma, policy=pol, use_cache=False)
        t0 = time.perf_counter()
        traces = eng.serve_batch(ep, cp, base, MAX_NEW)
        jax.block_until_ready(traces[-1].tokens)
        dt = time.perf_counter() - t0
        ct = sum(cloud_tokens(t, gamma) for t in traces)
        share = ct / (len(base) * MAX_NEW)
        quality = float(np.mean([trace_quality(t, MAX_NEW)
                                 for t in traces]))
        rows["policy"][name] = {"req_s": len(base) / dt,
                                "cloud_token_share": share,
                                "quality_proxy": quality}
        csv(f"policy_{name},req_s,{len(base) / dt:.3f}")
        csv(f"policy_{name},cloud_token_share,{share:.3f}")
        csv(f"policy_{name},quality_proxy,{quality:.3f}")

    # bandit adaptation on the easy half of the stream
    order = np.argsort(uncs)
    easy = [base[i] for i in order[:max(len(base) // 2, 2)]]
    w = float(uncs[order[len(easy) - 1]]) + 0.25   # accept must beat cloud
    pol = BanditPolicy(arms=("accept", "cloud"), kind="ucb",
                       cost_weight=w, c=0.05)
    eng = BatchedEngine(edge, cloud, batch_size=BATCH, temperature=0.0,
                        gamma=gamma, policy=pol, use_cache=False)
    shares = []
    for _ in range(4):
        traces = eng.serve_batch(ep, cp, easy, MAX_NEW)
        shares.append(sum(cloud_tokens(t, gamma) for t in traces)
                      / (len(easy) * MAX_NEW))
    rows["policy"]["bandit_adaptation"] = {
        "shares": shares, "share_first": shares[0],
        "share_last": shares[-1], "cost_weight": w,
        "pulls": eng.stats()["policy_pulls"]}
    assert shares[-1] < shares[0], \
        f"bandit cloud-token share failed to adapt downward: {shares}"
    csv(f"policy_bandit_adaptation,share_first,{shares[0]:.3f}")
    csv(f"policy_bandit_adaptation,share_last,{shares[-1]:.3f}")


def _noisy_params(params, scale, seed=11):
    """Draft = verifier + scale * gaussian on every float leaf: a same-
    architecture pair whose agreement rate is a smooth function of
    ``scale`` (the knob that calibrates speculative acceptance)."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    rngs = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    out = [l + scale * jax.random.normal(r, l.shape, l.dtype)
           if jnp.issubdtype(l.dtype, jnp.floating) else l
           for l, r in zip(leaves, rngs)]
    return jax.tree_util.tree_unflatten(treedef, out)


def _tree_spec(edge, ep, cloud, cp, csv, rows):
    """TREE/SELF-SPECULATION arm: multi-token acceptance on the batched
    hot decode path (``BatchedSpecDecoder`` mode="tree"/"self").

    The tree-vs-chain comparison is run at MATCHED VERIFY BUDGET — the
    control tree-speculation papers use (SpecInfer): both lanes stage the
    same candidate budget and the target verifies the same ``n_pad = 16``
    positions per pass; the tree lane reorganizes that budget into 4
    hedged levels (branching (2,2,1,1), 15 nodes) while the chain lane
    spends it on one depth-15 tape.  A chain that deep breaks at the
    first rejection, so the tree retires the stream in deterministically
    FEWER verify rounds at equal per-round cost — the asserted req/s win.
    An equal-DEPTH gamma=4 chain is reported as an informational
    reference (``chain_depth4``): on CPU, where compute is serial, its
    3x-smaller per-round budget makes it the throughput winner; the tree
    premium is the width a parallel accelerator verifies for free.

    Both speculative lanes are exact: every lane's greedy output must be
    token-identical to the non-speculative baseline (verifier-greedy for
    tree/chain, drafter-greedy for the self lane, which verifies with the
    SAME model's full depth and loads ZERO second-model params).

    Drafter/verifier are a same-config pair (verifier params + gaussian
    noise, ``noise_scale`` picked so per-token chain acceptance sits in
    the moderate regime where hedging matters).  Asserts: token parity on
    all lanes, tree ``accepted_tokens_per_step`` > 1.5, tree rounds <=
    chain rounds, tree req/s >= chain req/s, and
    ``second_model_params == 0`` on the self lane."""
    from repro.core.speculative import autoregressive_baseline

    noise = 1e-3
    depth = 4                 # tree depth == equal-depth chain gamma
    budget_gamma = 15         # chain gamma at the tree's verify budget
    m = edge                  # same-config pair: verifier + noisy drafter
    vp = m.init(jax.random.PRNGKey(9))
    dp = _noisy_params(vp, noise)
    synth = SyntheticLM(m.cfg.vocab_size)
    rng = np.random.default_rng(9)
    prompts = [synth.sample(rng, i % synth.n_domains, PROMPT_LEN)
               for i in range(REQUESTS)]
    base_v = [autoregressive_baseline(m, vp, p, MAX_NEW, temperature=0.0)
              for p in prompts]
    base_d = [autoregressive_baseline(m, dp, p, MAX_NEW, temperature=0.0)
              for p in prompts]

    def lane(mode, gamma):
        eng = BatchedEngine(m, m, batch_size=BATCH, temperature=0.0,
                            use_cache=False, gamma=gamma,
                            policy=SpeculativePolicy(-1.0, mode=mode))
        eng.serve_batch(dp, vp, prompts[:BATCH], MAX_NEW)      # warm jits
        return eng

    lanes = {"chain": lane("linear", budget_gamma),
             "tree": lane("tree", depth),
             "chain_depth4": lane("linear", depth),
             "self": lane("self", depth)}
    assert lanes["self"].spec.second_model_params == 0
    for name, eng in lanes.items():
        traces = eng.serve_batch(dp, vp, prompts, MAX_NEW)
        oracle = base_d if name == "self" else base_v
        for t, b in zip(traces, oracle):
            assert list(t.tokens) == list(b), \
                f"{name} lane diverged from the greedy baseline"

    best = {name: float("inf") for name in lanes}
    stats = {}
    reps = 1 if rows["config"]["smoke"] else 3
    for _ in range(reps):                       # interleaved best-of-N
        for name, eng in lanes.items():
            for key in eng.spec.counters:
                eng.spec.counters[key] = 0
            t0 = time.perf_counter()
            traces = eng.serve_batch(dp, vp, prompts, MAX_NEW)
            jax.block_until_ready(traces[-1].tokens)
            best[name] = min(best[name], time.perf_counter() - t0)
            stats[name] = (eng.stats(), dict(eng.spec.counters))

    rows["tree_spec"] = {"noise_scale": noise,
                         "verify_budget": lanes["tree"].spec.plan.n_pad,
                         "lanes": {}}
    for name in lanes:
        s, c = stats[name]
        rows["tree_spec"]["lanes"][name] = {
            "req_s": REQUESTS / best[name],
            "accepted_tokens_per_step": s["accepted_tokens_per_step"],
            "accept_rate": s["spec_accept_rate"],
            "rounds": c["member_rounds"],
            "spec_mode": s["spec_mode"],
        }
        csv(f"tree_spec_{name},req_s,{REQUESTS / best[name]:.3f}")
        csv(f"tree_spec_{name},accepted_tokens_per_step,"
            f"{s['accepted_tokens_per_step']:.3f}")
    tr = rows["tree_spec"]["lanes"]["tree"]
    ch = rows["tree_spec"]["lanes"]["chain"]
    rows["tree_spec"]["tree_vs_chain_speedup"] = tr["req_s"] / ch["req_s"]
    csv(f"tree_spec,tree_vs_chain_speedup,"
        f"{tr['req_s'] / ch['req_s']:.3f}")
    assert tr["accepted_tokens_per_step"] > 1.5, tr
    assert tr["rounds"] <= ch["rounds"], (tr["rounds"], ch["rounds"])
    assert tr["req_s"] >= ch["req_s"], \
        f"tree lane slower than the matched-budget chain: {tr} vs {ch}"


def _compile_stability(edge, ep, cloud, cp, csv, rows):
    """COMPILE-STABILITY arm: the runtime complement of repro-lint's static
    R2 rule.  Two identical drains through ONE engine under
    ``jax.log_compiles`` (``repro.analysis.compile_guard.CompileCounter``):
    the first (cold) drain is allowed to compile — that count is reported
    as ``decode_compiles``, the size of the steady compile set — but the
    second drain re-serves the SAME shapes through the SAME engine, so any
    compilation it triggers is a recompile leaking into steady state
    (a traced-value branch, an unhashable static, an unbucketed shape).
    ``steady_state_recompiles`` must be 0; the offending jit names are
    carried in ``recompile_events`` so a regression names its culprit.
    Every request escalates (threshold -1) so the speculative group path
    compiles too, and token parity across the two drains is asserted."""
    from repro.analysis.compile_guard import CompileCounter

    synth = SyntheticLM(edge.cfg.vocab_size)
    rng = np.random.default_rng(10)
    prompts = [synth.sample(rng, i % synth.n_domains, PROMPT_LEN)
               for i in range(REQUESTS)]
    eng = BatchedEngine(edge, cloud, batch_size=BATCH, temperature=0.0,
                        policy=SpeculativePolicy(-1.0), use_cache=False)
    with CompileCounter() as cold:
        tr_cold = eng.serve_batch(ep, cp, prompts, MAX_NEW)
    with CompileCounter() as steady:
        tr_steady = eng.serve_batch(ep, cp, prompts, MAX_NEW)
    assert all(a.tokens == b.tokens for a, b in zip(tr_cold, tr_steady)), \
        "steady-state drain diverged from the cold drain"
    rows["compile_stability"] = {
        "decode_compiles": cold.count,
        "steady_state_recompiles": steady.count,
        "recompile_events": steady.events,
    }
    csv(f"compile_stability,decode_compiles,{cold.count}")
    csv(f"compile_stability,steady_state_recompiles,{steady.count}")
    assert cold.count > 0, \
        "log_compiles saw no cold-drain compilation (counter broken?)"
    assert steady.count == 0, \
        f"steady-state recompiles: {steady.events}"


def _online_adaptation(edge, ep, cloud, cp, csv, rows):
    """ONLINE-ADAPTATION arm: serve-time feedback -> background
    distillation -> hot-swapped edge weights (``core/adaptation.py``),
    measured end to end.  A stationary stream is served in segments
    through ONE engine whose ``ThresholdPolicy`` gate sits at the
    25th-percentile probe uncertainty, so the random-init edge escalates
    ~3/4 of the cold segment; every escalation's cloud pass captures the
    corrected continuation plus teacher top-k (riding the wave's existing
    device pull), and one distillation update lands per segment.  As the
    edge sharpens on its own traffic, escalations — and with them the
    cloud-token share — must fall between the first and last third while
    edge acceptance rises.  The LAST segment runs under ``CompileCounter``
    with at least one hot-swap inside the counted window: the swap is a
    pure pytree exchange, so ``steady_state_recompiles`` must be 0."""
    from repro.analysis.compile_guard import CompileCounter
    from repro.core.adaptation import AdaptationLoop
    from repro.training.optimizer import AdamW

    gamma = 4
    segments = 9
    synth = SyntheticLM(edge.cfg.vocab_size)
    rng = np.random.default_rng(21)
    prompts = [synth.sample(rng, i % synth.n_domains, PROMPT_LEN)
               for i in range(REQUESTS)]
    domains = [i % synth.n_domains for i in range(REQUESTS)]

    # place the gate from a never-escalate probe of the same stream
    probe = BatchedEngine(edge, cloud, batch_size=BATCH, temperature=0.0,
                          policy=ThresholdPolicy(1.1), use_cache=False)
    uncs = np.array([t.uncertainty
                     for t in probe.serve_batch(ep, cp, prompts, MAX_NEW)])
    thr = float(np.quantile(uncs, 0.25))

    adapt = AdaptationLoop(mode="distill", interval=REQUESTS, batch_size=8,
                           seq_len=PROMPT_LEN + MAX_NEW, topk=8,
                           steps_per_update=8, opt=AdamW(lr=1e-3),
                           min_records=4)
    eng = BatchedEngine(edge, cloud, batch_size=BATCH, temperature=0.0,
                        policy=ThresholdPolicy(thr), use_cache=False,
                        adaptation=adapt)
    shares, accepts = [], []
    steady_recompiles = steady_swaps = -1
    t0 = time.perf_counter()
    for s in range(segments):
        if s == segments - 1:
            # steady window: pending update from the previous segment's
            # observations lands HERE, so the counter brackets >= 1 swap
            swaps_before = adapt.swaps
            with CompileCounter() as steady:
                traces = eng.serve_batch(ep, cp, prompts, MAX_NEW,
                                         domains=domains)
            steady_recompiles = steady.count
            steady_events = steady.events
            steady_swaps = adapt.swaps - swaps_before
        else:
            traces = eng.serve_batch(ep, cp, prompts, MAX_NEW,
                                     domains=domains)
        shares.append(sum(cloud_tokens(t, gamma) for t in traces)
                      / (REQUESTS * MAX_NEW))
        accepts.append(sum(t.path == "edge" for t in traces) / REQUESTS)
    dt = time.perf_counter() - t0

    third = max(1, segments // 3)
    share_first = float(np.mean(shares[:third]))
    share_last = float(np.mean(shares[-third:]))
    accept_first = float(np.mean(accepts[:third]))
    accept_last = float(np.mean(accepts[-third:]))
    st = adapt.stats()
    rows["online_adaptation"] = {
        "threshold": thr,
        "segments": segments,
        "req_s": segments * REQUESTS / dt,
        "cloud_share_first_third": share_first,
        "cloud_share_last_third": share_last,
        "accept_first_third": accept_first,
        "accept_last_third": accept_last,
        "swaps": st["swaps"],
        "train_steps": st["train_steps"],
        "last_loss": st["last_loss"],
        "store_size": st["store_size"],
        "steady_state_recompiles": steady_recompiles,
        "steady_swaps": steady_swaps,
    }
    csv(f"online_adaptation,cloud_share_first_third,{share_first:.3f}")
    csv(f"online_adaptation,cloud_share_last_third,{share_last:.3f}")
    csv(f"online_adaptation,accept_first_third,{accept_first:.3f}")
    csv(f"online_adaptation,accept_last_third,{accept_last:.3f}")
    csv(f"online_adaptation,swaps,{st['swaps']}")
    csv(f"online_adaptation,steady_state_recompiles,{steady_recompiles}")
    assert share_last < share_first, (shares, "cloud share did not fall")
    assert accept_last > accept_first, (accepts, "acceptance did not rise")
    assert steady_swaps >= 1, "no hot-swap inside the counted window"
    assert steady_recompiles == 0, \
        f"recompiles across a hot-swap: {steady_events}"


def _multi_device(edge, ep, cloud, cp, csv, rows):
    """SHARDED-SERVING arm: the batched scheduler on a simulated (2, 4)
    host mesh — cloud verifier tensor-parallel over 'model', edge drafts
    data-parallel over 'data', per-shard paged pools — against the
    single-device engine on the same every-request-escalates stream.
    Token parity must be exact, and the sharded pool's usable capacity
    (``kv_capacity_blocks``) must scale with the shard count at the same
    per-device byte budget.  Skipped (with a ``skipped`` row, so
    ``scripts/check_bench.py --require-multi-device`` can tell absence
    from failure) unless the process was started with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``."""
    if jax.device_count() < 8:
        rows["multi_device"] = {
            "skipped": "needs 8 devices (set XLA_FLAGS="
                       "--xla_force_host_platform_device_count=8 before "
                       f"process start), have {jax.device_count()}"}
        csv("serving_multi_device,skipped,1")
        return
    from repro.launch.mesh import make_host_mesh
    synth = SyntheticLM(edge.cfg.vocab_size)
    rng = np.random.default_rng(7)
    prompts = [synth.sample(rng, i % synth.n_domains, PROMPT_LEN)
               for i in range(REQUESTS)]
    arms = {}
    for name, mesh in (("single", None), ("mesh", make_host_mesh(2, 4))):
        dt, traces, stats = _batched(edge, cloud, ep, cp, prompts, -1.0,
                                     kv_layout="paged", mesh=mesh)
        arms[name] = (dt, traces, stats)
    (dt_s, tr_s, st_s), (dt_m, tr_m, st_m) = arms["single"], arms["mesh"]
    assert all(a.tokens == b.tokens for a, b in zip(tr_s, tr_m)), \
        "mesh engine diverged from the single-device engine"
    scale = st_m["kv_capacity_blocks"] / st_s["kv_capacity_blocks"]
    assert st_m["kv_shards"] > 1, st_m["kv_shards"]
    assert scale > 1.0, (st_s["kv_capacity_blocks"],
                         st_m["kv_capacity_blocks"])
    rows["multi_device"] = {
        "mesh_shape": st_m["mesh_shape"],
        "mesh_devices": st_m["mesh_devices"],
        "single_req_s": len(prompts) / dt_s,
        "mesh_req_s": len(prompts) / dt_m,
        "kv_shards": st_m["kv_shards"],
        "single_kv_capacity_blocks": st_s["kv_capacity_blocks"],
        "mesh_kv_capacity_blocks": st_m["kv_capacity_blocks"],
        "kv_capacity_scale_x": scale,
        "token_parity": True,
    }
    csv(f"serving_multi_device,single_req_s,{len(prompts) / dt_s:.3f}")
    csv(f"serving_multi_device,mesh_req_s,{len(prompts) / dt_m:.3f}")
    csv(f"serving_multi_device,kv_shards,{st_m['kv_shards']}")
    csv(f"serving_multi_device,kv_capacity_scale_x,{scale:.2f}")


def run(csv=print, smoke: bool = False, out: str = "BENCH_serving.json"):
    global REQUESTS, MAX_NEW, BATCH
    saved = (REQUESTS, MAX_NEW, BATCH)
    if smoke:
        REQUESTS, MAX_NEW, BATCH = 8, 8, 4
    try:
        edge, ep, cloud, cp, prompts = _setup()
        rows: dict = {"config": {"requests": REQUESTS,
                                 "prompt_len": PROMPT_LEN,
                                 "max_new": MAX_NEW, "batch": BATCH,
                                 "smoke": smoke}}
        if not smoke:
            _scheduler_regimes(edge, ep, cloud, cp, prompts, csv, rows)
        _paged_vs_dense(edge, ep, cloud, cp, csv, rows)
        _shared_prefix(edge, ep, cloud, cp, csv, rows)
        _overcommit(edge, ep, cloud, cp, csv, rows)
        _open_loop(edge, ep, cloud, cp, csv, rows)
        _recurrent_mix(cloud, cp, csv, rows)
        _policies(edge, ep, cloud, cp, csv, rows)
        _tree_spec(edge, ep, cloud, cp, csv, rows)
        _compile_stability(edge, ep, cloud, cp, csv, rows)
        _online_adaptation(edge, ep, cloud, cp, csv, rows)
        _multi_device(edge, ep, cloud, cp, csv, rows)
    finally:
        REQUESTS, MAX_NEW, BATCH = saved
    if out:
        with open(out, "w") as f:
            json.dump(rows, f, indent=2)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config: paged-vs-dense, shared-prefix, "
                         "overcommit, open-loop, recurrent and policy "
                         "arms (skips the slow per-request scheduler "
                         "regimes)")
    ap.add_argument("--out", default="BENCH_serving.json",
                    help="JSON results path ('' to skip)")
    args = ap.parse_args()
    print("name,case,value")
    run(smoke=args.smoke, out=args.out)
