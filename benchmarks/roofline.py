"""Roofline analysis (required deliverable g).

Reads the dry-run records (experiments/dryrun/*.json) and derives, per
(arch x shape) on the single-pod mesh:

    compute term    = HLO_FLOPs_per_device / peak_FLOPs            [s]
    memory term     = HLO_bytes_per_device / HBM_bw                [s]
    collective term = collective_bytes_per_device / ICI_link_bw    [s]

plus the dominant bottleneck, MODEL_FLOPS = 6·N·D (train) / 2·N·D
(prefill/decode; N_active for MoE), and the useful-compute ratio
MODEL_FLOPS / HLO_FLOPs (catches remat/redundancy waste).

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (conservative single-link model).
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = {"single": 256, "multi": 512}

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def model_flops_per_device(cfg, shape, devices: int) -> float:
    """Useful model FLOPs per device for the step the dry-run lowered."""
    n = cfg.n_active_params() if cfg.family == "moe" else cfg.n_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens / devices
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens / devices
    tokens = shape.global_batch            # one token per sequence
    return 2.0 * n * tokens / devices


def load_records(mesh: str = "single") -> List[Dict]:
    out = []
    if not os.path.isdir(DRYRUN_DIR):
        return out
    for f in sorted(os.listdir(DRYRUN_DIR)):
        if f.endswith(f"_{mesh}.json"):
            out.append(json.load(open(os.path.join(DRYRUN_DIR, f))))
    return out


def analyze(rec: Dict) -> Optional[Dict]:
    from repro.configs import SHAPES, get_config
    if rec.get("status") != "ok":
        return None
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    devices = CHIPS[rec["mesh"]]
    hc = rec.get("hlo_cost")
    if hc:   # trip-count-aware analysis (preferred; see launch/hlo_cost.py)
        flops, bytes_, coll = hc["flops"], hc["bytes"], hc["collective_bytes"]
    else:    # raw XLA cost_analysis (while bodies counted once — caveat)
        flops = rec["flops_per_device"]
        bytes_ = rec["bytes_per_device"]
        coll = sum(v for k, v in rec["collectives"].items() if k != "count")
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_ / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(cfg, shape, devices)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "step": rec.get("step", "?"),
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "dominant": dominant,
        "bound_s": terms[dominant],
        "model_flops_per_device": mf,
        "useful_ratio": mf / flops if flops else 0.0,
        "hlo_flops": flops,
        "hlo_bytes": bytes_,
        "collective_bytes": coll,
        "coll_breakdown": {k: v for k, v in (hc or {}).items()
                           if k.startswith("coll_")} or rec["collectives"],
    }


def table(mesh: str = "single") -> List[Dict]:
    rows = []
    for rec in load_records(mesh):
        a = analyze(rec)
        if a:
            rows.append(a)
    return rows


def render_markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | step | compute s | memory s | collective s | "
           "dominant | useful ratio |\n|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['step']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} |")
    return hdr + "\n".join(lines)


def main():
    rows = table("single")
    if not rows:
        print("roofline,status,no dryrun records — run repro.launch.dryrun")
        return
    print("name,arch,shape,compute_s,memory_s,collective_s,dominant,useful_ratio")
    for r in rows:
        print(f"roofline,{r['arch']},{r['shape']},{r['compute_s']:.4e},"
              f"{r['memory_s']:.4e},{r['collective_s']:.4e},{r['dominant']},"
              f"{r['useful_ratio']:.3f}")


if __name__ == "__main__":
    main()
