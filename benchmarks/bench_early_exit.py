"""Early-exit benchmark (survey §2.2.3 / Table 4 early-exit row):
per-exit quality and the latency (mean depth) vs quality trade of
confidence-gated exits, after LayerSkip-style training."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.early_exit import early_exit_decision, exit_logits, layerskip_loss
from repro.data import batches
from repro.models import Model, cross_entropy
from repro.training import AdamW, train


def run(csv=print):
    cfg = get_config("smollm-135m").reduced().replace(num_layers=4)
    m = Model(cfg)
    exits = [0, 1, 2]
    res = train(m, m.init(jax.random.PRNGKey(0)), batches(cfg, 8, 48),
                steps=60, opt=AdamW(lr=2e-3),
                loss_fn=lambda p, b: layerskip_loss(m, p, b, exits)[0],
                log_every=10_000, log=lambda *_: None)
    params = res["params"]

    b = next(batches(cfg, 4, 48, seed=7))
    _, _, hs = m.forward(params, b, collect_hidden=True)
    ex = exit_logits(m, params, hs, exits + [cfg.num_layers - 1])
    for i, l in enumerate(exits + [cfg.num_layers - 1]):
        ce = float(cross_entropy(ex[i][:, :-1], b["labels"][:, 1:]))
        csv(f"early_exit_ce,layer={l},{ce:.4f}")

    # confidence-gated exits at the last position of each sequence
    last = ex[:, :, -1, :]
    for thr in (0.2, 0.5, 0.8):
        idx, _ = early_exit_decision(last, threshold=thr)
        csv(f"early_exit_mean_depth,thr={thr},{float(jnp.mean(idx)):.3f}")


if __name__ == "__main__":
    run()
