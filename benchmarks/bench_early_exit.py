"""Early-exit benchmark (survey §2.2.3 / Table 4 early-exit row):
per-exit quality and the latency (mean depth) vs quality trade of
confidence-gated exits, after LayerSkip-style training — then the same
trained exits driving the SERVING stack's self-speculative lane
(``BatchedEngine`` + ``BatchedSpecDecoder`` mode="self"): the model's
first ``k`` blocks draft, its full depth verifies, output stays
token-identical to plain greedy decode.  Reports per-exit-depth
accepted-tokens-per-step and req/s, tying the exit-quality curve to an
end-to-end serving win instead of the stale per-request seed API."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.early_exit import early_exit_decision, exit_logits, layerskip_loss
from repro.core.policy import SpeculativePolicy
from repro.core.scheduler import BatchedEngine
from repro.core.speculative import autoregressive_baseline
from repro.data import SyntheticLM, batches
from repro.models import Model, cross_entropy

MAX_NEW = 24
BATCH = 8


def run(csv=print):
    cfg = get_config("smollm-135m").reduced().replace(num_layers=4)
    m = Model(cfg)
    exits = [0, 1, 2]
    from repro.training import AdamW, train
    res = train(m, m.init(jax.random.PRNGKey(0)), batches(cfg, 8, 48),
                steps=60, opt=AdamW(lr=2e-3),
                loss_fn=lambda p, b: layerskip_loss(m, p, b, exits)[0],
                log_every=10_000, log=lambda *_: None)
    params = res["params"]

    b = next(batches(cfg, 4, 48, seed=7))
    _, _, hs = m.forward(params, b, collect_hidden=True)
    ex = exit_logits(m, params, hs, exits + [cfg.num_layers - 1])
    for i, l in enumerate(exits + [cfg.num_layers - 1]):
        ce = float(cross_entropy(ex[i][:, :-1], b["labels"][:, 1:]))
        csv(f"early_exit_ce,layer={l},{ce:.4f}")

    # confidence-gated exits at the last position of each sequence
    last = ex[:, :, -1, :]
    for thr in (0.2, 0.5, 0.8):
        idx, _ = early_exit_decision(last, threshold=thr)
        csv(f"early_exit_mean_depth,thr={thr},{float(jnp.mean(idx)):.3f}")

    # --- the exits in the serving loop: self-speculative batched decode,
    # one engine per exit depth k (draft = first k blocks + shared head)
    synth = SyntheticLM(cfg.vocab_size)
    rng = np.random.default_rng(0)
    prompts = [synth.sample(rng, i % synth.n_domains, 12)
               for i in range(BATCH)]
    base = [autoregressive_baseline(m, params, p, MAX_NEW, temperature=0.0)
            for p in prompts]
    for k in (1, 2, 3):
        eng = BatchedEngine(m, m, batch_size=BATCH, temperature=0.0,
                            use_cache=False, gamma=4,
                            policy=SpeculativePolicy(-1.0, mode="self",
                                                     exit_layer=k))
        eng.serve_batch(params, params, prompts, MAX_NEW)    # warm jits
        t0 = time.perf_counter()
        traces = eng.serve_batch(params, params, prompts, MAX_NEW)
        jax.block_until_ready(traces[-1].tokens)
        dt = time.perf_counter() - t0
        assert eng.spec.second_model_params == 0
        for t, bb in zip(traces, base):       # self-spec is exact greedy
            assert list(t.tokens) == list(bb), f"exit_layer={k} diverged"
        stats = eng.stats()
        csv(f"early_exit_self_spec,exit_layer={k}:accepted_tokens_per_step,"
            f"{stats['accepted_tokens_per_step']:.3f}")
        csv(f"early_exit_self_spec,exit_layer={k}:req_s,"
            f"{len(prompts) / dt:.3f}")


if __name__ == "__main__":
    run()
