"""Speculative decoding benchmark (survey §2.4 / Table 2 token-level row).

Measures tokens-per-target-pass (the latency proxy that matters on a real
edge-cloud link: each target pass is one cloud round trip) and acceptance
rate vs draft length gamma, for (a) an undistilled draft and (b) a
DistillSpec-aligned draft — reproducing the survey's claim that draft
quality drives the speedup, and DistillSpec's claim that on-policy KD
raises acceptance.

Decoding runs through the SERVING stack — ``BatchedEngine`` with an
always-escalate ``SpeculativePolicy`` over ``BatchedSpecDecoder`` — not
the per-request seed ``SpecDecoder`` (that path is pinned by
``tests/test_speculative.py``), so the numbers here track the code the
scheduler actually ships.  ``accepted_tokens_per_step`` from
``BatchedEngine.stats()`` IS tokens-per-target-pass: every member-round
is one verify pass.  A mode sweep rides along: the same distilled draft
through the linear, tree, and self-speculative lanes at fixed depth.
"""
from __future__ import annotations


import jax
import numpy as np

from repro.configs import get_config
from repro.core.policy import SpeculativePolicy
from repro.core.scheduler import BatchedEngine
from repro.core.speculative import autoregressive_baseline
from repro.data import SyntheticLM, batches
from repro.models import Model
from repro.training import AdamW, make_train_step, train
from repro.training.distillation import (acceptance_estimate, kd_loss,
                                         teacher_logits_fn)

MAX_NEW = 24
BATCH = 8


def _train_target(cfg, steps=60):
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    res = train(m, params, batches(cfg, 8, 48), steps=steps,
                opt=AdamW(lr=2e-3), log_every=10_000, log=lambda *_: None)
    return m, res["params"]


def _serve(draft_model, target_model, dp, tp, prompts, **kw):
    """Drain ``prompts`` through an always-escalate batched engine and
    return (traces, stats) — stats carries the speculation counters."""
    kw.setdefault("policy", SpeculativePolicy(-1.0))
    eng = BatchedEngine(draft_model, target_model, batch_size=BATCH,
                        temperature=0.0, use_cache=False, **kw)
    traces = eng.serve_batch(dp, tp, prompts, MAX_NEW)
    return traces, eng.stats()


def run(csv=print):
    cfg = get_config("smollm-135m").reduced()
    target_model, target_params = _train_target(cfg)
    draft_cfg = cfg.replace(num_layers=1)
    draft_model = Model(draft_cfg)
    draft_params = draft_model.init(jax.random.PRNGKey(3))

    # --- DistillSpec: align the draft on (approx.) on-policy target data
    tlf = teacher_logits_fn(target_model, target_params)
    opt = AdamW(lr=2e-3)
    step = make_train_step(draft_model, opt,
                           loss_fn=lambda p, b: kd_loss(draft_model, p, b,
                                                        tlf(b), alpha=0.0),
                           donate=False)
    st = opt.init(draft_params)
    distilled = draft_params
    it = batches(cfg, 8, 48)
    for _ in range(60):
        distilled, st, _ = step(distilled, st, next(it))

    b = next(batches(cfg, 4, 32))
    acc_raw = float(acceptance_estimate(
        draft_model.forward(draft_params, b)[0], tlf(b)))
    acc_kd = float(acceptance_estimate(
        draft_model.forward(distilled, b)[0], tlf(b)))
    csv(f"spec_acceptance_estimate,draft=random,{acc_raw:.4f}")
    csv(f"spec_acceptance_estimate,draft=distilled,{acc_kd:.4f}")

    synth = SyntheticLM(cfg.vocab_size)
    rng = np.random.default_rng(0)
    prompts = [synth.sample(rng, 0, 12) for _ in range(BATCH)]

    for name, dp in [("random", draft_params), ("distilled", distilled)]:
        for gamma in (2, 4, 8):
            _, stats = _serve(draft_model, target_model, dp, target_params,
                              prompts, gamma=gamma)
            csv(f"spec_tokens_per_target_pass,draft={name}:gamma={gamma},"
                f"{stats['accepted_tokens_per_step']:.3f}")
            csv(f"spec_acceptance_rate,draft={name}:gamma={gamma},"
                f"{stats['spec_accept_rate']:.3f}")

    # --- lane sweep: same distilled draft, fixed depth 4, all three
    # speculation modes (the self lane drafts with the TARGET's own
    # early-exit head: a 1-layer draft has no interior exit)
    base = [autoregressive_baseline(target_model, target_params, p,
                                    MAX_NEW, temperature=0.0)
            for p in prompts]
    for mode in ("linear", "tree", "self"):
        dm = target_model if mode == "self" else draft_model
        dpm = target_params if mode == "self" else distilled
        traces, stats = _serve(dm, target_model, dpm, target_params,
                               prompts, gamma=4,
                               policy=SpeculativePolicy(-1.0, mode=mode))
        for t, bb in zip(traces, base):   # every lane is exact (greedy)
            assert list(t.tokens) == list(bb), \
                f"{mode} lane diverged from greedy baseline"
        csv(f"spec_lane_tokens_per_target_pass,mode={mode},"
            f"{stats['accepted_tokens_per_step']:.3f}")

    csv("spec_lossless_greedy,match,1")


if __name__ == "__main__":
    run()
