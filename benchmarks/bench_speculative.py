"""Speculative decoding benchmark (survey §2.4 / Table 2 token-level row).

Measures tokens-per-target-pass (the latency proxy that matters on a real
edge-cloud link: each target pass is one cloud round trip) and acceptance
rate vs draft length gamma, for (a) an undistilled draft and (b) a
DistillSpec-aligned draft — reproducing the survey's claim that draft
quality drives the speedup, and DistillSpec's claim that on-policy KD
raises acceptance.
"""
from __future__ import annotations


import jax
import numpy as np

from repro.configs import get_config
from repro.core.speculative import SpecDecoder, autoregressive_baseline
from repro.data import SyntheticLM, batches
from repro.models import Model
from repro.training import AdamW, make_train_step, train
from repro.training.distillation import (acceptance_estimate, kd_loss,
                                         teacher_logits_fn)


def _train_target(cfg, steps=60):
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    res = train(m, params, batches(cfg, 8, 48), steps=steps,
                opt=AdamW(lr=2e-3), log_every=10_000, log=lambda *_: None)
    return m, res["params"]


def run(csv=print):
    cfg = get_config("smollm-135m").reduced()
    target_model, target_params = _train_target(cfg)
    draft_cfg = cfg.replace(num_layers=1)
    draft_model = Model(draft_cfg)
    draft_params = draft_model.init(jax.random.PRNGKey(3))

    # --- DistillSpec: align the draft on (approx.) on-policy target data
    tlf = teacher_logits_fn(target_model, target_params)
    opt = AdamW(lr=2e-3)
    step = make_train_step(draft_model, opt,
                           loss_fn=lambda p, b: kd_loss(draft_model, p, b,
                                                        tlf(b), alpha=0.0),
                           donate=False)
    st = opt.init(draft_params)
    distilled = draft_params
    it = batches(cfg, 8, 48)
    for _ in range(60):
        distilled, st, _ = step(distilled, st, next(it))

    b = next(batches(cfg, 4, 32))
    acc_raw = float(acceptance_estimate(
        draft_model.forward(draft_params, b)[0], tlf(b)))
    acc_kd = float(acceptance_estimate(
        draft_model.forward(distilled, b)[0], tlf(b)))
    csv(f"spec_acceptance_estimate,draft=random,{acc_raw:.4f}")
    csv(f"spec_acceptance_estimate,draft=distilled,{acc_kd:.4f}")

    synth = SyntheticLM(cfg.vocab_size)
    rng = np.random.default_rng(0)
    prompts = [synth.sample(rng, 0, 12) for _ in range(3)]

    for name, dp in [("random", draft_params), ("distilled", distilled)]:
        for gamma in (2, 4, 8):
            dec = SpecDecoder(draft_model, target_model, gamma=gamma,
                              temperature=0.0)
            tps, acc = [], []
            for p in prompts:
                toks, stats = dec.generate(dp, target_params, p, 24)
                tps.append(stats.tokens_per_target_pass)
                acc.append(stats.mean_accepted / gamma)
            csv(f"spec_tokens_per_target_pass,draft={name}:gamma={gamma},"
                f"{np.mean(tps):.3f}")
            csv(f"spec_acceptance_rate,draft={name}:gamma={gamma},"
                f"{np.mean(acc):.3f}")

    # losslessness check rides along
    base = autoregressive_baseline(target_model, target_params, prompts[0],
                                   24, temperature=0.0)
    dec = SpecDecoder(draft_model, target_model, gamma=4, temperature=0.0)
    toks, _ = dec.generate(distilled, target_params, prompts[0], 24)
    csv(f"spec_lossless_greedy,match,{int(toks == base)}")


if __name__ == "__main__":
    run()
