"""Task-assignment benchmark (survey §2.1 / Table 2 + Table 4 routing rows).

Cost-quality frontier of confidence routing between a weak edge model and a
strong cloud model on mixed-difficulty synthetic data, plus UCB bandit
regret (PerLLM-style reward-minus-cost routing).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.routing import UCBRouter
from repro.core.uncertainty import entropy
from repro.data import batches
from repro.models import Model, cross_entropy
from repro.training import AdamW, train


def run(csv=print):
    cfg = get_config("smollm-135m").reduced()
    cloud_m = Model(cfg)
    edge_cfg = cfg.replace(num_layers=1, d_ff=128)
    edge_m = Model(edge_cfg)

    # train cloud well, edge poorly -> a real quality gap
    cloud = train(cloud_m, cloud_m.init(jax.random.PRNGKey(0)),
                  batches(cfg, 8, 48), steps=60, opt=AdamW(lr=2e-3),
                  log_every=10_000, log=lambda *_: None)["params"]
    edge = train(edge_m, edge_m.init(jax.random.PRNGKey(1)),
                 batches(cfg, 8, 48), steps=15, opt=AdamW(lr=2e-3),
                 log_every=10_000, log=lambda *_: None)["params"]

    eval_batches = [next(batches(cfg, 4, 48, seed=100 + i)) for i in range(6)]

    @jax.jit
    def per_request(edge_p, cloud_p, b):
        le, _ = edge_m.forward(edge_p, b)
        lc, _ = cloud_m.forward(cloud_p, b)
        ce_e = cross_entropy(le[:, :-1], b["labels"][:, 1:])
        ce_c = cross_entropy(lc[:, :-1], b["labels"][:, 1:])
        u = jnp.mean(entropy(le))
        return ce_e, ce_c, u

    rows = [per_request(edge, cloud, b) for b in eval_batches]
    ces_e = np.array([float(r[0]) for r in rows])
    ces_c = np.array([float(r[1]) for r in rows])
    us = np.array([float(r[2]) for r in rows])
    csv(f"routing_edge_ce,mean,{ces_e.mean():.4f}")
    csv(f"routing_cloud_ce,mean,{ces_c.mean():.4f}")

    # frontier: escalate when edge entropy above threshold
    for thr in (0.0, us.mean(), 1.0):
        to_cloud = us > thr
        ce = np.where(to_cloud, ces_c, ces_e).mean()
        cost = to_cloud.mean()          # fraction of cloud calls
        csv(f"routing_frontier,thr={thr:.2f}:cloud_frac={cost:.2f},{ce:.4f}")

    # bandit: reward = -ce - cost_weight * cost(model)
    rng = np.random.default_rng(0)
    router = UCBRouter(2, cost_weight=0.05)
    costs = [0.0, 1.0]
    for t in range(300):
        i = t % len(eval_batches)
        a = router.select()
        q = -(ces_e[i] if a == 0 else ces_c[i]) + rng.normal(0, 0.05)
        router.update(a, q, costs[a])
    csv(f"routing_bandit_pulls,edge,{int(router.n[0])}")
    csv(f"routing_bandit_pulls,cloud,{int(router.n[1])}")


if __name__ == "__main__":
    run()
