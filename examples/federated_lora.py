"""Federated adapter tuning (survey §3.4): non-IID clients fine-tune
heterogeneous-rank LoRA adapters on a frozen base model; the server
aggregates with HETLoRA's rank-aware scheme.

    PYTHONPATH=src python examples/federated_lora.py
"""
import jax

from repro.configs import get_config
from repro.data import SyntheticLM, batches, dirichlet_clients
from repro.data.pipeline import client_divergence
from repro.models import Model, cross_entropy
from repro.training import AdamW
from repro.training.lora import (hetlora_aggregate, init_lora, lora_loss_fn,
                                 lora_param_count, merge_lora)

cfg = get_config("smollm-135m").reduced()
model = Model(cfg)
base = model.init(jax.random.PRNGKey(0))
n_base = sum(x.size for x in jax.tree.leaves(base))

N_CLIENTS = 3
RANKS = [2, 4, 8]
mixtures = dirichlet_clients(N_CLIENTS, 4, alpha=0.2)
print(f"client divergence (mean pairwise TV): {client_divergence(mixtures):.3f}")

synth = SyntheticLM(cfg.vocab_size)
client_adapters = []
for c in range(N_CLIENTS):
    ad = init_lora(jax.random.PRNGKey(10 + c), base, rank=RANKS[c])
    loss_fn = lora_loss_fn(model, base)
    opt = AdamW(lr=3e-3, weight_decay=0.0)
    st = opt.init(ad)
    it = batches(cfg, 4, 48, domain_weights=mixtures[c], seed=c, synth=synth)
    grad = jax.jit(jax.value_and_grad(loss_fn))
    for i in range(12):
        l, g = grad(ad, next(it))
        ad, st, _ = opt.update(g, st, ad)
    print(f"client {c}: rank={RANKS[c]} local loss {float(l):.4f} "
          f"adapter params {lora_param_count(ad)} "
          f"({lora_param_count(ad)/n_base:.4%} of base — the only bytes "
          f"that cross the edge-cloud link)")
    client_adapters.append(ad)

print("\n== HETLoRA rank-aware aggregation ==")
agg = hetlora_aggregate(client_adapters, max_rank=max(RANKS))
merged = merge_lora(base, agg)
evalb = next(batches(cfg, 8, 48, seed=77, synth=synth))
lg, _ = model.forward(merged, evalb)
lg0, _ = model.forward(base, evalb)
print(f"base CE   : {float(cross_entropy(lg0[:, :-1], evalb['labels'][:, 1:])):.4f}")
print(f"merged CE : {float(cross_entropy(lg[:, :-1], evalb['labels'][:, 1:])):.4f}")
