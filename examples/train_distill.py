"""Collaborative training driver (deliverable b): train a ~100M-class cloud
teacher for a few hundred steps, then distill an edge student with
DistillSpec-style KD and show the speculative-acceptance uplift.

    PYTHONPATH=src python examples/train_distill.py [--steps 200]
"""
import argparse

import jax

from repro.configs import get_config
from repro.data import batches
from repro.models import Model, cross_entropy
from repro.training import AdamW, cosine_schedule, make_train_step, train
from repro.training.distillation import (acceptance_estimate, kd_loss,
                                         teacher_logits_fn)

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=64)
args = ap.parse_args()

# teacher: the reduced smollm family stands in for the ~100M cloud model on
# CPU; on TPU use get_config("smollm-135m") unreduced (135M params).
t_cfg = get_config("smollm-135m").reduced()
teacher_m = Model(t_cfg)
print("== train teacher ==")
res = train(teacher_m, teacher_m.init(jax.random.PRNGKey(0)),
            batches(t_cfg, args.batch, args.seq), steps=args.steps,
            opt=AdamW(lr=2e-3, schedule=cosine_schedule(20, args.steps)),
            log_every=max(args.steps // 8, 1))
teacher = res["params"]

# student: 1-layer edge SLM
s_cfg = t_cfg.replace(num_layers=2)
student_m = Model(s_cfg)
student = student_m.init(jax.random.PRNGKey(1))
tlf = teacher_logits_fn(teacher_m, teacher)

evalb = next(batches(t_cfg, args.batch, args.seq, seed=999))
before = float(acceptance_estimate(student_m.forward(student, evalb)[0],
                                   tlf(evalb)))

print("== distill student (forward KD on teacher logits) ==")
opt = AdamW(lr=2e-3)
step = make_train_step(student_m, opt,
                       loss_fn=lambda p, b: kd_loss(student_m, p, b, tlf(b),
                                                    alpha=0.3),
                       donate=False)
st = opt.init(student)
it = batches(t_cfg, args.batch, args.seq)
for i in range(args.steps // 2):
    student, st, m = step(student, st, next(it))
    if i % max(args.steps // 8, 1) == 0:
        print(f"  distill step {i}: loss {float(m['loss']):.4f}")

after = float(acceptance_estimate(student_m.forward(student, evalb)[0],
                                  tlf(evalb)))
lg, _ = student_m.forward(student, evalb)
print(f"\nstudent eval CE: {float(cross_entropy(lg[:, :-1], evalb['labels'][:, 1:])):.4f}")
print(f"expected speculative acceptance (1 - TV): {before:.3f} -> {after:.3f}")
print("(DistillSpec: higher acceptance = more tokens per cloud pass)")
