"""Quickstart: build an edge SLM + cloud LLM pair, run collaborative
(speculative) inference, and inspect the accounting.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core.speculative import SpecDecoder, autoregressive_baseline
from repro.core.uncertainty import dirichlet_evidence
from repro.models import Model

# --- models: any two assigned architectures with a shared vocab ----------
edge_cfg = get_config("smollm-135m").reduced()
cloud_cfg = get_config("granite-8b").reduced().replace(
    vocab_size=edge_cfg.vocab_size)
edge, cloud = Model(edge_cfg), Model(cloud_cfg)
edge_params = edge.init(jax.random.PRNGKey(0))
cloud_params = cloud.init(jax.random.PRNGKey(1))

prompt = np.arange(12) % edge_cfg.vocab_size

# --- cloud-only baseline vs edge-draft/cloud-verify ----------------------
base = autoregressive_baseline(cloud, cloud_params, prompt, 24, temperature=0.0)
dec = SpecDecoder(edge, cloud, gamma=4, temperature=0.0)
toks, stats = dec.generate(edge_params, cloud_params, prompt, 24)

print("cloud-only tokens :", base)
print("speculative tokens:", toks)
print("identical (lossless):", toks == base)
print("accounting:", stats.summary())
print(f"-> {stats.tokens_per_target_pass:.2f} tokens per cloud pass "
      f"(cloud-only = 1.00)")

# --- evidence-based uncertainty (survey §6) on the edge's next-token view
lg, _ = edge.prefill(edge_params, {"tokens": np.asarray(prompt)[None, :]})
u = dirichlet_evidence(lg[0])
print(f"edge uncertainty: epistemic={float(u['epistemic']):.3f} "
      f"aleatoric={float(u['aleatoric']):.3f}")
