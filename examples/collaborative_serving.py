"""End-to-end collaborative serving driver (deliverable b): batched
requests through the full engine — semantic cache, edge-first generation,
uncertainty-gated escalation to speculative cloud verification.

    PYTHONPATH=src python examples/collaborative_serving.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.engine import CollaborativeEngine
from repro.data import SyntheticLM
from repro.models import Model

edge_cfg = get_config("smollm-135m").reduced()
cloud_cfg = get_config("granite-8b").reduced().replace(
    vocab_size=edge_cfg.vocab_size)
edge, cloud = Model(edge_cfg), Model(cloud_cfg)
ep = edge.init(jax.random.PRNGKey(0))
cp = cloud.init(jax.random.PRNGKey(1))

engine = CollaborativeEngine(edge, cloud, gamma=4, temperature=0.0,
                             escalate_threshold=0.55, estimator="entropy",
                             escalation="speculative", cache_threshold=0.98)

synth = SyntheticLM(edge_cfg.vocab_size, n_domains=3)
rng = np.random.default_rng(0)

requests = [synth.sample(rng, i % 3, 12) for i in range(10)]
requests += requests[:3]          # repeats -> cache hits

paths = {}
edge_calls = cloud_passes = 0
t0 = time.time()
for i, prompt in enumerate(requests):
    tr = engine.serve(ep, cp, prompt, max_new=16)
    paths[tr.path] = paths.get(tr.path, 0) + 1
    edge_calls += tr.edge_calls
    cloud_passes += tr.cloud_passes
    print(f"req {i:2d}: path={tr.path:12s} unc={tr.uncertainty:.3f} "
          f"edge={tr.edge_calls:3d} cloud={tr.cloud_passes:2d}")

n = len(requests)
print(f"\n{n} requests in {time.time()-t0:.1f}s")
print(f"path mix: {paths}")
print(f"cloud passes/request: {cloud_passes/n:.1f} "
      f"(cloud-only would be 16.0)")
print(f"cache hit rate: {engine.stats()['cache_hit_rate']:.2f}")
