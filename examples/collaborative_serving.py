"""End-to-end collaborative serving driver (deliverable b): batched
requests through the REAL serving path — ``BatchedEngine.serve_batch``,
the continuous-batching scheduler production serving runs on: slot-based
admission into paged KV caches, one jitted decode scan per tick, semantic
cache with intra-batch dedup, and uncertainty-gated grouped escalation —
driven by TWO pluggable ``CollabPolicy`` implementations side by side:

  * ``SpeculativePolicy`` — confidence gate into grouped speculative cloud
    verification (token-level mixture);
  * ``CascadePolicy`` — FrugalGPT-style cost-ordered cascade over
    collaboration tiers (accept -> speculative -> full cloud regen).

Same traffic, same scheduler, different collaboration policy — compare
path mixes and cloud tokens per request in the printed summary.

    PYTHONPATH=src python examples/collaborative_serving.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.policy import CascadePolicy, SpeculativePolicy, cloud_tokens
from repro.core.scheduler import BatchedEngine
from repro.data import SyntheticLM
from repro.models import Model

edge_cfg = get_config("smollm-135m").reduced()
cloud_cfg = get_config("granite-8b").reduced().replace(
    vocab_size=edge_cfg.vocab_size)
edge, cloud = Model(edge_cfg), Model(cloud_cfg)
ep = edge.init(jax.random.PRNGKey(0))
cp = cloud.init(jax.random.PRNGKey(1))

synth = SyntheticLM(edge_cfg.vocab_size, n_domains=3)
rng = np.random.default_rng(0)

requests = [synth.sample(rng, i % 3, 12) for i in range(10)]
requests += requests[:3]          # repeats -> cache hits (dedup/coalescing)
GAMMA, MAX_NEW = 4, 16

summary = {}
for label, policy in [
        ("speculative@0.55", SpeculativePolicy(threshold=0.55)),
        ("cascade", CascadePolicy(thresholds=(0.45, 0.25), relief=0.5))]:
    engine = BatchedEngine(edge, cloud, batch_size=8, gamma=GAMMA,
                           temperature=0.0, policy=policy,
                           cache_threshold=0.98, tick_tokens=8)
    t0 = time.time()
    traces = engine.serve_batch(ep, cp, requests, MAX_NEW)
    dt = time.time() - t0

    print(f"\n=== policy: {label} ===")
    paths = {}
    for i, tr in enumerate(traces):
        paths[tr.path] = paths.get(tr.path, 0) + 1
        print(f"req {i:2d}: path={tr.path:12s} unc={tr.uncertainty:.3f} "
              f"edge={tr.edge_calls:3d} cloud={tr.cloud_passes:2d}")
    n = len(requests)
    ct = sum(cloud_tokens(tr, GAMMA) for tr in traces)
    stats = engine.stats()
    summary[label] = (n / dt, paths, ct / n, stats)
    print(f"{n} requests in {dt:.1f}s ({n / dt:.2f} req/s); "
          f"path mix: {paths}")
    print(f"cloud tokens/request: {ct / n:.1f} "
          f"(cloud-only would be {MAX_NEW:.1f}); "
          f"cache hit rate: {stats['cache_hit_rate']:.2f}")
    print(f"kv: layout={stats['kv_layout']} "
          f"peak={stats['kv_peak_bytes'] / 1e6:.2f}MB "
          f"capacity={stats['kv_capacity_bytes'] / 1e6:.2f}MB")

print("\n=== side by side ===")
for label, (req_s, paths, ct, stats) in summary.items():
    extra = {k.removeprefix("policy_"): v for k, v in stats.items()
             if k.startswith("policy_")}
    print(f"{label:18s} {req_s:5.2f} req/s  cloud tok/req {ct:5.1f}  "
          f"paths {paths} {extra or ''}")
