"""End-to-end collaborative serving driver (deliverable b): batched
requests through the REAL serving path — ``BatchedEngine.serve_batch``,
the continuous-batching scheduler production serving runs on: slot-based
admission into paged KV caches, one jitted decode scan per tick, semantic
cache with intra-batch dedup, uncertainty-gated grouped escalation to
speculative cloud verification.

    PYTHONPATH=src python examples/collaborative_serving.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.scheduler import BatchedEngine
from repro.data import SyntheticLM
from repro.models import Model

edge_cfg = get_config("smollm-135m").reduced()
cloud_cfg = get_config("granite-8b").reduced().replace(
    vocab_size=edge_cfg.vocab_size)
edge, cloud = Model(edge_cfg), Model(cloud_cfg)
ep = edge.init(jax.random.PRNGKey(0))
cp = cloud.init(jax.random.PRNGKey(1))

engine = BatchedEngine(edge, cloud, batch_size=8, gamma=4, temperature=0.0,
                       escalate_threshold=0.55, estimator="entropy",
                       escalation="speculative", cache_threshold=0.98,
                       tick_tokens=8)

synth = SyntheticLM(edge_cfg.vocab_size, n_domains=3)
rng = np.random.default_rng(0)

requests = [synth.sample(rng, i % 3, 12) for i in range(10)]
requests += requests[:3]          # repeats -> cache hits (dedup/coalescing)

t0 = time.time()
traces = engine.serve_batch(ep, cp, requests, 16)
dt = time.time() - t0

paths = {}
edge_calls = cloud_passes = 0
for i, tr in enumerate(traces):
    paths[tr.path] = paths.get(tr.path, 0) + 1
    edge_calls += tr.edge_calls
    cloud_passes += tr.cloud_passes
    print(f"req {i:2d}: path={tr.path:12s} unc={tr.uncertainty:.3f} "
          f"edge={tr.edge_calls:3d} cloud={tr.cloud_passes:2d}")

n = len(requests)
stats = engine.stats()
print(f"\n{n} requests in {dt:.1f}s ({n / dt:.2f} req/s)")
print(f"path mix: {paths}")
print(f"cloud passes/request: {cloud_passes/n:.1f} "
      f"(cloud-only would be 16.0)")
print(f"cache hit rate: {stats['cache_hit_rate']:.2f}")
print(f"kv: layout={stats['kv_layout']} "
      f"peak={stats['kv_peak_bytes'] / 1e6:.2f}MB "
      f"capacity={stats['kv_capacity_bytes'] / 1e6:.2f}MB")
